#!/usr/bin/env python3
"""Regenerate the checked-in fixture zoo under rust/tests/fixtures/.

The fixtures are tiny hand-built `.splat` / PLY files the asset tests
and the golden-frame harness load. They are deterministic (fixed LCG
seed, no dependency on Python's hash or float formatting) so a re-run
reproduces the committed bytes exactly; golden digests in
rust/tests/golden_digests.txt are blessed against these bytes — do not
regenerate without re-blessing (SLTARCH_BLESS=1, see docs/TESTING.md).

Formats (mirrors rust/src/assets/):
  .splat  32-byte records: pos f32x3 | scale f32x3 (linear) | RGBA u8x4
          (A = opacity, sigmoid-space) | rot u8x4 as (b-128)/128, wxyz.
  .ply    binary little-endian, header-driven property order; stored
          fields are log-scales, opacity logits and (c-0.5)/SH_C0 color
          coefficients; rot wxyz raw f32.
"""

import math
import os
import struct

OUT = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")
SH_C0 = 0.2820948


class Lcg:
    """Deterministic 64-bit LCG (MMIX constants) — no Python RNG drift."""

    def __init__(self, seed):
        self.s = seed & 0xFFFFFFFFFFFFFFFF

    def next(self):
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        return self.s

    def f(self, lo=0.0, hi=1.0):
        # 24-bit mantissa so the value is exact in f32.
        return lo + (hi - lo) * ((self.next() >> 40) / float(1 << 24))


def room_splats(seed, n_floor=14, n_wall=10, n_blob=120):
    """An origin-centred 'room': floor grid, two walls, scattered blobs.

    Visible from every scenario camera (they orbit the origin), which is
    what the golden harness's non-black check needs.
    """
    rng = Lcg(seed)
    splats = []  # (pos, scale, color, opacity, quat_wxyz)

    def jitter(amount):
        return rng.f(-amount, amount)

    # Floor grid at y = -1.5, extent +-4.
    for ix in range(n_floor):
        for iz in range(n_floor):
            x = -4.0 + 8.0 * ix / (n_floor - 1) + jitter(0.1)
            z = -4.0 + 8.0 * iz / (n_floor - 1) + jitter(0.1)
            splats.append(
                (
                    (x, -1.5, z),
                    (0.35, 0.08, 0.35),
                    (0.45 + jitter(0.1), 0.4 + jitter(0.1), 0.35),
                    0.9,
                    (1.0, 0.0, 0.0, 0.0),
                )
            )
    # Two walls.
    for iy in range(n_wall):
        for iz in range(n_wall):
            y = -1.5 + 3.0 * iy / (n_wall - 1)
            z = -4.0 + 8.0 * iz / (n_wall - 1)
            splats.append(
                (
                    (-4.0 + jitter(0.05), y, z),
                    (0.08, 0.3, 0.3),
                    (0.3, 0.35, 0.55 + jitter(0.1)),
                    0.85,
                    (1.0, 0.0, 0.0, 0.0),
                )
            )
        for ix in range(n_wall):
            x = -4.0 + 8.0 * ix / (n_wall - 1)
            y = -1.5 + 3.0 * iy / (n_wall - 1)
            splats.append(
                (
                    (x, y, -4.0 + jitter(0.05)),
                    (0.3, 0.3, 0.08),
                    (0.55 + jitter(0.1), 0.3, 0.3),
                    0.85,
                    (1.0, 0.0, 0.0, 0.0),
                )
            )
    # Scattered rotated blobs inside the room.
    for _ in range(n_blob):
        pos = (rng.f(-3.0, 3.0), rng.f(-1.2, 1.2), rng.f(-3.0, 3.0))
        scale = (rng.f(0.08, 0.3), rng.f(0.08, 0.3), rng.f(0.08, 0.3))
        color = (rng.f(0.1, 0.95), rng.f(0.1, 0.95), rng.f(0.1, 0.95))
        opacity = rng.f(0.5, 1.0)
        ang = rng.f(0.0, math.pi)
        ax = (rng.f(-1, 1), rng.f(-1, 1), rng.f(-1, 1))
        norm = math.sqrt(sum(a * a for a in ax)) or 1.0
        s = math.sin(ang / 2) / norm
        quat = (math.cos(ang / 2), ax[0] * s, ax[1] * s, ax[2] * s)
        splats.append((pos, scale, color, opacity, quat))
    return splats


def pack_splat_record(pos, scale, color, opacity, quat):
    def rot_byte(v):
        return max(0, min(255, int(round(v * 128.0 + 128.0))))

    def unit_byte(v):
        return max(0, min(255, int(round(v * 255.0))))

    return (
        struct.pack("<3f", *pos)
        + struct.pack("<3f", *scale)
        + bytes([unit_byte(color[0]), unit_byte(color[1]), unit_byte(color[2]), unit_byte(opacity)])
        + bytes([rot_byte(quat[0]), rot_byte(quat[1]), rot_byte(quat[2]), rot_byte(quat[3])])
    )


def write_dot_splat(path, splats, tail_bytes=b""):
    with open(path, "wb") as f:
        for s in splats:
            f.write(pack_splat_record(*s))
        f.write(tail_bytes)


# Shuffled on purpose: the loader must be header-driven, and the golden
# fixture keeps it honest (plus unknown nx/ny/nz and 9 f_rest coeffs).
PLY_ORDER = [
    "scale_2", "x", "f_dc_1", "rot_3", "nx", "opacity", "scale_0", "y",
    "rot_0", "f_dc_0", "ny", "rot_1", "scale_1", "z", "rot_2", "nz",
    "f_dc_2",
] + [f"f_rest_{i}" for i in range(9)]


def ply_field(name, pos, scale, color, opacity, quat, rng):
    axis = {"x": 0, "y": 1, "z": 2}
    if name in axis:
        return pos[axis[name]]
    if name.startswith("scale_"):
        return math.log(scale[int(name[-1])])
    if name.startswith("f_dc_"):
        return (color[int(name[-1])] - 0.5) / SH_C0
    if name == "opacity":
        o = min(max(opacity, 1e-6), 1.0 - 1e-6)
        return math.log(o / (1.0 - o))
    if name.startswith("rot_"):
        return quat[int(name[-1])]
    return rng.f(-1.0, 1.0)  # normals / f_rest junk


def write_ply(path, splats, order=PLY_ORDER):
    rng = Lcg(0xF1E57)
    header = ["ply", "format binary_little_endian 1.0",
              "comment sltarch fixture zoo (scripts/gen_fixtures.py)",
              f"element vertex {len(splats)}"]
    header += [f"property float {n}" for n in order]
    header.append("end_header")
    with open(path, "wb") as f:
        f.write(("\n".join(header) + "\n").encode())
        for s in splats:
            for name in order:
                f.write(struct.pack("<f", ply_field(name, *s, rng)))


def good(x=0.0, y=0.0, z=0.0):
    return ((x, y, z), (0.3, 0.3, 0.3), (0.8, 0.5, 0.2), 0.9, (1.0, 0.0, 0.0, 0.0))


def main():
    os.makedirs(OUT, exist_ok=True)

    # minimal.splat: 4 well-formed splats around the origin.
    write_dot_splat(
        os.path.join(OUT, "minimal.splat"),
        [good(0, 0, 0), good(1, 0, 0), good(0, 1, 0), good(-1, 0, -1)],
    )

    # minimal.ply: 3 well-formed vertices, shuffled header.
    write_ply(
        os.path.join(OUT, "minimal.ply"),
        [good(0, 0, 0), good(1.5, 0, 0), good(0, 0, -1.5)],
    )

    # degenerate.splat: good/bad interleaved + a 7-byte truncated tail.
    nan, inf = float("nan"), float("inf")
    records = [
        good(0, 0, 0),                                      # kept
        ((nan, 0, 0), (0.3,) * 3, (0.5,) * 3, 0.9, (1, 0, 0, 0)),   # bad pos
        ((0, 0, 0), (inf, 0.3, 0.3), (0.5,) * 3, 0.9, (1, 0, 0, 0)),  # bad scale
        ((0, 1, 0), (0.3,) * 3, (0.5,) * 3, 0.9, (0, 0, 0, 0)),     # zero quat
        good(1, 1, 0),                                      # kept
        ((-inf, 0, 1), (0.3,) * 3, (0.5,) * 3, 0.9, (1, 0, 0, 0)),  # bad pos
        ((0, 0, 1), (nan, nan, nan), (0.5,) * 3, 0.9, (1, 0, 0, 0)),  # bad scale
        good(0, -1, 1),                                     # kept
    ]
    write_dot_splat(
        os.path.join(OUT, "degenerate.splat"), records, tail_bytes=b"\x00" * 7
    )

    # degenerate.ply: 1 good + NaN x / NaN log-scale / zero-norm rot.
    ply_records = [
        good(0, 0, 0),
        ((nan, 0, 0), (0.3,) * 3, (0.5,) * 3, 0.9, (1, 0, 0, 0)),
        # NaN scale: math.log can't emit NaN from a valid input, so patch
        # below by writing the record then poisoning scale_0's bytes.
        good(1, 0, 0),
        ((0, 1, 0), (0.3,) * 3, (0.5,) * 3, 0.9, (0.0, 0.0, 0.0, 0.0)),
    ]
    path = os.path.join(OUT, "degenerate.ply")
    write_ply(path, ply_records)
    # Poison vertex 2's scale_0 with NaN (slot index in PLY_ORDER).
    with open(path, "r+b") as f:
        data = f.read()
        header_end = data.index(b"end_header\n") + len(b"end_header\n")
        stride = 4 * len(PLY_ORDER)
        off = header_end + 2 * stride + 4 * PLY_ORDER.index("scale_0")
        f.seek(off)
        f.write(struct.pack("<f", nan))

    # zoo_room: the golden fixtures (one per format, different seeds so
    # the two scenes differ).
    write_dot_splat(os.path.join(OUT, "zoo_room.splat"), room_splats(0xA11CE))
    write_ply(os.path.join(OUT, "zoo_room.ply"), room_splats(0xB0B5))

    for name in sorted(os.listdir(OUT)):
        p = os.path.join(OUT, name)
        print(f"{os.path.getsize(p):8d}  {name}")


if __name__ == "__main__":
    main()
