#!/usr/bin/env bash
# Fetch full-size 3DGS captures for local benchmarking.
#
# NEVER run in CI — CI renders only the checked-in fixture zoo under
# rust/tests/fixtures/ (a workflow grep enforces this). Downloads are
# sha256-verified before they are trusted; a mismatch deletes the file.
#
# Usage:
#   scripts/fetch_scenes.sh            # fetch everything into scenes/
#   scripts/fetch_scenes.sh bicycle    # fetch one scene by name
#
# Then: cargo run --release --example quickstart -- scenes/<name>.ply

set -euo pipefail

DEST="${SLTARCH_SCENES_DIR:-$(dirname "$0")/../scenes}"
mkdir -p "$DEST"

# name | url | sha256
# Public antimatter15-converted .splat captures and 3DGS training PLYs.
# Checksums pin the exact bytes benches were run against; refresh them
# deliberately (sha256sum <file>) when a source republishes.
SCENES='
train https://huggingface.co/cakewalk/splat-data/resolve/main/train.splat 9af56ae9478a438be5c4aa39ecd0a21edffee05a74fdd5b7c26f06fec14a4fe8
plush https://huggingface.co/cakewalk/splat-data/resolve/main/plush.splat 83abc29f6e27ef2d4299d3ab46f6e08f42268f47408e1022edbf06963b5e4c6a
'

fetch_one() {
    local name="$1" url="$2" sha="$3"
    local out="$DEST/$name.${url##*.}"
    if [ -f "$out" ] && echo "$sha  $out" | sha256sum -c --quiet 2>/dev/null; then
        echo "ok       $out (cached)"
        return 0
    fi
    echo "fetching $out"
    curl -fL --retry 3 -o "$out.part" "$url"
    local got
    got=$(sha256sum "$out.part" | cut -d' ' -f1)
    if [ "$got" != "$sha" ]; then
        rm -f "$out.part"
        echo "sha256 mismatch for $name: got $got, want $sha" >&2
        return 1
    fi
    mv "$out.part" "$out"
    echo "ok       $out"
}

want="${1:-}"
found=0
while read -r name url sha; do
    [ -z "$name" ] && continue
    if [ -z "$want" ] || [ "$name" = "$want" ]; then
        fetch_one "$name" "$url" "$sha"
        found=1
    fi
done <<<"$SCENES"

if [ "$found" = 0 ]; then
    echo "unknown scene '$want' — available:" >&2
    while read -r name _ _; do [ -n "$name" ] && echo "  $name" >&2; done <<<"$SCENES"
    exit 1
fi
