//! Multi-client serving over ONE shared pipeline, now through the
//! deadline-aware serving front end (`sltarch::serve`): a bounded frame
//! queue with typed backpressure, per-client admission control, render
//! workers, per-request deadlines and deadline-adaptive LoD
//! degradation. The open-loop load generator offers more work than the
//! worker pool can render, so the run shows the whole story: shed
//! counts, p50/p95/p99 latency percentiles, and per-stream tau walking
//! up under pressure (and back down when headroom returns).
//!
//! Run: `cargo run --release --example multi_client [-- --quick]
//!       [-- --clients N] [-- --frames N]`

use sltarch::config::SceneConfig;
use sltarch::coordinator::{CpuBackend, FramePipeline};
use sltarch::scene::orbit_cameras;
use sltarch::serve::{
    calibrate_frame_seconds, run_load, LoadGenConfig, QosConfig, ServeConfig,
};

fn arg_usize(args: &[String], key: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let clients = arg_usize(&args, "--clients", 4).max(1);
    let frames = arg_usize(&args, "--frames", if quick { 6 } else { 24 }).max(1);

    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 200_000;
    }
    let extent = cfg.extent;
    println!(
        "building `{}` ({} leaves) for {clients} clients x {frames} frames...",
        cfg.name, cfg.leaves
    );

    // One immutable pipeline for everyone; per-session scheduler width 2
    // so concurrent render workers share the machine instead of
    // oversubscribing it.
    let pipeline = FramePipeline::builder(cfg.build(42))
        .tau(16.0)
        .backend(CpuBackend::with_threads(2))
        .build();

    // Every client streams its own orbit band; the server recycles the
    // paths modulo, so each lane really follows a coherent trajectory
    // (which is what keeps its temporal cut cache warm).
    let paths: Vec<_> = (0..clients)
        .map(|c| {
            let range = 0.5 + 0.4 * (c as f32 + 1.0) / clients as f32;
            orbit_cameras(extent, range, frames.max(8), 256, 256)
        })
        .collect();

    // Calibrate the machine, then deliberately offer ~2x what the
    // worker pool can render: per-client period = one frame time, but
    // only 2 workers for `clients` streams. The budget is what one
    // uncontended frame needs plus headroom — under this overload a
    // fixed-tau server blows through it, the QoS controller trades LoD
    // for latency instead.
    let base = calibrate_frame_seconds(&pipeline, 16.0, &paths[0][..4.min(paths[0].len())]);
    let budget = (base * 2.0).max(1e-3);
    println!(
        "calibration: {:.1} ms/frame at tau 16 -> budget {:.1} ms/request",
        base * 1e3,
        budget * 1e3
    );

    let serve = ServeConfig {
        queue_capacity: clients * 4,
        max_inflight: 3,
        workers: 2,
        budget,
        shed_expired: false,
        keep_frames: false,
        qos: QosConfig {
            enabled: true,
            step: 8.0, // == CutCacheConfig::max_tau_step: nudges stay warm
            max_tau: 64.0,
            miss_threshold: 2,
            recover_headroom: 0.5,
            recover_after: 8,
        },
        ..ServeConfig::default()
    };
    let load = LoadGenConfig {
        clients,
        frames,
        warmup: frames.min(8),
        period: base,
        burst_every: 4,
        burst_extra: 2,
        jitter: 0.1,
        slow_client: clients > 1,
        ..LoadGenConfig::default()
    };

    let r = run_load(&pipeline, serve, &load, &paths);

    println!(
        "\n client   served  missed expired      p50      p95      p99     tau  degr/recov"
    );
    for c in &r.clients {
        let [p50, p95, p99] = c.e2e.percentiles_ms();
        println!(
            "{:>7} {:>8} {:>7} {:>7} {:>7.1}ms {:>7.1}ms {:>7.1}ms {:>7.1} {:>5}/{}",
            c.client, c.served, c.missed, c.expired, p50, p95, p99, c.tau,
            c.degrade_events, c.recover_events
        );
    }

    let [p50, p95, p99] = r.e2e_percentiles_ms();
    let [w50, w95, w99] = r.queue_wait.percentiles_ms();
    println!("\n=== serving window ({clients} clients, {} workers) ===", serve.workers);
    println!(
        "submitted          : {} ({} served, {} missed deadline, {} expired, {} failed)",
        r.submitted, r.served, r.missed, r.expired, r.failed
    );
    println!(
        "shed               : {} (queue-full {}, client-saturated {})",
        r.shed_total(),
        r.shed_queue,
        r.shed_admission
    );
    println!(
        "queue occupancy    : high water {} / capacity {}",
        r.queue_high_water, r.queue_capacity
    );
    println!("served fps         : {:.2} over {:.2} s", r.served_fps(), r.span_seconds);
    println!("e2e latency        : p50 {p50:.1} ms  p95 {p95:.1} ms  p99 {p99:.1} ms");
    println!("queue wait         : p50 {w50:.1} ms  p95 {w95:.1} ms  p99 {w99:.1} ms");
    println!(
        "qos                : {} degrade / {} recover steps (budget {:.1} ms)",
        r.degrade_events,
        r.recover_events,
        serve.budget * 1e3
    );
    println!(
        "cut-cache          : {}/{} frames hit ({} revalidated, {} reseeds — tau \
         nudges ride the warm path)",
        r.render.cache_hit, r.render.frames, r.render.revalidated, r.render.reseeded
    );
    print!("per-stage p95 (ms) :");
    for (name, [_, stage_p95, _]) in r.render.stages.percentile_rows_ms() {
        print!(" {name} {stage_p95:.2}");
    }
    println!();
    Ok(())
}
