//! Multi-client serving surface: N concurrent camera streams over ONE
//! shared, immutable `FramePipeline` (scene + SLTree partitioned once),
//! each client thread owning its private `RenderSession` (options,
//! front-end scratch, unified stats). This is the serving shape the
//! ROADMAP north star asks for: session setup amortized across frames,
//! zero cross-client locking, aggregate throughput reported via
//! `RenderStats`.
//!
//! Run: `cargo run --release --example multi_client [-- --quick]
//!       [-- --clients N] [-- --frames N]`

use sltarch::config::SceneConfig;
use sltarch::coordinator::renderer::AlphaMode;
use sltarch::coordinator::{
    BlendKernel, CpuBackend, FramePipeline, RenderOptions, RenderStats,
};
use sltarch::scene::orbit_cameras;

fn arg_usize(args: &[String], key: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let clients = arg_usize(&args, "--clients", 4).max(1);
    let frames = arg_usize(&args, "--frames", if quick { 6 } else { 24 }).max(1);

    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 200_000;
    }
    let extent = cfg.extent;
    println!(
        "building `{}` ({} leaves) for {clients} concurrent clients x {frames} frames...",
        cfg.name, cfg.leaves
    );

    // One pipeline for everyone. Per-client scheduler width 2 so the
    // clients share the machine instead of oversubscribing it; the one
    // knob drives each session's parallel front end (project -> CSR
    // bin -> tile sort) and its blend-stage tile scheduler together.
    let pipeline = FramePipeline::builder(cfg.build(42))
        .tau(16.0)
        .backend(CpuBackend::with_threads(2))
        .build();

    // Every client gets its own trajectory (different orbit band) and
    // alternates alpha dataflows, proving per-session options really
    // are per-session.
    let t0 = std::time::Instant::now();
    let per_client: Vec<RenderStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let pipeline = &pipeline;
                s.spawn(move || {
                    let alpha = if c % 2 == 0 { AlphaMode::Group } else { AlphaMode::Pixel };
                    // Every client blends through the divergence-free
                    // SoA kernel (byte-identical to the scalar
                    // reference; see `splat::kernel`).
                    let mut session = pipeline.session_with(RenderOptions {
                        alpha,
                        kernel: BlendKernel::Soa,
                        ..pipeline.default_options()
                    });
                    let range = 0.5 + 0.4 * (c as f32 + 1.0) / clients as f32;
                    let cams = orbit_cameras(extent, range, frames, 256, 256);
                    let images = session.render_path(&cams).expect("client render");
                    // Sanity: every client stream produced real content.
                    let mean: f32 = images
                        .iter()
                        .flat_map(|img| img.data.iter())
                        .map(|p| p[0] + p[1] + p[2])
                        .sum::<f32>()
                        / (images.len() * images[0].data.len() * 3) as f32;
                    assert!(mean > 1e-4, "client {c} rendered black frames");
                    *session.stats()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let span = t0.elapsed().as_secs_f64();

    println!("\n client  alpha   frames     fps   ms/frame      cut/frame   pairs/frame");
    for (c, st) in per_client.iter().enumerate() {
        println!(
            "{c:>7} {:>6} {:>8} {:>7.2} {:>10.1} {:>14.0} {:>13.1}k",
            if c % 2 == 0 { "group" } else { "pixel" },
            st.frames,
            st.fps(),
            st.ms_per_frame(),
            st.cut_total as f64 / st.frames as f64,
            st.pairs_total as f64 / st.frames as f64 / 1e3,
        );
    }

    // Aggregate serving report: the clients ran concurrently, so fold
    // them with `merge_concurrent` — it pins `wall_seconds` to the
    // measured span (a plain `merge` would sum the per-client clocks
    // and under-report aggregate fps).
    let busy: f64 = per_client.iter().map(|st| st.wall_seconds).sum();
    let mut total = RenderStats::default();
    for st in &per_client {
        total.merge_concurrent(st, span);
    }
    println!("\n=== aggregate ({clients} clients sharing one pipeline) ===");
    println!("frames             : {}", total.frames);
    println!(
        "scheduler width    : {} (front end + blend, per client)",
        total.front_end_threads
    );
    println!("wall-clock span    : {:.2} s", span);
    println!(
        "aggregate fps      : {:.2} ({:.1} ms/frame effective)",
        total.fps(),
        total.ms_per_frame()
    );
    println!(
        "concurrency        : {:.2}x (client-seconds / span)",
        busy / span.max(1e-12)
    );
    println!(
        "cut-cache hits     : {}/{} frames (per-stream temporal reuse; \
         {} frontier nodes revalidated, {} reseeds)",
        total.cache_hit, total.frames, total.revalidated, total.reseeded
    );
    print!("per-stage (s, all clients):");
    for (name, secs) in total.stages.rows() {
        print!(" {name} {secs:.2}");
    }
    println!();
    Ok(())
}
