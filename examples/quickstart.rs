//! Quickstart: build a scene, partition its LoD tree into an SLTree,
//! run the LoD search, render a frame, and simulate the paper's five
//! hardware variants — the whole public API in ~40 lines.
//!
//! Run: `cargo run --release --example quickstart`

use sltarch::prelude::*;
use sltarch::sim::HwVariant;

fn main() -> anyhow::Result<()> {
    // 1. A deterministic synthetic scene (HierarchicalGS stand-in).
    let scene = SceneConfig::small_scale().quick().build(42);
    println!(
        "scene `{}`: {} Gaussians, LoD tree height {}",
        scene.name,
        scene.gaussians.len(),
        scene.tree.height
    );

    // 2. Offline SLTree partitioning (paper Sec. III-B, tau_s = 32).
    let sltree = SlTree::partition(&scene.tree, 32);
    println!("SLTree: {} subtrees (size limit 32)", sltree.len());

    // 3. LoD search: the streaming subtree traversal finds the cut.
    let cam = scene.scenario_camera(0);
    let cut = sltree.traverse(&scene.tree, &cam, 16.0);
    println!("cut: {} Gaussians selected for rendering", cut.len());

    // 4. Render with the divergence-free group-alpha dataflow.
    let pipeline = FramePipeline::new(
        scene,
        RenderConfig::default(),
        ArchConfig::default(),
    );
    let img = pipeline.render(&cam, AlphaMode::Group)?;
    img.write_ppm(std::path::Path::new("quickstart.ppm"))?;
    println!("wrote quickstart.ppm ({}x{})", img.width, img.height);

    // 5. Simulate the Fig. 9 hardware variants on this frame.
    let report = pipeline.simulate(&cam, &HwVariant::fig9());
    let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
    for r in &report.sims {
        println!(
            "  {:<10} {:>8.3} ms  ({:>5.2}x vs GPU)",
            r.report.variant,
            r.report.total_seconds() * 1e3,
            gpu / r.report.total_seconds()
        );
    }
    Ok(())
}
