//! Quickstart: get a scene (a real `.splat`/`.ply` capture, or the
//! deterministic procedural stand-in), build the frame pipeline (which
//! partitions the SLTree exactly once), run the LoD search, render a
//! frame through a session, and simulate the paper's five hardware
//! variants — the whole public API in ~50 lines.
//!
//! Run: `cargo run --release --example quickstart`
//! or on a real capture (see `scripts/fetch_scenes.sh`):
//! `cargo run --release --example quickstart -- scenes/train.splat`

use sltarch::prelude::*;
use sltarch::sim::HwVariant;

fn main() -> anyhow::Result<()> {
    // 1. A scene: load a real .splat / .ply capture when a path is
    //    given, else the deterministic synthetic HierarchicalGS
    //    stand-in. Loaded splats flow through the exact same
    //    SceneBuilder -> SLTree -> session path.
    let scene = match std::env::args().nth(1) {
        Some(path) => {
            let (scene, report) = load_scene(
                std::path::Path::new(&path),
                LoadMode::Lossy,
                &AssembleOptions::default(),
            )?;
            println!(
                "loaded `{path}`: {} splats kept, {} dropped \
                 ({} SH rest coeffs truncated to degree 0)",
                report.kept,
                report.dropped.total(),
                report.sh_rest_coeffs,
            );
            scene
        }
        None => SceneConfig::small_scale().quick().build(42),
    };
    println!(
        "scene `{}`: {} Gaussians, LoD tree height {}",
        scene.name,
        scene.gaussians.len(),
        scene.tree.height
    );

    // 2. Build the pipeline: offline SLTree partitioning (paper
    //    Sec. III-B, tau_s = 32) happens exactly once, inside build().
    let pipeline = FramePipeline::builder(scene)
        .tau(16.0)
        .subtree_size(32)
        .build();
    println!("SLTree: {} subtrees (size limit 32)", pipeline.sltree().len());

    // 3. LoD search against the pipeline's own tree: the streaming
    //    subtree traversal finds the cut.
    let cam = pipeline.scene().scenario_camera(0);
    let cut = pipeline.search(&cam);
    println!("cut: {} Gaussians selected for rendering", cut.len());

    // 4. Render with the divergence-free group-alpha dataflow through a
    //    session (owns the reusable scratch + unified stats).
    let mut session = pipeline.session();
    let img = session.render(&cam)?;
    img.write_ppm(std::path::Path::new("quickstart.ppm"))?;
    let stats = session.stats();
    println!(
        "wrote quickstart.ppm ({}x{}) in {:.1} ms (search {:.1} / blend {:.1})",
        img.width,
        img.height,
        stats.wall_seconds * 1e3,
        stats.stages.search * 1e3,
        stats.stages.blend * 1e3,
    );

    // 5. Simulate the Fig. 9 hardware variants on this frame.
    let report = pipeline.simulate(&cam, &HwVariant::fig9());
    let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
    for r in &report.sims {
        println!(
            "  {:<10} {:>8.3} ms  ({:>5.2}x vs GPU)",
            r.report.variant,
            r.report.total_seconds() * 1e3,
            gpu / r.report.total_seconds()
        );
    }
    Ok(())
}
