//! Stereo-pair batch rendering through the PR-10 `ViewBatch`: every
//! frame of a VR walkthrough is rendered as a two-view batch (left eye
//! plus a 6.5 cm-offset right eye) over ONE shared pipeline, then the
//! same frames are re-rendered through two independent per-eye
//! sessions and the outputs are asserted byte-identical — the batch
//! path may share front-end work (cross-view cut-cache seeding, gather
//! skips on bit-equal cuts, identity coalescing) but may never change
//! pixels. The run prints the sharing telemetry (`BatchStats`) and the
//! front-end ms/frame of both paths so the win is visible.
//!
//! Run: `cargo run --release --example stereo [-- --quick]
//!       [-- --frames N]`

use std::time::Instant;

use sltarch::config::SceneConfig;
use sltarch::coordinator::{FramePipeline, RenderStats};
use sltarch::math::{Camera, Vec3};
use sltarch::scene::walkthrough;

fn arg_usize(args: &[String], key: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Shift a camera's eye by `offset` world units keeping orientation and
/// intrinsics exactly: for a view `V(x) = R x + t`, `t' = t - R d`.
fn offset_camera(cam: &Camera, offset: Vec3) -> Camera {
    let mut out = *cam;
    let r = cam.view.rotation();
    for i in 0..3 {
        out.view.m[i][3] -= r.row(i).dot(offset);
    }
    out
}

/// Front-end milliseconds per frame: everything before the blend.
fn front_end_ms_per_frame(stats: &RenderStats) -> f64 {
    (stats.stages.search + stats.stages.project + stats.stages.bin + stats.stages.sort)
        * 1e3
        / stats.frames.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let frames = arg_usize(&args, "--frames", if quick { 8 } else { 24 }).max(1);

    let mut cfg = SceneConfig::terrain();
    if quick {
        cfg = cfg.quick();
    }
    let extent = cfg.extent;
    println!(
        "building `{}` ({} leaves) for a {frames}-frame stereo walkthrough...",
        cfg.name, cfg.leaves
    );
    let pipeline = FramePipeline::builder(cfg.build(11)).tau(16.0).build();

    // One coherent head path; the right eye rides 6.5 cm to the side of
    // the left every frame (a human interpupillary distance).
    let path = walkthrough(extent, frames.max(2), 256, 256);
    let baseline = Vec3::new(0.065, 0.0, 0.0);

    // Pass 1 — the batch lane: one ViewBatch, both eyes per call. The
    // per-view sessions inside it keep their cut caches warm across
    // frames exactly like two long-lived single-view sessions would.
    let mut batch = pipeline.batch();
    let mut batch_frames = Vec::with_capacity(frames);
    let t = Instant::now();
    for f in 0..frames {
        let cam = path[f % path.len()];
        let cams = [cam, offset_camera(&cam, baseline)];
        batch_frames.push(batch.render(&cams)?);
    }
    let batch_secs = t.elapsed().as_secs_f64();

    // Pass 2 — the reference: two independent per-eye sessions render
    // the identical cameras. Byte-identity is the contract, not a
    // tolerance.
    let mut left = pipeline.session();
    let mut right = pipeline.session();
    let t = Instant::now();
    for (f, pair) in batch_frames.iter().enumerate() {
        let cam = path[f % path.len()];
        let want_l = left.render(&cam)?;
        let want_r = right.render(&offset_camera(&cam, baseline))?;
        assert_eq!(pair[0].data, want_l.data, "frame {f}: left eye diverged");
        assert_eq!(pair[1].data, want_r.data, "frame {f}: right eye diverged");
    }
    let single_secs = t.elapsed().as_secs_f64();

    // A duplicate feed (both eyes bitwise equal) coalesces to ONE front
    // end — the strongest sharing level, exercised once for telemetry.
    let dup = batch.render(&[path[0], path[0]])?;
    assert_eq!(dup[0].data, dup[1].data, "duplicate feed must coalesce");

    let bs = *batch.batch_stats();
    let batch_fe: f64 = (0..2)
        .filter_map(|v| batch.view_stats(v))
        .map(front_end_ms_per_frame)
        .sum();
    let single_fe = front_end_ms_per_frame(left.stats())
        + front_end_ms_per_frame(right.stats());

    println!("\n=== stereo walkthrough ({frames} frames x 2 eyes) ===");
    println!(
        "batch lane         : {:.1} ms/pair ({} batches, {} views)",
        batch_secs * 1e3 / frames as f64,
        bs.batches,
        bs.views
    );
    println!(
        "independent lane   : {:.1} ms/pair (two per-eye sessions)",
        single_secs * 1e3 / frames as f64
    );
    println!(
        "sharing telemetry  : {} searches seeded, {} gathers skipped, \
         {} front ends shared (duplicate feed)",
        bs.searches_seeded, bs.gathers_skipped, bs.front_ends_shared
    );
    println!(
        "front end          : {batch_fe:.2} ms/pair batched vs \
         {single_fe:.2} ms/pair independent"
    );
    println!("byte-identity      : all {frames} stereo pairs matched exactly");
    Ok(())
}
