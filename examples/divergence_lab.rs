//! Divergence lab: measure SIMT lane occupancy of the canonical
//! per-pixel splatting dataflow vs the SLTarch 2x2 group dataflow on
//! real frames (paper Bottleneck 3: "GPU utilization could be as low as
//! 31%"), plus the quality price of the approximation.
//!
//! Run: `cargo run --release --example divergence_lab [-- --quick]`

use sltarch::config::SceneConfig;
use sltarch::coordinator::renderer::AlphaMode;
use sltarch::coordinator::workload::{lod_workload, splat_workload};
use sltarch::coordinator::{BlendKernel, FramePipeline, RenderOptions};
use sltarch::metrics::psnr;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 200_000;
    }
    let pipeline = FramePipeline::builder(cfg.build(42)).build();

    // Three sessions over one pipeline: the canonical per-pixel stream,
    // the group-alpha stream (scalar reference kernel), and the same
    // group dataflow through the divergence-free SoA kernel — which
    // must reproduce the scalar frames bit for bit.
    let mut px_sess = pipeline
        .session_with(RenderOptions { alpha: AlphaMode::Pixel, ..pipeline.default_options() });
    let mut gp_sess = pipeline
        .session_with(RenderOptions { alpha: AlphaMode::Group, ..pipeline.default_options() });
    let mut soa_sess = pipeline.session_with(RenderOptions {
        alpha: AlphaMode::Group,
        kernel: BlendKernel::Soa,
        ..pipeline.default_options()
    });

    println!(
        "{:>9} {:>10} {:>12} {:>12} {:>13} {:>12}",
        "scenario", "pairs", "pixel util", "group util", "alpha saved", "PSNR (dB)"
    );
    for i in 0..pipeline.scene().cameras.len() {
        let cam = pipeline.scene().scenario_camera(i);
        let (cut, _) = lod_workload(
            pipeline.scene(),
            pipeline.sltree(),
            &cam,
            pipeline.rcfg(),
            64,
        );
        let w = splat_workload(pipeline.scene(), &cut, &cam, pipeline.rcfg());
        let saved = 1.0
            - (w.group.group_checks + w.group.alpha_evals) as f64
                / w.pixel.alpha_evals.max(1) as f64;
        let px = px_sess.render(&cam)?;
        let gp = gp_sess.render(&cam)?;
        let soa = soa_sess.render(&cam)?;
        assert_eq!(
            gp.data, soa.data,
            "SoA kernel must be bit-identical to the scalar kernel"
        );
        println!(
            "{i:>9} {:>10} {:>11.1}% {:>11.1}% {:>12.1}% {:>12.2}",
            w.pairs,
            w.pixel.divergence.utilization() * 100.0,
            w.group.divergence.utilization() * 100.0,
            saved * 100.0,
            psnr(&px, &gp).min(99.0)
        );
    }
    let (px, gp, soa) =
        (px_sess.stats(), gp_sess.stats(), soa_sess.stats());
    println!(
        "\nsession stats: pixel {:.1} ms/frame vs group {:.1} ms/frame \
         over {} frames each",
        px.ms_per_frame(),
        gp.ms_per_frame(),
        px.frames
    );
    let blend_ms = |st: &sltarch::coordinator::RenderStats| {
        st.stages.blend * 1e3 / st.frames.max(1) as f64
    };
    println!(
        "blend stage: scalar kernel {:.2} ms/frame vs SoA kernel {:.2} \
         ms/frame (identical pixels; RenderOptions::kernel)",
        blend_ms(gp),
        blend_ms(soa)
    );
    println!(
        "pixel util matches the paper's ~31% GPU-utilization floor; the\n\
         group dataflow removes the divergence (uniform 2x2 groups) while\n\
         keeping PSNR high — the SP-unit design point."
    );
    Ok(())
}
