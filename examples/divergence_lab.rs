//! Divergence lab: measure SIMT lane occupancy of the canonical
//! per-pixel splatting dataflow vs the SLTarch 2x2 group dataflow on
//! real frames (paper Bottleneck 3: "GPU utilization could be as low as
//! 31%"), plus the quality price of the approximation.
//!
//! Run: `cargo run --release --example divergence_lab [-- --quick]`

use sltarch::config::{RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer};
use sltarch::coordinator::workload::{lod_workload, splat_workload};
use sltarch::lod::SlTree;
use sltarch::metrics::psnr;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 200_000;
    }
    let scene = cfg.build(42);
    let rcfg = RenderConfig::default();
    let slt = SlTree::partition(&scene.tree, rcfg.subtree_size);

    println!(
        "{:>9} {:>10} {:>12} {:>12} {:>13} {:>12}",
        "scenario", "pairs", "pixel util", "group util", "alpha saved", "PSNR (dB)"
    );
    for i in 0..scene.cameras.len() {
        let cam = scene.scenario_camera(i);
        let (cut, _) = lod_workload(&scene, &slt, &cam, &rcfg, 64);
        let w = splat_workload(&scene, &cut, &cam, &rcfg);
        let saved = 1.0
            - (w.group.group_checks + w.group.alpha_evals) as f64
                / w.pixel.alpha_evals.max(1) as f64;
        let queue = scene.gaussians.gather(&cut);
        let px = CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &rcfg);
        let gp = CpuRenderer::render(&queue, &cam, AlphaMode::Group, &rcfg);
        println!(
            "{i:>9} {:>10} {:>11.1}% {:>11.1}% {:>12.1}% {:>12.2}",
            w.pairs,
            w.pixel.divergence.utilization() * 100.0,
            w.group.divergence.utilization() * 100.0,
            saved * 100.0,
            psnr(&px, &gp).min(99.0)
        );
    }
    println!(
        "\npixel util matches the paper's ~31% GPU-utilization floor; the\n\
         group dataflow removes the divergence (uniform 2x2 groups) while\n\
         keeping PSNR high — the SP-unit design point."
    );
    Ok(())
}
