//! City-scale LoD study: how the cut, the DRAM traffic and the
//! simulated frame time scale as the same city is rendered at
//! increasing LoD coarseness — the scalability story of the paper's
//! intro (rendering "at any scale" with bounded work).
//!
//! Run: `cargo run --release --example city_scale [-- --quick]`

use sltarch::config::SceneConfig;
use sltarch::coordinator::{CpuBackend, FramePipeline, RenderOptions};
use sltarch::residency::ResidencyConfig;
use sltarch::scene::orbit_cameras;
use sltarch::sim::workload::NODE_BYTES;
use sltarch::sim::HwVariant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 500_000;
    }
    println!("building `{}` with {} leaves...", cfg.name, cfg.leaves);
    let mut pipeline = FramePipeline::builder(cfg.build(42)).build();
    let cam = pipeline.scene().scenario_camera(4);
    let total_nodes = pipeline.scene().tree.len();
    println!(
        "LoD tree: {total_nodes} nodes, height {}",
        pipeline.scene().tree.height
    );

    println!(
        "\n{:>9} {:>9} {:>10} {:>12} {:>12} {:>12} {:>9}",
        "tau (px)", "cut", "visited", "lod DRAM", "exh DRAM", "SLT ms", "speedup"
    );
    for tau in [4.0f32, 8.0, 16.0, 32.0, 64.0, 128.0] {
        pipeline.set_lod_tau(tau);
        let (_, lod_w) = pipeline.lod_only(&cam);
        let report = pipeline.simulate(&cam, &[HwVariant::Gpu, HwVariant::SlTarch]);
        let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
        let slt = report.sim_seconds(HwVariant::SlTarch).unwrap();
        println!(
            "{tau:>9} {:>9} {:>10} {:>9.2} MB {:>9.2} MB {:>9.3} ms {:>8.2}x",
            lod_w.cut_len,
            lod_w.trace.visited,
            lod_w.trace.bytes_streamed as f64 / 1e6,
            (total_nodes as u64 * NODE_BYTES) as f64 / 1e6,
            slt * 1e3,
            gpu / slt
        );
    }
    println!(
        "\nThe cut (and so splat + traversal work) is bounded by the screen,\n\
         not the scene: that is the paper's scalability argument, and why\n\
         the GPU baseline's exhaustive search loses at scale."
    );

    // Batched many-camera traffic: an orbital sweep through the city via
    // a render session (scratch reused across frames, dynamic tile
    // scheduler), at serial vs full parallelism.
    pipeline.set_lod_tau(16.0);
    let frames = if quick { 8 } else { 60 };
    let cams = orbit_cameras(cfg.extent, 0.9, frames, 256, 256);
    let threads = CpuBackend::new().threads;
    println!("\nbatched session render over {frames} orbit cameras:");
    for t in [1usize, threads] {
        let backend = CpuBackend::with_threads(t);
        let mut session = pipeline.session_on(&backend, pipeline.default_options());
        let _ = session.render_path(&cams)?;
        let stats = session.stats();
        print!(
            "  {:>2} thread(s): {:>7.2} FPS  ({:.1} ms/frame, {:.1}k pairs/frame |",
            stats.threads,
            stats.fps(),
            stats.ms_per_frame(),
            stats.pairs_total as f64 / frames as f64 / 1e3,
        );
        for (name, ms) in stats.stages.rows_ms_per_frame(stats.frames) {
            print!(" {name} {ms:.2}");
        }
        println!(
            " ms/frame, cut cache {}/{} hits)",
            stats.cache_hit, stats.frames
        );
        if t == threads && threads == 1 {
            break;
        }
    }

    // Out-of-core residency: render the same orbit with a slab budget
    // well under the scene's total slab bytes. The budget is sized from
    // one frame's activated working set (~1.25x), so every frame fits
    // but sweeping the orbit forces steady eviction — exactly the
    // city-larger-than-memory regime. Frames must stay byte-identical
    // to the unmanaged render, and in steady state the cut-delta
    // prefetcher must be turning demand stalls into overlapped loads.
    let slab_total: u64 =
        pipeline.sltree().subtrees.iter().map(|s| s.bytes()).sum();
    let (_, probe) = pipeline.lod_only(&cams[0]);
    let working_set = probe.trace.bytes_streamed + probe.trace.bytes_streamed / 4;
    let mut budget = working_set.min(slab_total / 2);
    if budget == 0 {
        budget = 1;
    }
    assert!(budget < slab_total, "budget must be under the scene");
    println!(
        "\nout-of-core residency over the same {frames}-camera orbit:\n  \
         scene slabs {:.2} MB, budget {:.2} MB ({:.0}% of scene)",
        slab_total as f64 / 1e6,
        budget as f64 / 1e6,
        100.0 * budget as f64 / slab_total as f64
    );
    let mut managed = pipeline.session_with(RenderOptions {
        residency: ResidencyConfig::with_budget(budget),
        ..pipeline.default_options()
    });
    let mut plain = pipeline.session();
    let managed_imgs = managed.render_path(&cams)?;
    let plain_imgs = plain.render_path(&cams)?;
    for (i, (a, b)) in managed_imgs.iter().zip(&plain_imgs).enumerate() {
        assert_eq!(
            a.data, b.data,
            "residency changed pixels at frame {i} — the replay contract broke"
        );
    }
    let rs = managed.stats().residency;
    println!(
        "  slab touches: {:.1}% hit ({} hits / {} misses, {} cold + {} capacity)\n  \
         demand loads {:.2} MB (stall {:.3} ms/frame), evicted {:.2} MB, \
         bypass {}\n  \
         prefetch: {} issued, {} hit ({:.1}% accuracy), {:.2} MB overlapped",
        100.0 * rs.hit_rate(),
        rs.hits,
        rs.misses,
        rs.cold_misses,
        rs.misses - rs.cold_misses,
        rs.bytes_loaded as f64 / 1e6,
        rs.stall_seconds * 1e3 / rs.frames.max(1) as f64,
        rs.bytes_evicted as f64 / 1e6,
        rs.bypass_loads,
        rs.prefetch_issued,
        rs.prefetch_hits,
        100.0 * rs.prefetch_hit_rate(),
        rs.bytes_prefetched as f64 / 1e6,
    );
    assert!(rs.misses > 0, "an under-budget orbit must demand-fault");
    assert!(
        rs.prefetch_hits > 0,
        "steady-state prefetch hit rate must be > 0 on a coherent orbit"
    );
    println!(
        "  frames byte-identical to the unmanaged render — residency only\n  \
         decides when bytes move, never what the search computes."
    );
    Ok(())
}
