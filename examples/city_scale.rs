//! City-scale LoD study: how the cut, the DRAM traffic and the
//! simulated frame time scale as the same city is rendered at
//! increasing LoD coarseness — the scalability story of the paper's
//! intro (rendering "at any scale" with bounded work).
//!
//! Run: `cargo run --release --example city_scale [-- --quick]`

use sltarch::config::SceneConfig;
use sltarch::coordinator::{CpuBackend, FramePipeline};
use sltarch::scene::orbit_cameras;
use sltarch::sim::workload::NODE_BYTES;
use sltarch::sim::HwVariant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 500_000;
    }
    println!("building `{}` with {} leaves...", cfg.name, cfg.leaves);
    let mut pipeline = FramePipeline::builder(cfg.build(42)).build();
    let cam = pipeline.scene().scenario_camera(4);
    let total_nodes = pipeline.scene().tree.len();
    println!(
        "LoD tree: {total_nodes} nodes, height {}",
        pipeline.scene().tree.height
    );

    println!(
        "\n{:>9} {:>9} {:>10} {:>12} {:>12} {:>12} {:>9}",
        "tau (px)", "cut", "visited", "lod DRAM", "exh DRAM", "SLT ms", "speedup"
    );
    for tau in [4.0f32, 8.0, 16.0, 32.0, 64.0, 128.0] {
        pipeline.set_lod_tau(tau);
        let (_, lod_w) = pipeline.lod_only(&cam);
        let report = pipeline.simulate(&cam, &[HwVariant::Gpu, HwVariant::SlTarch]);
        let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
        let slt = report.sim_seconds(HwVariant::SlTarch).unwrap();
        println!(
            "{tau:>9} {:>9} {:>10} {:>9.2} MB {:>9.2} MB {:>9.3} ms {:>8.2}x",
            lod_w.cut_len,
            lod_w.trace.visited,
            lod_w.trace.bytes_streamed as f64 / 1e6,
            (total_nodes as u64 * NODE_BYTES) as f64 / 1e6,
            slt * 1e3,
            gpu / slt
        );
    }
    println!(
        "\nThe cut (and so splat + traversal work) is bounded by the screen,\n\
         not the scene: that is the paper's scalability argument, and why\n\
         the GPU baseline's exhaustive search loses at scale."
    );

    // Batched many-camera traffic: an orbital sweep through the city via
    // a render session (scratch reused across frames, dynamic tile
    // scheduler), at serial vs full parallelism.
    pipeline.set_lod_tau(16.0);
    let frames = if quick { 8 } else { 60 };
    let cams = orbit_cameras(cfg.extent, 0.9, frames, 256, 256);
    let threads = CpuBackend::new().threads;
    println!("\nbatched session render over {frames} orbit cameras:");
    for t in [1usize, threads] {
        let backend = CpuBackend::with_threads(t);
        let mut session = pipeline.session_on(&backend, pipeline.default_options());
        let _ = session.render_path(&cams)?;
        let stats = session.stats();
        print!(
            "  {:>2} thread(s): {:>7.2} FPS  ({:.1} ms/frame, {:.1}k pairs/frame |",
            stats.threads,
            stats.fps(),
            stats.ms_per_frame(),
            stats.pairs_total as f64 / frames as f64 / 1e3,
        );
        for (name, ms) in stats.stages.rows_ms_per_frame(stats.frames) {
            print!(" {name} {ms:.2}");
        }
        println!(
            " ms/frame, cut cache {}/{} hits)",
            stats.cache_hit, stats.frames
        );
        if t == threads && threads == 1 {
            break;
        }
    }
    Ok(())
}
