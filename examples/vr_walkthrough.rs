//! END-TO-END DRIVER (DESIGN.md §5 headline run): a VR walkthrough over
//! the large synthetic scene through the complete three-layer stack —
//! SLTree LoD search in rust, splatting executed by the **AOT-compiled
//! JAX/Pallas PJRT artifacts** (python never runs here), image quality
//! checked against the canonical dataflow, and the LTCore/SPCore/GPU
//! models reporting the paper's headline speedup per frame.
//!
//! Run: `make artifacts && cargo run --release --example vr_walkthrough`
//! (add `-- --quick` for a fast smoke pass; `-- --frames N` to resize)

use sltarch::config::SceneConfig;
use sltarch::coordinator::renderer::AlphaMode;
use sltarch::coordinator::{CpuBackend, FramePipeline, RenderOptions};
use sltarch::metrics::psnr;
use sltarch::runtime::{default_artifacts_dir, ArtifactSet, PjrtEngine};
use sltarch::scene::walkthrough;
use sltarch::sim::HwVariant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let frames: usize = args
        .iter()
        .position(|a| a == "--frames")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 4 } else { 24 });

    let mut cfg = SceneConfig::large_scale();
    if quick {
        cfg = cfg.quick();
    } else {
        cfg.leaves = 300_000; // walkthrough-sized slice of the city
    }
    println!("building scene `{}` ({} leaves)...", cfg.name, cfg.leaves);
    let scene = cfg.build(42);
    let extent = cfg.extent;

    let set = ArtifactSet::discover(&default_artifacts_dir())?;
    set.validate_headers()?;
    println!("compiling PJRT artifacts from {} ...", set.dir.display());
    let engine = PjrtEngine::load(&set)?;

    let pipeline = FramePipeline::builder(scene).engine(engine).build();

    // Two long-lived PJRT sessions: the production group-alpha stream
    // and the canonical per-pixel stream used as accuracy telemetry.
    let mut group_sess = pipeline.session();
    let mut pixel_sess = pipeline
        .session_with(RenderOptions { alpha: AlphaMode::Pixel, ..pipeline.default_options() });

    let cams = walkthrough(extent, frames, 256, 256);
    let mut cut_total = 0usize;
    let mut sim_gpu = 0.0f64;
    let mut sim_slt = 0.0f64;
    let mut worst_psnr = f64::INFINITY;

    println!("\n frame    cut      wall(ms)  sim GPU(ms)  sim SLT(ms)   PSNR(group vs pixel)");
    for (i, cam) in cams.iter().enumerate() {
        // The production path: PJRT artifacts, group-alpha dataflow.
        let wall_before = group_sess.stats().wall_seconds;
        let img = group_sess.render(cam)?;
        let wall = group_sess.stats().wall_seconds - wall_before;

        // Accuracy telemetry: compare against the canonical dataflow.
        let org = pixel_sess.render(cam)?;
        let p = psnr(&org, &img).min(99.0);
        worst_psnr = worst_psnr.min(p);

        // Architecture telemetry: the Fig. 9 headline per frame.
        let report = pipeline.simulate(cam, &[HwVariant::Gpu, HwVariant::SlTarch]);
        let g = report.sim_seconds(HwVariant::Gpu).unwrap();
        let s = report.sim_seconds(HwVariant::SlTarch).unwrap();
        sim_gpu += g;
        sim_slt += s;
        cut_total += report.cut_len;

        println!(
            "{i:>6} {:>7} {:>11.1} {:>12.3} {:>12.3} {:>14.2} dB",
            report.cut_len,
            wall * 1e3,
            g * 1e3,
            s * 1e3,
            p
        );
        if i == 0 || i == frames / 2 {
            let path = format!("walkthrough_{i:03}.ppm");
            img.write_ppm(std::path::Path::new(&path))?;
            println!("        -> wrote {path}");
        }
    }

    let n = frames as f64;
    let stats = group_sess.stats();
    println!("\n=== walkthrough summary ({frames} frames) ===");
    println!("avg cut            : {:.0} Gaussians", cut_total as f64 / n);
    println!(
        "rust+PJRT pipeline : {:.1} ms/frame ({:.1} FPS testbed wall-clock)",
        stats.ms_per_frame(),
        stats.fps()
    );
    print!("per-stage (ms/frame):");
    for (name, ms) in stats.stages.rows_ms_per_frame(stats.frames) {
        print!(" {name} {ms:.2}");
    }
    println!();
    println!(
        "cut cache          : {}/{} frames served incrementally \
         ({} frontier nodes revalidated, {} reseeds)",
        stats.cache_hit, stats.frames, stats.revalidated, stats.reseeded
    );
    println!(
        "simulated GPU      : {:.2} ms/frame ({:.1} FPS)",
        sim_gpu / n * 1e3,
        n / sim_gpu
    );
    println!(
        "simulated SLTARCH  : {:.2} ms/frame ({:.1} FPS) -> {:.2}x speedup",
        sim_slt / n * 1e3,
        n / sim_slt,
        sim_gpu / sim_slt
    );
    println!("worst group-vs-pixel PSNR: {worst_psnr:.2} dB (approximation cost)");

    // Many-camera traffic through the batched API: replay the whole
    // trajectory on a CPU-backend session (front-end scratch reused
    // across frames, dynamic-greedy tile scheduler) for the aggregate
    // CPU-mirror throughput the serving story cares about.
    let cpu = CpuBackend::new();
    let mut replay = pipeline.session_on(&cpu, pipeline.default_options());
    let _ = replay.render_path(&cams)?;
    let batch = replay.stats();
    println!(
        "batched CPU replay   : {:.1} ms/frame ({:.1} FPS on {} tile-scheduler \
         threads, {}/{} cut-cache hits)",
        batch.ms_per_frame(),
        batch.fps(),
        batch.threads,
        batch.cache_hit,
        batch.frames
    );
    Ok(())
}
