//! `sltarch` — the SLTarch CLI (leader entrypoint).
//!
//! Subcommands:
//!   info        scene / tree / SLTree statistics
//!   partition   run SLTree partitioning and report balance
//!   render      render a frame (CPU mirror or PJRT artifacts) to PPM
//!   simulate    run the hardware models for one frame
//!   experiment  regenerate a paper table/figure (fig2..fig12, table1,
//!               dram, area, or `all`)
//!
//! Argument parsing is hand-rolled (clap is not vendored offline).

use anyhow::{bail, Context, Result};
use sltarch::config::{ConfigDoc, RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::AlphaMode;
use sltarch::coordinator::{CpuBackend, FramePipeline};
use sltarch::lod::SlTree;
use sltarch::runtime::{default_artifacts_dir, ArtifactSet, PjrtEngine};
use sltarch::sim::HwVariant;
use sltarch::util::stats::{cov, summarize};

/// Minimal flag parser: `--key value`, `--flag`, and positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value =
                    i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn scene_config(args: &Args) -> Result<SceneConfig> {
    let name = args.get("scene").unwrap_or("small");
    let mut cfg = SceneConfig::preset(name)
        .with_context(|| format!("unknown scene preset `{name}` (small|large|terrain)"))?;
    if args.get_bool("quick") {
        cfg = cfg.quick();
    }
    if let Some(path) = args.get("config") {
        let doc = ConfigDoc::load(std::path::Path::new(path))?;
        cfg.apply_doc(&doc);
    }
    Ok(cfg)
}

fn render_config(args: &Args) -> RenderConfig {
    let mut rcfg = RenderConfig::default();
    rcfg.lod_tau = args.get_f32("tau", rcfg.lod_tau);
    rcfg.subtree_size = args.get_usize("tau-s", rcfg.subtree_size as usize) as u32;
    rcfg
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = scene_config(args)?;
    let seed = args.get_usize("seed", 42) as u64;
    let scene = cfg.build(seed);
    let rcfg = render_config(args);
    let slt = SlTree::partition(&scene.tree, rcfg.subtree_size);
    println!("scene      : {}", scene.name);
    println!("gaussians  : {}", scene.gaussians.len());
    println!("tree height: {}", scene.tree.height);
    println!("subtrees   : {} (tau_s = {})", slt.len(), rcfg.subtree_size);
    let sizes: Vec<f64> = slt.sizes().iter().map(|&s| s as f64).collect();
    let s = summarize(&sizes).unwrap();
    println!(
        "subtree sz : mean {:.1} std {:.1} max {:.0} (cov {:.3})",
        s.mean,
        s.std,
        s.max,
        cov(&sizes)
    );
    scene.tree.check_invariants().map_err(anyhow::Error::msg)?;
    slt.check_invariants(&scene.tree).map_err(anyhow::Error::msg)?;
    println!("invariants : ok");
    if args.get_bool("levels") {
        // Per-level node counts and world-size distribution.
        let mut by_level: std::collections::BTreeMap<u16, Vec<f64>> = Default::default();
        for (i, n) in scene.tree.nodes.iter().enumerate() {
            by_level
                .entry(n.level)
                .or_default()
                .push(scene.tree.world_size[i] as f64);
        }
        println!("{:>5} {:>8} {:>10} {:>10} {:>10}", "level", "nodes", "sz mean", "sz med", "sz max");
        for (lvl, sizes) in by_level {
            let s = summarize(&sizes).unwrap();
            println!(
                "{lvl:>5} {:>8} {:>10.2} {:>10.2} {:>10.2}",
                s.n, s.mean, s.median, s.max
            );
        }
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = scene_config(args)?;
    let scene = cfg.build(args.get_usize("seed", 42) as u64);
    let tau_s = args.get_usize("tau-s", 32) as u32;
    let merged = SlTree::partition(&scene.tree, tau_s);
    let unmerged = SlTree::partition_unmerged(&scene.tree, tau_s);
    for (name, slt) in [("unmerged", &unmerged), ("merged", &merged)] {
        let sizes: Vec<f64> = slt.sizes().iter().map(|&s| s as f64).collect();
        let s = summarize(&sizes).unwrap();
        println!(
            "{name:<9}: {:>7} subtrees | size mean {:>5.1} std {:>5.1} cov {:.3}",
            slt.len(),
            s.mean,
            s.std,
            cov(&sizes)
        );
    }
    Ok(())
}

fn cmd_render(args: &Args) -> Result<()> {
    let cfg = scene_config(args)?;
    let scene = cfg.build(args.get_usize("seed", 42) as u64);
    let mode = match args.get("mode").unwrap_or("group") {
        "pixel" | "org" => AlphaMode::Pixel,
        _ => AlphaMode::Group,
    };
    let mut builder = FramePipeline::builder(scene)
        .render_config(render_config(args))
        .alpha(mode);
    let threads: Option<usize> = args.get("threads").and_then(|v| v.parse().ok());
    if args.get_bool("pjrt") {
        if threads.is_some() {
            eprintln!("note: --threads is a CPU tile-scheduler knob; the PJRT backend ignores it");
        }
        let set = ArtifactSet::discover(&default_artifacts_dir())?;
        builder = builder.engine(PjrtEngine::load(&set)?);
        println!("renderer: PJRT artifacts ({})", set.dir.display());
    } else {
        if let Some(threads) = threads {
            builder = builder.backend(CpuBackend::with_threads(threads));
        }
        println!("renderer: CPU mirror");
    }
    let pipeline = builder.build();
    let scenario = args.get_usize("scenario", 0);
    let cam = pipeline.scene().scenario_camera(scenario);
    let mut session = pipeline.session();
    let img = session.render(&cam)?;
    let stats = session.stats();
    let out = args.get("out").unwrap_or("frame.ppm");
    img.write_ppm(std::path::Path::new(out))?;
    println!(
        "rendered scenario {scenario} ({}x{}) in {:.1} ms -> {out}",
        img.width,
        img.height,
        stats.wall_seconds * 1e3
    );
    print!("stages (ms):");
    for (name, ms) in stats.stages.rows_ms_per_frame(stats.frames) {
        print!(" {name} {ms:.2}");
    }
    println!(
        "  | cut {} | {:.1}k pairs | backend {}",
        stats.cut_total,
        stats.pairs_total as f64 / 1e3,
        pipeline.backend().name()
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = scene_config(args)?;
    let scene = cfg.build(args.get_usize("seed", 42) as u64);
    let pipeline = FramePipeline::builder(scene)
        .render_config(render_config(args))
        .build();
    let scenario = args.get_usize("scenario", 0);
    let cam = pipeline.scene().scenario_camera(scenario);
    if args.get_bool("debug") {
        let (lod_w, splat_w) = sltarch::coordinator::workload::frame_workload(
            pipeline.scene(),
            pipeline.sltree(),
            &cam,
            pipeline.rcfg(),
        );
        eprintln!("LOD: total_nodes {} visited {} cut {} fetches {} bytes {} activations {}",
            lod_w.total_nodes, lod_w.trace.visited, lod_w.cut_len,
            lod_w.trace.subtree_fetches, lod_w.trace.bytes_streamed,
            lod_w.trace.activations);
        {
            let cut = pipeline.search(&cam);
            let mut hist: std::collections::BTreeMap<u16, u32> = Default::default();
            for &n in &cut {
                *hist.entry(pipeline.scene().tree.nodes[n as usize].level).or_default() += 1;
            }
            eprintln!("CUT levels: {:?}", hist);
        }
        eprintln!("SPLAT: queue {} pairs {} | pixel: evals {} blends {} warps_issued {} warps_total {} util {:.3} | group: checks {} evals {} blends {} util {:.3}",
            splat_w.queue_len, splat_w.pairs,
            splat_w.pixel.alpha_evals, splat_w.pixel.blends,
            splat_w.pixel.divergence.warps_issued, splat_w.pixel.divergence.warps_total,
            splat_w.pixel.divergence.utilization(),
            splat_w.group.group_checks, splat_w.group.alpha_evals, splat_w.group.blends,
            splat_w.group.divergence.utilization());
    }
    let report = pipeline.simulate(&cam, &HwVariant::fig9());
    println!(
        "cut {} gaussians | {} nodes visited | extraction {:.1} ms\n",
        report.cut_len,
        report.lod_visited,
        report.wall_seconds * 1e3
    );
    let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
    for r in &report.sims {
        println!(
            "{}   speedup {:>5.2}x",
            r.report.summary(),
            gpu / r.report.total_seconds()
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.get_bool("quick");
    if !sltarch::experiments::run_by_name(name, quick) {
        bail!(
            "unknown experiment `{name}`; choose one of {:?} or `all`",
            sltarch::experiments::ALL
        );
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "sltarch — scalable point-based neural rendering (SLTarch repro)\n\n\
         usage: sltarch <command> [flags]\n\n\
         commands:\n\
           info        --scene small|large|terrain [--quick] [--tau-s N]\n\
           partition   --scene ... [--tau-s N] [--quick]\n\
           render      --scene ... [--scenario I] [--mode pixel|group]\n\
                       [--pjrt] [--threads N] [--out frame.ppm] [--tau F]\n\
                       [--quick]\n\
           simulate    --scene ... [--scenario I] [--quick]\n\
           experiment  <fig2|fig3|table1|fig9|fig10|dram|fig11|fig12|area|all>\n\
                       [--quick]\n"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let args = Args::parse(&argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&args),
        Some("partition") => cmd_partition(&args),
        Some("render") => cmd_render(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args),
        _ => usage(),
    }
}
