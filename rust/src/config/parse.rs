//! A tiny TOML-subset parser (the real `toml` crate is not vendored).
//!
//! Supports exactly the subset the config files use:
//! `[section]` headers, `key = value` pairs where value is an integer,
//! float, `true`/`false`, or a double-quoted string, plus `#` comments
//! and blank lines. Unknown syntax is a hard [`ParseError`] — configs
//! should never be silently misread.

use std::collections::BTreeMap;

/// Parse failure with line information.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
#[error("config parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

/// One parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

/// A parsed config document: `(section, key) -> value`.
#[derive(Clone, Debug, Default)]
pub struct ConfigDoc {
    values: BTreeMap<(String, String), Value>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let t = raw.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            if let Some(rest) = t.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ParseError {
                    line,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = t.split_once('=').ok_or(ParseError {
                line,
                msg: format!("expected `key = value`, got `{t}`"),
            })?;
            let key = key.trim().to_string();
            // Strip trailing comments outside strings.
            let val = val.trim();
            let val = if val.starts_with('"') {
                val
            } else {
                val.split('#').next().unwrap().trim()
            };
            let parsed = Self::parse_value(val).map_err(|msg| ParseError { line, msg })?;
            doc.values.insert((section.clone(), key), parsed);
        }
        Ok(doc)
    }

    fn parse_value(v: &str) -> Result<Value, String> {
        if v == "true" {
            return Ok(Value::Bool(true));
        }
        if v == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(s) = v.strip_prefix('"') {
            let inner = s.strip_suffix('"').ok_or("unterminated string")?;
            return Ok(Value::Str(inner.to_string()));
        }
        if let Ok(i) = v.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = v.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("cannot parse value `{v}`"))
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        match self.get(section, key)? {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn get_f32(&self, section: &str, key: &str) -> Option<f32> {
        match self.get(section, key)? {
            Value::Float(f) => Some(*f as f32),
            Value::Int(i) => Some(*i as f32),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
[scene]
leaves = 10_000
extent = 25.5
kind = "city"

[ltcore]
lt_units = 4       # inline comment
enabled = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_usize("scene", "leaves"), Some(10_000));
        assert_eq!(doc.get_f32("scene", "extent"), Some(25.5));
        assert_eq!(doc.get_str("scene", "kind"), Some("city"));
        assert_eq!(doc.get_usize("ltcore", "lt_units"), Some(4));
        assert_eq!(doc.get_bool("ltcore", "enabled"), Some(true));
        assert_eq!(doc.len(), 5);
    }

    #[test]
    fn int_coerces_to_f32_but_not_string() {
        let doc = ConfigDoc::parse("[a]\nx = 3\n").unwrap();
        assert_eq!(doc.get_f32("a", "x"), Some(3.0));
        assert_eq!(doc.get_str("a", "x"), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = ConfigDoc::parse("[ok]\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = ConfigDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = ConfigDoc::parse("[a]\nx = \"oops\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = ConfigDoc::parse("[a]\nx = 1\n").unwrap();
        assert!(doc.get("b", "x").is_none());
        assert!(doc.get_usize("a", "y").is_none());
    }
}
