//! Configuration system: scene recipes, architecture parameters and
//! render settings, with a small TOML-subset parser (`toml`/`serde` are
//! not vendored in this offline image — see `parse.rs`).
//!
//! Presets mirror the paper's evaluation setup (Sec. V-A): two scenes
//! (small-scale / large-scale), six scenarios each, subtree size 32,
//! LTCore 2x2 LT units + 128 KB 4-way subtree cache, SPCore with 4
//! projection/sorting units and 2x2 SP units.

pub mod arch;
mod parse;

pub use arch::{
    ArchConfig, DramConfig, GpuConfig, GsCoreConfig, LtCoreConfig, SpCoreConfig,
};
pub use parse::{ConfigDoc, ParseError};

use crate::scene::{
    build_lod_tree, scenario_cameras, GeneratorKind, Scene, SceneSpec,
};

/// Scene recipe: everything needed to deterministically build a scene.
#[derive(Clone, Debug)]
pub struct SceneConfig {
    pub name: String,
    pub kind: GeneratorKind,
    pub leaves: usize,
    pub extent: f32,
    pub mean_fanout: f32,
    pub max_fanout: usize,
    pub width: u32,
    pub height: u32,
}

impl SceneConfig {
    /// The paper's "small-scale" analogue: an indoor scene.
    pub fn small_scale() -> Self {
        SceneConfig {
            name: "small-scale".into(),
            kind: GeneratorKind::Room,
            leaves: 150_000,
            extent: 15.0,
            mean_fanout: 2.0,
            max_fanout: 512,
            width: 256,
            height: 256,
        }
    }

    /// The paper's "large-scale" analogue: a city block grid.
    pub fn large_scale() -> Self {
        SceneConfig {
            name: "large-scale".into(),
            kind: GeneratorKind::City,
            leaves: 1_000_000,
            extent: 200.0,
            mean_fanout: 2.0,
            max_fanout: 1024,
            width: 256,
            height: 256,
        }
    }

    /// Terrain variant used by the extension studies.
    pub fn terrain() -> Self {
        SceneConfig {
            name: "terrain".into(),
            kind: GeneratorKind::Terrain,
            leaves: 300_000,
            extent: 90.0,
            mean_fanout: 2.0,
            max_fanout: 768,
            width: 256,
            height: 256,
        }
    }

    /// A fast variant for unit/integration tests and `--quick` runs.
    /// Shrinks the leaf budget ~20x and the world extent by 20^(1/3) so
    /// the *density* (and therefore the LoD-cut geometry) matches the
    /// full-size scene statistically.
    pub fn quick(mut self) -> Self {
        let shrink = (self.leaves as f32 / (self.leaves / 20).max(2_000) as f32)
            .max(1.0);
        self.leaves = (self.leaves / 20).max(2_000);
        self.extent /= shrink.cbrt();
        self
    }

    /// Deterministically build the scene (generator -> LoD tree -> cams).
    pub fn build(&self, seed: u64) -> Scene {
        let spec = SceneSpec { kind: self.kind, leaves: self.leaves, extent: self.extent };
        let leaves = spec.generate(seed);
        let (gaussians, tree, _stats) =
            build_lod_tree(leaves, seed, self.mean_fanout, self.max_fanout);
        let cameras = scenario_cameras(self.extent, self.width, self.height);
        Scene { name: self.name.clone(), gaussians, tree, cameras }
    }

    /// Resolve a preset by name (CLI `--scene`).
    pub fn preset(name: &str) -> Option<SceneConfig> {
        match name {
            "small" | "small-scale" | "room" => Some(Self::small_scale()),
            "large" | "large-scale" | "city" => Some(Self::large_scale()),
            "terrain" => Some(Self::terrain()),
            _ => None,
        }
    }

    /// Override fields from a parsed config document (`[scene]` section).
    pub fn apply_doc(&mut self, doc: &ConfigDoc) {
        if let Some(v) = doc.get_usize("scene", "leaves") {
            self.leaves = v;
        }
        if let Some(v) = doc.get_f32("scene", "extent") {
            self.extent = v;
        }
        if let Some(v) = doc.get_f32("scene", "mean_fanout") {
            self.mean_fanout = v;
        }
        if let Some(v) = doc.get_usize("scene", "max_fanout") {
            self.max_fanout = v;
        }
        if let Some(v) = doc.get_usize("scene", "width") {
            self.width = v as u32;
        }
        if let Some(v) = doc.get_usize("scene", "height") {
            self.height = v as u32;
        }
        if let Some(v) = doc.get_str("scene", "kind") {
            self.kind = match v {
                "city" => GeneratorKind::City,
                "terrain" => GeneratorKind::Terrain,
                _ => GeneratorKind::Room,
            };
        }
    }
}

/// Render-time knobs.
#[derive(Clone, Copy, Debug)]
pub struct RenderConfig {
    /// Target LoD granularity in projected pixels (paper's tau).
    pub lod_tau: f32,
    /// SLTree subtree size limit (paper default: 32).
    pub subtree_size: u32,
    /// Tile side in pixels (16 matches the splat artifacts).
    pub tile: u32,
    /// Early-terminate a tile when max transmittance drops below this.
    pub t_min: f32,
}

impl Default for RenderConfig {
    fn default() -> Self {
        RenderConfig { lod_tau: 32.0, subtree_size: 32, tile: 16, t_min: 1.0 / 255.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert!(SceneConfig::preset("small").is_some());
        assert!(SceneConfig::preset("large-scale").is_some());
        assert!(SceneConfig::preset("terrain").is_some());
        assert!(SceneConfig::preset("nope").is_none());
    }

    #[test]
    fn quick_shrinks() {
        let q = SceneConfig::large_scale().quick();
        assert!(q.leaves < SceneConfig::large_scale().leaves);
        assert!(q.leaves >= 2_000);
    }

    #[test]
    fn build_quick_scene() {
        let scene = SceneConfig::small_scale().quick().build(1);
        assert_eq!(scene.cameras.len(), 6);
        assert!(scene.tree.len() > scene.gaussians.len() / 2);
        scene.tree.check_invariants().unwrap();
    }

    #[test]
    fn doc_overrides() {
        let doc = ConfigDoc::parse(
            "[scene]\nleaves = 123\nextent = 9.5\nkind = \"terrain\"\n",
        )
        .unwrap();
        let mut cfg = SceneConfig::small_scale();
        cfg.apply_doc(&doc);
        assert_eq!(cfg.leaves, 123);
        assert!((cfg.extent - 9.5).abs() < 1e-6);
        assert_eq!(cfg.kind, GeneratorKind::Terrain);
    }
}
