//! Architecture parameters for every hardware model the paper evaluates
//! (Sec. V-A). Defaults reproduce the published configuration; the
//! ablation benches sweep individual fields.

/// LTCore — the LoD-search accelerator (paper Fig. 6/7).
#[derive(Clone, Copy, Debug)]
pub struct LtCoreConfig {
    /// Number of LT units (paper: 2x2 array).
    pub lt_units: usize,
    /// Clock in GHz (paper: 1 GHz).
    pub clock_ghz: f64,
    /// Subtree-cache associativity (paper: 4-way).
    pub cache_ways: usize,
    /// Subtree-cache sets (paper: 4 x 128 entries => 128 sets).
    pub cache_sets: usize,
    /// Total subtree cache capacity in bytes (paper: 128 KB).
    pub cache_bytes: usize,
    /// Output buffer bytes (paper: 8 KB, double-buffered).
    pub output_buffer_bytes: usize,
    /// Subtree queue capacity in SIDs (paper: 1 x 48 B queue).
    pub queue_entries: usize,
    /// Cycles for one node's frustum + LoD check in an LT unit
    /// (pipelined: issue 1/cycle once warm).
    pub node_test_cycles: u64,
    /// Pipeline depth of an LT unit (fill latency per subtree switch).
    pub pipeline_depth: u64,
}

impl Default for LtCoreConfig {
    fn default() -> Self {
        LtCoreConfig {
            lt_units: 4,
            clock_ghz: 1.0,
            cache_ways: 4,
            cache_sets: 128,
            cache_bytes: 128 << 10,
            output_buffer_bytes: 8 << 10,
            queue_entries: 48,
            node_test_cycles: 1,
            pipeline_depth: 4,
        }
    }
}

impl LtCoreConfig {
    /// Bytes of one subtree-cache entry (all node attributes for one
    /// subtree: AABB 24 B + remaining-size 4 B + child-SID 4 B + NID 4 B
    /// per node, at the configured subtree size limit).
    pub fn entry_bytes(&self, subtree_size: u32) -> usize {
        subtree_size as usize * (24 + 4 + 4 + 4)
    }
}

/// SPCore — the splatting accelerator (paper Fig. 8). Front end
/// (projection/duplication/sorting) follows GSCore; the SP units are the
/// paper's contribution.
#[derive(Clone, Copy, Debug)]
pub struct SpCoreConfig {
    pub clock_ghz: f64,
    /// Projection units (paper: 4, same as GSCore).
    pub proj_units: usize,
    /// Sorting units (paper: 4, same as GSCore).
    pub sort_units: usize,
    /// SP units (paper: 2x2).
    pub sp_units: usize,
    /// Blending lanes per SP unit (paper: 4 = one 2x2 pixel group).
    pub blend_lanes: usize,
    /// Group alpha checks evaluated per cycle per SP unit. The check is
    /// a quadratic form + compare (no exp), so the check array is wide
    /// and cheap — this is the asymmetry the SP unit exploits.
    pub check_width: usize,
    /// Global buffer in bytes (paper: 256 KB double-buffered).
    pub global_buffer_bytes: usize,
    /// Cycles for a group alpha check (exponent-power compare — no exp).
    pub alpha_check_cycles: u64,
    /// Cycles for the full per-pixel alpha (exp) in a blending unit for
    /// surviving groups.
    pub alpha_exp_cycles: u64,
    /// Cycles for one blend op per lane (MADD + T update).
    pub blend_cycles: u64,
    /// Cycles per Gaussian in a projection unit (pipelined).
    pub proj_cycles: u64,
    /// Sorting throughput: elements per cycle per sort unit (bitonic).
    pub sort_elems_per_cycle: f64,
}

impl Default for SpCoreConfig {
    fn default() -> Self {
        SpCoreConfig {
            clock_ghz: 1.0,
            proj_units: 4,
            sort_units: 4,
            sp_units: 4,
            blend_lanes: 4,
            check_width: 16,
            global_buffer_bytes: 256 << 10,
            alpha_check_cycles: 1,
            alpha_exp_cycles: 2,
            blend_cycles: 1,
            proj_cycles: 4,
            sort_elems_per_cycle: 8.0,
        }
    }
}

/// GSCore baseline (Lee et al., ASPLOS'24) as the paper models it:
/// same front end, but per-pixel volume-rendering units with precise
/// (OBB) intersection tests and per-pixel alpha checks.
#[derive(Clone, Copy, Debug)]
pub struct GsCoreConfig {
    pub clock_ghz: f64,
    pub proj_units: usize,
    pub sort_units: usize,
    /// Volume-rendering units (pixel-parallel lanes).
    pub vr_lanes: usize,
    /// Extra cycles per Gaussian for the OBB intersection refinement.
    pub obb_cycles: u64,
    /// Cycles for a per-pixel alpha evaluation (includes exp).
    pub alpha_cycles: u64,
    pub blend_cycles: u64,
    pub proj_cycles: u64,
    pub sort_elems_per_cycle: f64,
}

impl Default for GsCoreConfig {
    fn default() -> Self {
        GsCoreConfig {
            clock_ghz: 1.0,
            proj_units: 4,
            sort_units: 4,
            vr_lanes: 16,
            obb_cycles: 2,
            alpha_cycles: 1,
            blend_cycles: 1,
            proj_cycles: 4,
            sort_elems_per_cycle: 8.0,
        }
    }
}

/// Mobile Ampere GPU (Jetson Orin class) SIMT timing model.
#[derive(Clone, Copy, Debug)]
pub struct GpuConfig {
    pub clock_ghz: f64,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Lanes per warp (CUDA: 32).
    pub warp_lanes: usize,
    /// Resident warps issuing per SM per cycle (dual-issue approximated).
    pub warps_per_sm: usize,
    /// Cycles per node test on a GPU lane (load + AABB test + LoD test,
    /// assuming cache hit).
    pub node_test_cycles: u64,
    /// Average extra stall cycles for an irregular (pointer-chase) DRAM
    /// access that misses cache — the paper's "irregular memory access"
    /// penalty.
    pub irregular_miss_cycles: u64,
    /// Fraction of irregular tree-node accesses that miss on-chip cache.
    pub tree_miss_rate: f64,
    /// Cycles per alpha evaluation (exp) per lane.
    pub alpha_cycles: u64,
    /// Cycles per blend per lane.
    pub blend_cycles: u64,
    /// Cycles per Gaussian projection per lane.
    pub proj_cycles: u64,
    /// Cycles per (gaussian, tile) pair for the GPU radix sort.
    pub sort_cycles_per_pair: u64,
    /// Fraction of peak warp-issue throughput a mobile GPU sustains on
    /// this kind of kernel (memory stalls, sync, tile-list atomics —
    /// the paper measures utilization as low as 31% from divergence
    /// alone; overall sustained efficiency on Orin-class parts is far
    /// lower). Calibration constant for the Fig. 9 ratios.
    pub issue_efficiency: f64,
    /// GPU board power in watts at full tilt (energy model input,
    /// scaled to 16 nm per DeepScaleTool like the paper).
    pub power_w: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            clock_ghz: 0.93,
            sms: 8,
            warp_lanes: 32,
            warps_per_sm: 2,
            node_test_cycles: 16,
            irregular_miss_cycles: 40,
            tree_miss_rate: 0.35,
            alpha_cycles: 4,
            blend_cycles: 2,
            proj_cycles: 16,
            sort_cycles_per_pair: 8,
            issue_efficiency: 0.05,
            power_w: 15.0,
        }
    }
}

/// LPDDR4 DRAM + SRAM energy/latency model. Ratios follow Sec. V-A:
/// random DRAM : SRAM energy ~= 25 : 1, non-streaming : streaming
/// DRAM ~= 3 : 1 (aligned with TETRIS / GANAX as the paper notes).
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    /// Channels (paper: Micron 32 Gb LPDDR4 x 4 channels).
    pub channels: usize,
    /// Peak bandwidth per channel, bytes/cycle at 1 GHz reference.
    pub bytes_per_cycle_per_channel: f64,
    /// pJ per byte for *streaming* DRAM access.
    pub stream_pj_per_byte: f64,
    /// Multiplier for non-streaming (random) DRAM access (paper: ~3x).
    pub random_multiplier: f64,
    /// pJ per byte for SRAM access (paper ratio: random DRAM ~25x this).
    pub sram_pj_per_byte: f64,
    /// Latency of a random row activation in cycles.
    pub random_latency_cycles: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        let stream = 8.0; // pJ/B streaming LPDDR4 (datasheet-scale)
        DramConfig {
            channels: 4,
            bytes_per_cycle_per_channel: 8.0,
            stream_pj_per_byte: stream,
            random_multiplier: 3.0,
            // random DRAM (stream * 3) : sram == 25 : 1
            sram_pj_per_byte: stream * 3.0 / 25.0,
            random_latency_cycles: 40,
        }
    }
}

impl DramConfig {
    #[inline]
    pub fn random_pj_per_byte(&self) -> f64 {
        self.stream_pj_per_byte * self.random_multiplier
    }

    #[inline]
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.channels as f64 * self.bytes_per_cycle_per_channel
    }
}

/// The full architecture bundle the experiments sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArchConfig {
    pub ltcore: LtCoreConfig,
    pub spcore: SpCoreConfig,
    pub gscore: GsCoreConfig,
    pub gpu: GpuConfig,
    pub dram: DramConfig,
}

/// Published area numbers (mm^2, 16 nm) for the `area` experiment.
pub mod area {
    pub const SLTARCH_TOTAL: f64 = 1.90;
    pub const LTCORE: f64 = 0.14;
    pub const SPCORE: f64 = 1.76;
    pub const LT_UNIT_ARRAY: f64 = 0.03;
    pub const SUBTREE_CACHE: f64 = 0.10;
    pub const GSCORE_TOTAL: f64 = 1.78;
    /// A typical mobile SoC for the "negligible overhead" comparison.
    pub const MOBILE_SOC: f64 = 100.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios_hold() {
        let d = DramConfig::default();
        assert!((d.random_pj_per_byte() / d.sram_pj_per_byte - 25.0).abs() < 1e-9);
        assert!((d.random_multiplier - 3.0).abs() < 1e-9);
    }

    #[test]
    fn paper_configuration_defaults() {
        let lt = LtCoreConfig::default();
        assert_eq!(lt.lt_units, 4); // 2x2
        assert_eq!(lt.cache_ways, 4);
        assert_eq!(lt.cache_bytes, 131072);
        let sp = SpCoreConfig::default();
        assert_eq!(sp.sp_units, 4); // 2x2
        assert_eq!(sp.blend_lanes, 4); // 2x2 pixel group
        assert_eq!(sp.proj_units, 4);
    }

    #[test]
    fn cache_entry_fits_capacity() {
        let lt = LtCoreConfig::default();
        // 4 ways x 128 sets entries of subtree size 32 must fit 128 KB
        // within a small metadata margin.
        let total = lt.entry_bytes(32) * lt.cache_ways * lt.cache_sets;
        // Paper stores 512 entries of 32-node subtrees in 128 KB + tags;
        // our entry layout is close (within 5x of capacity guard).
        assert!(total <= lt.cache_bytes * 5, "entry layout exploded: {total}");
    }
}
