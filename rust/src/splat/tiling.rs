//! Screen tiling and Gaussian-to-tile binning (the "duplication" stage
//! of the SPCore/GSCore front end).
//!
//! Uses the basic 3-sigma bounding-square intersection test the paper
//! adopts for SPCore ("we simplify the design of the projection unit by
//! using the basic 3-σ Gaussian-tile intersection test") — precise
//! AABB/OBB refinement is deliberately *not* done: the group alpha check
//! in the SP unit performs the finer-grained filtering for free.

use crate::gaussian::Splat2D;

/// Tile side in pixels — fixed at 16 to match the splat HLO artifacts.
pub const TILE: u32 = 16;

/// Per-tile lists of indices into the projected-splat array.
#[derive(Clone, Debug)]
pub struct TileBins {
    pub tiles_x: u32,
    pub tiles_y: u32,
    /// `per_tile[ty * tiles_x + tx]` = splat indices touching that tile.
    pub per_tile: Vec<Vec<u32>>,
    /// Total (gaussian, tile) pairs — the duplication factor the sorting
    /// hardware has to chew through.
    pub pairs: u64,
}

impl TileBins {
    #[inline]
    pub fn tile_count(&self) -> usize {
        (self.tiles_x * self.tiles_y) as usize
    }

    #[inline]
    pub fn tile_origin(&self, idx: usize) -> (f32, f32) {
        let tx = idx as u32 % self.tiles_x;
        let ty = idx as u32 / self.tiles_x;
        ((tx * TILE) as f32, (ty * TILE) as f32)
    }
}

/// Bin projected splats into tiles covering a `width x height` screen.
/// Culled splats (radius 0) never generate pairs.
pub fn bin_splats(splats: &[Splat2D], width: u32, height: u32) -> TileBins {
    let tiles_x = width.div_ceil(TILE);
    let tiles_y = height.div_ceil(TILE);
    let mut per_tile = vec![Vec::new(); (tiles_x * tiles_y) as usize];
    let mut pairs = 0u64;
    for (i, s) in splats.iter().enumerate() {
        if !s.visible() {
            continue;
        }
        let r = s.radius;
        // 3-sigma bounding square, clamped to the screen tile grid.
        let x0 = ((s.mean.x - r) / TILE as f32).floor().max(0.0) as u32;
        let y0 = ((s.mean.y - r) / TILE as f32).floor().max(0.0) as u32;
        let x1 = ((s.mean.x + r) / TILE as f32).floor() as i64;
        let y1 = ((s.mean.y + r) / TILE as f32).floor() as i64;
        if x1 < 0 || y1 < 0 {
            continue;
        }
        let x1 = (x1 as u32).min(tiles_x - 1);
        let y1 = (y1 as u32).min(tiles_y - 1);
        if x0 > x1 || y0 > y1 {
            continue;
        }
        for ty in y0..=y1 {
            for tx in x0..=x1 {
                per_tile[(ty * tiles_x + tx) as usize].push(i as u32);
                pairs += 1;
            }
        }
    }
    TileBins { tiles_x, tiles_y, per_tile, pairs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;

    fn splat_at(x: f32, y: f32, r: f32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(x, y),
            conic: [0.1, 0.0, 0.1],
            depth: 1.0,
            radius: r,
            color: [1.0, 1.0, 1.0],
            opacity: 0.5,
            id: 0,
        }
    }

    #[test]
    fn small_splat_hits_one_tile() {
        let bins = bin_splats(&[splat_at(8.0, 8.0, 3.0)], 64, 64);
        assert_eq!(bins.tiles_x, 4);
        assert_eq!(bins.pairs, 1);
        assert_eq!(bins.per_tile[0], vec![0]);
    }

    #[test]
    fn large_splat_hits_many_tiles() {
        let bins = bin_splats(&[splat_at(32.0, 32.0, 20.0)], 64, 64);
        // Covers tiles 0..=3 in both axes partially: (12..52) -> tiles 0..3.
        assert_eq!(bins.pairs, 16);
    }

    #[test]
    fn culled_and_offscreen_generate_no_pairs() {
        let culled = splat_at(8.0, 8.0, 0.0);
        let offscreen = splat_at(-100.0, -100.0, 5.0);
        let bins = bin_splats(&[culled, offscreen], 64, 64);
        assert_eq!(bins.pairs, 0);
    }

    #[test]
    fn edge_splat_is_clamped() {
        let bins = bin_splats(&[splat_at(63.0, 63.0, 10.0)], 64, 64);
        assert!(bins.pairs > 0);
        // Bottom-right tile must contain it.
        assert!(bins.per_tile[15].contains(&0));
    }

    #[test]
    fn non_multiple_screen_sizes() {
        let bins = bin_splats(&[splat_at(70.0, 5.0, 4.0)], 72, 40);
        assert_eq!(bins.tiles_x, 5);
        assert_eq!(bins.tiles_y, 3);
        assert!(bins.per_tile[4].contains(&0));
    }
}
