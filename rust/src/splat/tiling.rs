//! Screen tiling and Gaussian-to-tile binning (the "duplication" stage
//! of the SPCore/GSCore front end).
//!
//! Uses the basic 3-sigma bounding-square intersection test the paper
//! adopts for SPCore ("we simplify the design of the projection unit by
//! using the basic 3-σ Gaussian-tile intersection test") — precise
//! AABB/OBB refinement is deliberately *not* done: the group alpha check
//! in the SP unit performs the finer-grained filtering for free.
//!
//! The bins live in a **CSR layout**: one flat index array plus a
//! per-tile offset table, built count -> prefix-sum -> scatter (the same
//! shape GPU duplication kernels use with atomics + a prefix scan).
//! Compared to the old `Vec<Vec<u32>>` this removes per-tile heap churn,
//! keeps every tile's list contiguous — the depth sorter works in place
//! on the CSR slices — and lets the whole structure be reused across
//! frames with zero steady-state allocation.

use crate::gaussian::{project_one, Gaussians, Splat2D};
use crate::math::Camera;

/// Tile side in pixels — fixed at 16 to match the splat HLO artifacts.
pub const TILE: u32 = 16;

/// One unit of blend work in a **multi-view** tile schedule: a tile of
/// one view of a [`crate::coordinator::batch::ViewBatch`], plus an
/// optional per-tile LoD override.
///
/// The batch blend scheduler hands interleaved `(view, tile)` items
/// from all views of a batch to one scoped worker pool through a single
/// atomic cursor, so a view with heavy tiles borrows the workers that a
/// view with light tiles is not using — the LT-unit dynamic-dequeue
/// idea applied across views instead of within one frame.
///
/// `tau` is a **reserved foveated-rendering hook**: it rides through
/// the scheduler so a future per-tile LoD policy (coarser tau in the
/// periphery, finer at the gaze point) needs no work-item change. The
/// current blend kernels deliberately ignore it — the batch path's
/// byte-identity contract (batch output == K independent renders)
/// requires uniform per-view LoD today — so [`BatchWorkItem::new`]
/// items and [`BatchWorkItem::with_tau`] items blend identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchWorkItem {
    /// Index of the view in the batch's blend-view list.
    pub view: u32,
    /// Tile index into that view's [`TileBins`].
    pub tile: u32,
    /// Per-tile tau override as f32 bits; `u32::MAX` (a NaN pattern no
    /// valid tau produces) encodes "no override".
    tau_bits: u32,
}

/// Sentinel bit pattern for "no per-tile tau override" (a NaN; taus are
/// finite and positive, so no real override collides with it).
const TAU_NONE: u32 = u32::MAX;

impl BatchWorkItem {
    /// A work item with no per-tile tau override (the whole-view tau
    /// applies — the only mode the byte-identity contract allows today).
    #[inline]
    pub fn new(view: u32, tile: u32) -> Self {
        BatchWorkItem { view, tile, tau_bits: TAU_NONE }
    }

    /// A work item carrying a per-tile tau override (the foveated
    /// hook). `tau` must be finite (NaN would collide with the "no
    /// override" sentinel encoding).
    #[inline]
    pub fn with_tau(view: u32, tile: u32, tau: f32) -> Self {
        debug_assert!(tau.is_finite(), "per-tile tau must be finite");
        BatchWorkItem { view, tile, tau_bits: tau.to_bits() }
    }

    /// The per-tile tau override, if one was attached.
    #[inline]
    pub fn tau(&self) -> Option<f32> {
        if self.tau_bits == TAU_NONE {
            None
        } else {
            Some(f32::from_bits(self.tau_bits))
        }
    }
}

/// Binning-stage failure. Carried as a typed error (instead of the old
/// `panic!`/`assert!`) through `RenderBackend`/`RenderSession`'s
/// `Result` render path, so one malformed frame degrades that request
/// instead of killing a serving process. On error the target
/// [`TileBins`] holds unspecified (but memory-safe) contents; the next
/// successful bin fully rebuilds every buffer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TilingError {
    /// The frame's (gaussian, tile) pair count does not fit the u32 CSR
    /// offset table — only reachable with astronomically large screens
    /// or splat counts, but a serving process must shed such a frame,
    /// not die on it.
    PairOverflow {
        /// The offending pair count.
        pairs: u64,
    },
    /// A rebuilt CSR table failed [`TileBins::validate_csr`] (the scan
    /// runs in debug builds only; the message names the violated
    /// invariant).
    CsrInvariant(String),
}

impl std::fmt::Display for TilingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TilingError::PairOverflow { pairs } => write!(
                f,
                "tile-pair count {pairs} overflows the u32 CSR offsets"
            ),
            TilingError::CsrInvariant(e) => write!(f, "CSR invariant violated: {e}"),
        }
    }
}

impl std::error::Error for TilingError {}

/// A splat's clamped tile-space bounding rectangle (inclusive).
#[derive(Clone, Copy, Debug)]
struct TileRect {
    x0: u32,
    y0: u32,
    x1: u32,
    y1: u32,
}

/// Compute the 3-sigma bounding square of `s` clamped to the tile grid;
/// `None` when the splat is culled, degenerate, or entirely off-screen.
#[inline]
fn tile_rect(s: &Splat2D, tiles_x: u32, tiles_y: u32) -> Option<TileRect> {
    // Empty grid (zero-dimension image): nothing can bin, and the
    // `tiles_x - 1` clamps below would underflow to u32::MAX.
    if !s.visible() || tiles_x == 0 || tiles_y == 0 {
        return None;
    }
    // Non-finite splats must never reach a bin: a NaN mean with positive
    // radius survives `visible()`, then `floor().max(0.0) as u32` maps
    // NaN to 0 and the splat lands in tile (0, 0), where exp(NaN)
    // poisons the pixel row. Projection culls these at the source (see
    // `project_one`); this guard covers splats that bypass projection.
    if !(s.mean.x.is_finite() && s.mean.y.is_finite() && s.radius.is_finite()) {
        return None;
    }
    let r = s.radius;
    let x0 = ((s.mean.x - r) / TILE as f32).floor().max(0.0) as u32;
    let y0 = ((s.mean.y - r) / TILE as f32).floor().max(0.0) as u32;
    let x1 = ((s.mean.x + r) / TILE as f32).floor() as i64;
    let y1 = ((s.mean.y + r) / TILE as f32).floor() as i64;
    if x1 < 0 || y1 < 0 {
        return None;
    }
    let x1 = (x1 as u32).min(tiles_x - 1);
    let y1 = (y1 as u32).min(tiles_y - 1);
    if x0 > x1 || y0 > y1 {
        return None;
    }
    Some(TileRect { x0, y0, x1, y1 })
}

/// Visit every tile index covered by `rect`, row-major — the ONE
/// iteration-order definition all count/scatter passes (serial,
/// parallel and the nested reference) share, so they can never diverge.
#[inline]
fn for_each_covered_tile(rect: TileRect, tiles_x: u32, mut f: impl FnMut(usize)) {
    for ty in rect.y0..=rect.y1 {
        let row = (ty * tiles_x) as usize;
        for tx in rect.x0..=rect.x1 {
            f(row + tx as usize);
        }
    }
}

/// CSR tile bins: indices of splats touching tile `t` live in
/// `indices[offsets[t] as usize .. offsets[t + 1] as usize]`.
#[derive(Clone, Debug, Default)]
pub struct TileBins {
    pub tiles_x: u32,
    pub tiles_y: u32,
    /// CSR offset table, length `tile_count() + 1`; `offsets[0] == 0`
    /// and `offsets[tile_count()] as u64 == pairs`.
    pub offsets: Vec<u32>,
    /// Flat splat-index array, grouped by tile, ascending splat index
    /// within each tile until a depth sort reorders the slices in place.
    pub indices: Vec<u32>,
    /// Total (gaussian, tile) pairs — the duplication factor the sorting
    /// hardware has to chew through. (The CSR offsets are `u32`, so one
    /// frame is capped at 2^32 - 1 pairs — far beyond any screen here.)
    pub pairs: u64,
    /// Scratch: cached per-splat tile rectangles `(splat index, rect)`
    /// from the count pass, replayed by the scatter pass.
    rects: Vec<(u32, TileRect)>,
    /// Scratch: per-tile write cursors for the scatter pass.
    cursor: Vec<u32>,
    /// Scratch: per-worker cached rects (parallel count pass).
    worker_rects: Vec<Vec<(u32, TileRect)>>,
    /// Scratch: per-worker per-tile histograms, rewritten in place into
    /// per-worker write cursors by the merge pass.
    worker_counts: Vec<Vec<u32>>,
}

impl TileBins {
    #[inline]
    pub fn tile_count(&self) -> usize {
        (self.tiles_x * self.tiles_y) as usize
    }

    #[inline]
    pub fn tile_origin(&self, idx: usize) -> (f32, f32) {
        let tx = idx as u32 % self.tiles_x;
        let ty = idx as u32 / self.tiles_x;
        ((tx * TILE) as f32, (ty * TILE) as f32)
    }

    /// Splat indices binned into tile `idx`.
    #[inline]
    pub fn tile(&self, idx: usize) -> &[u32] {
        &self.indices[self.offsets[idx] as usize..self.offsets[idx + 1] as usize]
    }

    /// Mutable view of tile `idx` (the depth sorter reorders in place).
    #[inline]
    pub fn tile_mut(&mut self, idx: usize) -> &mut [u32] {
        let lo = self.offsets[idx] as usize;
        let hi = self.offsets[idx + 1] as usize;
        debug_assert!(
            lo <= hi,
            "CSR offsets not monotone at tile {idx}: {lo} > {hi}"
        );
        debug_assert!(
            hi <= self.indices.len(),
            "CSR slice for tile {idx} ends at {hi}, past indices len {}",
            self.indices.len()
        );
        &mut self.indices[lo..hi]
    }

    /// Number of splats binned into tile `idx`.
    #[inline]
    pub fn tile_len(&self, idx: usize) -> usize {
        (self.offsets[idx + 1] - self.offsets[idx]) as usize
    }

    /// Check every CSR invariant: offset-table shape, `offsets[0] == 0`,
    /// monotone offsets, terminal offset == `indices.len()` == `pairs`,
    /// and every stored splat index in `0..n_splats`. Debug builds run
    /// this after every (serial or parallel) rebuild; tests call it
    /// directly.
    pub fn validate_csr(&self, n_splats: usize) -> Result<(), String> {
        let tiles = self.tile_count();
        if self.offsets.len() != tiles + 1 {
            return Err(format!(
                "offsets len {} != tile count {tiles} + 1",
                self.offsets.len()
            ));
        }
        if self.offsets[0] != 0 {
            return Err(format!("offsets[0] == {} != 0", self.offsets[0]));
        }
        if let Some(t) = self.offsets.windows(2).position(|w| w[0] > w[1]) {
            return Err(format!(
                "offsets not monotone at tile {t}: {} > {}",
                self.offsets[t],
                self.offsets[t + 1]
            ));
        }
        if self.offsets[tiles] as usize != self.indices.len()
            || self.indices.len() as u64 != self.pairs
        {
            return Err(format!(
                "terminal offset {} / indices len {} / pairs {} disagree",
                self.offsets[tiles],
                self.indices.len(),
                self.pairs
            ));
        }
        if let Some(&i) = self.indices.iter().find(|&&i| i as usize >= n_splats) {
            return Err(format!(
                "splat index {i} out of bounds (n_splats = {n_splats})"
            ));
        }
        Ok(())
    }
}

/// Debug-build CSR sanity after a rebuild: reports the violated
/// invariant as a [`TilingError`] (release builds skip the scan
/// entirely).
fn debug_validate(bins: &TileBins, n_splats: usize) -> Result<(), TilingError> {
    if cfg!(debug_assertions) {
        if let Err(e) = bins.validate_csr(n_splats) {
            return Err(TilingError::CsrInvariant(e));
        }
    }
    Ok(())
}

/// Bin projected splats into tiles covering a `width x height` screen.
/// Culled splats (radius 0) never generate pairs. Infallible signature
/// for tests/benches — a [`TilingError`] here means the harness itself
/// is broken, so it unwraps; serving paths use [`bin_splats_into`] /
/// [`bin_splats_into_threaded`] and propagate.
pub fn bin_splats(splats: &[Splat2D], width: u32, height: u32) -> TileBins {
    let mut bins = TileBins::default();
    bin_splats_into(splats, width, height, &mut bins)
        .expect("tile binning (test/bench reference path)");
    bins
}

/// Result of a front-end count sweep, consumed by [`finish_bins`].
/// Produced by the split count passes ([`bin_splats_into`] /
/// [`bin_splats_into_threaded`]) and the fused projection sweep
/// ([`project_bin_sweep`]) alike — the finish code cannot tell which
/// front end ran, which is what keeps their CSR output identical.
#[derive(Clone, Copy, Debug)]
struct CountSweep {
    /// Total (splat, tile) pairs counted.
    total_pairs: u64,
    /// Worker count of a parallel sweep. `0` marks a serial sweep:
    /// rects cached in `TileBins::rects` with counts accumulated in
    /// `offsets[t + 1]`, rather than in the per-worker scratch.
    workers: usize,
}

/// Size the CSR tile grid for a `width x height` screen.
#[inline]
fn set_grid(bins: &mut TileBins, width: u32, height: u32) {
    bins.tiles_x = width.div_ceil(TILE);
    bins.tiles_y = height.div_ceil(TILE);
}

/// Serial count sweep over already-projected splats: per-tile overlap
/// counts accumulate in `offsets[t + 1]` (so the in-place scan in
/// [`finish_bins`] lands the exclusive offsets) and the rects are
/// cached for the scatter replay.
fn count_serial(splats: &[Splat2D], bins: &mut TileBins) -> CountSweep {
    let tiles = bins.tile_count();
    let (tiles_x, tiles_y) = (bins.tiles_x, bins.tiles_y);
    bins.offsets.clear();
    bins.offsets.resize(tiles + 1, 0);
    bins.rects.clear();
    let mut total_pairs = 0u64;
    for (i, s) in splats.iter().enumerate() {
        let Some(rect) = tile_rect(s, tiles_x, tiles_y) else {
            continue;
        };
        bins.rects.push((i as u32, rect));
        total_pairs += (rect.x1 - rect.x0 + 1) as u64 * (rect.y1 - rect.y0 + 1) as u64;
        let offsets = &mut bins.offsets;
        for_each_covered_tile(rect, tiles_x, |t| offsets[t + 1] += 1);
    }
    CountSweep { total_pairs, workers: 0 }
}

/// Turn a finished count sweep into the CSR arrays: overflow check,
/// exclusive prefix-sum (merging the per-worker histograms when the
/// sweep was parallel), then the ordered scatter replay of the cached
/// rects. Shared verbatim by the split and fused front ends, so their
/// CSR output can never diverge. `Err` leaves `bins`
/// unspecified-but-safe (see [`TilingError`]).
fn finish_bins(
    bins: &mut TileBins,
    sweep: CountSweep,
    n_splats: usize,
) -> Result<(), TilingError> {
    if sweep.total_pairs > u32::MAX as u64 {
        return Err(TilingError::PairOverflow { pairs: sweep.total_pairs });
    }
    let tiles = bins.tile_count();
    let tiles_x = bins.tiles_x;

    if sweep.workers == 0 {
        // Prefix sum: offsets[t + 1] becomes the end of tile t's slice.
        let mut acc = 0u32;
        for o in bins.offsets.iter_mut() {
            acc += *o;
            *o = acc;
        }
        bins.pairs = bins.offsets[tiles] as u64;

        // Scatter pass: replay the cached rects through per-tile
        // cursors. Splats are replayed in ascending index order, so
        // each tile's slice comes out ascending — exactly the
        // nested-Vec push order.
        bins.indices.clear();
        bins.indices.resize(bins.pairs as usize, 0);
        bins.cursor.clear();
        bins.cursor.extend_from_slice(&bins.offsets[..tiles]);
        let TileBins { ref rects, ref mut cursor, ref mut indices, .. } = *bins;
        for &(i, rect) in rects {
            for_each_covered_tile(rect, tiles_x, |t| {
                indices[cursor[t] as usize] = i;
                cursor[t] += 1;
            });
        }
    } else {
        let workers = sweep.workers;
        // Merge pass: one exclusive prefix-sum over (tile, worker)
        // lands the CSR offset table and, inside each tile's slice,
        // every worker's private write cursor (rewriting the histograms
        // in place).
        bins.offsets.clear();
        bins.offsets.resize(tiles + 1, 0);
        let mut acc = 0u32;
        for t in 0..tiles {
            bins.offsets[t] = acc;
            for counts in bins.worker_counts[..workers].iter_mut() {
                let c = counts[t];
                counts[t] = acc;
                acc += c;
            }
        }
        bins.offsets[tiles] = acc;
        bins.pairs = acc as u64;
        debug_assert_eq!(bins.pairs, sweep.total_pairs);

        // Scatter pass: every worker replays its cached rects through
        // its own per-tile cursors into disjoint `indices` slots. Bare
        // resize (no clear): the cursor ranges tile 0..pairs exactly,
        // so every retained slot is overwritten.
        bins.indices.resize(bins.pairs as usize, 0);
        let shared = SharedIndices { ptr: bins.indices.as_mut_ptr() };
        std::thread::scope(|s| {
            for (rects, cursors) in bins.worker_rects[..workers]
                .iter()
                .zip(bins.worker_counts[..workers].iter_mut())
            {
                s.spawn(move || {
                    for &(i, rect) in rects.iter() {
                        for_each_covered_tile(rect, tiles_x, |t| {
                            // SAFETY: the merge pass gave each
                            // (worker, tile) pair a disjoint cursor
                            // range inside `indices`, every worker only
                            // advances its own cursors, and `indices`
                            // outlives the scope — so no two writes
                            // alias.
                            unsafe {
                                *shared.ptr.add(cursors[t] as usize) = i;
                            }
                            cursors[t] += 1;
                        });
                    }
                });
            }
        });
    }
    debug_validate(bins, n_splats)
}

/// Bin into a reusable [`TileBins`]: after the first frame warms the
/// buffers up, rebinning allocates nothing. Three passes over flat
/// arrays: count per-tile overlaps, exclusive prefix-sum into the offset
/// table, scatter the splat indices through per-tile cursors. `Err`
/// leaves `bins` unspecified-but-safe (see [`TilingError`]).
pub fn bin_splats_into(
    splats: &[Splat2D],
    width: u32,
    height: u32,
    bins: &mut TileBins,
) -> Result<(), TilingError> {
    set_grid(bins, width, height);
    let sweep = count_serial(splats, bins);
    finish_bins(bins, sweep, splats.len())
}

/// Below this many splats the per-worker histogram merge costs more than
/// the serial three-pass build, so the threaded path falls back.
const PAR_BIN_MIN: usize = 1024;

/// Minimum splats per worker chunk: on wide machines a small frame
/// otherwise fans out into near-empty workers whose spawn + histogram
/// cost exceeds their work (fewer, larger chunks — never different
/// output).
const PAR_BIN_CHUNK: usize = 256;

/// Shared base pointer into the CSR `indices` buffer for scoped workers
/// that write/sort provably disjoint slots (the parallel scatter here
/// and the parallel tile sorter in `splat::sort`). Every use site must
/// carry its own SAFETY argument for disjointness.
#[derive(Clone, Copy)]
pub(crate) struct SharedIndices {
    pub(crate) ptr: *mut u32,
}

unsafe impl Send for SharedIndices {}
unsafe impl Sync for SharedIndices {}

/// Multi-threaded [`bin_splats_into`]: scoped workers build per-thread
/// tile-count histograms over contiguous splat chunks, one serial
/// prefix-sum merges them into the CSR offset table *and* per-worker
/// write cursors, then the workers scatter their cached rects into
/// disjoint `indices` slots. Workers own ascending splat-index ranges
/// and the merge orders their sub-slices worker-after-worker inside each
/// tile, so every tile slice comes out in ascending splat order — the
/// CSR arrays are byte-identical to the serial build at any thread
/// count. `Err` leaves `bins` unspecified-but-safe (see
/// [`TilingError`]).
pub fn bin_splats_into_threaded(
    splats: &[Splat2D],
    width: u32,
    height: u32,
    bins: &mut TileBins,
    threads: usize,
) -> Result<(), TilingError> {
    let n = splats.len();
    if threads <= 1 || n < PAR_BIN_MIN {
        return bin_splats_into(splats, width, height, bins);
    }
    set_grid(bins, width, height);
    let sweep = count_threaded(splats, bins, threads);
    finish_bins(bins, sweep, n)
}

/// Grow the per-worker scratch vectors to hold `workers` entries
/// (never shrinks — stale tails are ignored via `[..workers]` slices).
fn grow_worker_scratch(bins: &mut TileBins, workers: usize) {
    if bins.worker_rects.len() < workers {
        bins.worker_rects.resize_with(workers, Vec::new);
    }
    if bins.worker_counts.len() < workers {
        bins.worker_counts.resize_with(workers, Vec::new);
    }
}

/// Parallel count sweep over already-projected splats: scoped workers
/// build per-thread tile-count histograms plus cached rects over
/// disjoint contiguous splat chunks (chunk w holds splat indices
/// `w * chunk ..`, so worker order == ascending splat order).
fn count_threaded(splats: &[Splat2D], bins: &mut TileBins, threads: usize) -> CountSweep {
    let n = splats.len();
    let tiles = bins.tile_count();
    let (tiles_x, tiles_y) = (bins.tiles_x, bins.tiles_y);
    let chunk = n.div_ceil(threads).max(PAR_BIN_CHUNK);
    let workers = n.div_ceil(chunk);
    grow_worker_scratch(bins, workers);
    let total_pairs: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = splats
            .chunks(chunk)
            .zip(bins.worker_rects.iter_mut().zip(bins.worker_counts.iter_mut()))
            .enumerate()
            .map(|(w, (chunk_splats, (rects, counts)))| {
                let base = (w * chunk) as u32;
                s.spawn(move || {
                    rects.clear();
                    counts.clear();
                    counts.resize(tiles, 0);
                    let mut pairs = 0u64;
                    for (j, sp) in chunk_splats.iter().enumerate() {
                        let Some(rect) = tile_rect(sp, tiles_x, tiles_y) else {
                            continue;
                        };
                        rects.push((base + j as u32, rect));
                        pairs += (rect.x1 - rect.x0 + 1) as u64
                            * (rect.y1 - rect.y0 + 1) as u64;
                        for_each_covered_tile(rect, tiles_x, |t| {
                            counts[t] += 1;
                        });
                    }
                    pairs
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bin count worker panicked"))
            .sum()
    });
    CountSweep { total_pairs, workers }
}

/// Below this many Gaussians the fused sweep runs serially. Mirrors the
/// split paths' thresholds — output is byte-identical either way, this
/// is purely a thread-spawn-cost cutoff.
const PAR_FUSED_MIN: usize = 1024;

/// Minimum Gaussians per fused worker chunk (same rationale as
/// [`PAR_BIN_CHUNK`]).
const PAR_FUSED_CHUNK: usize = 256;

/// In-flight fused front-end sweep: returned by [`project_bin_sweep`],
/// consumed by [`project_bin_finish`]. Splitting the sweep from the
/// finish lets callers time them as the projection and binning stages
/// respectively.
#[must_use = "pass to project_bin_finish to build the CSR arrays"]
#[derive(Debug)]
pub struct FusedSweep {
    counts: CountSweep,
    n_splats: usize,
}

/// Fused projection + tile-count sweep (ROADMAP item 3): ONE pass over
/// the rendering queue both projects every Gaussian into `splats` and
/// accumulates the per-tile overlap counts the CSR build needs — where
/// the split front end
/// ([`project_into_threaded`](crate::gaussian::project_into_threaded)
/// then [`bin_splats_into_threaded`]) makes two full passes, the second
/// re-reading every projected splat from memory. Each worker projects a
/// disjoint contiguous chunk and bins each splat inline while it is
/// still in registers, halving front-end memory traffic.
///
/// The grid is sized from `cam.intr.width/height`. The prefix-sum merge
/// and ordered scatter are shared verbatim with the split path (see
/// [`project_bin_finish`]), so both the projected splats and the CSR
/// arrays are byte-identical to the split front end at any thread
/// count.
pub fn project_bin_sweep(
    queue: &Gaussians,
    cam: &Camera,
    splats: &mut Vec<Splat2D>,
    bins: &mut TileBins,
    threads: usize,
) -> FusedSweep {
    let n = queue.len();
    set_grid(bins, cam.intr.width, cam.intr.height);
    let tiles = bins.tile_count();
    let (tiles_x, tiles_y) = (bins.tiles_x, bins.tiles_y);

    if threads <= 1 || n < PAR_FUSED_MIN {
        // Serial fused sweep: project and count in one loop, leaving
        // the same state `count_serial` would (counts in
        // `offsets[t + 1]`, rects cached for the scatter replay).
        splats.clear();
        splats.reserve(n);
        bins.offsets.clear();
        bins.offsets.resize(tiles + 1, 0);
        bins.rects.clear();
        let mut total_pairs = 0u64;
        for i in 0..n {
            let sp = project_one(queue, i, cam);
            if let Some(rect) = tile_rect(&sp, tiles_x, tiles_y) {
                bins.rects.push((i as u32, rect));
                total_pairs +=
                    (rect.x1 - rect.x0 + 1) as u64 * (rect.y1 - rect.y0 + 1) as u64;
                let offsets = &mut bins.offsets;
                for_each_covered_tile(rect, tiles_x, |t| offsets[t + 1] += 1);
            }
            splats.push(sp);
        }
        return FusedSweep {
            counts: CountSweep { total_pairs, workers: 0 },
            n_splats: n,
        };
    }

    // Parallel fused sweep: the same disjoint contiguous chunks and
    // per-worker scratch as `count_threaded`, but each worker projects
    // its `splats` slice itself and bins each splat straight out of the
    // projection. Bare resize (no clear): every slot in 0..n is
    // overwritten by exactly one worker below.
    splats.resize(n, Splat2D::default());
    let chunk = n.div_ceil(threads).max(PAR_FUSED_CHUNK);
    let workers = n.div_ceil(chunk);
    grow_worker_scratch(bins, workers);
    let total_pairs: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = splats
            .chunks_mut(chunk)
            .zip(bins.worker_rects.iter_mut().zip(bins.worker_counts.iter_mut()))
            .enumerate()
            .map(|(w, (slots, (rects, counts)))| {
                let base = w * chunk;
                s.spawn(move || {
                    rects.clear();
                    counts.clear();
                    counts.resize(tiles, 0);
                    let mut pairs = 0u64;
                    for (j, slot) in slots.iter_mut().enumerate() {
                        let sp = project_one(queue, base + j, cam);
                        if let Some(rect) = tile_rect(&sp, tiles_x, tiles_y) {
                            rects.push(((base + j) as u32, rect));
                            pairs += (rect.x1 - rect.x0 + 1) as u64
                                * (rect.y1 - rect.y0 + 1) as u64;
                            for_each_covered_tile(rect, tiles_x, |t| {
                                counts[t] += 1;
                            });
                        }
                        *slot = sp;
                    }
                    pairs
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("fused front-end worker panicked"))
            .sum()
    });
    FusedSweep { counts: CountSweep { total_pairs, workers }, n_splats: n }
}

/// Build the CSR arrays from a finished [`project_bin_sweep`] — the
/// exact merge + scatter code the split binning paths run, so the
/// output is byte-identical to theirs. `Err` leaves `bins`
/// unspecified-but-safe (see [`TilingError`]).
pub fn project_bin_finish(
    bins: &mut TileBins,
    sweep: FusedSweep,
) -> Result<(), TilingError> {
    finish_bins(bins, sweep.counts, sweep.n_splats)
}

/// One-call fused front end ([`project_bin_sweep`] +
/// [`project_bin_finish`]) for callers that don't split stage timing.
pub fn project_bin_fused(
    queue: &Gaussians,
    cam: &Camera,
    splats: &mut Vec<Splat2D>,
    bins: &mut TileBins,
    threads: usize,
) -> Result<(), TilingError> {
    let sweep = project_bin_sweep(queue, cam, splats, bins, threads);
    project_bin_finish(bins, sweep)
}

/// Reference nested-Vec binning (the pre-CSR implementation), kept for
/// equivalence testing: returns per-tile index lists and the pair count.
pub fn bin_splats_nested(
    splats: &[Splat2D],
    width: u32,
    height: u32,
) -> (Vec<Vec<u32>>, u64) {
    let tiles_x = width.div_ceil(TILE);
    let tiles_y = height.div_ceil(TILE);
    let mut per_tile = vec![Vec::new(); (tiles_x * tiles_y) as usize];
    let mut pairs = 0u64;
    for (i, s) in splats.iter().enumerate() {
        let Some(rect) = tile_rect(s, tiles_x, tiles_y) else {
            continue;
        };
        for_each_covered_tile(rect, tiles_x, |t| {
            per_tile[t].push(i as u32);
            pairs += 1;
        });
    }
    (per_tile, pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;
    use crate::util::Rng;

    fn splat_at(x: f32, y: f32, r: f32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(x, y),
            conic: [0.1, 0.0, 0.1],
            depth: 1.0,
            radius: r,
            color: [1.0, 1.0, 1.0],
            opacity: 0.5,
            id: 0,
            ..Splat2D::default()
        }
        .with_keep_thresh()
    }

    #[test]
    fn small_splat_hits_one_tile() {
        let bins = bin_splats(&[splat_at(8.0, 8.0, 3.0)], 64, 64);
        assert_eq!(bins.tiles_x, 4);
        assert_eq!(bins.pairs, 1);
        assert_eq!(bins.tile(0), &[0]);
    }

    #[test]
    fn large_splat_hits_many_tiles() {
        let bins = bin_splats(&[splat_at(32.0, 32.0, 20.0)], 64, 64);
        // Covers tiles 0..=3 in both axes partially: (12..52) -> tiles 0..3.
        assert_eq!(bins.pairs, 16);
    }

    #[test]
    fn culled_and_offscreen_generate_no_pairs() {
        let culled = splat_at(8.0, 8.0, 0.0);
        let offscreen = splat_at(-100.0, -100.0, 5.0);
        let bins = bin_splats(&[culled, offscreen], 64, 64);
        assert_eq!(bins.pairs, 0);
        assert!(bins.indices.is_empty());
    }

    #[test]
    fn edge_splat_is_clamped() {
        let bins = bin_splats(&[splat_at(63.0, 63.0, 10.0)], 64, 64);
        assert!(bins.pairs > 0);
        // Bottom-right tile must contain it.
        assert!(bins.tile(15).contains(&0));
    }

    #[test]
    fn non_multiple_screen_sizes() {
        let bins = bin_splats(&[splat_at(70.0, 5.0, 4.0)], 72, 40);
        assert_eq!(bins.tiles_x, 5);
        assert_eq!(bins.tiles_y, 3);
        assert!(bins.tile(4).contains(&0));
    }

    #[test]
    fn offsets_are_a_valid_csr_table() {
        let splats: Vec<Splat2D> = (0..64)
            .map(|i| splat_at(3.0 * i as f32, 2.0 * i as f32, 5.0))
            .collect();
        let bins = bin_splats(&splats, 128, 96);
        assert_eq!(bins.offsets.len(), bins.tile_count() + 1);
        assert_eq!(bins.offsets[0], 0);
        assert!(bins.offsets.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(bins.offsets[bins.tile_count()] as u64, bins.pairs);
        assert_eq!(bins.indices.len() as u64, bins.pairs);
    }

    fn random_splats(rng: &mut Rng, n: usize, w: f32, h: f32) -> Vec<Splat2D> {
        (0..n)
            .map(|i| {
                // Include off-screen and culled splats on purpose.
                let r = if rng.below(8) == 0 { 0.0 } else { rng.range(0.5, 40.0) };
                let mut s = splat_at(
                    rng.range(-60.0, w + 60.0),
                    rng.range(-60.0, h + 60.0),
                    r,
                );
                s.id = i as u32;
                s
            })
            .collect()
    }

    #[test]
    fn csr_matches_nested_reference() {
        let mut rng = Rng::new(0xC5A0_71E5);
        for case in 0..24 {
            let n = 1 + rng.below(400);
            let (w, h) = ([64u32, 72, 256][rng.below(3)], [64u32, 40, 256][rng.below(3)]);
            let splats = random_splats(&mut rng, n, w as f32, h as f32);
            let bins = bin_splats(&splats, w, h);
            let (nested, pairs) = bin_splats_nested(&splats, w, h);
            assert_eq!(bins.pairs, pairs, "case {case}: pair count");
            assert_eq!(bins.tile_count(), nested.len(), "case {case}: tile count");
            for t in 0..nested.len() {
                assert_eq!(bins.tile(t), nested[t].as_slice(), "case {case}: tile {t}");
            }
        }
    }

    #[test]
    fn threaded_bins_are_byte_identical_to_serial() {
        let mut rng = Rng::new(0x7EAD_B1A5);
        for &threads in &[2usize, 3, 8] {
            for case in 0..4 {
                // Above PAR_BIN_MIN so the scoped workers really run.
                let n = 1_100 + rng.below(1_500);
                let splats = random_splats(&mut rng, n, 256.0, 256.0);
                let serial = bin_splats(&splats, 256, 256);
                let mut par = TileBins::default();
                bin_splats_into_threaded(&splats, 256, 256, &mut par, threads).unwrap();
                par.validate_csr(splats.len()).unwrap();
                assert_eq!(par.offsets, serial.offsets, "case {case}/{threads}");
                assert_eq!(par.indices, serial.indices, "case {case}/{threads}");
                assert_eq!(par.pairs, serial.pairs, "case {case}/{threads}");
            }
        }
    }

    #[test]
    fn threaded_bins_reuse_is_byte_identical() {
        // One reused TileBins across frames of varying size and thread
        // count must never read stale worker scratch.
        let mut rng = Rng::new(0xD0_5E11);
        let mut reused = TileBins::default();
        for (i, &threads) in [8usize, 2, 5, 1, 8].iter().enumerate() {
            let n = 1_050 + rng.below(2_000);
            let splats = random_splats(&mut rng, n, 192.0, 160.0);
            bin_splats_into_threaded(&splats, 192, 160, &mut reused, threads).unwrap();
            let fresh = bin_splats(&splats, 192, 160);
            assert_eq!(reused.offsets, fresh.offsets, "frame {i}");
            assert_eq!(reused.indices, fresh.indices, "frame {i}");
            assert_eq!(reused.pairs, fresh.pairs, "frame {i}");
        }
    }

    #[test]
    fn degenerate_all_splats_in_one_tile() {
        // Every splat lands in exactly tile 0 — the pathological
        // imbalance case for the per-worker histogram merge.
        let splats: Vec<Splat2D> = (0..1_500)
            .map(|i| {
                let mut s = splat_at(8.0, 8.0, 2.0);
                s.id = i as u32;
                s
            })
            .collect();
        for threads in [1usize, 8] {
            let mut bins = TileBins::default();
            bin_splats_into_threaded(&splats, 64, 64, &mut bins, threads).unwrap();
            bins.validate_csr(splats.len()).unwrap();
            assert_eq!(bins.pairs, splats.len() as u64);
            assert_eq!(bins.tile_len(0), splats.len());
            for t in 1..bins.tile_count() {
                assert_eq!(bins.tile_len(t), 0, "tile {t} not empty");
            }
            let want: Vec<u32> = (0..splats.len() as u32).collect();
            assert_eq!(bins.tile(0), want.as_slice());
        }
    }

    #[test]
    fn degenerate_zero_visible_splat_frame() {
        // All splats culled: zero pairs, all-zero offsets, empty CSR.
        let splats: Vec<Splat2D> =
            (0..1_200).map(|_| splat_at(8.0, 8.0, 0.0)).collect();
        for threads in [1usize, 8] {
            let mut bins = TileBins::default();
            bin_splats_into_threaded(&splats, 64, 64, &mut bins, threads).unwrap();
            bins.validate_csr(splats.len()).unwrap();
            assert_eq!(bins.pairs, 0);
            assert!(bins.indices.is_empty());
            assert!(bins.offsets.iter().all(|&o| o == 0));
        }
        // And the fully empty frame (no splats at all).
        let empty: Vec<Splat2D> = Vec::new();
        let bins = bin_splats(&empty, 64, 64);
        bins.validate_csr(0).unwrap();
        assert_eq!(bins.pairs, 0);
    }

    #[test]
    fn degenerate_zero_size_image() {
        // A zero-dimension image yields an empty tile grid; the old
        // `tiles_x - 1` clamp underflowed to u32::MAX here. Every grid
        // shape must produce a valid, empty CSR instead.
        let splats = vec![splat_at(8.0, 8.0, 3.0)];
        for &(w, h) in &[(0u32, 0u32), (0, 64), (64, 0)] {
            let bins = bin_splats(&splats, w, h);
            bins.validate_csr(splats.len()).unwrap();
            assert_eq!(bins.pairs, 0, "{w}x{h}");
            assert!(bins.indices.is_empty(), "{w}x{h}");
        }
        // The threaded path (real workers) must agree.
        let many: Vec<Splat2D> = (0..1_200).map(|_| splat_at(8.0, 8.0, 3.0)).collect();
        let mut bins = TileBins::default();
        bin_splats_into_threaded(&many, 0, 64, &mut bins, 8).unwrap();
        bins.validate_csr(many.len()).unwrap();
        assert_eq!(bins.pairs, 0);
    }

    #[test]
    fn non_finite_splats_are_rejected_at_the_rect_stage() {
        // A NaN mean with positive radius used to fall through the
        // `floor().max(0.0)` clamps into tile (0, 0). None of these may
        // generate a single pair.
        let mut nan_x = splat_at(8.0, 8.0, 3.0);
        nan_x.mean.x = f32::NAN;
        let mut nan_y = splat_at(8.0, 8.0, 3.0);
        nan_y.mean.y = f32::NAN;
        let mut inf_mean = splat_at(8.0, 8.0, 3.0);
        inf_mean.mean.x = f32::INFINITY;
        let mut inf_radius = splat_at(8.0, 8.0, 3.0);
        inf_radius.radius = f32::INFINITY;
        let mut neg_inf = splat_at(8.0, 8.0, 3.0);
        neg_inf.mean.y = f32::NEG_INFINITY;
        let splats = vec![nan_x, nan_y, inf_mean, inf_radius, neg_inf];
        let bins = bin_splats(&splats, 64, 64);
        bins.validate_csr(splats.len()).unwrap();
        assert_eq!(bins.pairs, 0);
        assert!(bins.indices.is_empty());
        // A finite splat alongside them still bins normally.
        let mut with_good = splats.clone();
        with_good.push(splat_at(8.0, 8.0, 3.0));
        let bins = bin_splats(&with_good, 64, 64);
        assert_eq!(bins.pairs, 1);
        assert_eq!(bins.tile(0), &[5]);
    }

    #[test]
    fn fused_sweep_matches_split_front_end_shapes() {
        // Pure-tiling check that the fused convenience wrapper produces
        // the same CSR as projecting-then-binning; the renderer test
        // covers the real scene path. Here: synthesize a queue whose
        // projection is deterministic and compare both pipelines.
        use crate::math::{Intrinsics, Quat, Vec3};
        let mut queue = Gaussians::default();
        let mut rng = Rng::new(0xF0_5ED);
        for _ in 0..1_400 {
            queue.push(
                Vec3::new(rng.range(-3.0, 3.0), rng.range(-3.0, 3.0), rng.range(2.0, 9.0)),
                Vec3::splat(rng.range(0.01, 0.2)),
                Quat::IDENTITY,
                [0.5, 0.5, 0.5],
                rng.range(0.05, 0.9),
            );
        }
        let cam = Camera::look_at(
            Vec3::new(0.0, 0.0, -1.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.0, 1.0, 0.0),
            Intrinsics::from_fov(128, 128, 90f32.to_radians()),
        );
        let split_splats = crate::gaussian::project(&queue, &cam);
        let split_bins = bin_splats(&split_splats, cam.intr.width, cam.intr.height);
        for threads in [1usize, 2, 8] {
            let mut splats = Vec::new();
            let mut bins = TileBins::default();
            project_bin_fused(&queue, &cam, &mut splats, &mut bins, threads).unwrap();
            bins.validate_csr(splats.len()).unwrap();
            assert_eq!(splats.len(), split_splats.len(), "threads {threads}");
            for (a, b) in splats.iter().zip(&split_splats) {
                assert_eq!(a.bit_pattern(), b.bit_pattern(), "threads {threads}");
            }
            assert_eq!(bins.offsets, split_bins.offsets, "threads {threads}");
            assert_eq!(bins.indices, split_bins.indices, "threads {threads}");
            assert_eq!(bins.pairs, split_bins.pairs, "threads {threads}");
        }
    }

    #[test]
    fn tiling_error_formats_both_variants() {
        let overflow = TilingError::PairOverflow { pairs: u32::MAX as u64 + 1 };
        assert!(overflow.to_string().contains("4294967296"));
        assert!(overflow.to_string().contains("overflows"));
        let csr = TilingError::CsrInvariant("offsets[0] == 3 != 0".into());
        assert!(csr.to_string().contains("CSR invariant violated"));
        assert!(csr.to_string().contains("offsets[0]"));
        // The error is a std error, so it threads through anyhow.
        let boxed: Box<dyn std::error::Error> = Box::new(overflow);
        assert!(boxed.to_string().contains("overflows"));
    }

    #[test]
    fn validate_csr_rejects_corruption() {
        let splats = vec![splat_at(8.0, 8.0, 3.0)];
        let mut bins = bin_splats(&splats, 64, 64);
        bins.validate_csr(1).unwrap();
        bins.indices[0] = 7; // splat index out of bounds
        assert!(bins.validate_csr(1).is_err());
        let mut bad = bin_splats(&splats, 64, 64);
        bad.offsets[3] = 99; // breaks monotonicity
        assert!(bad.validate_csr(1).is_err());
        let mut short = bin_splats(&splats, 64, 64);
        short.offsets.pop(); // breaks the offset-table shape
        assert!(short.validate_csr(1).is_err());
    }

    #[test]
    fn batch_work_item_tau_roundtrip() {
        let plain = BatchWorkItem::new(3, 41);
        assert_eq!(plain.view, 3);
        assert_eq!(plain.tile, 41);
        assert_eq!(plain.tau(), None);
        let fov = BatchWorkItem::with_tau(1, 7, 24.0);
        assert_eq!(fov.tau(), Some(24.0));
        assert_ne!(plain, BatchWorkItem::new(3, 40));
        // 0.0 is a representable (if silly) override, distinct from
        // the "no override" sentinel.
        assert_eq!(BatchWorkItem::with_tau(0, 0, 0.0).tau(), Some(0.0));
    }

    #[test]
    fn reused_bins_match_fresh_bins() {
        let mut rng = Rng::new(0xBEEF);
        let mut reused = TileBins::default();
        for _ in 0..8 {
            let n = 1 + rng.below(200);
            let splats = random_splats(&mut rng, n, 256.0, 256.0);
            bin_splats_into(&splats, 256, 256, &mut reused).unwrap();
            let fresh = bin_splats(&splats, 256, 256);
            assert_eq!(reused.offsets, fresh.offsets);
            assert_eq!(reused.indices, fresh.indices);
            assert_eq!(reused.pairs, fresh.pairs);
        }
    }
}
