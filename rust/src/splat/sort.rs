//! Per-tile depth ordering (the "sorting unit" stage).
//!
//! Front-to-back compositing requires each tile's Gaussian list sorted
//! by camera depth. Ties break on splat id so results are deterministic
//! across runs and platforms (floats compare totally here because
//! projection never emits NaN depths for visible splats).

use crate::gaussian::Splat2D;

/// Sort one tile's splat indices front-to-back (ascending depth).
pub fn sort_tile_by_depth(indices: &mut [u32], splats: &[Splat2D]) {
    indices.sort_unstable_by(|&a, &b| {
        let da = splats[a as usize].depth;
        let db = splats[b as usize].depth;
        da.partial_cmp(&db)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cmp(&b))
    });
}

/// Comparator-network cost model used by the sorting-unit simulators:
/// a bitonic network over n elements does ~n log^2 n / 4 compare-exchange
/// ops; hardware sorters process `elems_per_cycle` of those per cycle.
pub fn bitonic_compare_ops(n: u64) -> u64 {
    if n <= 1 {
        return 0;
    }
    let logn = 64 - (n - 1).leading_zeros() as u64; // ceil(log2 n)
    n * logn * (logn + 1) / 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;

    fn splat(depth: f32, id: u32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(0.0, 0.0),
            conic: [0.1, 0.0, 0.1],
            depth,
            radius: 1.0,
            color: [0.0; 3],
            opacity: 0.5,
            id,
        }
    }

    #[test]
    fn sorts_front_to_back() {
        let splats = vec![splat(3.0, 0), splat(1.0, 1), splat(2.0, 2)];
        let mut idx = vec![0u32, 1, 2];
        sort_tile_by_depth(&mut idx, &splats);
        assert_eq!(idx, vec![1, 2, 0]);
    }

    #[test]
    fn ties_break_on_id_deterministically() {
        let splats = vec![splat(1.0, 0), splat(1.0, 1), splat(1.0, 2)];
        let mut idx = vec![2u32, 0, 1];
        sort_tile_by_depth(&mut idx, &splats);
        assert_eq!(idx, vec![0, 1, 2]);
    }

    #[test]
    fn bitonic_cost_grows_superlinearly() {
        assert_eq!(bitonic_compare_ops(0), 0);
        assert_eq!(bitonic_compare_ops(1), 0);
        let c64 = bitonic_compare_ops(64);
        let c128 = bitonic_compare_ops(128);
        assert!(c128 > 2 * c64);
        // n log^2 n / 4 for n=64: 64*6*7/4 = 672.
        assert_eq!(c64, 672);
    }
}
