//! Per-tile depth ordering (the "sorting unit" stage).
//!
//! Front-to-back compositing requires each tile's Gaussian list sorted
//! by camera depth. Ties break on splat id so results are deterministic
//! across runs and platforms (floats compare totally here because
//! projection never emits NaN depths for visible splats).
//!
//! Two implementations:
//!
//! * [`sort_tile_by_depth`] — the reference comparison sort (kept as
//!   ground truth; the radix path is asserted identical against it).
//! * [`radix_sort_tile`] / [`sort_bins_with`] — the production path: an
//!   LSD radix sort over 64-bit `(sortable-depth, splat-id)` keys that
//!   works directly inside the CSR bin slices with reusable key buffers,
//!   so a whole frame's worth of tile sorts allocates nothing in steady
//!   state. The key layout makes the id tie-break fall out of the
//!   numeric order for free, exactly matching the comparison sort.
//!
//! [`sort_bins_threaded`] runs the production sorter over all tiles with
//! scoped workers on a dynamic atomic cursor (the blend scheduler's
//! dequeue shape), byte-identical to the serial pass at any width.

use super::tiling::TileBins;
use crate::gaussian::Splat2D;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Sort one tile's splat indices front-to-back (ascending depth).
pub fn sort_tile_by_depth(indices: &mut [u32], splats: &[Splat2D]) {
    indices.sort_unstable_by(|&a, &b| {
        let da = splats[a as usize].depth;
        let db = splats[b as usize].depth;
        da.partial_cmp(&db)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cmp(&b))
    });
}

/// Map a float to a `u32` whose unsigned order equals the float's
/// numeric order (the classic sign-flip trick radix sorters use):
/// negative floats get all bits inverted, non-negative floats get the
/// sign bit set.
#[inline]
pub fn float_to_sortable_uint(f: f32) -> u32 {
    let v = f.to_bits();
    if v & 0x8000_0000 != 0 {
        !v
    } else {
        v | 0x8000_0000
    }
}

/// 64-bit radix key: sortable depth in the high half, splat index in the
/// low half — ascending key order is exactly (depth asc, id asc).
/// `-0.0` is canonicalised to `+0.0` so the key order agrees with the
/// comparison sort's `partial_cmp` (which treats them as equal and falls
/// through to the id tie-break).
#[inline]
fn depth_key(depth: f32, idx: u32) -> u64 {
    let depth = if depth == 0.0 { 0.0 } else { depth };
    ((float_to_sortable_uint(depth) as u64) << 32) | idx as u64
}

/// Reusable buffers for the radix tile sorter. One instance serves any
/// number of tiles/frames; buffers grow to the largest tile seen.
#[derive(Clone, Debug, Default)]
pub struct DepthSortScratch {
    keys: Vec<u64>,
    tmp: Vec<u64>,
}

impl DepthSortScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Below this many elements a binary-insertion-style pass beats the
/// 256-bucket histogram setup cost of a radix pass.
const RADIX_CUTOFF: usize = 48;

fn insertion_sort_keys(keys: &mut [u64]) {
    for i in 1..keys.len() {
        let k = keys[i];
        let mut j = i;
        while j > 0 && keys[j - 1] > k {
            keys[j] = keys[j - 1];
            j -= 1;
        }
        keys[j] = k;
    }
}

/// The production LSD radix sort (8-bit digits) over `keys`, using
/// `tmp` as the ping-pong buffer, with the count pass **fused into the
/// scatter** (the same fusion shape as `splat::project_bin_sweep`):
/// only digit 0's histogram is gathered up front (one increment per
/// key instead of the split path's eight), and every scatter pass
/// counts the *next* digit's histogram on the keys it is already
/// moving through registers. A digit position where every key shares
/// the same byte still skips its scatter — in practice a tile's depth
/// keys share high bytes, so most passes vanish — and gathers the next
/// histogram in a read-only sweep instead. Histogram contents are
/// permutation-invariant, so every pass sees byte-for-byte the
/// counts/cursors the split path computes and the output is identical
/// ([`radix_sort_keys_split`] stays as the proptested equivalence
/// reference).
fn radix_sort_keys(keys: &mut [u64], tmp: &mut Vec<u64>) {
    let n = keys.len();
    if n <= 1 {
        return;
    }
    if n < RADIX_CUTOFF {
        insertion_sort_keys(keys);
        return;
    }
    tmp.clear();
    tmp.resize(n, 0);
    let mut hist = [0u32; 256];
    for &k in keys.iter() {
        hist[(k & 0xFF) as usize] += 1;
    }
    let mut in_keys = true; // does `keys` currently hold the data?
    for b in 0..8usize {
        let shift = b * 8;
        let probe = if in_keys { keys[0] } else { tmp[0] };
        let mut next = [0u32; 256];
        if hist[((probe >> shift) & 0xFF) as usize] as usize == n {
            // Every key shares this byte: the scatter is a no-op, but
            // the next digit still needs its histogram (read-only
            // sweep; the final digit needs none).
            if b < 7 {
                let src: &[u64] = if in_keys { keys } else { tmp };
                for &k in src {
                    next[((k >> (shift + 8)) & 0xFF) as usize] += 1;
                }
                hist = next;
            }
            continue;
        }
        let mut cursors = [0u32; 256];
        let mut acc = 0u32;
        for (c, &count) in cursors.iter_mut().zip(hist.iter()) {
            *c = acc;
            acc += count;
        }
        let (src, dst): (&[u64], &mut [u64]) = if in_keys {
            (&keys[..], &mut tmp[..])
        } else {
            (&tmp[..], &mut keys[..])
        };
        if b < 7 {
            for &k in src {
                let d = ((k >> shift) & 0xFF) as usize;
                dst[cursors[d] as usize] = k;
                cursors[d] += 1;
                next[((k >> (shift + 8)) & 0xFF) as usize] += 1;
            }
            hist = next;
        } else {
            for &k in src {
                let d = ((k >> shift) & 0xFF) as usize;
                dst[cursors[d] as usize] = k;
                cursors[d] += 1;
            }
        }
        in_keys = !in_keys;
    }
    if !in_keys {
        keys.copy_from_slice(&tmp[..n]);
    }
}

/// The split-pass reference radix sort: histograms for all 8 digit
/// positions gathered in one pre-pass, then plain scatters. Kept (like
/// the split project/bin pair) as the equivalence reference the fused
/// production path is proptested against.
fn radix_sort_keys_split(keys: &mut [u64], tmp: &mut Vec<u64>) {
    let n = keys.len();
    if n <= 1 {
        return;
    }
    if n < RADIX_CUTOFF {
        insertion_sort_keys(keys);
        return;
    }
    let mut hist = [[0u32; 256]; 8];
    for &k in keys.iter() {
        for (b, h) in hist.iter_mut().enumerate() {
            h[((k >> (b * 8)) & 0xFF) as usize] += 1;
        }
    }
    tmp.clear();
    tmp.resize(n, 0);
    let mut in_keys = true; // does `keys` currently hold the data?
    for (b, h) in hist.iter().enumerate() {
        let shift = b * 8;
        let probe = if in_keys { keys[0] } else { tmp[0] };
        if h[((probe >> shift) & 0xFF) as usize] as usize == n {
            continue; // every key shares this byte: pass is a no-op
        }
        let mut cursors = [0u32; 256];
        let mut acc = 0u32;
        for (c, &count) in cursors.iter_mut().zip(h.iter()) {
            *c = acc;
            acc += count;
        }
        let (src, dst): (&[u64], &mut [u64]) = if in_keys {
            (&keys[..], &mut tmp[..])
        } else {
            (&tmp[..], &mut keys[..])
        };
        for &k in src {
            let d = ((k >> shift) & 0xFF) as usize;
            dst[cursors[d] as usize] = k;
            cursors[d] += 1;
        }
        in_keys = !in_keys;
    }
    if !in_keys {
        keys.copy_from_slice(&tmp[..n]);
    }
}

/// Radix-sort one tile's splat indices front-to-back in place (the
/// fused count+scatter production path). Produces bit-identical order
/// to [`sort_tile_by_depth`] for NaN-free depths (the only depths
/// projection emits), including the id tie-break.
pub fn radix_sort_tile(
    indices: &mut [u32],
    splats: &[Splat2D],
    scratch: &mut DepthSortScratch,
) {
    if indices.len() <= 1 {
        return;
    }
    scratch.keys.clear();
    scratch
        .keys
        .extend(indices.iter().map(|&i| depth_key(splats[i as usize].depth, i)));
    radix_sort_keys(&mut scratch.keys, &mut scratch.tmp);
    for (slot, &k) in indices.iter_mut().zip(scratch.keys.iter()) {
        *slot = k as u32;
    }
}

/// [`radix_sort_tile`] through the split-pass reference sorter
/// ([`radix_sort_keys_split`]) — the equivalence baseline for the
/// fused-radix property test; never on the production path.
pub fn radix_sort_tile_split(
    indices: &mut [u32],
    splats: &[Splat2D],
    scratch: &mut DepthSortScratch,
) {
    if indices.len() <= 1 {
        return;
    }
    scratch.keys.clear();
    scratch
        .keys
        .extend(indices.iter().map(|&i| depth_key(splats[i as usize].depth, i)));
    radix_sort_keys_split(&mut scratch.keys, &mut scratch.tmp);
    for (slot, &k) in indices.iter_mut().zip(scratch.keys.iter()) {
        *slot = k as u32;
    }
}

/// Depth-sort every CSR tile slice of `bins` in place, reusing one
/// scratch across all tiles (the zero-clone front-end sort path).
pub fn sort_bins_with(
    bins: &mut TileBins,
    splats: &[Splat2D],
    scratch: &mut DepthSortScratch,
) {
    for idx in 0..bins.tile_count() {
        radix_sort_tile(bins.tile_mut(idx), splats, scratch);
    }
}

/// Convenience wrapper over [`sort_bins_with`] with a throwaway scratch.
pub fn sort_bins_by_depth(bins: &mut TileBins, splats: &[Splat2D]) {
    let mut scratch = DepthSortScratch::new();
    sort_bins_with(bins, splats, &mut scratch);
}

/// Depth-sort every CSR tile slice in place with `threads` scoped
/// workers pulling tiles from a shared atomic cursor — the same
/// dynamic-greedy dequeue the blend-stage tile scheduler uses, applied
/// to the sorting stage (per-tile sort cost is just as imbalanced as
/// per-tile blend cost). Each worker owns one scratch from `pool`,
/// which grows to the worker count on first use and is reused frame to
/// frame. Tiles are independent and sorted in place inside disjoint CSR
/// slices, so the result is byte-identical to [`sort_bins_with`] at any
/// thread count.
pub fn sort_bins_threaded(
    bins: &mut TileBins,
    splats: &[Splat2D],
    pool: &mut Vec<DepthSortScratch>,
    threads: usize,
) {
    let tiles = bins.tile_count();
    if pool.is_empty() {
        pool.push(DepthSortScratch::new());
    }
    if threads <= 1 || tiles <= 1 || bins.pairs == 0 {
        sort_bins_with(bins, splats, &mut pool[0]);
        return;
    }
    // Bound the fan-out by the total sort workload too: spawning a
    // worker per tile for a near-empty frame costs more than sorting.
    let workers = threads.min(tiles).min(1 + bins.pairs as usize / 1024);
    if pool.len() < workers {
        pool.resize_with(workers, DepthSortScratch::default);
    }
    let offsets = &bins.offsets[..];
    let shared = super::tiling::SharedIndices { ptr: bins.indices.as_mut_ptr() };
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let cursor = &cursor;
        for scratch in pool[..workers].iter_mut() {
            s.spawn(move || loop {
                // Dynamic greedy dequeue: whoever finishes a tile first
                // grabs the next one, soaking up per-tile sort-cost
                // imbalance exactly like the blend scheduler.
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= tiles {
                    break;
                }
                let lo = offsets[t] as usize;
                let hi = offsets[t + 1] as usize;
                if hi <= lo + 1 {
                    continue;
                }
                // SAFETY: CSR tile slices are disjoint (offsets are
                // monotone), the cursor hands each tile index to
                // exactly one worker, and `indices` outlives the scope
                // — so no two workers ever touch the same slots.
                let tile = unsafe {
                    std::slice::from_raw_parts_mut(shared.ptr.add(lo), hi - lo)
                };
                radix_sort_tile(tile, splats, scratch);
            });
        }
    });
}

/// Comparator-network cost model used by the sorting-unit simulators:
/// a bitonic network over n elements does ~n log^2 n / 4 compare-exchange
/// ops; hardware sorters process `elems_per_cycle` of those per cycle.
pub fn bitonic_compare_ops(n: u64) -> u64 {
    if n <= 1 {
        return 0;
    }
    let logn = 64 - (n - 1).leading_zeros() as u64; // ceil(log2 n)
    n * logn * (logn + 1) / 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;
    use crate::util::Rng;

    fn splat(depth: f32, id: u32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(0.0, 0.0),
            conic: [0.1, 0.0, 0.1],
            depth,
            radius: 1.0,
            color: [0.0; 3],
            opacity: 0.5,
            id,
            ..Splat2D::default()
        }
        .with_keep_thresh()
    }

    #[test]
    fn sorts_front_to_back() {
        let splats = vec![splat(3.0, 0), splat(1.0, 1), splat(2.0, 2)];
        let mut idx = vec![0u32, 1, 2];
        sort_tile_by_depth(&mut idx, &splats);
        assert_eq!(idx, vec![1, 2, 0]);
        let mut ridx = vec![0u32, 1, 2];
        radix_sort_tile(&mut ridx, &splats, &mut DepthSortScratch::new());
        assert_eq!(ridx, idx);
    }

    #[test]
    fn ties_break_on_id_deterministically() {
        let splats = vec![splat(1.0, 0), splat(1.0, 1), splat(1.0, 2)];
        let mut idx = vec![2u32, 0, 1];
        sort_tile_by_depth(&mut idx, &splats);
        assert_eq!(idx, vec![0, 1, 2]);
        let mut ridx = vec![2u32, 0, 1];
        radix_sort_tile(&mut ridx, &splats, &mut DepthSortScratch::new());
        assert_eq!(ridx, vec![0, 1, 2]);
    }

    #[test]
    fn sortable_uint_preserves_float_order() {
        let xs = [
            f32::NEG_INFINITY,
            -1e30,
            -2.5,
            -0.0,
            0.0,
            1e-20,
            0.5,
            2.5,
            1e30,
            f32::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                float_to_sortable_uint(w[0]) <= float_to_sortable_uint(w[1]),
                "{} !<= {}",
                w[0],
                w[1]
            );
        }
        assert!(float_to_sortable_uint(-1.0) < float_to_sortable_uint(1.0));
    }

    #[test]
    fn radix_matches_reference_on_random_inputs() {
        let mut rng = Rng::new(0x5027_D47A);
        let mut scratch = DepthSortScratch::new();
        for case in 0..48 {
            // Mix of sizes straddling the insertion/radix cutoff, with
            // heavy depth duplication to stress the id tie-break.
            let n = 1 + rng.below(300);
            let splats: Vec<Splat2D> = (0..n)
                .map(|i| {
                    let d = if rng.below(3) == 0 {
                        [0.5f32, 1.0, 2.0, 1e9][rng.below(4)]
                    } else {
                        rng.range(0.2, 1e6)
                    };
                    splat(d, i as u32)
                })
                .collect();
            // A shuffled index multiset (indices unique, random order).
            let mut idx: Vec<u32> = (0..n as u32).collect();
            for i in (1..idx.len()).rev() {
                idx.swap(i, rng.below(i + 1));
            }
            let mut want = idx.clone();
            sort_tile_by_depth(&mut want, &splats);
            let mut got = idx;
            radix_sort_tile(&mut got, &splats, &mut scratch);
            assert_eq!(got, want, "case {case} (n={n})");
        }
    }

    #[test]
    fn fused_radix_matches_split_reference() {
        let mut rng = Rng::new(0xFA5E_D501);
        let mut fused_scratch = DepthSortScratch::new();
        let mut split_scratch = DepthSortScratch::new();
        for case in 0..64 {
            // Straddle the insertion cutoff and stress both the
            // uniform-byte skip (heavy duplication) and full scatters.
            let n = 1 + rng.below(512);
            let splats: Vec<Splat2D> = (0..n)
                .map(|i| {
                    let d = if rng.below(2) == 0 {
                        [0.25f32, 0.25, 3.5, 7.0][rng.below(4)]
                    } else {
                        rng.range(0.2, 1e6)
                    };
                    splat(d, i as u32)
                })
                .collect();
            let mut idx: Vec<u32> = (0..n as u32).collect();
            for i in (1..idx.len()).rev() {
                idx.swap(i, rng.below(i + 1));
            }
            let mut want = idx.clone();
            radix_sort_tile_split(&mut want, &splats, &mut split_scratch);
            let mut got = idx;
            radix_sort_tile(&mut got, &splats, &mut fused_scratch);
            assert_eq!(got, want, "case {case} (n={n})");
        }
    }

    #[test]
    fn scratch_is_reusable_across_tiles() {
        let splats: Vec<Splat2D> =
            (0..200).map(|i| splat((i * 7 % 31) as f32, i as u32)).collect();
        let mut scratch = DepthSortScratch::new();
        // A big tile warms the buffers, then a small one must not read
        // stale keys from the previous sort.
        let mut big: Vec<u32> = (0..200).rev().collect();
        radix_sort_tile(&mut big, &splats, &mut scratch);
        let mut small = vec![9u32, 3, 6];
        radix_sort_tile(&mut small, &splats, &mut scratch);
        let mut want = vec![9u32, 3, 6];
        sort_tile_by_depth(&mut want, &splats);
        assert_eq!(small, want);
    }

    #[test]
    fn threaded_bin_sort_is_byte_identical_to_serial() {
        use crate::splat::tiling::bin_splats;
        let mut rng = Rng::new(0x50CA_7712);
        let splats: Vec<Splat2D> = (0..1_400)
            .map(|i| {
                let mut sp = splat(rng.range(0.2, 1e4), i as u32);
                sp.mean =
                    Vec2::new(rng.range(-20.0, 270.0), rng.range(-20.0, 270.0));
                sp.radius = rng.range(0.5, 24.0);
                sp
            })
            .collect();
        let mut serial = bin_splats(&splats, 256, 256);
        let mut scratch = DepthSortScratch::new();
        sort_bins_with(&mut serial, &splats, &mut scratch);
        for threads in [1usize, 2, 8, 64] {
            let mut par = bin_splats(&splats, 256, 256);
            let mut pool = Vec::new();
            sort_bins_threaded(&mut par, &splats, &mut pool, threads);
            assert_eq!(par.indices, serial.indices, "{threads} threads");
            assert_eq!(par.offsets, serial.offsets, "{threads} threads");
        }
    }

    #[test]
    fn bitonic_cost_grows_superlinearly() {
        assert_eq!(bitonic_compare_ops(0), 0);
        assert_eq!(bitonic_compare_ops(1), 0);
        let c64 = bitonic_compare_ops(64);
        let c128 = bitonic_compare_ops(128);
        assert!(c128 > 2 * c64);
        // n log^2 n / 4 for n=64: 64*6*7/4 = 672.
        assert_eq!(c64, 672);
    }
}
