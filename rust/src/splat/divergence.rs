//! SIMT lane-occupancy accounting (the paper's Bottleneck 3).
//!
//! On a GPU, one thread renders one pixel and 32 threads form a
//! lockstep warp; a 16x16 tile is 8 warps. For every Gaussian each warp
//! executes the blend path if *any* lane needs it, with inactive lanes
//! masked — so warp time is `ceil(any active) * body`, and utilization
//! is `active lanes / (32 * warps that issued)`. The paper measures
//! utilization as low as 31% for per-pixel splatting; the 2x2 group
//! check makes every group (and empirically almost every warp) uniform.

/// Lanes per warp (CUDA).
pub const WARP_LANES: usize = 32;
/// Warps per 256-pixel tile.
pub const WARPS_PER_TILE: usize = 256 / WARP_LANES;

/// Accumulated lane-occupancy statistics over a blending pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DivergenceStats {
    /// Active lane executions (lane wanted the blend body).
    pub active_lanes: u64,
    /// Lane slots issued: 32 x warps that had >= 1 active lane.
    pub issued_lane_slots: u64,
    /// Warps that issued (>= 1 active lane) across all Gaussians.
    pub warps_issued: u64,
    /// Warps that were fully uniform (all 32 active or all 32 inactive).
    pub warps_uniform: u64,
    /// Total warp evaluations (issued or not).
    pub warps_total: u64,
    /// Scratch: per-warp active count for the Gaussian in flight.
    cur: [u16; WARPS_PER_TILE],
}

impl DivergenceStats {
    /// Record one lane's decision for the Gaussian in flight.
    /// `pixel` indexes the 256-pixel tile row-major; warp = pixel / 32.
    #[inline]
    pub fn record_lane(&mut self, pixel: usize, active: bool) {
        if active {
            self.cur[pixel / WARP_LANES] += 1;
        }
    }

    /// Bulk [`DivergenceStats::record_lane`]: credit `active` active
    /// lanes to the warp containing `pixel`. The SoA kernel computes
    /// per-row activation counts in its vector loop and records them in
    /// one call (a 16-pixel tile row sits inside one 32-lane warp);
    /// `pixel` and the lanes it stands for must share one warp.
    #[inline]
    pub fn record_lanes(&mut self, pixel: usize, active: u16) {
        self.cur[pixel / WARP_LANES] += active;
    }

    /// Close out the Gaussian in flight: fold per-warp counts into the
    /// totals and reset the scratch counters.
    pub fn end_gaussian(&mut self) {
        for w in 0..WARPS_PER_TILE {
            let a = self.cur[w] as u64;
            self.warps_total += 1;
            if a > 0 {
                self.warps_issued += 1;
                self.issued_lane_slots += WARP_LANES as u64;
                self.active_lanes += a;
            }
            if a == 0 || a == WARP_LANES as u64 {
                self.warps_uniform += 1;
            }
            self.cur[w] = 0;
        }
    }

    /// SIMT utilization: active lanes / issued lane slots (1.0 = no
    /// divergence). Returns 1.0 when nothing issued.
    pub fn utilization(&self) -> f64 {
        if self.issued_lane_slots == 0 {
            1.0
        } else {
            self.active_lanes as f64 / self.issued_lane_slots as f64
        }
    }

    /// Fraction of warps with uniform lane decisions.
    pub fn uniformity(&self) -> f64 {
        if self.warps_total == 0 {
            1.0
        } else {
            self.warps_uniform as f64 / self.warps_total as f64
        }
    }

    /// Merge another tile's statistics into this one.
    pub fn merge(&mut self, o: &DivergenceStats) {
        self.active_lanes += o.active_lanes;
        self.issued_lane_slots += o.issued_lane_slots;
        self.warps_issued += o.warps_issued;
        self.warps_uniform += o.warps_uniform;
        self.warps_total += o.warps_total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_active_warp_is_uniform() {
        let mut d = DivergenceStats::default();
        for p in 0..256 {
            d.record_lane(p, true);
        }
        d.end_gaussian();
        assert_eq!(d.utilization(), 1.0);
        assert_eq!(d.uniformity(), 1.0);
        assert_eq!(d.warps_issued, 8);
    }

    #[test]
    fn half_active_lanes_give_half_utilization() {
        let mut d = DivergenceStats::default();
        for p in 0..256 {
            d.record_lane(p, p % 2 == 0); // alternate lanes
        }
        d.end_gaussian();
        assert!((d.utilization() - 0.5).abs() < 1e-12);
        assert_eq!(d.uniformity(), 0.0);
    }

    #[test]
    fn inactive_warps_cost_nothing() {
        let mut d = DivergenceStats::default();
        for p in 0..32 {
            d.record_lane(p, true); // only warp 0 active
        }
        d.end_gaussian();
        assert_eq!(d.warps_issued, 1);
        assert_eq!(d.issued_lane_slots, 32);
        assert_eq!(d.utilization(), 1.0);
        // 7 idle warps + 1 full warp are all uniform.
        assert_eq!(d.uniformity(), 1.0);
    }

    #[test]
    fn record_lanes_equals_per_lane_recording() {
        // The SoA kernel's bulk path must fold to the same totals as
        // the scalar kernel's per-lane calls, row by row.
        let pattern = |p: usize| p % 3 == 0 || p / 32 == 2;
        let mut per_lane = DivergenceStats::default();
        let mut bulk = DivergenceStats::default();
        for p in 0..256 {
            per_lane.record_lane(p, pattern(p));
        }
        for row in 0..8 {
            let active =
                (0..32).filter(|i| pattern(row * 32 + i)).count() as u16;
            bulk.record_lanes(row * 32, active);
        }
        per_lane.end_gaussian();
        bulk.end_gaussian();
        assert_eq!(per_lane, bulk);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = DivergenceStats::default();
        for p in 0..256 {
            a.record_lane(p, true);
        }
        a.end_gaussian();
        let b = a;
        a.merge(&b);
        assert_eq!(a.warps_total, 16);
        assert_eq!(a.active_lanes, 512);
    }
}
