//! The divergence-free SoA blend kernel — the software model of the
//! SPcore splatting unit (paper Sec. IV-C), and the crate's optimized
//! CPU blend inner loop.
//!
//! Three ideas, each bit-identical to the scalar reference
//! [`blend_tile`](super::blend::blend_tile) per [`BlendMode`]:
//!
//! 1. **SoA tile state, SIMD-shaped rows** ([`TileState`],
//!    `blend_row`) — the accumulation planes are separate
//!    `r`/`g`/`b`/`t` arrays instead of an AoS `[[f32; 3]]` buffer, and
//!    every touched row blends through one fixed 16-lane branch-free
//!    loop over `&mut [f32; 16]` plane slices: no bounds checks, no
//!    data-dependent trip count, only mul/add/compare — the shape the
//!    autovectorizer turns into vector ops (std-only; no intrinsics).
//!    The scalar `exp` evaluations are staged *before* the lane loop
//!    into a row-wide effective-alpha array. Safe for bit-identity:
//!    every pixel's arithmetic sequence is unchanged — a masked or
//!    out-of-footprint lane carries `alpha = 0.0`, which is a bitwise
//!    no-op on its planes (`t *= 1.0`, `rgb += 0.0`), and pixels never
//!    read each other's planes so lane order is immaterial.
//! 2. **No-exp group check** ([`group_keep_threshold`]) — the SPcore
//!    hardware trick: precompute `ln(ALPHA_THRESH / opacity)` once per
//!    splat and compare raw Gaussian powers against it, so the per-group
//!    keep decision costs one compare and no `exp`. The threshold is
//!    probed to the exact f32 decision boundary of the exp-form check,
//!    so the kept set is identical bit for bit — and since PR 8 it is
//!    hoisted all the way to projection time ([`Splat2D::keep_thresh`]),
//!    so the blend loops just read a field. The per-group-row keep
//!    decisions land in a bitset that drives a maskless inner loop
//!    (iterate set bits; blend whole groups unconditionally).
//! 3. **Incremental early termination** — a running saturated-pixel
//!    count (`t < t_min`, bumped exactly when a blend drops a pixel
//!    across the threshold) replaces the scalar kernel's per-Gaussian
//!    O(256) `t_max` scan. `all pixels saturated` is decided identically
//!    (`max t < t_min  <=>  saturated == 256`), just without re-reading
//!    the whole transmittance plane per Gaussian.
//!
//! Selected per session via
//! [`RenderOptions::kernel`](crate::coordinator::RenderOptions); the
//! equivalence contract is pinned by unit tests here, kernel proptests
//! in `rust/tests/proptests.rs` and the golden-frame harness
//! (`rust/tests/golden.rs` renders every golden scene through both
//! kernels and asserts byte-equal frames at scheduler widths {1, 8}).

use super::blend::{gauss_power, tile_bbox, BlendMode, BlendStats, GROUP, GROUPS, GSIDE, PIXELS};
use super::sort::float_to_sortable_uint;
use super::tiling::TILE;
use crate::gaussian::{Splat2D, ALPHA_CLAMP, ALPHA_THRESH};

/// Which CPU blend-kernel implementation a session runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BlendKernel {
    /// The branchy AoS scalar reference loop
    /// ([`blend_tile`](super::blend::blend_tile)).
    Scalar,
    /// The divergence-free SoA kernel ([`blend_tile_soa`]) — same
    /// pixels, same [`BlendStats`], faster inner loop. The default
    /// since the SIMD-shaped row rework (PR 8): the bench rows confirm
    /// it beats the scalar loop at widths {1, N}, and the golden
    /// harness pins it byte-identical, so sessions get the fast kernel
    /// unless they opt back into the reference.
    #[default]
    Soa,
}

/// SoA accumulation state for one 16x16 tile: separate `r`/`g`/`b`
/// colour planes and the transmittance plane `t`. Lives in a per-worker
/// pool inside `FrameScratch`, so steady-state frames reuse the planes
/// without allocating.
#[derive(Clone, Debug)]
pub struct TileState {
    /// Accumulated red, row-major.
    pub r: [f32; PIXELS],
    /// Accumulated green, row-major.
    pub g: [f32; PIXELS],
    /// Accumulated blue, row-major.
    pub b: [f32; PIXELS],
    /// Per-pixel transmittance (1 = untouched).
    pub t: [f32; PIXELS],
}

impl Default for TileState {
    fn default() -> Self {
        Self::fresh()
    }
}

impl TileState {
    /// A fresh tile: black, fully transmissive.
    pub fn fresh() -> Self {
        TileState {
            r: [0.0; PIXELS],
            g: [0.0; PIXELS],
            b: [0.0; PIXELS],
            t: [1.0; PIXELS],
        }
    }

    /// Reset to the fresh state (between tiles; keeps the storage).
    pub fn reset(&mut self) {
        self.r = [0.0; PIXELS];
        self.g = [0.0; PIXELS];
        self.b = [0.0; PIXELS];
        self.t = [1.0; PIXELS];
    }
}

/// Inverse of [`float_to_sortable_uint`] (the radix sorter's monotone
/// bit-space key): `a < b  <=>  key(a) < key(b)`, so stepping or
/// bisecting keys steps/bisects representable values.
fn from_ord(k: u32) -> f32 {
    if k & 0x8000_0000 != 0 {
        f32::from_bits(k & 0x7fff_ffff)
    } else {
        f32::from_bits(!k)
    }
}

/// The exact no-exp group-keep threshold (paper Sec. IV-C): the
/// smallest f32 `power` for which the exp-form group check
/// `(opacity * power.exp()).min(ALPHA_CLAMP) >= ALPHA_THRESH`
/// passes, so `power >= group_keep_threshold(opacity)` reproduces the
/// reference keep decision **bit for bit** over the kernel's power
/// domain (`gauss_power` is clamped to `<= 0`) while the per-group loop
/// does one compare and no `exp`.
///
/// `f32::INFINITY` (keep nothing) when no non-positive power can pass:
/// zero/negative/NaN opacity (the reference also gates on
/// `opacity > 0`), or `opacity < ALPHA_THRESH` (for `power <= 0` the
/// rounded product `opacity * exp(power)` never exceeds `opacity`
/// itself).
///
/// A plain `ln(ALPHA_THRESH / opacity)` is only correct to a few ulps,
/// and a keep decision flipped by one ulp would change rendered pixels
/// — so the exact boundary is found on the exp-form predicate in f32
/// bit space: an exponential search brackets the edge within a few ulps
/// of the `ln` estimate, then a short bisection pins it (~10 `exp`
/// evaluations typical, once per splat per tile it touches, versus one
/// `exp` per covered group in the pre-fix keep loop — cheaper whenever
/// the footprint covers more than a handful of groups, and off the
/// per-group hot path either way). Working in key space also rides out
/// the flat spots of `expf` (near `power = 0` whole ulp ranges share
/// one `exp` value), where an ulp walk would never terminate.
pub fn group_keep_threshold(opacity: f32) -> f32 {
    // `min(ALPHA_CLAMP)` can never flip the decision: ALPHA_CLAMP >
    // ALPHA_THRESH, so a clamped pass still passes. The predicate is
    // `opacity * power.exp() >= ALPHA_THRESH`. NaN opacity keeps
    // nothing (the reference's `opacity > 0` gate is false for NaN);
    // `opacity < ALPHA_THRESH` covers every zero/negative value too.
    if opacity.is_nan() || opacity < ALPHA_THRESH {
        return f32::INFINITY;
    }
    let pass = |p: f32| opacity * p.exp() >= ALPHA_THRESH;
    // opacity >= ALPHA_THRESH makes power 0 pass exactly
    // (`opacity * exp(0) == opacity`), so the boundary is <= 0; its
    // key is capped by key(0.0).
    let zero_k = float_to_sortable_uint(0.0);
    debug_assert!(pass(0.0));
    let est = (ALPHA_THRESH / opacity).ln().min(0.0);
    let est_k = float_to_sortable_uint(est);
    // Upper bound: walk up in doubling key steps to the first passing
    // value (0.0 passes, so the cap always terminates the walk).
    let mut hi_k = est_k;
    let mut step = 32u32;
    while !pass(from_ord(hi_k)) && hi_k < zero_k {
        hi_k = hi_k.saturating_add(step).min(zero_k);
        step = step.saturating_mul(2);
    }
    // Lower bound: walk down to the first failing value. Terminates:
    // far below the estimate `exp` underflows to 0 (or the key space
    // bottoms out in NaNs) and the predicate is false.
    let mut lo_k = est_k;
    let mut step = 32u32;
    while pass(from_ord(lo_k)) {
        lo_k = lo_k.saturating_sub(step);
        step = step.saturating_mul(2);
    }
    // Bisect to the exact f32 decision edge: invariant pass(hi) and
    // !pass(lo), shrink until they are bitwise neighbours.
    while hi_k - lo_k > 1 {
        let mid = lo_k + (hi_k - lo_k) / 2;
        if pass(from_ord(mid)) {
            hi_k = mid;
        } else {
            lo_k = mid;
        }
    }
    from_ord(hi_k)
}

/// Lane count of the SIMD-shaped row blend — one full 16-pixel tile
/// row, the natural vector width of the planes.
const LANES: usize = TILE as usize;

/// Blend one staged row of effective alphas into the tile planes — the
/// SIMD-shaped stage of the SoA kernel. A fixed 16-lane trip count over
/// `&mut [f32; LANES]` plane slices with only mul/add/sub/compare in
/// the body (every `exp` happened in the staging pass), so the
/// autovectorizer emits vector ops without intrinsics. Lanes with
/// `aeff == 0.0` (masked or outside the splat's footprint) are bitwise
/// no-ops: `w = t * 0.0` is `+0.0` (`t > 0` or `+0.0`, never negative),
/// the planes never hold `-0.0` (they accumulate `x + (-x) -> +0.0`
/// under round-to-nearest), and `t * (1.0 - 0.0)` is exact — so
/// blending the whole row matches the scalar kernel's sparse writes bit
/// for bit. Returns how many lanes crossed the `t_min` saturation
/// threshold in this row.
#[inline]
fn blend_row(
    state: &mut TileState,
    row: usize,
    aeff: &[f32; LANES],
    color: [f32; 3],
    t_min: f32,
) -> u32 {
    let TileState { r, g, b, t } = state;
    let r: &mut [f32; LANES] = (&mut r[row..row + LANES]).try_into().expect("tile row");
    let g: &mut [f32; LANES] = (&mut g[row..row + LANES]).try_into().expect("tile row");
    let b: &mut [f32; LANES] = (&mut b[row..row + LANES]).try_into().expect("tile row");
    let t: &mut [f32; LANES] = (&mut t[row..row + LANES]).try_into().expect("tile row");
    let mut newly_sat = 0u32;
    for l in 0..LANES {
        let t_old = t[l];
        let a = aeff[l];
        let w = t_old * a;
        r[l] += w * color[0];
        g[l] += w * color[1];
        b[l] += w * color[2];
        let t_new = t_old * (1.0 - a);
        t[l] = t_new;
        newly_sat += ((t_old >= t_min) & (t_new < t_min)) as u32;
    }
    newly_sat
}

/// Blend `order`ed splats into one tile — the divergence-free SoA
/// kernel. Same contract as [`blend_tile`](super::blend::blend_tile)
/// (carried accumulation state, early termination on `t_min`), same
/// pixels and the same [`BlendStats`], bit for bit, in both modes.
pub fn blend_tile_soa(
    order: &[u32],
    splats: &[Splat2D],
    origin: (f32, f32),
    mode: BlendMode,
    state: &mut TileState,
    t_min: f32,
) -> BlendStats {
    let mut stats = BlendStats::default();
    // Incremental early termination: `saturated` counts pixels with
    // `t < t_min`; the scalar kernel's whole-plane `t_max < t_min` scan
    // is exactly `saturated == PIXELS`. One entry scan supports carried
    // (partially saturated) state; `t` only decreases, so each pixel
    // crosses the threshold at most once.
    let mut saturated =
        state.t.iter().filter(|&&v| v < t_min).count() as u32;

    for &si in order {
        if saturated == PIXELS as u32 {
            stats.early_terminated = true;
            break;
        }
        let s = &splats[si as usize];
        stats.gaussians += 1;

        let Some((x0, y0, x1, y1)) = tile_bbox(s, origin) else {
            // Footprint misses the tile entirely: all warps idle.
            stats.divergence.end_gaussian();
            match mode {
                BlendMode::PerPixel => stats.alpha_evals += PIXELS as u64,
                BlendMode::PixelGroup => stats.group_checks += GROUPS as u64,
            }
            continue;
        };

        match mode {
            BlendMode::PerPixel => {
                stats.alpha_evals += PIXELS as u64;
                let opaque = s.opacity > 0.0;
                for py in y0..=y1 {
                    let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                    let row = py * TILE as usize;
                    // Stage 1 (scalar): evaluate the Gaussian only
                    // inside the bbox; out-of-bbox lanes keep alpha 0.0
                    // — a bitwise no-op in the row blend below, so the
                    // full-row pass writes exactly what the scalar
                    // kernel's sparse loop wrote.
                    let mut aeff = [0.0f32; LANES];
                    let mut active = 0u32;
                    for px in x0..=x1 {
                        let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                        let power = gauss_power(&s.conic, dx, dy);
                        let alpha = (s.opacity * power.exp()).min(ALPHA_CLAMP);
                        let keep = alpha >= ALPHA_THRESH && opaque;
                        aeff[px] = if keep { alpha } else { 0.0 };
                        active += keep as u32;
                    }
                    // Stage 2 (SIMD-shaped): fixed 16-lane blend.
                    let newly_sat = blend_row(state, row, &aeff, s.color, t_min);
                    // A 16-pixel row sits inside one 32-lane warp, so
                    // one bulk record replaces 16 per-lane calls.
                    stats.divergence.record_lanes(row, active as u16);
                    stats.blends += active as u64;
                    saturated += newly_sat;
                }
                stats.divergence.end_gaussian();
            }
            BlendMode::PixelGroup => {
                stats.group_checks += GROUPS as u64;
                // One threshold per splat, precomputed at projection
                // time ([`Splat2D::keep_thresh`]); per group just a
                // compare — the SPcore no-exp check with zero exp
                // probes on the blend path.
                let thr = s.keep_thresh;
                let (gx0, gx1) = (x0 / GROUP, x1 / GROUP);
                let (gy0, gy1) = (y0 / GROUP, y1 / GROUP);
                // Per-group-row keep bitset (bit gx = keep group gx).
                let mut keep_bits = [0u8; GSIDE];
                for (gy, bits) in keep_bits.iter_mut().enumerate().take(gy1 + 1).skip(gy0) {
                    let cy = origin.1 + 2.0 * gy as f32 + 1.0;
                    for gx in gx0..=gx1 {
                        let cx = origin.0 + 2.0 * gx as f32 + 1.0;
                        let power =
                            gauss_power(&s.conic, cx - s.mean.x, cy - s.mean.y);
                        *bits |= u8::from(power >= thr) << gx;
                    }
                }
                // Maskless inner loop: iterate the set bits and blend
                // whole groups unconditionally (no per-pixel checks).
                for py in GROUP * gy0..=GROUP * gy1 + (GROUP - 1) {
                    let bits = keep_bits[py / GROUP];
                    if bits == 0 {
                        continue;
                    }
                    let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                    let row = py * TILE as usize;
                    let kept = bits.count_ones();
                    // Stage 1 (scalar): alphas for the kept groups
                    // only; dropped groups stay at 0.0, a bitwise
                    // no-op in the row blend below.
                    let mut aeff = [0.0f32; LANES];
                    let mut rest = bits;
                    while rest != 0 {
                        let gx = rest.trailing_zeros() as usize;
                        rest &= rest - 1;
                        for px in GROUP * gx..GROUP * gx + GROUP {
                            let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                            let power = gauss_power(&s.conic, dx, dy);
                            aeff[px] =
                                (s.opacity * power.exp()).min(ALPHA_CLAMP);
                        }
                    }
                    // Stage 2 (SIMD-shaped): fixed 16-lane blend.
                    let newly_sat = blend_row(state, row, &aeff, s.color, t_min);
                    stats.divergence.record_lanes(row, (GROUP as u32 * kept) as u16);
                    stats.alpha_evals += GROUP as u64 * kept as u64;
                    stats.blends += GROUP as u64 * kept as u64;
                    saturated += newly_sat;
                }
                stats.divergence.end_gaussian();
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;
    use crate::splat::blend::blend_tile;
    use crate::util::Rng;

    /// Next representable f32 toward `+inf` (test probe).
    fn step_up(x: f32) -> f32 {
        if x == 0.0 {
            return f32::from_bits(1);
        }
        if x < 0.0 {
            f32::from_bits(x.to_bits() - 1)
        } else {
            f32::from_bits(x.to_bits() + 1)
        }
    }

    /// Next representable f32 toward `-inf` (test probe).
    fn step_down(x: f32) -> f32 {
        if x == 0.0 {
            return f32::from_bits(0x8000_0001);
        }
        if x < 0.0 {
            f32::from_bits(x.to_bits() + 1)
        } else {
            f32::from_bits(x.to_bits() - 1)
        }
    }

    fn splat(x: f32, y: f32, opacity: f32, sharp: f32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(x, y),
            conic: [sharp, 0.0, sharp],
            depth: 1.0,
            radius: 3.0 / sharp.sqrt(),
            color: [0.9, 0.5, 0.25],
            opacity,
            id: 0,
            ..Splat2D::default()
        }
        .with_keep_thresh()
    }

    fn assert_soa_matches_scalar(
        order: &[u32],
        splats: &[Splat2D],
        origin: (f32, f32),
        t_min: f32,
        label: &str,
    ) {
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            let mut rgb = [[0.0f32; 3]; PIXELS];
            let mut t = [1.0f32; PIXELS];
            let want = blend_tile(order, splats, origin, mode, &mut rgb, &mut t, t_min);
            let mut state = TileState::fresh();
            let got = blend_tile_soa(order, splats, origin, mode, &mut state, t_min);
            for p in 0..PIXELS {
                assert_eq!(
                    state.r[p].to_bits(),
                    rgb[p][0].to_bits(),
                    "{label} {mode:?}: r[{p}]"
                );
                assert_eq!(
                    state.g[p].to_bits(),
                    rgb[p][1].to_bits(),
                    "{label} {mode:?}: g[{p}]"
                );
                assert_eq!(
                    state.b[p].to_bits(),
                    rgb[p][2].to_bits(),
                    "{label} {mode:?}: b[{p}]"
                );
                assert_eq!(
                    state.t[p].to_bits(),
                    t[p].to_bits(),
                    "{label} {mode:?}: t[{p}]"
                );
            }
            assert_eq!(got, want, "{label} {mode:?}: stats");
        }
    }

    #[test]
    fn soa_matches_scalar_on_simple_tiles() {
        let s = vec![
            splat(8.0, 8.0, 0.99, 0.5),
            splat(7.3, 9.1, 0.8, 0.08),
            splat(3.0, 4.0, 0.6, 0.15),
            splat(12.0, 2.0, 0.0, 0.3), // zero opacity padding
        ];
        assert_soa_matches_scalar(&[0], &s, (0.0, 0.0), 1.0 / 255.0, "one");
        assert_soa_matches_scalar(&[1, 2, 3], &s, (0.0, 0.0), 0.0, "three");
        assert_soa_matches_scalar(&[0, 0, 1, 2], &s, (16.0, 32.0), 0.5, "offset");
    }

    #[test]
    fn soa_matches_scalar_on_randomized_tiles() {
        let mut rng = Rng::new(0x50A_B1E4D);
        for case in 0..40 {
            let n = 1 + rng.below(24);
            let splats: Vec<Splat2D> = (0..n)
                .map(|i| {
                    let sharp = rng.range(0.02, 2.0);
                    let opacity = match rng.below(6) {
                        0 => 0.0,
                        1 => 1.0,
                        // Stress the keep boundary around ALPHA_THRESH.
                        2 => rng.range(0.003, 0.005),
                        _ => rng.range(0.01, 1.0),
                    };
                    let mut s = splat(
                        rng.range(-30.0, 46.0),
                        rng.range(-30.0, 46.0),
                        opacity,
                        sharp,
                    );
                    s.id = i as u32;
                    if rng.below(8) == 0 {
                        s.radius = 0.0; // culled splat in the order
                        s.conic = [60.0, 0.0, 60.0];
                    }
                    s
                })
                .collect();
            let order: Vec<u32> = (0..n as u32).collect();
            let t_min = [0.0, 1.0 / 255.0, 0.5, 1.5][rng.below(4)];
            assert_soa_matches_scalar(
                &order,
                &splats,
                (0.0, 0.0),
                t_min,
                &format!("case {case}"),
            );
        }
    }

    #[test]
    fn soa_early_termination_matches_scalar() {
        // Opaque full-tile splats: the incremental saturated counter
        // must stop on exactly the same Gaussian as the t_max scan.
        let s = vec![splat(8.0, 8.0, 0.99, 0.001), splat(8.0, 8.0, 0.99, 0.001)];
        let order = [0u32, 1, 1, 1];
        assert_soa_matches_scalar(&order, &s, (0.0, 0.0), 0.5, "early-term");
        let mut state = TileState::fresh();
        let stats = blend_tile_soa(
            &order,
            &s,
            (0.0, 0.0),
            BlendMode::PerPixel,
            &mut state,
            0.5,
        );
        assert!(stats.early_terminated);
        assert!(stats.gaussians < 4);
    }

    #[test]
    fn soa_carried_state_matches_scalar() {
        // Chunked blending: feed the same order in two calls over
        // carried state, against one scalar pass.
        let s = vec![splat(5.0, 6.0, 0.7, 0.1), splat(10.0, 9.0, 0.9, 0.2)];
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            let mut rgb = [[0.0f32; 3]; PIXELS];
            let mut t = [1.0f32; PIXELS];
            blend_tile(&[0, 1], &s, (0.0, 0.0), mode, &mut rgb, &mut t, 0.0);
            let mut state = TileState::fresh();
            blend_tile_soa(&[0], &s, (0.0, 0.0), mode, &mut state, 0.0);
            blend_tile_soa(&[1], &s, (0.0, 0.0), mode, &mut state, 0.0);
            for p in 0..PIXELS {
                assert_eq!(state.r[p].to_bits(), rgb[p][0].to_bits(), "{mode:?}");
                assert_eq!(state.t[p].to_bits(), t[p].to_bits(), "{mode:?}");
            }
        }
    }

    #[test]
    fn noexp_threshold_matches_exp_form_keep() {
        // The satellite contract: the precomputed compare reproduces
        // the exp-form keep decision exactly — including at the ulp
        // neighbours of the threshold itself — for opacities spanning
        // 0, the ALPHA_THRESH boundary region and 1.
        let opacities = [
            0.0,
            1e-30,
            1e-6,
            ALPHA_THRESH,
            0.0039,
            0.004,
            0.01,
            0.25,
            0.5,
            0.9,
            0.99,
            1.0,
        ];
        for &opacity in &opacities {
            let thr = group_keep_threshold(opacity);
            let mut powers: Vec<f32> =
                (0..=2048).map(|i| -8.0 * i as f32 / 2048.0).collect();
            if thr.is_finite() {
                let mut lo = thr;
                let mut hi = thr;
                powers.push(thr);
                for _ in 0..8 {
                    lo = step_down(lo);
                    hi = step_up(hi);
                    powers.push(lo);
                    powers.push(hi);
                }
            }
            for &p in &powers {
                if !(p <= 0.0) {
                    continue; // outside the kernel's gauss_power domain
                }
                let galpha = (opacity * p.exp()).min(ALPHA_CLAMP);
                let want = galpha >= ALPHA_THRESH && opacity > 0.0;
                assert_eq!(
                    p >= thr,
                    want,
                    "opacity {opacity} power {p}: compare {} vs exp-form {want}",
                    p >= thr
                );
            }
        }
    }

    #[test]
    fn noexp_threshold_edge_opacities() {
        assert_eq!(group_keep_threshold(0.0), f32::INFINITY);
        assert_eq!(group_keep_threshold(-0.5), f32::INFINITY);
        assert_eq!(group_keep_threshold(f32::NAN), f32::INFINITY);
        // Below the alpha threshold nothing can pass at power <= 0.
        assert_eq!(group_keep_threshold(1e-3), f32::INFINITY);
        // At or above it, the boundary is a finite non-positive power.
        let thr = group_keep_threshold(1.0);
        assert!(thr.is_finite() && thr < 0.0);
        assert!((thr - ALPHA_THRESH.ln()).abs() < 1e-4);
        assert!(group_keep_threshold(ALPHA_THRESH) <= 0.0);
    }

    #[test]
    fn tile_state_reset_restores_fresh() {
        let mut state = TileState::fresh();
        let s = vec![splat(8.0, 8.0, 0.9, 0.3)];
        blend_tile_soa(&[0], &s, (0.0, 0.0), BlendMode::PerPixel, &mut state, 0.0);
        assert!(state.t.iter().any(|&v| v != 1.0));
        state.reset();
        let fresh = TileState::fresh();
        assert_eq!(state.r, fresh.r);
        assert_eq!(state.g, fresh.g);
        assert_eq!(state.b, fresh.b);
        assert_eq!(state.t, fresh.t);
    }
}
