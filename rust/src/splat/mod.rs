//! The splatting stage: tile binning, depth sorting and alpha blending,
//! in both dataflows the paper contrasts (Sec. IV-C):
//!
//! * **per-pixel** alpha check — the canonical 3DGS rasterizer, which
//!   diverges on SIMT hardware (different lanes integrate different
//!   Gaussian subsets), and
//! * **2x2 pixel-group** alpha check — SLTarch's divergence-free
//!   approximation (one alpha-check per group, decision broadcast to
//!   all four pixels).
//!
//! The CPU implementations here mirror the L1 Pallas kernels exactly and
//! also emit the per-warp lane-occupancy statistics the GPU/SPCore
//! timing models replay ([`divergence`]).
//!
//! Both dataflows come in two interchangeable kernel implementations:
//! the branchy AoS scalar reference ([`blend::blend_tile`]) and the
//! divergence-free SoA kernel ([`kernel::blend_tile_soa`]) — the
//! software SPcore, selected per session via [`kernel::BlendKernel`]
//! and byte-identical to the reference per mode.

pub mod blend;
pub mod divergence;
pub mod kernel;
pub mod sort;
pub mod tiling;

pub use blend::{blend_tile, BlendMode, BlendStats};
pub use divergence::DivergenceStats;
pub use kernel::{blend_tile_soa, group_keep_threshold, BlendKernel, TileState};
pub use sort::{
    float_to_sortable_uint, radix_sort_tile, radix_sort_tile_split,
    sort_bins_by_depth, sort_bins_threaded, sort_bins_with,
    sort_tile_by_depth, DepthSortScratch,
};
pub use tiling::{
    bin_splats, bin_splats_into, bin_splats_into_threaded, bin_splats_nested,
    project_bin_finish, project_bin_fused, project_bin_sweep, BatchWorkItem,
    FusedSweep, TileBins, TilingError, TILE,
};
