//! Tile alpha blending — the CPU mirror of the L1 splat kernel, in both
//! dataflows, with the lane-occupancy accounting the timing models need.
//!
//! Numerics are identical to `python/compile/kernels/ref.py`
//! (`splat_tile_ref`): front-to-back compositing, alpha clamped at 0.99,
//! integration threshold 1/255, early termination when every pixel's
//! transmittance drops below `t_min`.

use super::divergence::DivergenceStats;
use super::tiling::TILE;
use crate::gaussian::{Splat2D, ALPHA_CLAMP, ALPHA_THRESH};

/// Which alpha-check dataflow to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlendMode {
    /// Canonical per-pixel check (divergent on SIMT hardware).
    PerPixel,
    /// SLTarch 2x2 pixel-group check (divergence-free, Sec. IV-C).
    PixelGroup,
}

/// Work counters for one tile's blending pass (replayed by the GPU,
/// GSCore and SPCore timing models).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlendStats {
    /// Gaussians processed before early termination.
    pub gaussians: u64,
    /// Full alpha evaluations (with exp): per-pixel mode evaluates 256
    /// per Gaussian; group mode evaluates only for surviving groups.
    pub alpha_evals: u64,
    /// Group alpha checks (exponent-power compares, no exp).
    pub group_checks: u64,
    /// Blend operations actually performed (lane-activations).
    pub blends: u64,
    /// Early-terminated before exhausting the list?
    pub early_terminated: bool,
    /// SIMT lane-occupancy bookkeeping.
    pub divergence: DivergenceStats,
}

impl BlendStats {
    /// Fold another tile's counters into this one.
    pub fn merge(&mut self, o: &BlendStats) {
        self.gaussians += o.gaussians;
        self.alpha_evals += o.alpha_evals;
        self.group_checks += o.group_checks;
        self.blends += o.blends;
        self.early_terminated |= o.early_terminated;
        self.divergence.merge(&o.divergence);
    }
}

pub const PIXELS: usize = (TILE * TILE) as usize;
pub(crate) const GROUP: usize = 2;
pub(crate) const GSIDE: usize = TILE as usize / GROUP;
pub(crate) const GROUPS: usize = GSIDE * GSIDE;

#[inline]
pub(crate) fn gauss_power(conic: &[f32; 3], dx: f32, dy: f32) -> f32 {
    let p = -0.5 * (conic[0] * dx * dx + conic[2] * dy * dy) - conic[1] * dx * dy;
    p.min(0.0)
}

/// §Perf: the Gaussian's alpha-threshold bounding box inside the tile
/// (inclusive pixel coords), or `None` when the footprint misses the
/// tile entirely. `radius` is the 3-sigma extent; alpha >= 1/255
/// requires distance <= sqrt(2 ln(255*0.99)) sigma ~= 3.33 sigma, so a
/// 3.4-sigma box is exactly conservative: every skipped pixel/group
/// would have been masked anyway, and the blend result and all
/// divergence counters are unchanged. Shared by the scalar and SoA
/// kernels so their scan restriction can never diverge.
#[inline]
pub(crate) fn tile_bbox(
    s: &Splat2D,
    origin: (f32, f32),
) -> Option<(usize, usize, usize, usize)> {
    let margin = s.radius * (3.4 / 3.0) + 1.0;
    let x0 = (s.mean.x - margin - origin.0).floor().max(0.0) as usize;
    let y0 = (s.mean.y - margin - origin.1).floor().max(0.0) as usize;
    let x1f = (s.mean.x + margin - origin.0).ceil();
    let y1f = (s.mean.y + margin - origin.1).ceil();
    if x1f < 0.0 || y1f < 0.0 || x0 >= TILE as usize || y0 >= TILE as usize {
        return None;
    }
    let x1 = (x1f as usize).min(TILE as usize - 1);
    let y1 = (y1f as usize).min(TILE as usize - 1);
    Some((x0, y0, x1, y1))
}

/// Blend `order`ed splats into one tile.
///
/// * `origin` — pixel coordinates of the tile's top-left corner.
/// * `rgb` / `t` — accumulation state (carried across calls like the
///   PJRT chunks; pass fresh buffers for a full tile render).
/// * `t_min` — early-termination threshold on max transmittance.
pub fn blend_tile(
    order: &[u32],
    splats: &[Splat2D],
    origin: (f32, f32),
    mode: BlendMode,
    rgb: &mut [[f32; 3]; PIXELS],
    t: &mut [f32; PIXELS],
    t_min: f32,
) -> BlendStats {
    let mut stats = BlendStats::default();

    for &si in order {
        // Early termination: the whole tile is saturated.
        let t_max = t.iter().cloned().fold(0.0f32, f32::max);
        if t_max < t_min {
            stats.early_terminated = true;
            break;
        }
        let s = &splats[si as usize];
        stats.gaussians += 1;

        // §Perf: restrict the scan to the Gaussian's alpha-threshold
        // bounding box inside the tile (see [`tile_bbox`]).
        let Some((x0, y0, x1, y1)) = tile_bbox(s, origin) else {
            // Footprint misses the tile entirely: all warps idle.
            stats.divergence.end_gaussian();
            match mode {
                BlendMode::PerPixel => stats.alpha_evals += PIXELS as u64,
                BlendMode::PixelGroup => stats.group_checks += GROUPS as u64,
            }
            continue;
        };

        match mode {
            BlendMode::PerPixel => {
                // 8 warps of 32 lanes cover the 256-pixel tile; the
                // hardware evaluates all 256 alphas (counted), the
                // model only computes the ones that can pass.
                stats.alpha_evals += PIXELS as u64;
                for py in y0..=y1 {
                    for px in x0..=x1 {
                        let p = py * TILE as usize + px;
                        let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                        let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                        let power = gauss_power(&s.conic, dx, dy);
                        let alpha = (s.opacity * power.exp()).min(ALPHA_CLAMP);
                        let active = alpha >= ALPHA_THRESH && s.opacity > 0.0;
                        stats.divergence.record_lane(p, active);
                        if active {
                            let w = t[p] * alpha;
                            rgb[p][0] += w * s.color[0];
                            rgb[p][1] += w * s.color[1];
                            rgb[p][2] += w * s.color[2];
                            t[p] *= 1.0 - alpha;
                            stats.blends += 1;
                        }
                    }
                }
                stats.divergence.end_gaussian();
            }
            BlendMode::PixelGroup => {
                // One alpha check per 2x2 group at the group centre;
                // the keep decision is broadcast to all 4 pixels. The
                // hardware checks all 64 groups (counted); out-of-box
                // groups are guaranteed-masked so only in-box ones are
                // computed.
                stats.group_checks += GROUPS as u64;
                // Hardware trick (Sec. IV-C): compare the power against
                // the precomputed exact boundary of
                // `ln(ALPHA_THRESH / opacity)` — no exp in the keep
                // loop, same decisions bit for bit. The boundary is
                // computed once per splat at projection time
                // (`Splat2D::keep_thresh`, see
                // `splat::kernel::group_keep_threshold`).
                let thr = s.keep_thresh;
                let mut keep = [false; GROUPS];
                for gy in y0 / GROUP..=y1 / GROUP {
                    for gx in x0 / GROUP..=x1 / GROUP {
                        let cx = origin.0 + 2.0 * gx as f32 + 1.0;
                        let cy = origin.1 + 2.0 * gy as f32 + 1.0;
                        let power = gauss_power(&s.conic, cx - s.mean.x, cy - s.mean.y);
                        keep[gy * GSIDE + gx] = power >= thr;
                    }
                }
                for gy in y0 / GROUP..=y1 / GROUP {
                    for gx in x0 / GROUP..=x1 / GROUP {
                        let g = gy * GSIDE + gx;
                        if !keep[g] {
                            continue;
                        }
                        for sy in 0..GROUP {
                            for sx in 0..GROUP {
                                let py = gy * GROUP + sy;
                                let px = gx * GROUP + sx;
                                let p = py * TILE as usize + px;
                                stats.divergence.record_lane(p, true);
                                let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                                let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                                let power = gauss_power(&s.conic, dx, dy);
                                let alpha =
                                    (s.opacity * power.exp()).min(ALPHA_CLAMP);
                                stats.alpha_evals += 1;
                                let w = t[p] * alpha;
                                rgb[p][0] += w * s.color[0];
                                rgb[p][1] += w * s.color[1];
                                rgb[p][2] += w * s.color[2];
                                t[p] *= 1.0 - alpha;
                                stats.blends += 1;
                            }
                        }
                    }
                }
                stats.divergence.end_gaussian();
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec2;
    use crate::splat::kernel::group_keep_threshold;

    fn splat(x: f32, y: f32, opacity: f32, sharp: f32) -> Splat2D {
        Splat2D {
            mean: Vec2::new(x, y),
            conic: [sharp, 0.0, sharp],
            depth: 1.0,
            radius: 3.0 / sharp.sqrt(),
            color: [1.0, 0.5, 0.25],
            opacity,
            id: 0,
            ..Splat2D::default()
        }
        .with_keep_thresh()
    }

    fn fresh() -> ([[f32; 3]; PIXELS], [f32; PIXELS]) {
        ([[0.0; 3]; PIXELS], [1.0; PIXELS])
    }

    #[test]
    fn opaque_center_saturates_center_pixel() {
        let s = vec![splat(8.0, 8.0, 0.99, 0.5)];
        let (mut rgb, mut t) = fresh();
        let stats = blend_tile(
            &[0],
            &s,
            (0.0, 0.0),
            BlendMode::PerPixel,
            &mut rgb,
            &mut t,
            1.0 / 255.0,
        );
        let center = 8 * 16 + 8;
        assert!(rgb[center][0] > 0.8);
        assert!(t[center] < 0.2);
        assert!(stats.blends > 0);
        assert!(!stats.early_terminated);
    }

    #[test]
    fn group_mode_close_to_pixel_mode() {
        // A moderately sized Gaussian: the two dataflows must agree to
        // within a small image error (paper Tbl. I).
        let s = vec![splat(7.3, 9.1, 0.8, 0.08), splat(3.0, 4.0, 0.6, 0.15)];
        let order = [0u32, 1];
        let (mut rgb_p, mut t_p) = fresh();
        blend_tile(&order, &s, (0.0, 0.0), BlendMode::PerPixel, &mut rgb_p, &mut t_p, 0.0);
        let (mut rgb_g, mut t_g) = fresh();
        blend_tile(&order, &s, (0.0, 0.0), BlendMode::PixelGroup, &mut rgb_g, &mut t_g, 0.0);
        let mut err = 0.0f32;
        for p in 0..PIXELS {
            for c in 0..3 {
                err += (rgb_p[p][c] - rgb_g[p][c]).abs();
            }
        }
        assert!(err / PIXELS as f32 / 3.0 < 0.01, "mean err {err}");
    }

    #[test]
    fn group_mode_has_zero_divergence() {
        let s = vec![splat(5.0, 5.0, 0.7, 0.3)];
        let (mut rgb, mut t) = fresh();
        let stats = blend_tile(
            &[0],
            &s,
            (0.0, 0.0),
            BlendMode::PixelGroup,
            &mut rgb,
            &mut t,
            0.0,
        );
        // Within each 2x2 group all lanes agree; with warps aligned to
        // pixel rows, group mode can still have inter-group variation in
        // a warp, but each *group* is uniform. Check group uniformity by
        // construction: divergence utilization must be >= per-pixel's.
        let (mut rgb2, mut t2) = fresh();
        let stats_p = blend_tile(
            &[0],
            &s,
            (0.0, 0.0),
            BlendMode::PerPixel,
            &mut rgb2,
            &mut t2,
            0.0,
        );
        assert!(stats.divergence.utilization() >= stats_p.divergence.utilization());
    }

    #[test]
    fn early_termination_stops_work() {
        // Two fully opaque splats: the second is mostly skipped.
        let s = vec![splat(8.0, 8.0, 0.99, 0.001), splat(8.0, 8.0, 0.99, 0.001)];
        // 0.001 conic -> the Gaussian covers the whole tile strongly.
        let order = [0u32, 1, 1, 1];
        let (mut rgb, mut t) = fresh();
        let stats = blend_tile(
            &order,
            &s,
            (0.0, 0.0),
            BlendMode::PerPixel,
            &mut rgb,
            &mut t,
            0.5, // aggressive threshold
        );
        assert!(stats.early_terminated);
        assert!(stats.gaussians < 4);
    }

    /// Reference scan with the bounding-box restriction removed: every
    /// pixel (and every group) of the tile is evaluated for every
    /// Gaussian. [`blend_tile`]'s restricted scan must match it exactly
    /// — `tile_bbox` is conservative, so skipped pixels/groups would
    /// have been masked anyway.
    fn blend_tile_unrestricted(
        order: &[u32],
        splats: &[Splat2D],
        origin: (f32, f32),
        mode: BlendMode,
        rgb: &mut [[f32; 3]; PIXELS],
        t: &mut [f32; PIXELS],
        t_min: f32,
    ) -> BlendStats {
        let mut stats = BlendStats::default();
        for &si in order {
            let t_max = t.iter().cloned().fold(0.0f32, f32::max);
            if t_max < t_min {
                stats.early_terminated = true;
                break;
            }
            let s = &splats[si as usize];
            stats.gaussians += 1;
            match mode {
                BlendMode::PerPixel => {
                    stats.alpha_evals += PIXELS as u64;
                    for py in 0..TILE as usize {
                        for px in 0..TILE as usize {
                            let p = py * TILE as usize + px;
                            let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                            let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                            let power = gauss_power(&s.conic, dx, dy);
                            let alpha = (s.opacity * power.exp()).min(ALPHA_CLAMP);
                            let active = alpha >= ALPHA_THRESH && s.opacity > 0.0;
                            stats.divergence.record_lane(p, active);
                            if active {
                                let w = t[p] * alpha;
                                rgb[p][0] += w * s.color[0];
                                rgb[p][1] += w * s.color[1];
                                rgb[p][2] += w * s.color[2];
                                t[p] *= 1.0 - alpha;
                                stats.blends += 1;
                            }
                        }
                    }
                    stats.divergence.end_gaussian();
                }
                BlendMode::PixelGroup => {
                    stats.group_checks += GROUPS as u64;
                    let thr = group_keep_threshold(s.opacity);
                    let mut keep = [false; GROUPS];
                    for (g, k) in keep.iter_mut().enumerate() {
                        let (gy, gx) = (g / GSIDE, g % GSIDE);
                        let cx = origin.0 + 2.0 * gx as f32 + 1.0;
                        let cy = origin.1 + 2.0 * gy as f32 + 1.0;
                        let power =
                            gauss_power(&s.conic, cx - s.mean.x, cy - s.mean.y);
                        *k = power >= thr;
                    }
                    for (g, &k) in keep.iter().enumerate() {
                        if !k {
                            continue;
                        }
                        let (gy, gx) = (g / GSIDE, g % GSIDE);
                        for sy in 0..GROUP {
                            for sx in 0..GROUP {
                                let py = gy * GROUP + sy;
                                let px = gx * GROUP + sx;
                                let p = py * TILE as usize + px;
                                stats.divergence.record_lane(p, true);
                                let dx = origin.0 + px as f32 + 0.5 - s.mean.x;
                                let dy = origin.1 + py as f32 + 0.5 - s.mean.y;
                                let power = gauss_power(&s.conic, dx, dy);
                                let alpha =
                                    (s.opacity * power.exp()).min(ALPHA_CLAMP);
                                stats.alpha_evals += 1;
                                let w = t[p] * alpha;
                                rgb[p][0] += w * s.color[0];
                                rgb[p][1] += w * s.color[1];
                                rgb[p][2] += w * s.color[2];
                                t[p] *= 1.0 - alpha;
                                stats.blends += 1;
                            }
                        }
                    }
                    stats.divergence.end_gaussian();
                }
            }
        }
        stats
    }

    fn assert_restricted_matches_unrestricted(splats: &[Splat2D], label: &str) {
        let order: Vec<u32> = (0..splats.len() as u32).collect();
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            let (mut rgb_r, mut t_r) = fresh();
            let got = blend_tile(
                &order, splats, (0.0, 0.0), mode, &mut rgb_r, &mut t_r,
                1.0 / 255.0,
            );
            let (mut rgb_u, mut t_u) = fresh();
            let want = blend_tile_unrestricted(
                &order, splats, (0.0, 0.0), mode, &mut rgb_u, &mut t_u,
                1.0 / 255.0,
            );
            for p in 0..PIXELS {
                assert_eq!(
                    rgb_r[p].map(f32::to_bits),
                    rgb_u[p].map(f32::to_bits),
                    "{label} {mode:?}: rgb[{p}]"
                );
                assert_eq!(
                    t_r[p].to_bits(),
                    t_u[p].to_bits(),
                    "{label} {mode:?}: t[{p}]"
                );
            }
            assert_eq!(got, want, "{label} {mode:?}: stats");
        }
    }

    #[test]
    fn bbox_splats_straddling_each_tile_border() {
        // Footprints poking in from every side: the restricted scan
        // clamps a partial bounding box against each border.
        for (label, x, y) in [
            ("left", -3.0, 8.0),
            ("right", 19.0, 8.0),
            ("top", 8.0, -3.0),
            ("bottom", 8.0, 19.0),
            ("corner", -2.5, 18.5),
        ] {
            let s = vec![splat(x, y, 0.9, 0.4), splat(8.0, 8.0, 0.5, 0.3)];
            assert_restricted_matches_unrestricted(&s, label);
        }
    }

    #[test]
    fn bbox_fully_offscreen_footprints() {
        // Fully-left/above footprints drive `x1f`/`y1f` negative (the
        // early-miss branch), and far right/below ones push `x0`/`y0`
        // past the tile.
        for (label, x, y) in [
            ("fully-left", -40.0, 8.0),
            ("fully-above", 8.0, -40.0),
            ("fully-right", 60.0, 8.0),
            ("fully-below", 8.0, 60.0),
            ("far-corner", -40.0, -40.0),
        ] {
            let s = vec![splat(x, y, 0.9, 0.4), splat(6.0, 9.0, 0.7, 0.2)];
            assert_restricted_matches_unrestricted(&s, label);
        }
    }

    #[test]
    fn bbox_zero_and_huge_radius_splats() {
        // Zero radius with a consistently sharp conic (3.3 sigma well
        // inside the +1 px margin) and a footprint larger than the
        // whole tile (bbox clamps to the full tile).
        let mut zero = splat(8.2, 7.7, 0.9, 64.0);
        zero.radius = 0.0;
        let mut huge = splat(3.0, 12.0, 0.8, 0.0009);
        huge.radius = 1e4;
        assert_restricted_matches_unrestricted(&[zero], "zero-radius");
        assert_restricted_matches_unrestricted(&[huge], "huge-radius");
        assert_restricted_matches_unrestricted(
            &[zero, huge, splat(15.5, 0.5, 0.6, 0.5)],
            "mixed",
        );
    }

    #[test]
    fn group_keep_mask_matches_exp_form_on_real_splats() {
        // The satellite-1 contract at the blend level: for real conic
        // footprints, the no-exp compare selects exactly the groups the
        // exp-form check would, across opacities including 0 and 1.
        for opacity in [0.0, 0.003, 0.004, 0.3, 0.92, 0.99, 1.0] {
            for (x, y, sharp) in
                [(8.0, 8.0, 0.08), (2.5, 13.0, 0.3), (-1.0, 5.0, 0.05)]
            {
                let s = splat(x, y, opacity, sharp);
                let thr = group_keep_threshold(s.opacity);
                for g in 0..GROUPS {
                    let (gy, gx) = (g / GSIDE, g % GSIDE);
                    let cx = 2.0 * gx as f32 + 1.0;
                    let cy = 2.0 * gy as f32 + 1.0;
                    let power =
                        gauss_power(&s.conic, cx - s.mean.x, cy - s.mean.y);
                    let galpha = (s.opacity * power.exp()).min(ALPHA_CLAMP);
                    let want = galpha >= ALPHA_THRESH && s.opacity > 0.0;
                    assert_eq!(
                        power >= thr,
                        want,
                        "opacity {opacity} group {g} power {power}"
                    );
                }
            }
        }
    }

    #[test]
    fn padding_zero_opacity_is_inert() {
        let mut s = vec![splat(8.0, 8.0, 0.8, 0.3)];
        // Padding carries the INFINITY threshold its zero opacity implies.
        s.push(Splat2D { opacity: 0.0, keep_thresh: f32::INFINITY, ..s[0] });
        let (mut rgb_a, mut t_a) = fresh();
        blend_tile(&[0], &s, (0.0, 0.0), BlendMode::PerPixel, &mut rgb_a, &mut t_a, 0.0);
        let (mut rgb_b, mut t_b) = fresh();
        blend_tile(&[0, 1], &s, (0.0, 0.0), BlendMode::PerPixel, &mut rgb_b, &mut t_b, 0.0);
        assert_eq!(rgb_a, rgb_b);
        assert_eq!(t_a, t_b);
    }
}
