//! Artifact discovery and validation.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// The artifact names the runtime expects — must mirror
/// `python/compile/model.py: ENTRY_POINTS`.
pub const REQUIRED: [&str; 3] = ["project_n256", "splat_pixel_k64", "splat_group_k64"];

/// Resolved artifact file paths.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub project: PathBuf,
    pub splat_pixel: PathBuf,
    pub splat_group: PathBuf,
}

impl ArtifactSet {
    /// Locate and validate the artifacts in `dir`.
    pub fn discover(dir: &Path) -> Result<ArtifactSet> {
        let file = |name: &str| -> Result<PathBuf> {
            let p = dir.join(format!("{name}.hlo.txt"));
            if !p.is_file() {
                bail!(
                    "missing artifact {p:?} — run `make artifacts` first \
                     (python -m compile.aot)"
                );
            }
            Ok(p)
        };
        Ok(ArtifactSet {
            dir: dir.to_path_buf(),
            project: file(REQUIRED[0])?,
            splat_pixel: file(REQUIRED[1])?,
            splat_group: file(REQUIRED[2])?,
        })
    }

    /// Quick sanity check that the files parse as HLO text headers.
    pub fn validate_headers(&self) -> Result<()> {
        for p in [&self.project, &self.splat_pixel, &self.splat_group] {
            let head = std::fs::read_to_string(p)
                .with_context(|| format!("reading {p:?}"))?
                .chars()
                .take(200)
                .collect::<String>();
            if !head.contains("HloModule") {
                bail!("{p:?} does not look like HLO text (missing HloModule)");
            }
        }
        Ok(())
    }
}

/// The repo-relative default artifact directory, resolved from the
/// current dir or `SLTARCH_ARTIFACTS` env var.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SLTARCH_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from cwd looking for an `artifacts/` directory.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..4 {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_fails_cleanly_on_missing_dir() {
        let err = ArtifactSet::discover(Path::new("/nonexistent-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn discover_finds_real_artifacts_if_built() {
        // Soft test: only asserts when artifacts exist (CI runs
        // `make artifacts` first; unit tests shouldn't hard-require it).
        let dir = default_artifacts_dir();
        if dir.join("project_n256.hlo.txt").is_file() {
            let set = ArtifactSet::discover(&dir).unwrap();
            set.validate_headers().unwrap();
        }
    }
}
