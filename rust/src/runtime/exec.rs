//! Typed wrappers over the PJRT executables: padding, literal packing
//! and output unpacking. This is the only place raw `xla::Literal`
//! plumbing appears.

use super::engine::PjrtEngine;
use super::{K_CHUNK, PROJECT_N, TILE_PIXELS};
use crate::gaussian::{Gaussians, Splat2D};
use crate::math::{Camera, Vec2};
use crate::splat::group_keep_threshold;
use anyhow::Result;

fn lit2(data: &[f32], d0: usize, d1: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64])?)
}

fn lit1(data: &[f32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data))
}

/// Batched projection through the `project_n256` artifact.
pub struct ProjectBatch;

impl ProjectBatch {
    /// Project all of `g` through `cam`, chunking/padding to
    /// [`PROJECT_N`]. Returns one `Splat2D` per input Gaussian (colour,
    /// opacity and id are filled from the store).
    pub fn run(engine: &PjrtEngine, g: &Gaussians, cam: &Camera) -> Result<Vec<Splat2D>> {
        let viewmat = lit2(&cam.view.to_flat(), 4, 4)?;
        let intr = lit1(&cam.intr.to_array())?;
        let mut out = Vec::with_capacity(g.len());

        let mut start = 0usize;
        while start < g.len() {
            let end = (start + PROJECT_N).min(g.len());
            let idx: Vec<u32> = (start as u32..end as u32).collect();
            let batch = g.gather(&idx);
            let flat = batch.to_flat_padded(PROJECT_N);

            let outputs = PjrtEngine::run(
                &engine.project,
                &[
                    lit2(&flat.means, PROJECT_N, 3)?,
                    lit2(&flat.scales, PROJECT_N, 3)?,
                    lit2(&flat.quats, PROJECT_N, 4)?,
                    viewmat.clone(),
                    intr.clone(),
                ],
            )?;
            let mean2d = outputs[0].to_vec::<f32>()?;
            let conic = outputs[1].to_vec::<f32>()?;
            let depth = outputs[2].to_vec::<f32>()?;
            let radius = outputs[3].to_vec::<f32>()?;

            for i in 0..flat.n_real {
                let gi = start + i;
                out.push(Splat2D {
                    mean: Vec2::new(mean2d[i * 2], mean2d[i * 2 + 1]),
                    conic: [conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]],
                    depth: depth[i],
                    radius: radius[i],
                    color: g.colors[gi],
                    opacity: g.opacity[gi],
                    // Same hoisting contract as the CPU `project_one`:
                    // visible splats carry the exact per-splat keep
                    // threshold, culled ones keep-nothing.
                    keep_thresh: if radius[i] > 0.0 {
                        group_keep_threshold(g.opacity[gi])
                    } else {
                        f32::INFINITY
                    },
                    id: gi as u32,
                });
            }
            start = end;
        }
        Ok(out)
    }
}

/// Per-tile accumulation state carried across splat chunks.
#[derive(Clone, Debug)]
pub struct SplatState {
    pub rgb: Vec<f32>, // 256 x 3
    pub t: Vec<f32>,   // 256
}

impl SplatState {
    pub fn fresh() -> SplatState {
        SplatState { rgb: vec![0.0; TILE_PIXELS * 3], t: vec![1.0; TILE_PIXELS] }
    }

    /// Max remaining transmittance (early-termination test).
    pub fn t_max(&self) -> f32 {
        self.t.iter().cloned().fold(0.0, f32::max)
    }
}

/// One K_CHUNK-sized splat call on a 16x16 tile.
pub struct SplatChunk;

impl SplatChunk {
    /// Blend up to [`K_CHUNK`] splats (already depth-sorted) into the
    /// tile state. `group` selects the SLTarch group-alpha artifact.
    pub fn run(
        engine: &PjrtEngine,
        splats: &[Splat2D],
        origin: (f32, f32),
        state: &SplatState,
        group: bool,
    ) -> Result<SplatState> {
        assert!(splats.len() <= K_CHUNK, "chunk too large: {}", splats.len());
        let mut mean2d = vec![0.0f32; K_CHUNK * 2];
        let mut conic = vec![0.0f32; K_CHUNK * 3];
        // Padding conics must be SPD-ish to keep the kernel maths finite.
        for i in splats.len()..K_CHUNK {
            conic[i * 3] = 1.0;
            conic[i * 3 + 2] = 1.0;
        }
        let mut color = vec![0.0f32; K_CHUNK * 3];
        let mut opacity = vec![0.0f32; K_CHUNK]; // 0 => inert padding row
        for (i, s) in splats.iter().enumerate() {
            mean2d[i * 2] = s.mean.x;
            mean2d[i * 2 + 1] = s.mean.y;
            conic[i * 3..i * 3 + 3].copy_from_slice(&s.conic);
            color[i * 3..i * 3 + 3].copy_from_slice(&s.color);
            opacity[i] = s.opacity;
        }
        let exe = if group { &engine.splat_group } else { &engine.splat_pixel };
        let outputs = PjrtEngine::run(
            exe,
            &[
                lit2(&mean2d, K_CHUNK, 2)?,
                lit2(&conic, K_CHUNK, 3)?,
                lit2(&color, K_CHUNK, 3)?,
                lit1(&opacity)?,
                lit1(&[origin.0, origin.1])?,
                lit2(&state.rgb, TILE_PIXELS, 3)?,
                lit1(&state.t)?,
            ],
        )?;
        Ok(SplatState {
            rgb: outputs[0].to_vec::<f32>()?,
            t: outputs[1].to_vec::<f32>()?,
        })
    }
}
