//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers each entry point in `model.ENTRY_POINTS`
//! to HLO **text** under `artifacts/`; this module loads those files via
//! the `xla` crate (PJRT CPU client), compiles them once at startup, and
//! exposes typed wrappers over the raw literal plumbing. Python never
//! runs at render time — the rust binary is self-contained once
//! `make artifacts` has produced the files.

mod artifacts;
mod engine;
mod exec;

pub use artifacts::{default_artifacts_dir, ArtifactSet};
pub use engine::PjrtEngine;
pub use exec::{ProjectBatch, SplatChunk, SplatState};

/// Batch size of the projection artifact (`project_n256`).
pub const PROJECT_N: usize = 256;
/// Gaussian chunk size of the splat artifacts (`splat_*_k64`).
pub const K_CHUNK: usize = 64;
/// Pixels per tile (16 x 16).
pub const TILE_PIXELS: usize = 256;
