//! The PJRT engine: one CPU client + the compiled executables.

use super::artifacts::ArtifactSet;
use anyhow::{Context, Result};

/// Compiled-and-ready PJRT state. Construct once, render many frames.
pub struct PjrtEngine {
    pub client: xla::PjRtClient,
    pub project: xla::PjRtLoadedExecutable,
    pub splat_pixel: xla::PjRtLoadedExecutable,
    pub splat_group: xla::PjRtLoadedExecutable,
}

impl PjrtEngine {
    /// Load HLO text artifacts and compile them on the CPU client.
    pub fn load(set: &ArtifactSet) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))
        };
        Ok(PjrtEngine {
            project: compile(&set.project)?,
            splat_pixel: compile(&set.splat_pixel)?,
            splat_group: compile(&set.splat_group)?,
            client,
        })
    }

    /// Execute one compiled entry point on literal inputs and unpack the
    /// `return_tuple=True` output into its component literals.
    pub fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}
