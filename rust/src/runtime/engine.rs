//! The PJRT engine: one CPU client + the compiled executables.

use super::artifacts::ArtifactSet;
use anyhow::{Context, Result};

/// Compiled-and-ready PJRT state. Construct once, render many frames.
///
/// Fields are crate-private on purpose: the `Send` assertion below is
/// only sound because no handle to the client/executables can escape
/// this crate and alias the engine from another thread.
pub struct PjrtEngine {
    pub(crate) client: xla::PjRtClient,
    pub(crate) project: xla::PjRtLoadedExecutable,
    pub(crate) splat_pixel: xla::PjRtLoadedExecutable,
    pub(crate) splat_group: xla::PjRtLoadedExecutable,
}

// SAFETY: `Send` (ownership/borrow transfer between threads) is the
// only marker asserted — deliberately NOT `Sync`. The coordinator's
// `PjrtBackend` wraps the engine in a `Mutex`, so at most one thread
// touches the client/executables at a time; all we rely on is that the
// PJRT CPU client has no thread-affinity (it may be driven from a
// thread other than the one that created it), which the PJRT C API
// contract guarantees. If a future `xla` wrapper adds non-atomic
// shared ownership internally, serialized single-thread access through
// the mutex remains the required discipline.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    /// Load HLO text artifacts and compile them on the CPU client.
    pub fn load(set: &ArtifactSet) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))
        };
        Ok(PjrtEngine {
            project: compile(&set.project)?,
            splat_pixel: compile(&set.splat_pixel)?,
            splat_group: compile(&set.splat_group)?,
            client,
        })
    }

    /// Execute one compiled entry point on literal inputs and unpack the
    /// `return_tuple=True` output into its component literals.
    pub fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}
