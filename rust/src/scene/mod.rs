//! Synthetic scene substrate.
//!
//! The paper evaluates on the HierarchicalGS dataset (two scenes × six
//! scenarios) which is not available here; this module builds procedural
//! stand-ins that reproduce the *structural* properties the experiments
//! depend on (DESIGN.md §2):
//!
//! * heavy-tailed LoD-tree fan-out (single parents with up to 10^3
//!   children, tree height >= ~10) — the source of workload imbalance,
//! * spatially clustered geometry (streets/rooms) — the source of
//!   view-dependent cuts,
//! * scenario cameras sweeping near->far — the source of the Fig. 2
//!   bottleneck shift.

mod builder;
mod camera_path;
mod generator;

pub use builder::{build_lod_tree, BuildStats};
pub use camera_path::{orbit_cameras, scenario_cameras, walkthrough};
pub use generator::{GeneratorKind, SceneSpec};

use crate::gaussian::Gaussians;
use crate::lod::LodTree;
use crate::math::Camera;

/// A complete renderable scene: Gaussians, their LoD tree (node i ==
/// Gaussian i) and the evaluation cameras.
#[derive(Clone, Debug)]
pub struct Scene {
    pub name: String,
    pub gaussians: Gaussians,
    pub tree: LodTree,
    pub cameras: Vec<Camera>,
}

impl Scene {
    /// The i-th evaluation scenario camera (wraps around).
    pub fn scenario_camera(&self, i: usize) -> Camera {
        self.cameras[i % self.cameras.len()]
    }
}
