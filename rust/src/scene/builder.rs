//! Bottom-up LoD-tree construction over generated leaf Gaussians.
//!
//! Mirrors how HierarchicalGS builds its hierarchy: leaves are the
//! trained Gaussians; each interior node is a *merged* Gaussian standing
//! in for its children at coarser detail. Fan-out is deliberately
//! heavy-tailed (`Rng::heavy_tail`) — the paper's trees have parents
//! with >10^3 children, and that irregularity is precisely what SLTree
//! has to tame. Spatial grouping uses a Morton order so siblings are
//! spatially coherent.
//!
//! The finished tree is re-ordered to BFS (parents before children,
//! siblings contiguous) and the Gaussian store is permuted along with it
//! so that node id == Gaussian id.

use crate::gaussian::Gaussians;
use crate::lod::tree::{LodTree, Node, NONE};
use crate::math::{Aabb, Quat, Vec3};
use crate::util::Rng;

/// Construction statistics (reported by `sltarch partition --stats`).
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    pub leaves: usize,
    pub interior: usize,
    pub height: u32,
    pub max_fanout: u32,
    pub mean_fanout: f64,
}

/// Morton (Z-order) key from a quantized 3D position.
fn morton3(p: Vec3, lo: Vec3, inv_extent: Vec3) -> u64 {
    #[inline]
    fn spread(x: u32) -> u64 {
        // Spread the low 21 bits of x so consecutive bits are 3 apart.
        let mut v = x as u64 & 0x1F_FFFF;
        v = (v | (v << 32)) & 0x1F00000000FFFF;
        v = (v | (v << 16)) & 0x1F0000FF0000FF;
        v = (v | (v << 8)) & 0x100F00F00F00F00F;
        v = (v | (v << 4)) & 0x10C30C30C30C30C3;
        v = (v | (v << 2)) & 0x1249249249249249;
        v
    }
    let q = |v: f32, lo: f32, inv: f32| -> u32 {
        (((v - lo) * inv).clamp(0.0, 1.0) * ((1 << 21) - 1) as f32) as u32
    };
    spread(q(p.x, lo.x, inv_extent.x))
        | (spread(q(p.y, lo.y, inv_extent.y)) << 1)
        | (spread(q(p.z, lo.z, inv_extent.z)) << 2)
}

/// Merge a sibling group into one coarser parent Gaussian.
fn merge_group(g: &Gaussians, children: &[u32]) -> (Vec3, Vec3, Quat, [f32; 3], f32) {
    let n = children.len() as f32;
    let mut mean = Vec3::ZERO;
    let mut color = [0.0f32; 3];
    let mut opacity = 0.0;
    for &c in children {
        mean += g.mean(c as usize);
        for k in 0..3 {
            color[k] += g.colors[c as usize][k];
        }
        opacity += g.opacity[c as usize];
    }
    mean = mean / n;
    for k in &mut color {
        *k /= n;
    }
    opacity /= n;
    // Parent extent: spread of child centres plus the mean child scale,
    // so the parent visually covers the set it stands in for.
    let mut var = Vec3::ZERO;
    let mut child_scale = Vec3::ZERO;
    for &c in children {
        let d = g.mean(c as usize) - mean;
        var += d * d;
        child_scale += g.scale(c as usize);
    }
    var = var / n;
    child_scale = child_scale / n;
    let scale = Vec3::new(
        (var.x.sqrt() + child_scale.x).max(1e-4),
        (var.y.sqrt() + child_scale.y).max(1e-4),
        (var.z.sqrt() + child_scale.z).max(1e-4),
    );
    (mean, scale, Quat::IDENTITY, color, opacity)
}

/// Build the LoD tree over `leaves`, permuting the store to BFS order.
///
/// `mean_fanout` sets the centre of the heavy-tailed sibling-group size
/// distribution (the paper's trees are irregular; 4-8 reproduces the
/// HierarchicalGS skew); `max_fanout` caps it (paper observes ~10^3).
pub fn build_lod_tree(
    leaves: Gaussians,
    seed: u64,
    mean_fanout: f32,
    max_fanout: usize,
) -> (Gaussians, LodTree, BuildStats) {
    assert!(!leaves.is_empty(), "cannot build a tree over zero leaves");
    // Seed-mix so the builder's stream is independent of the generator's.
    let mut rng = Rng::new(seed ^ 0x7AEE_5EED_0000_0001);
    let n_leaves = leaves.len();

    // Working store: starts as the leaves; interior nodes appended.
    let mut store = leaves;
    // parent link per working node (NONE until assigned).
    let mut parent: Vec<u32> = vec![NONE; n_leaves];
    // children lists per interior node (indexed by working id).
    let mut children_of: Vec<Vec<u32>> = vec![Vec::new(); n_leaves];

    // Scene bounds for Morton keys.
    let mut bounds = Aabb::EMPTY;
    for i in 0..store.len() {
        bounds.grow(store.mean(i));
    }
    let ext = bounds.max - bounds.min;
    let inv = Vec3::new(
        1.0 / ext.x.max(1e-6),
        1.0 / ext.y.max(1e-6),
        1.0 / ext.z.max(1e-6),
    );

    let mut level: Vec<u32> = (0..n_leaves as u32).collect();
    let mut levels_up = 0u32;
    let mut max_fan = 0u32;
    let mut fan_sum = 0u64;
    let mut fan_cnt = 0u64;

    while level.len() > 1 {
        // Spatial order within the level.
        level.sort_by_key(|&i| morton3(store.mean(i as usize), bounds.min, inv));
        let mut next = Vec::with_capacity(level.len() / 2 + 1);
        let mut pos = 0usize;
        while pos < level.len() {
            let want = rng.heavy_tail(mean_fanout, max_fanout);
            let take = want.min(level.len() - pos).max(1);
            // Never leave a singleton remainder group at the level end
            // unless the level itself is a singleton.
            let take = if level.len() - pos - take == 1 { take + 1 } else { take };
            let group = &level[pos..pos + take];
            pos += take;
            if group.len() == 1 && level.len() == 1 {
                next.push(group[0]);
                continue;
            }
            let (mean, scale, quat, color, opacity) = merge_group(&store, group);
            let pid = store.push(mean, scale, quat, color, opacity) as u32;
            parent.push(NONE);
            children_of.push(group.to_vec());
            for &c in group {
                parent[c as usize] = pid;
            }
            max_fan = max_fan.max(group.len() as u32);
            fan_sum += group.len() as u64;
            fan_cnt += 1;
            next.push(pid);
        }
        level = next;
        levels_up += 1;
        debug_assert!(levels_up < 64, "tree build diverged");
    }
    let root_working = level[0];

    // ---- BFS reorder: working ids -> final ids --------------------------
    let total = store.len();
    let mut order = Vec::with_capacity(total); // final order: working ids
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(root_working);
    while let Some(w) = queue.pop_front() {
        order.push(w);
        for &c in &children_of[w as usize] {
            queue.push_back(c);
        }
    }
    assert_eq!(order.len(), total, "disconnected nodes in tree build");
    let mut new_id = vec![0u32; total];
    for (fid, &w) in order.iter().enumerate() {
        new_id[w as usize] = fid as u32;
    }

    // Permute the Gaussian store into BFS order.
    let gaussians = store.gather(&order);

    // Build the final node array.
    let mut nodes = Vec::with_capacity(total);
    for &w in &order {
        let kids = &children_of[w as usize];
        let first_child = kids.iter().map(|&c| new_id[c as usize]).min().unwrap_or(0);
        // BFS layout makes siblings contiguous: verify in debug builds.
        #[cfg(debug_assertions)]
        if !kids.is_empty() {
            let mut ids: Vec<u32> = kids.iter().map(|&c| new_id[c as usize]).collect();
            ids.sort_unstable();
            for (a, b) in ids.iter().zip(ids.iter().skip(1)) {
                debug_assert_eq!(b - a, 1, "siblings not contiguous");
            }
        }
        nodes.push(Node {
            parent: if parent[w as usize] == NONE {
                NONE
            } else {
                new_id[parent[w as usize] as usize]
            },
            first_child,
            child_count: kids.len() as u32,
            level: 0, // filled below
        });
    }
    // Levels: root 0, child = parent + 1 (BFS order => single pass).
    for i in 1..total {
        let p = nodes[i].parent as usize;
        nodes[i].level = nodes[p].level + 1;
    }
    let height = nodes.iter().map(|n| n.level as u32).max().unwrap_or(0) + 1;

    // AABBs bottom-up + world sizes.
    let mut aabbs = vec![Aabb::EMPTY; total];
    let mut world_size = vec![0.0f32; total];
    for i in (0..total).rev() {
        let own = gaussians.aabb(i, 3.0);
        aabbs[i] = aabbs[i].union(&own);
        world_size[i] = own.longest_edge();
        let p = nodes[i].parent;
        if p != NONE {
            aabbs[p as usize] = aabbs[p as usize].union(&aabbs[i]);
        }
    }

    let tree = LodTree { nodes, aabbs, world_size, height };
    let stats = BuildStats {
        leaves: n_leaves,
        interior: total - n_leaves,
        height,
        max_fanout: max_fan,
        mean_fanout: if fan_cnt > 0 { fan_sum as f64 / fan_cnt as f64 } else { 0.0 },
    };
    (gaussians, tree, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::generator::{GeneratorKind, SceneSpec};

    fn small() -> (Gaussians, LodTree, BuildStats) {
        let spec = SceneSpec { kind: GeneratorKind::Room, leaves: 3_000, extent: 10.0 };
        build_lod_tree(spec.generate(42), 42, 6.0, 512)
    }

    #[test]
    fn tree_invariants() {
        let (g, tree, stats) = small();
        assert_eq!(g.len(), tree.len());
        tree.check_invariants().unwrap();
        assert_eq!(stats.leaves + stats.interior, tree.len());
        assert!(stats.height >= 3, "height {}", stats.height);
    }

    #[test]
    fn fanout_is_heavy_tailed() {
        let (_, tree, stats) = small();
        assert!(stats.max_fanout as f64 > stats.mean_fanout * 4.0,
            "max {} vs mean {}", stats.max_fanout, stats.mean_fanout);
        // Unfixed child counts: at least 3 distinct fanouts must occur.
        let mut distinct = std::collections::HashSet::new();
        for n in &tree.nodes {
            if n.child_count > 0 {
                distinct.insert(n.child_count);
            }
        }
        assert!(distinct.len() >= 3, "fanouts too regular: {distinct:?}");
    }

    #[test]
    fn interior_nodes_are_coarser() {
        let (_, tree, _) = small();
        // A parent's world_size should generally exceed its children's
        // (coarser detail higher up). Check on average.
        let mut coarser = 0u32;
        let mut total = 0u32;
        for (i, n) in tree.nodes.iter().enumerate() {
            for c in tree.children(i as u32) {
                total += 1;
                if tree.world_size[i] >= tree.world_size[c as usize] {
                    coarser += 1;
                }
            }
            let _ = n;
        }
        assert!(coarser as f64 / total as f64 > 0.85,
            "hierarchy not coarsening: {coarser}/{total}");
    }

    #[test]
    fn build_is_deterministic() {
        let (g1, t1, _) = small();
        let (g2, t2, _) = small();
        assert_eq!(g1.means, g2.means);
        assert_eq!(t1.len(), t2.len());
        for (a, b) in t1.nodes.iter().zip(t2.nodes.iter()) {
            assert_eq!(a.parent, b.parent);
            assert_eq!(a.child_count, b.child_count);
        }
    }

    #[test]
    fn leaves_survive_into_tree() {
        let (g, tree, stats) = small();
        let leaf_count = tree.nodes.iter().filter(|n| n.is_leaf()).count();
        assert_eq!(leaf_count, stats.leaves);
        let _ = g;
    }

    #[test]
    #[should_panic(expected = "zero leaves")]
    fn empty_input_panics() {
        build_lod_tree(Gaussians::default(), 1, 4.0, 64);
    }
}
