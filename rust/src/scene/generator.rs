//! Procedural leaf-Gaussian generators.
//!
//! Three generators cover the workload regimes of the paper's scenes:
//! `Room` (small-scale indoor: dense, near geometry), `City` (large-scale
//! urban: street grid of building blocks, the HierarchicalGS "large
//! scene" analogue) and `Terrain` (height-field with scattered clutter,
//! exercising wide flat cuts).

use crate::gaussian::Gaussians;
use crate::math::{Quat, Vec3};
use crate::util::Rng;

/// Which procedural world to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    Room,
    City,
    Terrain,
}

/// Scene recipe: generator + leaf budget + world extent.
#[derive(Clone, Debug)]
pub struct SceneSpec {
    pub kind: GeneratorKind,
    /// Number of *leaf* Gaussians (interior LoD nodes come on top).
    pub leaves: usize,
    /// World half-extent in metres.
    pub extent: f32,
}

impl SceneSpec {
    pub fn generate(&self, seed: u64) -> Gaussians {
        let mut rng = Rng::new(seed);
        match self.kind {
            GeneratorKind::Room => room(&mut rng, self.leaves, self.extent),
            GeneratorKind::City => city(&mut rng, self.leaves, self.extent),
            GeneratorKind::Terrain => terrain(&mut rng, self.leaves, self.extent),
        }
    }
}

fn rand_quat(rng: &mut Rng) -> Quat {
    Quat::new(rng.normal(), rng.normal(), rng.normal(), rng.normal())
}

fn push_leaf(g: &mut Gaussians, rng: &mut Rng, p: Vec3, size: f32, color: [f32; 3]) {
    let scale = Vec3::new(
        size * rng.range(0.5, 1.5),
        size * rng.range(0.5, 1.5),
        size * rng.range(0.5, 1.5),
    );
    let quat = rand_quat(rng);
    let mut jitter = |c: f32| (c + rng.range(-0.1, 0.1)).clamp(0.0, 1.0);
    let color = [jitter(color[0]), jitter(color[1]), jitter(color[2])];
    let opacity = rng.range(0.35, 0.95);
    g.push(p, scale, quat, color, opacity);
}

/// Indoor room: walls/floor shells plus furniture-like clusters.
fn room(rng: &mut Rng, leaves: usize, extent: f32) -> Gaussians {
    let mut g = Gaussians::with_capacity(leaves);
    let e = extent;
    // Leaf size tracks the surface sampling spacing so the Gaussians
    // tile surfaces like trained 3DGS leaves do (keeps the LoD-tree
    // parent/child size ratio scale-invariant).
    let unit = e / (leaves as f32).sqrt();
    // 60% surfaces (walls, floor, ceiling), 40% object clusters.
    let n_surface = leaves * 6 / 10;
    for _ in 0..n_surface {
        let wall = rng.below(5);
        let (p, color) = match wall {
            0 => (Vec3::new(rng.range(-e, e), -e, rng.range(-e, e)), [0.55, 0.45, 0.35]),
            1 => (Vec3::new(rng.range(-e, e), e, rng.range(-e, e)), [0.9, 0.9, 0.85]),
            2 => (Vec3::new(-e, rng.range(-e, e), rng.range(-e, e)), [0.75, 0.7, 0.6]),
            3 => (Vec3::new(e, rng.range(-e, e), rng.range(-e, e)), [0.75, 0.7, 0.6]),
            _ => (Vec3::new(rng.range(-e, e), rng.range(-e, e), e), [0.7, 0.72, 0.75]),
        };
        push_leaf(&mut g, rng, p, 1.8 * unit, color);
    }
    // Object clusters.
    let n_clusters = 24.max(leaves / 4000);
    let cluster_centers: Vec<Vec3> = (0..n_clusters)
        .map(|_| {
            Vec3::new(
                rng.range(-e * 0.8, e * 0.8),
                rng.range(-e * 0.9, -e * 0.2),
                rng.range(-e * 0.8, e * 0.8),
            )
        })
        .collect();
    let palette = [[0.8, 0.2, 0.2], [0.2, 0.5, 0.8], [0.3, 0.7, 0.3], [0.85, 0.7, 0.2]];
    while g.len() < leaves {
        let ci = rng.below(cluster_centers.len());
        let c = cluster_centers[ci];
        let p = c + Vec3::new(rng.normal(), rng.normal(), rng.normal()) * (e * 0.05);
        push_leaf(&mut g, rng, p, 1.5 * unit, palette[ci % palette.len()]);
    }
    g
}

/// Urban grid: building blocks along streets, ground plane, canopy trees.
/// The density varies strongly block-to-block, which is what makes the
/// large-scale LoD cut view-dependent and imbalanced.
fn city(rng: &mut Rng, leaves: usize, extent: f32) -> Gaussians {
    let mut g = Gaussians::with_capacity(leaves);
    let e = extent;
    // See `room`: leaf size tracks sampling spacing.
    let unit = e / (leaves as f32).sqrt();
    let blocks = 8; // 8x8 street grid
    let block_w = 2.0 * e / blocks as f32;

    // Per-block density weights: heavy-tailed (downtown vs suburbs).
    let mut weights = Vec::with_capacity(blocks * blocks);
    for _ in 0..blocks * blocks {
        weights.push(rng.heavy_tail(4.0, 400) as f32);
    }
    let wsum: f32 = weights.iter().sum();

    // 20% ground, 70% buildings, 10% canopy.
    let n_ground = leaves / 5;
    for _ in 0..n_ground {
        let p = Vec3::new(rng.range(-e, e), 0.0, rng.range(-e, e));
        push_leaf(&mut g, rng, p, 2.0 * unit, [0.4, 0.4, 0.42]);
    }
    let n_buildings = leaves * 7 / 10;
    for _ in 0..n_buildings {
        // Pick a block by weight.
        let mut pick = rng.f32() * wsum;
        let mut bi = 0;
        for (i, w) in weights.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                bi = i;
                break;
            }
        }
        let bx = (bi % blocks) as f32;
        let bz = (bi / blocks) as f32;
        let cx = -e + (bx + 0.5) * block_w;
        let cz = -e + (bz + 0.5) * block_w;
        let height = e * 0.05 + weights[bi] / wsum * e * 4.0;
        // Points on the building shell.
        let u = rng.range(-0.35, 0.35) * block_w;
        let v = rng.range(-0.35, 0.35) * block_w;
        let y = rng.range(0.0, height);
        let face = rng.below(4);
        let (px, pz) = match face {
            0 => (cx + u, cz - 0.35 * block_w),
            1 => (cx + u, cz + 0.35 * block_w),
            2 => (cx - 0.35 * block_w, cz + v),
            _ => (cx + 0.35 * block_w, cz + v),
        };
        let shade = rng.range(0.5, 0.85);
        push_leaf(&mut g, rng, Vec3::new(px, y, pz), 1.5 * unit, [shade, shade * 0.95, shade * 0.9]);
    }
    while g.len() < leaves {
        // Canopy: clumps along streets.
        let p = Vec3::new(
            rng.range(-e, e),
            rng.range(e * 0.01, e * 0.04),
            rng.range(-e, e),
        );
        push_leaf(&mut g, rng, p, 2.5 * unit, [0.2, 0.55, 0.25]);
    }
    g
}

/// Rolling terrain with scattered rocks/bushes.
fn terrain(rng: &mut Rng, leaves: usize, extent: f32) -> Gaussians {
    let mut g = Gaussians::with_capacity(leaves);
    let e = extent;
    // See `room`: leaf size tracks sampling spacing.
    let unit = e / (leaves as f32).sqrt();
    let height = |x: f32, z: f32| -> f32 {
        let fx = x / e * 3.0;
        let fz = z / e * 3.0;
        (fx.sin() * fz.cos() + (fx * 2.3).sin() * 0.4 + (fz * 1.7).cos() * 0.3)
            * e
            * 0.08
    };
    let n_ground = leaves * 8 / 10;
    for _ in 0..n_ground {
        let x = rng.range(-e, e);
        let z = rng.range(-e, e);
        let y = height(x, z);
        let green = rng.range(0.35, 0.6);
        push_leaf(&mut g, rng, Vec3::new(x, y, z), 1.8 * unit, [0.25, green, 0.2]);
    }
    while g.len() < leaves {
        let x = rng.range(-e, e);
        let z = rng.range(-e, e);
        let y = height(x, z) + rng.range(0.0, e * 0.02);
        push_leaf(&mut g, rng, Vec3::new(x, y, z), 2.5 * unit, [0.5, 0.45, 0.4]);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_hit_leaf_budget() {
        for kind in [GeneratorKind::Room, GeneratorKind::City, GeneratorKind::Terrain] {
            let spec = SceneSpec { kind, leaves: 5_000, extent: 20.0 };
            let g = spec.generate(1);
            assert_eq!(g.len(), 5_000, "{kind:?}");
            // All attributes in sane ranges.
            for i in 0..g.len() {
                assert!(g.opacity[i] > 0.0 && g.opacity[i] <= 1.0);
                let s = g.scale(i);
                assert!(s.x > 0.0 && s.y > 0.0 && s.z > 0.0);
                for c in g.colors[i] {
                    assert!((0.0..=1.0).contains(&c));
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = SceneSpec { kind: GeneratorKind::City, leaves: 2_000, extent: 50.0 };
        let a = spec.generate(7);
        let b = spec.generate(7);
        assert_eq!(a.means, b.means);
        assert_eq!(a.opacity, b.opacity);
    }

    #[test]
    fn city_blocks_have_skewed_density() {
        let spec = SceneSpec { kind: GeneratorKind::City, leaves: 20_000, extent: 100.0 };
        let g = spec.generate(3);
        // Histogram leaves into the 8x8 block grid; expect strong skew.
        let mut hist = [0u32; 64];
        for i in 0..g.len() {
            let m = g.mean(i);
            let bx = (((m.x + 100.0) / 25.0) as usize).min(7);
            let bz = (((m.z + 100.0) / 25.0) as usize).min(7);
            hist[bz * 8 + bx] += 1;
        }
        let max = *hist.iter().max().unwrap() as f64;
        let min = *hist.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 3.0, "density not skewed: {max} vs {min}");
    }
}
