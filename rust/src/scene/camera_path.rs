//! Evaluation camera scenarios.
//!
//! The HierarchicalGS dataset pairs each scene with six rendering
//! scenarios; we reproduce the *sweep structure* the paper's figures
//! rely on: scenarios 0..5 move the camera progressively farther out
//! (and orbit), so the LoD cut migrates upward and the Fig. 2 bottleneck
//! shift (splatting-bound -> LoD-search-bound) appears naturally.

use crate::math::{Camera, Intrinsics, Vec3};

/// Six scenario cameras for a scene of half-extent `extent`, orbiting
/// the origin at increasing range and elevation.
pub fn scenario_cameras(extent: f32, width: u32, height: u32) -> Vec<Camera> {
    let intr = Intrinsics::from_fov(width, height, 60f32.to_radians());
    // Near interior view -> far aerial view.
    let ranges = [0.35, 0.6, 0.9, 1.3, 1.9, 2.6];
    let angles = [0.0f32, 0.9, 1.9, 2.9, 4.1, 5.3];
    let heights = [0.08, 0.15, 0.3, 0.5, 0.8, 1.1];
    ranges
        .iter()
        .zip(angles.iter())
        .zip(heights.iter())
        .map(|((&r, &a), &h)| {
            let eye = Vec3::new(
                extent * r * a.cos(),
                extent * h,
                extent * r * a.sin(),
            );
            Camera::look_at(eye, Vec3::ZERO, Vec3::new(0.0, 1.0, 0.0), intr)
        })
        .collect()
}

/// A smooth orbital path of `n` cameras at fixed range (ablation sweeps).
pub fn orbit_cameras(extent: f32, range: f32, n: usize, width: u32, height: u32) -> Vec<Camera> {
    let intr = Intrinsics::from_fov(width, height, 60f32.to_radians());
    (0..n)
        .map(|i| {
            let a = i as f32 / n as f32 * std::f32::consts::TAU;
            let eye = Vec3::new(
                extent * range * a.cos(),
                extent * 0.3,
                extent * range * a.sin(),
            );
            Camera::look_at(eye, Vec3::ZERO, Vec3::new(0.0, 1.0, 0.0), intr)
        })
        .collect()
}

/// A VR-walkthrough trajectory: dolly in from afar, swing through the
/// scene centre, and pull back out — `n` frames covering near and far
/// regimes (used by `examples/vr_walkthrough.rs`).
pub fn walkthrough(extent: f32, n: usize, width: u32, height: u32) -> Vec<Camera> {
    let intr = Intrinsics::from_fov(width, height, 60f32.to_radians());
    (0..n)
        .map(|i| {
            let t = i as f32 / (n - 1).max(1) as f32; // 0..1
            // Range: far -> near -> far (cosine ease).
            let range = 0.35 + 1.8 * (std::f32::consts::PI * (t * 2.0 - 1.0)).cos().mul_add(-0.5, 0.5).max(0.0);
            let a = t * std::f32::consts::TAU * 0.75;
            let eye = Vec3::new(
                extent * range * a.cos(),
                extent * (0.12 + 0.5 * t),
                extent * range * a.sin(),
            );
            let target = Vec3::new(0.0, extent * 0.05, 0.0);
            Camera::look_at(eye, target, Vec3::new(0.0, 1.0, 0.0), intr)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_scenarios_increasing_range() {
        let cams = scenario_cameras(100.0, 256, 256);
        assert_eq!(cams.len(), 6);
        let mut last = 0.0;
        for c in &cams {
            let r = c.eye().length();
            assert!(r > last, "ranges must increase: {r} <= {last}");
            last = r;
        }
    }

    #[test]
    fn cameras_look_at_origin() {
        for c in scenario_cameras(50.0, 256, 256) {
            // Origin should project near the principal point.
            let d = c.depth(Vec3::ZERO);
            assert!(d > 0.0, "origin must be in front of the camera");
        }
    }

    #[test]
    fn walkthrough_covers_near_and_far() {
        let cams = walkthrough(80.0, 32, 256, 256);
        assert_eq!(cams.len(), 32);
        let ranges: Vec<f32> = cams.iter().map(|c| c.eye().length()).collect();
        let min = ranges.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = ranges.iter().cloned().fold(0.0f32, f32::max);
        assert!(max / min > 2.0, "trajectory too flat: {min}..{max}");
    }

    #[test]
    fn orbit_is_closed_loop() {
        let cams = orbit_cameras(50.0, 1.0, 8, 128, 128);
        assert_eq!(cams.len(), 8);
        for c in &cams {
            assert!((c.eye().length()
                - (50.0f32.powi(2) + 15.0f32.powi(2)).sqrt())
            .abs()
                < 1.0);
        }
    }
}
