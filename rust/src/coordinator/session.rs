//! Long-lived render sessions: one per client camera stream.
//!
//! A [`RenderSession`] borrows an immutable [`FramePipeline`] (scene +
//! SLTree + config + backend) and owns everything mutable a stream
//! needs: its [`RenderOptions`], its front-end [`FrameScratch`] (so
//! single-frame renders are as allocation-lean as batched paths), its
//! temporal [`CutCache`] (frame-to-frame LoD search reuse along the
//! stream's camera path, bit-identical to the full search) and a
//! unified [`RenderStats`] accumulator with per-stage timings. Sessions
//! are independent, so N clients over one `&FramePipeline` form a
//! thread-safe serving surface (see `examples/multi_client.rs`).

use super::backend::{RenderBackend, RenderOptions};
use super::pipeline::FramePipeline;
use super::renderer::{default_threads, front_end_timed, FrameScratch};
use super::stats::{RenderStats, StageTimings};
use crate::gaussian::Gaussians;
use crate::lod::{CutCache, TraversalTrace};
use crate::math::Camera;
use crate::metrics::Image;
use crate::residency::{ResidencyManager, ResidencyStats};
use anyhow::Result;
use std::time::Instant;

/// One client's rendering state over a shared pipeline.
///
/// Fields are `pub(crate)` so the multi-view [`ViewBatch`]
/// (`super::batch`) can drive the same per-frame stages with
/// cross-view sharing (seeded searches through a neighbour's cut
/// cache, reused rendering queues, deferred interleaved blending)
/// while committing through the exact same [`FrameWork`] bookkeeping
/// `render` uses — that is what keeps batch stats bit-identical to
/// independent sessions.
pub struct RenderSession<'p> {
    pub(crate) pipeline: &'p FramePipeline,
    pub(crate) backend: &'p dyn RenderBackend,
    pub(crate) opts: RenderOptions,
    pub(crate) scratch: FrameScratch,
    /// Reusable rendering-queue buffer (the gathered cut); with it the
    /// steady-state frame really allocates only its output image.
    pub(crate) queue: Gaussians,
    pub(crate) cut_cache: CutCache,
    /// Out-of-core slab residency (active only when
    /// [`RenderOptions::residency`] is enabled): replays each frame's
    /// slab-access trace after the search, so it can never change what
    /// the search computed.
    pub(crate) residency: ResidencyManager,
    /// Simulated demand-stall seconds of the most recent frame (0 when
    /// residency is disabled) — the serving layer folds this into its
    /// QoS miss signal.
    pub(crate) last_stall: f64,
    pub(crate) stats: RenderStats,
}

/// Per-frame bookkeeping for one in-flight frame of one session: stage
/// timings plus every deterministic counter the frame will commit.
/// Accumulated locally and committed to the session's [`RenderStats`]
/// only once the whole frame succeeded, so a mid-frame error can never
/// leave the counters mutually inconsistent. Both the single-view
/// [`RenderSession::render`] and the multi-view batch path
/// (`super::batch`) flow through this one struct.
pub(crate) struct FrameWork {
    /// Frame start (drives `wall_seconds` + the latency histogram).
    pub(crate) started: Instant,
    pub(crate) stages: StageTimings,
    pub(crate) cut_len: u64,
    /// (gaussian, tile) pairs this frame binned. The single-view path
    /// reads it off its own scratch after the front end; batch views
    /// that reuse a neighbour's prepared front end copy the owner's
    /// value so their stats match an independent render.
    pub(crate) pairs: u64,
    pub(crate) cache_hit: u64,
    pub(crate) revalidated: u64,
    pub(crate) reseeded: u64,
    pub(crate) verdicts_skipped: u64,
    pub(crate) residency: ResidencyStats,
}

impl FrameWork {
    /// Fold one LoD-search trace's cache counters into the frame.
    pub(crate) fn record_search(&mut self, trace: &TraversalTrace) {
        self.cache_hit += trace.cache_hit;
        self.revalidated += trace.revalidated;
        self.reseeded += trace.reseeded;
        self.verdicts_skipped += trace.verdicts_skipped;
    }
}

impl<'p> RenderSession<'p> {
    pub(crate) fn new(
        pipeline: &'p FramePipeline,
        backend: &'p dyn RenderBackend,
        opts: RenderOptions,
    ) -> Self {
        RenderSession {
            pipeline,
            backend,
            opts,
            scratch: FrameScratch::new(),
            queue: Gaussians::default(),
            cut_cache: CutCache::new(),
            residency: ResidencyManager::new(),
            last_stall: 0.0,
            stats: RenderStats::default(),
        }
    }

    /// The pipeline this session renders from.
    pub fn pipeline(&self) -> &'p FramePipeline {
        self.pipeline
    }

    /// The backend blending this session's frames.
    pub fn backend(&self) -> &'p dyn RenderBackend {
        self.backend
    }

    /// Current render options.
    pub fn options(&self) -> &RenderOptions {
        &self.opts
    }

    /// Mutable render options (e.g. a tau sweep mid-stream).
    pub fn options_mut(&mut self) -> &mut RenderOptions {
        &mut self.opts
    }

    /// Statistics accumulated since creation / the last reset.
    pub fn stats(&self) -> &RenderStats {
        &self.stats
    }

    /// The session's temporal cut cache (LoD-search frontier reuse
    /// across this stream's frames). Read-only; the policy knob is
    /// [`RenderOptions::cut_cache`] via [`RenderSession::options_mut`].
    pub fn cut_cache(&self) -> &CutCache {
        &self.cut_cache
    }

    /// The session's slab residency manager (unbound until the first
    /// residency-enabled frame). Read-only; the knob is
    /// [`RenderOptions::residency`] via [`RenderSession::options_mut`].
    pub fn residency(&self) -> &ResidencyManager {
        &self.residency
    }

    /// Simulated out-of-core demand-stall seconds of the most recent
    /// frame (0 when residency is disabled). The serving layer adds
    /// this to the observed latency it feeds the QoS controller, so
    /// adaptive tau responds to memory pressure too.
    pub fn last_residency_stall_seconds(&self) -> f64 {
        self.last_stall
    }

    /// The unified scheduler width for this session: the backend's
    /// resolved tile-scheduler width when it has one (CPU), else the
    /// session's `RenderOptions::threads`, else the process default.
    /// One knob drives the parallel front end (project -> CSR bin ->
    /// tile sort) and the CPU blend-stage tile scheduler together, so
    /// offload backends still get a parallel CPU front end.
    pub fn scheduler_width(&self) -> usize {
        let backend = self.backend.threads(&self.opts);
        if backend > 0 {
            backend
        } else if self.opts.threads > 0 {
            self.opts.threads
        } else {
            default_threads()
        }
    }

    /// Return the accumulated statistics and start a fresh window.
    pub fn reset_stats(&mut self) -> RenderStats {
        std::mem::take(&mut self.stats)
    }

    /// Start a frame: arm the cut cache's residency touch collection
    /// and open the local [`FrameWork`] bookkeeping the frame commits
    /// through on success.
    pub(crate) fn begin_frame(&mut self) -> FrameWork {
        // Warm-frame residency replay needs the revalidation touch
        // stream, which the cut cache only collects when asked.
        self.cut_cache.set_collect_touched(self.opts.residency.enabled);
        FrameWork {
            started: Instant::now(),
            stages: StageTimings::default(),
            cut_len: 0,
            pairs: 0,
            cache_hit: 0,
            revalidated: 0,
            reseeded: 0,
            verdicts_skipped: 0,
            residency: ResidencyStats::default(),
        }
    }

    /// LoD-search + gather stage through this session's own cut cache,
    /// then the residency replay. The batch path substitutes a
    /// neighbour's cache (cross-view seeding) and calls
    /// [`RenderSession::charge_residency`] itself.
    pub(crate) fn search_and_gather(&mut self, cam: &Camera, fw: &mut FrameWork) {
        let t = Instant::now();
        let trace = {
            let (cut, trace) = self.cut_cache.search(
                &self.pipeline.scene().tree,
                self.pipeline.sltree(),
                cam,
                self.opts.lod_tau,
                &self.opts.cut_cache,
            );
            // Gather into the session-owned queue buffer: no per-frame
            // rendering-queue allocation once the buffers are warm.
            self.pipeline.scene().gaussians.gather_into(cut, &mut self.queue);
            fw.cut_len = cut.len() as u64;
            trace
        };
        fw.record_search(&trace);
        fw.stages.record_stage(StageTimings::SEARCH, t.elapsed().as_secs_f64());
        let cut = std::mem::take(&mut self.cut_cache);
        self.charge_residency(&trace, cut.cut(), fw);
        self.cut_cache = cut;
    }

    /// Replay the frame's slab-access streams through the residency
    /// manager: revalidation touches first (empty on cold frames),
    /// then activation fetches. Strictly after the search, so the
    /// pixels can never depend on residency state. `cut` is the frame's
    /// selected cut — passed in because the batch path may have
    /// searched through a *different* session's cache.
    pub(crate) fn charge_residency(
        &mut self,
        trace: &TraversalTrace,
        cut: &[u32],
        fw: &mut FrameWork,
    ) {
        let residency_delta = if self.opts.residency.enabled {
            let streams: [&[u32]; 2] =
                [&trace.touched_sids, &trace.activation_sids];
            self.residency.charge_frame(
                self.pipeline.sltree(),
                cut,
                &streams,
                &self.opts.residency,
                &self.pipeline.arch().dram,
            )
        } else {
            ResidencyStats::default()
        };
        self.last_stall = residency_delta.stall_seconds;
        fw.residency = residency_delta;
    }

    /// Front-end stage (project -> CSR bin -> depth sort) over this
    /// session's own queue and scratch at the unified scheduler width.
    pub(crate) fn front_end(&mut self, cam: &Camera, fw: &mut FrameWork) -> Result<()> {
        let width = self.scheduler_width();
        front_end_timed(&self.queue, cam, &mut self.scratch, &mut fw.stages, width)?;
        fw.pairs = self.scratch.bins.pairs;
        Ok(())
    }

    /// Commit a successfully finished frame's bookkeeping into the
    /// session's accumulated [`RenderStats`]. Never called on the
    /// error path, so a blend error can never leave the counters
    /// mutually inconsistent (cut_total counting a frame that
    /// `frames`/`pairs_total` do not).
    pub(crate) fn commit_frame(&mut self, fw: &FrameWork) {
        self.stats.stages.accumulate(&fw.stages);
        self.stats.cut_total += fw.cut_len;
        self.stats.pairs_total += fw.pairs;
        self.stats.cache_hit += fw.cache_hit;
        self.stats.revalidated += fw.revalidated;
        self.stats.reseeded += fw.reseeded;
        self.stats.verdicts_skipped += fw.verdicts_skipped;
        self.stats.residency.accumulate(&fw.residency);
        self.stats.frames += 1;
        self.stats.threads = self.backend.threads(&self.opts);
        self.stats.front_end_threads = self.scheduler_width();
        let frame_seconds = fw.started.elapsed().as_secs_f64();
        self.stats.wall_seconds += frame_seconds;
        self.stats.frame_latency.record(frame_seconds);
    }

    /// Render one frame. Reuses this session's front-end scratch and
    /// temporal cut cache, so a steady-state frame allocates only its
    /// output image; output is bit-identical to the stateless reference
    /// renderer (`CpuRenderer`) at any thread count — the cut cache
    /// reproduces the full LoD search exactly (see
    /// [`crate::lod::cut_cache`]), it only makes the search stage
    /// faster on coherent camera paths.
    pub fn render(&mut self, cam: &Camera) -> Result<Image> {
        let mut fw = self.begin_frame();
        self.search_and_gather(cam, &mut fw);
        self.front_end(cam, &mut fw)?;

        let mut img = Image::new(cam.intr.width, cam.intr.height);
        let t = Instant::now();
        self.backend
            .blend(&mut self.scratch, &self.opts, self.pipeline.rcfg(), &mut img)?;
        fw.stages.record_stage(StageTimings::BLEND, t.elapsed().as_secs_f64());

        self.commit_frame(&fw);
        Ok(img)
    }

    /// Render a whole camera path through this session (scratch and
    /// stats carry across frames, as in the old `render_path`).
    pub fn render_path(&mut self, cams: &[Camera]) -> Result<Vec<Image>> {
        let mut images = Vec::with_capacity(cams.len());
        for cam in cams {
            images.push(self.render(cam)?);
        }
        Ok(images)
    }
}
