//! Long-lived render sessions: one per client camera stream.
//!
//! A [`RenderSession`] borrows an immutable [`FramePipeline`] (scene +
//! SLTree + config + backend) and owns everything mutable a stream
//! needs: its [`RenderOptions`], its front-end [`FrameScratch`] (so
//! single-frame renders are as allocation-lean as batched paths), its
//! temporal [`CutCache`] (frame-to-frame LoD search reuse along the
//! stream's camera path, bit-identical to the full search) and a
//! unified [`RenderStats`] accumulator with per-stage timings. Sessions
//! are independent, so N clients over one `&FramePipeline` form a
//! thread-safe serving surface (see `examples/multi_client.rs`).

use super::backend::{RenderBackend, RenderOptions};
use super::pipeline::FramePipeline;
use super::renderer::{default_threads, front_end_timed, FrameScratch};
use super::stats::{RenderStats, StageTimings};
use crate::gaussian::Gaussians;
use crate::lod::CutCache;
use crate::math::Camera;
use crate::metrics::Image;
use crate::residency::{ResidencyManager, ResidencyStats};
use anyhow::Result;
use std::time::Instant;

/// One client's rendering state over a shared pipeline.
pub struct RenderSession<'p> {
    pipeline: &'p FramePipeline,
    backend: &'p dyn RenderBackend,
    opts: RenderOptions,
    scratch: FrameScratch,
    /// Reusable rendering-queue buffer (the gathered cut); with it the
    /// steady-state frame really allocates only its output image.
    queue: Gaussians,
    cut_cache: CutCache,
    /// Out-of-core slab residency (active only when
    /// [`RenderOptions::residency`] is enabled): replays each frame's
    /// slab-access trace after the search, so it can never change what
    /// the search computed.
    residency: ResidencyManager,
    /// Simulated demand-stall seconds of the most recent frame (0 when
    /// residency is disabled) — the serving layer folds this into its
    /// QoS miss signal.
    last_stall: f64,
    stats: RenderStats,
}

impl<'p> RenderSession<'p> {
    pub(crate) fn new(
        pipeline: &'p FramePipeline,
        backend: &'p dyn RenderBackend,
        opts: RenderOptions,
    ) -> Self {
        RenderSession {
            pipeline,
            backend,
            opts,
            scratch: FrameScratch::new(),
            queue: Gaussians::default(),
            cut_cache: CutCache::new(),
            residency: ResidencyManager::new(),
            last_stall: 0.0,
            stats: RenderStats::default(),
        }
    }

    /// The pipeline this session renders from.
    pub fn pipeline(&self) -> &'p FramePipeline {
        self.pipeline
    }

    /// The backend blending this session's frames.
    pub fn backend(&self) -> &'p dyn RenderBackend {
        self.backend
    }

    /// Current render options.
    pub fn options(&self) -> &RenderOptions {
        &self.opts
    }

    /// Mutable render options (e.g. a tau sweep mid-stream).
    pub fn options_mut(&mut self) -> &mut RenderOptions {
        &mut self.opts
    }

    /// Statistics accumulated since creation / the last reset.
    pub fn stats(&self) -> &RenderStats {
        &self.stats
    }

    /// The session's temporal cut cache (LoD-search frontier reuse
    /// across this stream's frames). Read-only; the policy knob is
    /// [`RenderOptions::cut_cache`] via [`RenderSession::options_mut`].
    pub fn cut_cache(&self) -> &CutCache {
        &self.cut_cache
    }

    /// The session's slab residency manager (unbound until the first
    /// residency-enabled frame). Read-only; the knob is
    /// [`RenderOptions::residency`] via [`RenderSession::options_mut`].
    pub fn residency(&self) -> &ResidencyManager {
        &self.residency
    }

    /// Simulated out-of-core demand-stall seconds of the most recent
    /// frame (0 when residency is disabled). The serving layer adds
    /// this to the observed latency it feeds the QoS controller, so
    /// adaptive tau responds to memory pressure too.
    pub fn last_residency_stall_seconds(&self) -> f64 {
        self.last_stall
    }

    /// The unified scheduler width for this session: the backend's
    /// resolved tile-scheduler width when it has one (CPU), else the
    /// session's `RenderOptions::threads`, else the process default.
    /// One knob drives the parallel front end (project -> CSR bin ->
    /// tile sort) and the CPU blend-stage tile scheduler together, so
    /// offload backends still get a parallel CPU front end.
    pub fn scheduler_width(&self) -> usize {
        let backend = self.backend.threads(&self.opts);
        if backend > 0 {
            backend
        } else if self.opts.threads > 0 {
            self.opts.threads
        } else {
            default_threads()
        }
    }

    /// Return the accumulated statistics and start a fresh window.
    pub fn reset_stats(&mut self) -> RenderStats {
        std::mem::take(&mut self.stats)
    }

    /// Render one frame. Reuses this session's front-end scratch and
    /// temporal cut cache, so a steady-state frame allocates only its
    /// output image; output is bit-identical to the stateless reference
    /// renderer (`CpuRenderer`) at any thread count — the cut cache
    /// reproduces the full LoD search exactly (see
    /// [`crate::lod::cut_cache`]), it only makes the search stage
    /// faster on coherent camera paths.
    pub fn render(&mut self, cam: &Camera) -> Result<Image> {
        let frame_t0 = Instant::now();
        // Accumulate the frame locally and commit to `self.stats` only
        // once the whole frame succeeded, so a blend error can never
        // leave the counters mutually inconsistent (cut_total counting
        // a frame that `frames`/`pairs_total` do not).
        let mut stages = StageTimings::default();

        // Warm-frame residency replay needs the revalidation touch
        // stream, which the cut cache only collects when asked.
        self.cut_cache.set_collect_touched(self.opts.residency.enabled);

        let t = Instant::now();
        let (cut_len, search_trace) = {
            let (cut, trace) = self.cut_cache.search(
                &self.pipeline.scene().tree,
                self.pipeline.sltree(),
                cam,
                self.opts.lod_tau,
                &self.opts.cut_cache,
            );
            // Gather into the session-owned queue buffer: no per-frame
            // rendering-queue allocation once the buffers are warm.
            self.pipeline.scene().gaussians.gather_into(cut, &mut self.queue);
            (cut.len() as u64, trace)
        };
        stages.record_stage(StageTimings::SEARCH, t.elapsed().as_secs_f64());

        // Replay the frame's slab-access streams through the residency
        // manager: revalidation touches first (empty on cold frames),
        // then activation fetches. Strictly after the search, so the
        // pixels can never depend on residency state.
        let residency_delta = if self.opts.residency.enabled {
            let streams: [&[u32]; 2] =
                [&search_trace.touched_sids, &search_trace.activation_sids];
            self.residency.charge_frame(
                self.pipeline.sltree(),
                self.cut_cache.cut(),
                &streams,
                &self.opts.residency,
                &self.pipeline.arch().dram,
            )
        } else {
            ResidencyStats::default()
        };
        self.last_stall = residency_delta.stall_seconds;

        let width = self.scheduler_width();
        front_end_timed(&self.queue, cam, &mut self.scratch, &mut stages, width)?;

        let mut img = Image::new(cam.intr.width, cam.intr.height);
        let t = Instant::now();
        self.backend
            .blend(&mut self.scratch, &self.opts, self.pipeline.rcfg(), &mut img)?;
        stages.record_stage(StageTimings::BLEND, t.elapsed().as_secs_f64());

        self.stats.stages.accumulate(&stages);
        self.stats.cut_total += cut_len;
        self.stats.pairs_total += self.scratch.bins.pairs;
        self.stats.cache_hit += search_trace.cache_hit;
        self.stats.revalidated += search_trace.revalidated;
        self.stats.reseeded += search_trace.reseeded;
        self.stats.residency.accumulate(&residency_delta);
        self.stats.frames += 1;
        self.stats.threads = self.backend.threads(&self.opts);
        self.stats.front_end_threads = width;
        let frame_seconds = frame_t0.elapsed().as_secs_f64();
        self.stats.wall_seconds += frame_seconds;
        self.stats.frame_latency.record(frame_seconds);
        Ok(img)
    }

    /// Render a whole camera path through this session (scratch and
    /// stats carry across frames, as in the old `render_path`).
    pub fn render_path(&mut self, cams: &[Camera]) -> Result<Vec<Image>> {
        let mut images = Vec::with_capacity(cams.len());
        for cam in cams {
            images.push(self.render(cam)?);
        }
        Ok(images)
    }
}
