//! Frame-workload extraction: run the real pipeline once and distil the
//! traces the hardware models replay (DESIGN.md §2 — all Fig. 9/10/11/12
//! variants are compared on identical, actually-executed work).

use crate::config::RenderConfig;
use crate::gaussian::project;
use crate::lod::{naive_static_workloads, traverse_sltree, SlTree};
use crate::math::Camera;
use crate::scene::Scene;
use crate::sim::workload::{LodWorkload, SplatWorkload};
use crate::splat::{bin_splats, blend_tile, sort_bins_by_depth, BlendMode, BlendStats};
use crate::splat::blend::PIXELS;

/// Build the LoD-search workload for one frame.
pub fn lod_workload(
    scene: &Scene,
    slt: &SlTree,
    cam: &Camera,
    rcfg: &RenderConfig,
    gpu_threads: usize,
) -> (Vec<u32>, LodWorkload) {
    let (cut, trace) =
        traverse_sltree(&scene.tree, slt, cam, rcfg.lod_tau, 4);
    let (_, canon_trace) = scene.tree.canonical_search(cam, rcfg.lod_tau);
    let naive = naive_static_workloads(&scene.tree, cam, rcfg.lod_tau, gpu_threads);
    let w = LodWorkload {
        total_nodes: scene.tree.len() as u64,
        canonical_visited: canon_trace.visited,
        cut_len: cut.len() as u64,
        trace,
        naive_thread_loads: naive,
    };
    (cut, w)
}

/// Build the splatting workload for one frame given the cut.
pub fn splat_workload(
    scene: &Scene,
    cut: &[u32],
    cam: &Camera,
    rcfg: &RenderConfig,
) -> SplatWorkload {
    let queue = scene.gaussians.gather(cut);
    let splats = project(&queue, cam);
    let mut bins = bin_splats(&splats, cam.intr.width, cam.intr.height);
    // Depth-sort every CSR slice in place — no per-tile clones.
    sort_bins_by_depth(&mut bins, &splats);

    let mut pixel = BlendStats::default();
    let mut group = BlendStats::default();
    let mut tile_lens = Vec::with_capacity(bins.tile_count());
    let mut rgb = [[0.0f32; 3]; PIXELS];
    let mut t = [0.0f32; PIXELS];

    for idx in 0..bins.tile_count() {
        let order = bins.tile(idx);
        tile_lens.push(order.len() as u64);
        if order.is_empty() {
            continue;
        }
        let origin = bins.tile_origin(idx);
        // Per-pixel pass.
        rgb.iter_mut().for_each(|p| *p = [0.0; 3]);
        t.iter_mut().for_each(|v| *v = 1.0);
        let sp = blend_tile(
            order, &splats, origin, BlendMode::PerPixel, &mut rgb, &mut t,
            rcfg.t_min,
        );
        pixel.merge(&sp);
        // Group pass.
        rgb.iter_mut().for_each(|p| *p = [0.0; 3]);
        t.iter_mut().for_each(|v| *v = 1.0);
        let sg = blend_tile(
            order, &splats, origin, BlendMode::PixelGroup, &mut rgb, &mut t,
            rcfg.t_min,
        );
        group.merge(&sg);
    }

    SplatWorkload {
        queue_len: cut.len() as u64,
        pairs: bins.pairs,
        tile_lens,
        pixel,
        group,
        image_bytes: cam.intr.width as u64 * cam.intr.height as u64 * 12,
    }
}

/// Full frame workload (LoD + splat) in one call.
pub fn frame_workload(
    scene: &Scene,
    slt: &SlTree,
    cam: &Camera,
    rcfg: &RenderConfig,
) -> (LodWorkload, SplatWorkload) {
    let (cut, lod) = lod_workload(scene, slt, cam, rcfg, 64);
    let splat = splat_workload(scene, &cut, cam, rcfg);
    (lod, splat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;

    #[test]
    fn workload_is_internally_consistent() {
        let scene = SceneConfig::small_scale().quick().build(5);
        let slt = SlTree::partition(&scene.tree, 32);
        let rcfg = RenderConfig::default();
        let cam = scene.scenario_camera(1);
        let (lod, splat) = frame_workload(&scene, &slt, &cam, &rcfg);
        assert_eq!(lod.cut_len, splat.queue_len);
        assert_eq!(lod.trace.selected, lod.cut_len);
        assert!(lod.canonical_visited >= lod.trace.visited);
        assert_eq!(
            splat.tile_lens.iter().sum::<u64>(),
            splat.pairs,
            "tile lists must account for every pair"
        );
        // Group dataflow does ~4x fewer checks than per-pixel evals on
        // the same frame.
        assert!(splat.group.group_checks * 3 < splat.pixel.alpha_evals);
    }

    #[test]
    fn group_utilization_beats_pixel() {
        let scene = SceneConfig::small_scale().quick().build(6);
        let slt = SlTree::partition(&scene.tree, 32);
        let rcfg = RenderConfig::default();
        let cam = scene.scenario_camera(0);
        let (_, splat) = frame_workload(&scene, &slt, &cam, &rcfg);
        assert!(
            splat.group.divergence.utilization()
                >= splat.pixel.divergence.utilization(),
            "group {} !>= pixel {}",
            splat.group.divergence.utilization(),
            splat.pixel.divergence.utilization()
        );
    }
}
