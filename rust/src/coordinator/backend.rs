//! Rendering backends: where the blending maths runs.
//!
//! The frame *front end* (LoD search -> projection -> CSR binning ->
//! radix depth sort) is backend-agnostic and runs in
//! [`super::session::RenderSession`]; a [`RenderBackend`] consumes the
//! prepared, depth-sorted [`FrameScratch`] and produces pixels. Both
//! built-in backends therefore see bit-identical sorted bins — the
//! cross-backend correctness contract `rust/tests/pjrt_roundtrip.rs`
//! asserts.

use super::renderer::{
    blend_tiles, blend_tiles_batch, blend_tiles_pjrt, default_threads,
    AlphaMode, BatchBlendView, FrameScratch,
};
use crate::config::RenderConfig;
use crate::lod::CutCacheConfig;
use crate::metrics::Image;
use crate::residency::ResidencyConfig;
use crate::runtime::PjrtEngine;
use crate::splat::{BatchWorkItem, BlendKernel, TileState};
use anyhow::Result;

/// Typed per-session render knobs (replaces the per-call `AlphaMode`
/// argument and the `SLTARCH_THREADS` hot-path env read of the old API).
#[derive(Clone, Copy, Debug)]
pub struct RenderOptions {
    /// Alpha dataflow: canonical per-pixel or SLTarch 2x2 group.
    pub alpha: AlphaMode,
    /// CPU blend-kernel implementation: the branchy AoS scalar
    /// reference loop or the divergence-free SoA kernel
    /// ([`crate::splat::kernel`], the software SPcore). Byte-identical
    /// outputs per alpha mode — this knob only trades blend time.
    /// Defaults to the SoA kernel since its SIMD-shaped row rework;
    /// pick [`BlendKernel::Scalar`] to run the reference loop. Offload
    /// backends (PJRT) ignore it.
    pub kernel: BlendKernel,
    /// LoD granularity in projected pixels (the paper's tau).
    pub lod_tau: f32,
    /// Unified scheduler width: drives the chunked projection, the
    /// parallel CSR binning, the parallel tile sort AND the CPU blend
    /// tile scheduler (`RenderSession::scheduler_width`). 0 defers to
    /// the backend's width (which itself falls back to
    /// `SLTARCH_THREADS` / the machine).
    pub threads: usize,
    /// Temporal cut-cache policy for the session's LoD search: when the
    /// incremental frame-to-frame revalidation path may run and when it
    /// must fall back to a full traversal. The cut is bit-identical to
    /// the full search either way; this only trades search time.
    pub cut_cache: CutCacheConfig,
    /// Out-of-core slab residency: disabled by default; enable with a
    /// byte budget ([`ResidencyConfig::with_budget`]) to manage subtree
    /// slabs under memory pressure (demand faulting + pinned LRU
    /// eviction + cut-delta prefetch). Pixels are byte-identical either
    /// way; this only adds simulated demand-stall time and telemetry
    /// ([`crate::coordinator::RenderStats::residency`]).
    pub residency: ResidencyConfig,
}

impl Default for RenderOptions {
    fn default() -> Self {
        RenderOptions {
            alpha: AlphaMode::Group,
            kernel: BlendKernel::Soa,
            lod_tau: 32.0,
            threads: 0,
            cut_cache: CutCacheConfig::default(),
            residency: ResidencyConfig::default(),
        }
    }
}

/// A rendering backend: blends a prepared (projected, binned,
/// depth-sorted) frame into an image. `Send + Sync` so one pipeline can
/// serve concurrent sessions from multiple client threads.
pub trait RenderBackend: Send + Sync {
    /// Short backend name for reports.
    fn name(&self) -> &'static str;

    /// Tile-scheduler worker count a session with `opts` will use
    /// (0 = not a threaded backend).
    fn threads(&self, opts: &RenderOptions) -> usize;

    /// Blend `scratch` (already projected, binned and depth-sorted)
    /// into `img`. The scratch is mutable so CPU kernels can use its
    /// per-worker accumulation pools (`FrameScratch::tiles`); the
    /// prepared bins/splats are only read.
    fn blend(
        &self,
        scratch: &mut FrameScratch,
        opts: &RenderOptions,
        rcfg: &RenderConfig,
        img: &mut Image,
    ) -> Result<()>;

    /// Blend a whole multi-view batch: `views` holds each view's
    /// prepared scratch + output image, `items` the interleaved
    /// `(view, tile)` schedule covering every non-empty tile of every
    /// view exactly once, and `pool` a caller-owned SoA tile-state
    /// pool shared across the batch.
    ///
    /// The default implementation ignores the combined schedule and
    /// blends each view independently through [`RenderBackend::blend`]
    /// — correct for any backend (the schedule covers exactly the tiles
    /// a per-view blend would touch), just without cross-view work
    /// stealing. The CPU backend overrides it with the interleaved
    /// single-cursor scheduler. Either way the output is byte-identical
    /// to per-view blends.
    fn blend_batch(
        &self,
        views: &mut [BatchBlendView<'_>],
        items: &[BatchWorkItem],
        pool: &mut Vec<TileState>,
        opts: &RenderOptions,
        rcfg: &RenderConfig,
    ) -> Result<()> {
        let _ = (items, pool);
        for v in views.iter_mut() {
            self.blend(v.scratch, opts, rcfg, v.img)?;
        }
        Ok(())
    }
}

/// The pure-CPU backend: the dynamic-greedy multi-threaded tile
/// scheduler (bit-identical to the serial schedule at any width).
#[derive(Clone, Copy, Debug)]
pub struct CpuBackend {
    /// Default tile-scheduler width for sessions that don't override it.
    pub threads: usize,
}

impl CpuBackend {
    /// Width from `SLTARCH_THREADS` / available parallelism.
    pub fn new() -> Self {
        CpuBackend { threads: default_threads() }
    }

    /// Explicit scheduler width (clamped to >= 1).
    pub fn with_threads(threads: usize) -> Self {
        CpuBackend { threads: threads.max(1) }
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl RenderBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn threads(&self, opts: &RenderOptions) -> usize {
        if opts.threads > 0 {
            opts.threads
        } else {
            self.threads
        }
    }

    fn blend(
        &self,
        scratch: &mut FrameScratch,
        opts: &RenderOptions,
        rcfg: &RenderConfig,
        img: &mut Image,
    ) -> Result<()> {
        blend_tiles(
            scratch,
            opts.alpha.blend_mode(),
            opts.kernel,
            rcfg.t_min,
            self.threads(opts),
            img,
        );
        Ok(())
    }

    fn blend_batch(
        &self,
        views: &mut [BatchBlendView<'_>],
        items: &[BatchWorkItem],
        pool: &mut Vec<TileState>,
        opts: &RenderOptions,
        rcfg: &RenderConfig,
    ) -> Result<()> {
        blend_tiles_batch(
            views,
            items,
            pool,
            opts.alpha.blend_mode(),
            opts.kernel,
            rcfg.t_min,
            self.threads(opts),
        );
        Ok(())
    }
}

/// The PJRT backend: blending via the AOT-compiled JAX/Pallas artifacts
/// in K_CHUNK batches with early termination between chunks.
///
/// The engine sits behind a `Mutex`: PJRT dispatch is serialized, so
/// concurrent sessions over a PJRT pipeline are safe (they time-share
/// the artifacts) without asserting `Sync` for the raw `xla` wrapper
/// types. Multi-client *parallelism* is the CPU backend's job.
pub struct PjrtBackend {
    engine: std::sync::Mutex<PjrtEngine>,
}

impl PjrtBackend {
    /// Wrap a loaded [`PjrtEngine`] as a session backend (dispatch is
    /// serialized through an internal mutex).
    pub fn new(engine: PjrtEngine) -> Self {
        PjrtBackend { engine: std::sync::Mutex::new(engine) }
    }
}

impl RenderBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn threads(&self, _opts: &RenderOptions) -> usize {
        0
    }

    fn blend(
        &self,
        scratch: &mut FrameScratch,
        opts: &RenderOptions,
        rcfg: &RenderConfig,
        img: &mut Image,
    ) -> Result<()> {
        // A panicked blend can't leave the engine in a bad state (each
        // SplatChunk::run is self-contained), so ride through poison.
        // `RenderOptions::kernel` is CPU-only; the artifacts implement
        // one (group-check) dataflow per alpha mode.
        let engine = self.engine.lock().unwrap_or_else(|e| e.into_inner());
        blend_tiles_pjrt(
            &engine,
            scratch,
            opts.alpha == AlphaMode::Group,
            rcfg.t_min,
            img,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_backend_resolves_threads() {
        let b = CpuBackend::with_threads(6);
        let defaults = RenderOptions::default();
        assert_eq!(b.threads(&defaults), 6);
        let pinned = RenderOptions { threads: 2, ..defaults };
        assert_eq!(b.threads(&pinned), 2);
        assert_eq!(CpuBackend::with_threads(0).threads, 1);
        assert!(CpuBackend::new().threads >= 1);
        assert_eq!(b.name(), "cpu");
    }
}
