//! The frame pipeline: owns the scene, the SLTree, the architecture
//! config and the rendering backend, and hands out [`RenderSession`]s
//! that turn cameras into images + statistics.
//!
//! Construction goes through [`FramePipeline::builder`]; the pipeline
//! itself is immutable at render time (sessions own all mutable state),
//! so one `&FramePipeline` safely serves many concurrent client
//! sessions.

use super::backend::{CpuBackend, PjrtBackend, RenderBackend, RenderOptions};
use super::batch::{BatchConfig, ViewBatch};
use super::session::RenderSession;
use super::workload::{frame_workload, lod_workload};
use crate::config::{ArchConfig, RenderConfig};
use crate::lod::SlTree;
use crate::math::Camera;
use crate::runtime::PjrtEngine;
use crate::scene::Scene;
use crate::sim::{simulate_variant, HwVariant};

/// Hardware-simulation output for one frame (the Fig. 9/10 rows).
/// Rendering statistics live in [`super::stats::RenderStats`]; this
/// report only covers the cycle-approximate models.
#[derive(Debug, Default)]
pub struct SimulationReport {
    /// Rendering-queue length (cut size).
    pub cut_len: usize,
    /// Nodes visited during LoD search.
    pub lod_visited: u64,
    /// Simulated per-variant frame reports (Fig. 9/10 rows).
    pub sims: Vec<crate::sim::VariantResult>,
    /// Wall-clock seconds the rust pipeline itself spent on the frame.
    pub wall_seconds: f64,
}

impl SimulationReport {
    /// Simulated seconds for a named variant, if simulated.
    pub fn sim_seconds(&self, v: HwVariant) -> Option<f64> {
        self.sims
            .iter()
            .find(|r| r.variant == v)
            .map(|r| r.report.total_seconds())
    }
}

/// Builder for [`FramePipeline`]: typed options in, immutable pipeline
/// out (the SLTree is partitioned once, at `build`).
pub struct FramePipelineBuilder {
    scene: Scene,
    rcfg: RenderConfig,
    arch: ArchConfig,
    defaults: RenderOptions,
    tau_set: bool,
    tau_s_set: bool,
    backend: Option<Box<dyn RenderBackend>>,
}

impl FramePipelineBuilder {
    /// Replace the whole render config. Explicit
    /// [`FramePipelineBuilder::tau`] / [`FramePipelineBuilder::subtree_size`]
    /// calls win over the corresponding `rcfg` fields regardless of
    /// call order, so the pipeline config and the session defaults can
    /// never desynchronize.
    pub fn render_config(mut self, rcfg: RenderConfig) -> Self {
        let (tau, tau_s) = (self.rcfg.lod_tau, self.rcfg.subtree_size);
        self.rcfg = rcfg;
        if self.tau_set {
            self.rcfg.lod_tau = tau;
        }
        if self.tau_s_set {
            self.rcfg.subtree_size = tau_s;
        }
        self
    }

    /// Replace the architecture config used by `simulate`.
    pub fn arch_config(mut self, arch: ArchConfig) -> Self {
        self.arch = arch;
        self
    }

    /// Default alpha dataflow for sessions.
    pub fn alpha(mut self, alpha: super::renderer::AlphaMode) -> Self {
        self.defaults.alpha = alpha;
        self
    }

    /// Default CPU blend kernel for sessions: the scalar reference loop
    /// or the divergence-free SoA kernel (byte-identical outputs; see
    /// [`crate::splat::kernel`]).
    pub fn kernel(mut self, kernel: crate::splat::BlendKernel) -> Self {
        self.defaults.kernel = kernel;
        self
    }

    /// LoD granularity tau (projected pixels) — sets both the pipeline
    /// config and the session default.
    pub fn tau(mut self, tau: f32) -> Self {
        self.rcfg.lod_tau = tau;
        self.defaults.lod_tau = tau;
        self.tau_set = true;
        self
    }

    /// SLTree subtree size limit (the paper's tau_s).
    pub fn subtree_size(mut self, tau_s: u32) -> Self {
        self.rcfg.subtree_size = tau_s;
        self.tau_s_set = true;
        self
    }

    /// Default tile-scheduler width for sessions (0 = backend default,
    /// which falls back to `SLTARCH_THREADS` / machine parallelism).
    pub fn threads(mut self, threads: usize) -> Self {
        self.defaults.threads = threads;
        self
    }

    /// Use an explicit rendering backend.
    pub fn backend(mut self, backend: impl RenderBackend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Sugar: blend through the AOT PJRT artifacts.
    pub fn engine(self, engine: PjrtEngine) -> Self {
        self.backend(PjrtBackend::new(engine))
    }

    /// Partition the SLTree and assemble the pipeline (CPU backend
    /// unless one was chosen).
    pub fn build(self) -> FramePipeline {
        let FramePipelineBuilder {
            scene,
            rcfg,
            arch,
            mut defaults,
            tau_set,
            tau_s_set: _,
            backend,
        } = self;
        if !tau_set {
            defaults.lod_tau = rcfg.lod_tau;
        }
        let sltree = SlTree::partition(&scene.tree, rcfg.subtree_size);
        FramePipeline {
            scene,
            sltree,
            rcfg,
            arch,
            defaults,
            backend: backend.unwrap_or_else(|| Box::new(CpuBackend::new())),
        }
    }
}

/// The long-lived, render-time-immutable pipeline state.
pub struct FramePipeline {
    scene: Scene,
    sltree: SlTree,
    rcfg: RenderConfig,
    arch: ArchConfig,
    defaults: RenderOptions,
    backend: Box<dyn RenderBackend>,
}

impl FramePipeline {
    /// Start building a pipeline around a scene.
    pub fn builder(scene: Scene) -> FramePipelineBuilder {
        FramePipelineBuilder {
            scene,
            rcfg: RenderConfig::default(),
            arch: ArchConfig::default(),
            defaults: RenderOptions::default(),
            tau_set: false,
            tau_s_set: false,
            backend: None,
        }
    }

    /// Shorthand constructor (CPU backend, session defaults from
    /// `rcfg`). Equivalent to
    /// `builder(scene).render_config(rcfg).arch_config(arch).build()`.
    pub fn new(scene: Scene, rcfg: RenderConfig, arch: ArchConfig) -> Self {
        Self::builder(scene).render_config(rcfg).arch_config(arch).build()
    }

    /// The scene this pipeline renders.
    pub fn scene(&self) -> &Scene {
        &self.scene
    }

    /// The pipeline's own SLTree (partitioned once at build — reuse it
    /// instead of re-partitioning the scene's LoD tree by hand).
    pub fn sltree(&self) -> &SlTree {
        &self.sltree
    }

    /// Render-time configuration.
    pub fn rcfg(&self) -> &RenderConfig {
        &self.rcfg
    }

    /// Architecture configuration for the hardware models.
    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    /// The backend blending this pipeline's frames.
    pub fn backend(&self) -> &dyn RenderBackend {
        self.backend.as_ref()
    }

    /// Default options new sessions start from.
    pub fn default_options(&self) -> RenderOptions {
        self.defaults
    }

    /// Re-target the LoD granularity (tau sweeps between frames; this
    /// is the one sanctioned mutation — everything else is fixed at
    /// build).
    pub fn set_lod_tau(&mut self, tau: f32) {
        self.rcfg.lod_tau = tau;
        self.defaults.lod_tau = tau;
    }

    /// Open a session with the pipeline's default options.
    pub fn session(&self) -> RenderSession<'_> {
        self.session_with(self.defaults)
    }

    /// Open a session with explicit options.
    pub fn session_with(&self, opts: RenderOptions) -> RenderSession<'_> {
        RenderSession::new(self, self.backend.as_ref(), opts)
    }

    /// Open a session on a caller-owned backend (e.g. a CPU replay of a
    /// PJRT pipeline, or per-client scheduler widths).
    pub fn session_on<'p>(
        &'p self,
        backend: &'p dyn RenderBackend,
        opts: RenderOptions,
    ) -> RenderSession<'p> {
        RenderSession::new(self, backend, opts)
    }

    /// Open a multi-view batch renderer with the pipeline's default
    /// options and the default sharing policy ([`BatchConfig`]): K
    /// cameras in, K images out, byte-identical to K independent
    /// sessions but sharing front-end work across close views.
    pub fn batch(&self) -> ViewBatch<'_> {
        self.batch_with(self.defaults, BatchConfig::default())
    }

    /// Open a multi-view batch renderer with explicit options and
    /// sharing policy (e.g. [`BatchConfig::independent`] for the
    /// stats-equality reference mode).
    pub fn batch_with(&self, opts: RenderOptions, cfg: BatchConfig) -> ViewBatch<'_> {
        ViewBatch::new(self, self.backend.as_ref(), opts, cfg)
    }

    /// Open a multi-view batch renderer on a caller-owned backend
    /// (mirrors [`FramePipeline::session_on`]).
    pub fn batch_on<'p>(
        &'p self,
        backend: &'p dyn RenderBackend,
        opts: RenderOptions,
        cfg: BatchConfig,
    ) -> ViewBatch<'p> {
        ViewBatch::new(self, backend, opts, cfg)
    }

    /// LoD search only: the cut for a camera at the pipeline's tau.
    ///
    /// Stateless (always a full traversal). Sessions route their
    /// searches through a per-stream temporal
    /// [`CutCache`](crate::lod::CutCache) instead, which reuses the
    /// previous frame's cut along a camera path while staying
    /// bit-identical to this reference.
    pub fn search(&self, cam: &Camera) -> Vec<u32> {
        self.search_with_tau(cam, self.rcfg.lod_tau)
    }

    /// LoD search at an explicit tau (per-session granularity).
    /// Stateless full traversal — see [`FramePipeline::search`].
    pub fn search_with_tau(&self, cam: &Camera, tau: f32) -> Vec<u32> {
        self.sltree.traverse(&self.scene.tree, cam, tau)
    }

    /// Run the workload extraction + the given hardware variants for
    /// one camera.
    pub fn simulate(&self, cam: &Camera, variants: &[HwVariant]) -> SimulationReport {
        let t0 = std::time::Instant::now();
        let (lod_w, splat_w) = frame_workload(&self.scene, &self.sltree, cam, &self.rcfg);
        let sims = variants
            .iter()
            .map(|&v| simulate_variant(v, &lod_w, &splat_w, &self.arch))
            .collect();
        SimulationReport {
            cut_len: lod_w.cut_len as usize,
            lod_visited: lod_w.trace.visited,
            sims,
            wall_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// LoD-stage-only workload (Fig. 11 / Fig. 12 experiments).
    pub fn lod_only(&self, cam: &Camera) -> (Vec<u32>, crate::sim::workload::LodWorkload) {
        lod_workload(&self.scene, &self.sltree, cam, &self.rcfg, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::coordinator::renderer::{AlphaMode, CpuRenderer};

    fn pipeline() -> FramePipeline {
        FramePipeline::builder(SceneConfig::small_scale().quick().build(9)).build()
    }

    #[test]
    fn session_render_and_simulate_roundtrip() {
        let p = pipeline();
        let cam = p.scene().scenario_camera(0);
        let mut session = p.session();
        let img = session.render(&cam).unwrap();
        assert_eq!(img.dims(), (256, 256));
        let stats = session.stats();
        assert_eq!(stats.frames, 1);
        assert!(stats.cut_total > 0);
        assert!(stats.pairs_total > 0);
        // The unified scheduler width drove the front end.
        assert_eq!(stats.front_end_threads, session.scheduler_width());
        assert!(stats.front_end_threads >= 1);
        let report = p.simulate(&cam, &HwVariant::fig9());
        assert_eq!(report.sims.len(), 5);
        assert!(report.cut_len > 0);
        assert_eq!(report.cut_len as u64, stats.cut_total);
        let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
        let slt = report.sim_seconds(HwVariant::SlTarch).unwrap();
        assert!(slt < gpu, "SLTARCH {slt} !< GPU {gpu}");
    }

    #[test]
    fn session_path_matches_per_frame_renders() {
        let p = pipeline();
        let cams: Vec<Camera> = (0..3).map(|i| p.scene().scenario_camera(i)).collect();
        let mut session = p.session();
        let images = session.render_path(&cams).unwrap();
        let stats = *session.stats();
        assert_eq!(images.len(), 3);
        assert_eq!(stats.frames, 3);
        assert!(stats.cut_total > 0);
        assert!(stats.pairs_total > 0);
        assert!(stats.fps() > 0.0);
        for (i, (img, cam)) in images.iter().zip(cams.iter()).enumerate() {
            let per_frame = p.session().render(cam).unwrap();
            assert_eq!(img.data, per_frame.data, "frame {i} diverged from a fresh session");
        }
    }

    #[test]
    fn session_cut_cache_reports_hits_and_stays_identical() {
        use crate::lod::CutCacheConfig;
        let p = pipeline();
        let cam = p.scene().scenario_camera(1);
        let mut session = p.session();
        let first = session.render(&cam).unwrap();
        let second = session.render(&cam).unwrap();
        assert_eq!(first.data, second.data);
        let stats = session.stats();
        assert_eq!(stats.frames, 2);
        assert_eq!(stats.cache_hit, 1, "second frame must hit the cut cache");
        assert!(stats.revalidated > 0);
        assert!(session.cut_cache().is_warm());
        // A cache-disabled session renders the identical frame.
        let mut cold = p.session_with(RenderOptions {
            cut_cache: CutCacheConfig::disabled(),
            ..p.default_options()
        });
        let cold_img = cold.render(&cam).unwrap();
        assert_eq!(cold.stats().cache_hit, 0);
        assert_eq!(cold.stats().revalidated, 0);
        assert_eq!(cold_img.data, first.data);
    }

    #[test]
    fn sessions_agree_across_thread_counts() {
        let p = pipeline();
        let cams: Vec<Camera> = (0..2).map(|i| p.scene().scenario_camera(i)).collect();
        let opts = RenderOptions { alpha: AlphaMode::Pixel, ..p.default_options() };
        let serial = CpuBackend::with_threads(1);
        let wide = CpuBackend::with_threads(8);
        let a = p.session_on(&serial, opts).render_path(&cams).unwrap();
        let b = p.session_on(&wide, opts).render_path(&cams).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn session_matches_reference_renderer() {
        use crate::splat::BlendKernel;
        let p = pipeline();
        let cam = p.scene().scenario_camera(1);
        let cut = p.search(&cam);
        let queue = p.scene().gaussians.gather(&cut);
        for alpha in [AlphaMode::Pixel, AlphaMode::Group] {
            // Both kernels must reproduce the stateless scalar
            // reference exactly.
            for kernel in [BlendKernel::Scalar, BlendKernel::Soa] {
                let mut session = p.session_with(RenderOptions {
                    alpha,
                    kernel,
                    ..p.default_options()
                });
                let got = session.render(&cam).unwrap();
                let want = CpuRenderer::render(&queue, &cam, alpha, p.rcfg());
                assert_eq!(got.data, want.data, "{alpha:?} / {kernel:?}");
            }
        }
    }

    #[test]
    fn search_respects_tau() {
        let p = pipeline();
        let cam = p.scene().scenario_camera(2);
        let fine = p.search_with_tau(&cam, 2.0).len();
        let coarse = p.search_with_tau(&cam, 32.0).len();
        assert!(coarse < fine);
    }

    #[test]
    fn builder_wires_options_and_tree() {
        let scene = SceneConfig::small_scale().quick().build(9);
        let tree_len = scene.tree.len();
        let p = FramePipeline::builder(scene)
            .tau(8.0)
            .subtree_size(16)
            .alpha(AlphaMode::Pixel)
            .kernel(crate::splat::BlendKernel::Soa)
            .threads(2)
            .backend(CpuBackend::with_threads(4))
            .build();
        assert_eq!(p.rcfg().lod_tau, 8.0);
        assert_eq!(p.rcfg().subtree_size, 16);
        let opts = p.default_options();
        assert_eq!(opts.alpha, AlphaMode::Pixel);
        assert_eq!(opts.kernel, crate::splat::BlendKernel::Soa);
        assert_eq!(opts.lod_tau, 8.0);
        assert_eq!(opts.threads, 2);
        assert_eq!(p.backend().threads(&opts), 2);
        assert_eq!(p.sltree().sizes().iter().sum::<usize>(), tree_len);
        // render_config after-the-fact tau still seeds session defaults.
        let q = FramePipeline::builder(SceneConfig::small_scale().quick().build(9))
            .render_config(RenderConfig { lod_tau: 12.0, ..Default::default() })
            .build();
        assert_eq!(q.default_options().lod_tau, 12.0);
        // Explicit tau/subtree_size win regardless of call order: the
        // pipeline config and session defaults never desynchronize.
        let r = FramePipeline::builder(SceneConfig::small_scale().quick().build(9))
            .tau(8.0)
            .subtree_size(16)
            .render_config(RenderConfig::default())
            .build();
        assert_eq!(r.rcfg().lod_tau, 8.0);
        assert_eq!(r.rcfg().subtree_size, 16);
        assert_eq!(r.default_options().lod_tau, 8.0);
    }

    #[test]
    fn stats_reset_opens_a_fresh_window() {
        let p = pipeline();
        let cam = p.scene().scenario_camera(0);
        let mut session = p.session();
        session.render(&cam).unwrap();
        let first = session.reset_stats();
        assert_eq!(first.frames, 1);
        assert_eq!(session.stats().frames, 0);
        session.render(&cam).unwrap();
        assert_eq!(session.stats().frames, 1);
        assert_eq!(session.stats().cut_total, first.cut_total);
    }
}
