//! The frame pipeline: owns the scene, the SLTree, the architecture
//! config and (optionally) the PJRT engine, and turns cameras into
//! images + simulation reports.

use super::renderer::{
    default_threads, AlphaMode, CpuRenderer, FrameScratch, PjrtRenderer,
};
use super::workload::{frame_workload, lod_workload};
use crate::config::{ArchConfig, RenderConfig};
use crate::lod::SlTree;
use crate::math::Camera;
use crate::metrics::Image;
use crate::runtime::PjrtEngine;
use crate::scene::Scene;
use crate::sim::{simulate_variant, HwVariant};
use anyhow::Result;

/// Per-frame output.
#[derive(Debug, Default)]
pub struct FrameReport {
    /// Rendering-queue length (cut size).
    pub cut_len: usize,
    /// Nodes visited during LoD search.
    pub lod_visited: u64,
    /// Simulated per-variant frame reports (Fig. 9/10 rows).
    pub sims: Vec<crate::sim::VariantResult>,
    /// Wall-clock seconds the rust pipeline itself spent on the frame.
    pub wall_seconds: f64,
}

impl FrameReport {
    /// Simulated seconds for a named variant, if simulated.
    pub fn sim_seconds(&self, v: HwVariant) -> Option<f64> {
        self.sims
            .iter()
            .find(|r| r.variant == v)
            .map(|r| r.report.total_seconds())
    }
}

/// Aggregate report for a batched camera-path render
/// ([`FramePipeline::render_path`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PathReport {
    /// Frames rendered.
    pub frames: usize,
    /// Wall-clock seconds for the whole batch (search + render).
    pub wall_seconds: f64,
    /// Total rendering-queue length across frames.
    pub cut_total: u64,
    /// Total (gaussian, tile) pairs across frames.
    pub pairs_total: u64,
    /// Tile-scheduler worker count used (0 = PJRT path).
    pub threads: usize,
}

impl PathReport {
    /// Aggregate throughput in frames per second.
    pub fn fps(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.frames as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// The long-lived pipeline state.
pub struct FramePipeline {
    pub scene: Scene,
    pub sltree: SlTree,
    pub rcfg: RenderConfig,
    pub arch: ArchConfig,
    pub engine: Option<PjrtEngine>,
}

impl FramePipeline {
    /// Build from a scene (partitioning the SLTree offline, as the
    /// paper prescribes — zero render-time cost).
    pub fn new(scene: Scene, rcfg: RenderConfig, arch: ArchConfig) -> Self {
        let sltree = SlTree::partition(&scene.tree, rcfg.subtree_size);
        FramePipeline { scene, sltree, rcfg, arch, engine: None }
    }

    /// Attach a PJRT engine (renders then execute the AOT artifacts).
    pub fn with_engine(mut self, engine: PjrtEngine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// LoD search only: the cut for a camera.
    pub fn search(&self, cam: &Camera) -> Vec<u32> {
        self.sltree.traverse(&self.scene.tree, cam, self.rcfg.lod_tau)
    }

    /// Render one frame to an image. Uses the PJRT artifacts when an
    /// engine is attached, the CPU mirror otherwise.
    pub fn render(&self, cam: &Camera, mode: AlphaMode) -> Result<Image> {
        let cut = self.search(cam);
        let queue = self.scene.gaussians.gather(&cut);
        match &self.engine {
            Some(engine) => {
                PjrtRenderer::render(engine, &queue, cam, mode, &self.rcfg)
            }
            None => Ok(CpuRenderer::render(&queue, cam, mode, &self.rcfg)),
        }
    }

    /// Render a whole camera path as one batch. Uses the PJRT artifacts
    /// when an engine is attached, otherwise the parallel CPU renderer
    /// with front-end scratch (projection buffer, CSR bins, sort keys)
    /// reused across frames — zero steady-state allocation per frame.
    /// Returns the frames plus an aggregate throughput report.
    pub fn render_path(
        &self,
        cams: &[Camera],
        mode: AlphaMode,
    ) -> Result<(Vec<Image>, PathReport)> {
        match &self.engine {
            Some(engine) => {
                let t0 = std::time::Instant::now();
                let mut scratch = FrameScratch::new();
                let mut report = PathReport { frames: cams.len(), ..Default::default() };
                let mut images = Vec::with_capacity(cams.len());
                for cam in cams {
                    let cut = self.search(cam);
                    report.cut_total += cut.len() as u64;
                    let queue = self.scene.gaussians.gather(&cut);
                    images.push(PjrtRenderer::render_with_scratch(
                        engine, &queue, cam, mode, &self.rcfg, &mut scratch,
                    )?);
                    report.pairs_total += scratch.bins.pairs;
                }
                report.wall_seconds = t0.elapsed().as_secs_f64();
                Ok((images, report))
            }
            None => Ok(self.render_path_cpu(cams, mode, default_threads())),
        }
    }

    /// The CPU batched path with an explicit tile-scheduler worker
    /// count, regardless of any attached engine (the examples use this
    /// for apples-to-apples CPU throughput numbers).
    pub fn render_path_cpu(
        &self,
        cams: &[Camera],
        mode: AlphaMode,
        threads: usize,
    ) -> (Vec<Image>, PathReport) {
        let t0 = std::time::Instant::now();
        let mut scratch = FrameScratch::new();
        let mut report = PathReport {
            frames: cams.len(),
            threads: threads.max(1),
            ..Default::default()
        };
        let mut images = Vec::with_capacity(cams.len());
        for cam in cams {
            let cut = self.search(cam);
            report.cut_total += cut.len() as u64;
            let queue = self.scene.gaussians.gather(&cut);
            images.push(CpuRenderer::render_with_scratch(
                &queue, cam, mode, &self.rcfg, threads, &mut scratch,
            ));
            report.pairs_total += scratch.bins.pairs;
        }
        report.wall_seconds = t0.elapsed().as_secs_f64();
        (images, report)
    }

    /// Run the workload extraction + all five Fig. 9 variants for one
    /// camera.
    pub fn simulate(&self, cam: &Camera, variants: &[HwVariant]) -> FrameReport {
        let t0 = std::time::Instant::now();
        let (lod_w, splat_w) = frame_workload(&self.scene, &self.sltree, cam, &self.rcfg);
        let sims = variants
            .iter()
            .map(|&v| simulate_variant(v, &lod_w, &splat_w, &self.arch))
            .collect();
        FrameReport {
            cut_len: lod_w.cut_len as usize,
            lod_visited: lod_w.trace.visited,
            sims,
            wall_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// LoD-stage-only workload (Fig. 11 / Fig. 12 experiments).
    pub fn lod_only(&self, cam: &Camera) -> (Vec<u32>, crate::sim::workload::LodWorkload) {
        lod_workload(&self.scene, &self.sltree, cam, &self.rcfg, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;

    fn pipeline() -> FramePipeline {
        FramePipeline::new(
            SceneConfig::small_scale().quick().build(9),
            RenderConfig::default(),
            ArchConfig::default(),
        )
    }

    #[test]
    fn render_and_simulate_roundtrip() {
        let p = pipeline();
        let cam = p.scene.scenario_camera(0);
        let img = p.render(&cam, AlphaMode::Group).unwrap();
        assert_eq!(img.dims(), (256, 256));
        let report = p.simulate(&cam, &HwVariant::fig9());
        assert_eq!(report.sims.len(), 5);
        assert!(report.cut_len > 0);
        let gpu = report.sim_seconds(HwVariant::Gpu).unwrap();
        let slt = report.sim_seconds(HwVariant::SlTarch).unwrap();
        assert!(slt < gpu, "SLTARCH {slt} !< GPU {gpu}");
    }

    #[test]
    fn render_path_matches_per_frame_renders() {
        let p = pipeline();
        let cams: Vec<Camera> = (0..3).map(|i| p.scene.scenario_camera(i)).collect();
        let (images, report) = p.render_path(&cams, AlphaMode::Group).unwrap();
        assert_eq!(images.len(), 3);
        assert_eq!(report.frames, 3);
        assert!(report.cut_total > 0);
        assert!(report.pairs_total > 0);
        assert!(report.fps() > 0.0);
        for (i, (img, cam)) in images.iter().zip(cams.iter()).enumerate() {
            let per_frame = p.render(cam, AlphaMode::Group).unwrap();
            assert_eq!(img.data, per_frame.data, "frame {i} diverged from render()");
        }
    }

    #[test]
    fn render_path_cpu_thread_counts_agree() {
        let p = pipeline();
        let cams: Vec<Camera> = (0..2).map(|i| p.scene.scenario_camera(i)).collect();
        let (a, ra) = p.render_path_cpu(&cams, AlphaMode::Pixel, 1);
        let (b, rb) = p.render_path_cpu(&cams, AlphaMode::Pixel, 8);
        assert_eq!(ra.pairs_total, rb.pairs_total);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn search_respects_tau() {
        let mut p = pipeline();
        let cam = p.scene.scenario_camera(2);
        p.rcfg.lod_tau = 2.0;
        let fine = p.search(&cam).len();
        p.rcfg.lod_tau = 32.0;
        let coarse = p.search(&cam).len();
        assert!(coarse < fine);
    }
}
