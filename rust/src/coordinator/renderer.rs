//! Image production: a pure-CPU renderer (mirrors the L1 kernels) and a
//! PJRT renderer (executes the AOT artifacts). Both share the same
//! front end (projection -> binning -> sorting) and differ only in who
//! runs the blending maths — the integration test
//! `rust/tests/pjrt_roundtrip.rs` asserts they agree.

use crate::config::RenderConfig;
use crate::gaussian::{project, Gaussians, Splat2D};
use crate::math::Camera;
use crate::metrics::Image;
use crate::runtime::{PjrtEngine, SplatChunk, SplatState, K_CHUNK};
use crate::splat::blend::PIXELS;
use crate::splat::{bin_splats, blend_tile, sort_tile_by_depth, BlendMode, TILE};
use anyhow::Result;

/// Which alpha dataflow to render with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlphaMode {
    /// Canonical per-pixel check (the paper's "Org." column).
    Pixel,
    /// SLTarch 2x2 group check (the paper's "SLTARCH" column).
    Group,
}

impl AlphaMode {
    fn blend_mode(self) -> BlendMode {
        match self {
            AlphaMode::Pixel => BlendMode::PerPixel,
            AlphaMode::Group => BlendMode::PixelGroup,
        }
    }
}

/// Shared front end: project the queue, bin, and depth-sort each tile.
fn front_end(
    queue: &Gaussians,
    cam: &Camera,
) -> (Vec<Splat2D>, crate::splat::TileBins, Vec<Vec<u32>>) {
    let splats = project(queue, cam);
    let bins = bin_splats(&splats, cam.intr.width, cam.intr.height);
    let mut orders = Vec::with_capacity(bins.tile_count());
    for idx in 0..bins.tile_count() {
        let mut order = bins.per_tile[idx].clone();
        sort_tile_by_depth(&mut order, &splats);
        orders.push(order);
    }
    (splats, bins, orders)
}

/// Write one tile's accumulated RGB into the frame image.
fn store_tile(img: &mut Image, origin: (f32, f32), rgb: &[[f32; 3]]) {
    let ox = origin.0 as u32;
    let oy = origin.1 as u32;
    for py in 0..TILE {
        for px in 0..TILE {
            let x = ox + px;
            let y = oy + py;
            if x < img.width && y < img.height {
                img.set(x, y, rgb[(py * TILE + px) as usize]);
            }
        }
    }
}

/// Pure-CPU renderer.
pub struct CpuRenderer;

impl CpuRenderer {
    /// Render the gathered rendering queue (a cut of the LoD tree).
    pub fn render(
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
    ) -> Image {
        let (splats, bins, orders) = front_end(queue, cam);
        let mut img = Image::new(cam.intr.width, cam.intr.height);
        let mut rgb = [[0.0f32; 3]; PIXELS];
        let mut t = [0.0f32; PIXELS];
        for idx in 0..bins.tile_count() {
            let order = &orders[idx];
            if order.is_empty() {
                continue;
            }
            rgb.iter_mut().for_each(|p| *p = [0.0; 3]);
            t.iter_mut().for_each(|v| *v = 1.0);
            let origin = bins.tile_origin(idx);
            blend_tile(
                order,
                &splats,
                origin,
                mode.blend_mode(),
                &mut rgb,
                &mut t,
                rcfg.t_min,
            );
            store_tile(&mut img, origin, &rgb);
        }
        img
    }
}

/// PJRT renderer: same front end, blending via the AOT artifacts in
/// K_CHUNK batches with early termination between chunks.
pub struct PjrtRenderer;

impl PjrtRenderer {
    pub fn render(
        engine: &PjrtEngine,
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
    ) -> Result<Image> {
        // Front end on CPU (binning/sorting is L3 work); blending on PJRT.
        let (splats, bins, orders) = front_end(queue, cam);
        let mut img = Image::new(cam.intr.width, cam.intr.height);
        let group = mode == AlphaMode::Group;
        for idx in 0..bins.tile_count() {
            let order = &orders[idx];
            if order.is_empty() {
                continue;
            }
            let origin = bins.tile_origin(idx);
            let mut state = SplatState::fresh();
            for chunk in order.chunks(K_CHUNK) {
                let chunk_splats: Vec<Splat2D> =
                    chunk.iter().map(|&i| splats[i as usize]).collect();
                state = SplatChunk::run(engine, &chunk_splats, origin, &state, group)?;
                if state.t_max() < rcfg.t_min {
                    break; // tile saturated: skip remaining chunks
                }
            }
            let rgb: Vec<[f32; 3]> = state
                .rgb
                .chunks_exact(3)
                .map(|c| [c[0], c[1], c[2]])
                .collect();
            store_tile(&mut img, origin, &rgb);
        }
        Ok(img)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::lod::SlTree;

    fn setup() -> (crate::scene::Scene, Vec<u32>, Camera) {
        let scene = SceneConfig::small_scale().quick().build(3);
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(0);
        let cut = slt.traverse(&scene.tree, &cam, 8.0);
        (scene, cut, cam)
    }

    #[test]
    fn cpu_render_produces_content() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let img = CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &RenderConfig::default());
        let mean: f32 = img.data.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>()
            / (img.data.len() as f32 * 3.0);
        assert!(mean > 0.01, "image is black: mean {mean}");
    }

    #[test]
    fn group_mode_is_close_to_pixel_mode() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        let px = CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &rcfg);
        let gp = CpuRenderer::render(&queue, &cam, AlphaMode::Group, &rcfg);
        let mad = px.mad(&gp);
        assert!(mad < 0.02, "group approximation too lossy: {mad}");
        // And the approximation is not a no-op (some pixels differ) —
        // unless the scene is degenerate, which quick() scenes are not.
        assert!(mad > 0.0, "suspicious: identical images");
    }

    #[test]
    fn coarser_lod_renders_similar_image() {
        // The LoD system's whole premise: a coarser cut approximates the
        // finer render.
        let (scene, _, _) = setup();
        // Mid-distance camera so both cuts sit strictly inside the tree.
        let cam = scene.scenario_camera(3);
        let slt = SlTree::partition(&scene.tree, 32);
        let fine = slt.traverse(&scene.tree, &cam, 2.0);
        let coarse = slt.traverse(&scene.tree, &cam, 24.0);
        assert!(coarse.len() < fine.len());
        let rcfg = RenderConfig::default();
        let qa = scene.gaussians.gather(&fine);
        let qb = scene.gaussians.gather(&coarse);
        let ia = CpuRenderer::render(&qa, &cam, AlphaMode::Pixel, &rcfg);
        let ib = CpuRenderer::render(&qb, &cam, AlphaMode::Pixel, &rcfg);
        let p = crate::metrics::psnr(&ia, &ib);
        assert!(p > 14.0, "coarse LoD diverged: psnr {p}");
    }
}
