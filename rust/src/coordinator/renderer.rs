//! Image production internals: the shared front end (one fused
//! projection + tile-count sweep with per-worker inline histograms ->
//! CSR merge/scatter -> dynamic-cursor parallel radix depth sort, each
//! byte-identical to the split serial reference at any scheduler
//! width), the CPU and PJRT blend loops
//! that the [`super::backend`] implementations drive, and the stateless
//! reference renderers (`CpuRenderer` / `PjrtRenderer`) the equivalence
//! tests compare the session API against. Both blend paths consume the
//! identical sorted bins and differ only in who runs the blending maths
//! — the integration test `rust/tests/pjrt_roundtrip.rs` asserts they
//! agree.
//!
//! The CPU renderer splats tiles with a **dynamic-greedy multi-threaded
//! scheduler**: workers pull non-empty tiles one at a time from a shared
//! atomic queue — the software mirror of the LT-unit dynamic dequeue in
//! `lod/traversal.rs`, applied to the splatting stage's tile workload
//! (the paper's other imbalance source). Each worker owns reusable
//! `rgb`/`t` scratch and writes its finished tiles straight into the
//! frame image; tiles are disjoint, so the output is bit-identical to
//! the serial schedule regardless of thread count.

use crate::config::RenderConfig;
use crate::gaussian::{Gaussians, Splat2D};
use crate::math::Camera;
use crate::metrics::Image;
use crate::runtime::{PjrtEngine, SplatChunk, SplatState, K_CHUNK};
use crate::splat::blend::PIXELS;
use crate::splat::{
    blend_tile, blend_tile_soa, project_bin_finish, project_bin_sweep,
    sort_bins_threaded, BatchWorkItem, BlendKernel, BlendMode,
    DepthSortScratch, TileBins, TileState, TILE,
};
use super::stats::StageTimings;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Which alpha dataflow to render with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlphaMode {
    /// Canonical per-pixel check (the paper's "Org." column).
    Pixel,
    /// SLTarch 2x2 group check (the paper's "SLTARCH" column).
    Group,
}

impl AlphaMode {
    pub(crate) fn blend_mode(self) -> BlendMode {
        match self {
            AlphaMode::Pixel => BlendMode::PerPixel,
            AlphaMode::Group => BlendMode::PixelGroup,
        }
    }
}

/// Reusable front-end state: the projection buffer, the CSR tile bins
/// and the radix-sort key buffers. One instance per render loop — after
/// the first frame warms it up, a frame's front end allocates nothing.
#[derive(Debug, Default)]
pub struct FrameScratch {
    /// Projected 2D splats for the current frame's rendering queue.
    pub splats: Vec<Splat2D>,
    /// CSR tile bins over `splats` (indices + offsets, reused buffers).
    pub bins: TileBins,
    /// Per-worker radix-sort scratches (grown to the scheduler width on
    /// first use; index 0 serves the serial path).
    pub sort: Vec<DepthSortScratch>,
    /// Per-worker SoA tile accumulation planes for the SoA blend kernel
    /// (grown to the scheduler width on first use; index 0 serves the
    /// serial path). The scalar kernel uses per-worker stack arrays and
    /// leaves this pool empty.
    pub tiles: Vec<TileState>,
    /// Work list of non-empty tile indices (the scheduler's queue).
    /// `pub(crate)` so the multi-view batch path can splice several
    /// views' work lists into one interleaved schedule.
    pub(crate) work: Vec<u32>,
}

impl FrameScratch {
    /// Empty scratch; buffers grow on first use and are reused after.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Shared front end: one fused projection + tile-count sweep over the
/// queue, the CSR merge/scatter finish, and the in-place depth sort of
/// every tile slice — all on `threads` scoped workers (1 = the serial
/// reference path; output is byte-identical at any width) —
/// accumulating per-stage wall-clock (sums + histograms) into `stages`
/// (the session API's unified stats). The fused sweep (ROADMAP item 3)
/// bins each splat while it is still in registers instead of re-reading
/// the projection buffer in a second pass, halving front-end memory
/// traffic; the merge + scatter finish is shared with the split path,
/// so the CSR output is unchanged byte for byte. A binning invariant
/// failure surfaces as `Err` so one malformed frame degrades that
/// request instead of killing a serving process.
pub(crate) fn front_end_timed(
    queue: &Gaussians,
    cam: &Camera,
    scratch: &mut FrameScratch,
    stages: &mut StageTimings,
    threads: usize,
) -> Result<()> {
    let threads = threads.max(1);
    // The fused sweep does the old PROJECT stage's work plus the
    // binning count pass inline, so it is timed as PROJECT; the
    // merge/scatter finish plus the work list is what remains of BIN.
    let t = Instant::now();
    let sweep =
        project_bin_sweep(queue, cam, &mut scratch.splats, &mut scratch.bins, threads);
    stages.record_stage(StageTimings::PROJECT, t.elapsed().as_secs_f64());

    let t = Instant::now();
    project_bin_finish(&mut scratch.bins, sweep)?;
    // The scheduler work list only needs the finished offset table, so
    // it is built (and timed) with the binning stage.
    scratch.work.clear();
    scratch.work.extend(
        (0..scratch.bins.tile_count() as u32).filter(|&t| scratch.bins.tile_len(t as usize) > 0),
    );
    stages.record_stage(StageTimings::BIN, t.elapsed().as_secs_f64());

    let t = Instant::now();
    sort_bins_threaded(&mut scratch.bins, &scratch.splats, &mut scratch.sort, threads);
    stages.record_stage(StageTimings::SORT, t.elapsed().as_secs_f64());
    Ok(())
}

/// Untimed front end for the stateless reference renderers.
fn front_end_into(
    queue: &Gaussians,
    cam: &Camera,
    scratch: &mut FrameScratch,
    threads: usize,
) -> Result<()> {
    front_end_timed(queue, cam, scratch, &mut StageTimings::default(), threads)
}

/// Write one tile's accumulated RGB into the frame image (exclusive
/// access — delegates to the same store the scheduler workers use so
/// serial and parallel schedules share one clipping/indexing path).
fn store_tile(img: &mut Image, origin: (f32, f32), rgb: &[[f32; 3]]) {
    let shared = SharedImage::new(img);
    // SAFETY: `img` is exclusively borrowed, so no concurrent writes.
    unsafe { shared.store_tile(origin, rgb) };
}

/// Raw view of the frame image that lets scheduler workers store
/// *disjoint* tiles concurrently without locking.
struct SharedImage {
    data: *mut [f32; 3],
    width: u32,
    height: u32,
}

// SAFETY: workers only ever write through `store_tile` /
// `store_tile_planes`, and the atomic work queue hands each tile index
// to exactly one worker, so concurrent writes never alias.
unsafe impl Send for SharedImage {}
unsafe impl Sync for SharedImage {}

impl SharedImage {
    fn new(img: &mut Image) -> SharedImage {
        SharedImage {
            data: img.data.as_mut_ptr(),
            width: img.width,
            height: img.height,
        }
    }

    /// Store one tile's pixels.
    ///
    /// # Safety
    /// No two concurrent calls may cover overlapping pixels, and the
    /// backing image must outlive every call (both guaranteed by the
    /// scoped-thread scheduler: unique tile ids, join before return).
    unsafe fn store_tile(&self, origin: (f32, f32), rgb: &[[f32; 3]]) {
        let ox = origin.0 as u32;
        let oy = origin.1 as u32;
        for py in 0..TILE {
            let y = oy + py;
            if y >= self.height {
                break;
            }
            for px in 0..TILE {
                let x = ox + px;
                if x >= self.width {
                    break;
                }
                unsafe {
                    *self.data.add((y * self.width + x) as usize) =
                        rgb[(py * TILE + px) as usize];
                }
            }
        }
    }

    /// Store one tile's pixels from SoA colour planes (the SoA blend
    /// kernel's `TileState`), interleaving on the fly.
    ///
    /// # Safety
    /// Same contract as [`SharedImage::store_tile`].
    unsafe fn store_tile_planes(
        &self,
        origin: (f32, f32),
        r: &[f32; PIXELS],
        g: &[f32; PIXELS],
        b: &[f32; PIXELS],
    ) {
        let ox = origin.0 as u32;
        let oy = origin.1 as u32;
        for py in 0..TILE {
            let y = oy + py;
            if y >= self.height {
                break;
            }
            for px in 0..TILE {
                let x = ox + px;
                if x >= self.width {
                    break;
                }
                let p = (py * TILE + px) as usize;
                unsafe {
                    *self.data.add((y * self.width + x) as usize) =
                        [r[p], g[p], b[p]];
                }
            }
        }
    }
}

/// Reset the accumulation scratch and blend one tile into it.
#[inline]
fn blend_one_tile(
    order: &[u32],
    splats: &[Splat2D],
    origin: (f32, f32),
    mode: BlendMode,
    rgb: &mut [[f32; 3]; PIXELS],
    t: &mut [f32; PIXELS],
    t_min: f32,
) {
    rgb.iter_mut().for_each(|p| *p = [0.0; 3]);
    t.iter_mut().for_each(|v| *v = 1.0);
    blend_tile(order, splats, origin, mode, rgb, t, t_min);
}

/// Splat every non-empty tile of `scratch` into `img`, using `threads`
/// workers over a dynamic-greedy shared queue (1 = serial reference)
/// and the chosen blend-kernel implementation. The two kernels are
/// byte-identical per [`BlendMode`]; `kernel` only trades blend time.
pub(crate) fn blend_tiles(
    scratch: &mut FrameScratch,
    mode: BlendMode,
    kernel: BlendKernel,
    t_min: f32,
    threads: usize,
    img: &mut Image,
) {
    match kernel {
        BlendKernel::Scalar => blend_tiles_scalar(scratch, mode, t_min, threads, img),
        BlendKernel::Soa => blend_tiles_soa(scratch, mode, t_min, threads, img),
    }
}

/// [`blend_tiles`] with the scalar reference kernel ([`blend_tile`]).
fn blend_tiles_scalar(
    scratch: &FrameScratch,
    mode: BlendMode,
    t_min: f32,
    threads: usize,
    img: &mut Image,
) {
    let bins = &scratch.bins;
    let splats = &scratch.splats[..];
    let work = &scratch.work[..];
    if threads <= 1 || work.len() <= 1 {
        let mut rgb = [[0.0f32; 3]; PIXELS];
        let mut t = [0.0f32; PIXELS];
        for &idx in work {
            let origin = bins.tile_origin(idx as usize);
            blend_one_tile(
                bins.tile(idx as usize),
                splats,
                origin,
                mode,
                &mut rgb,
                &mut t,
                t_min,
            );
            store_tile(img, origin, &rgb);
        }
        return;
    }

    let cursor = AtomicUsize::new(0);
    let target = SharedImage::new(img);
    // Never spawn more workers than there are tiles to hand out (also
    // bounds a runaway SLTARCH_THREADS setting to the tile count).
    let workers = threads.min(work.len());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                // Per-worker reusable accumulation scratch.
                let mut rgb = [[0.0f32; 3]; PIXELS];
                let mut t = [0.0f32; PIXELS];
                loop {
                    // Dynamic greedy dequeue: whoever finishes a tile
                    // first grabs the next one, soaking up the per-tile
                    // workload imbalance (cf. the LT-unit dequeue).
                    let w = cursor.fetch_add(1, Ordering::Relaxed);
                    if w >= work.len() {
                        break;
                    }
                    let idx = work[w] as usize;
                    let origin = bins.tile_origin(idx);
                    blend_one_tile(
                        bins.tile(idx),
                        splats,
                        origin,
                        mode,
                        &mut rgb,
                        &mut t,
                        t_min,
                    );
                    // SAFETY: `w` (hence `idx`) is claimed by exactly
                    // one worker and tiles never overlap; the image
                    // outlives the scope.
                    unsafe { target.store_tile(origin, &rgb) };
                }
            });
        }
    });
}

/// [`blend_tiles`] with the divergence-free SoA kernel
/// ([`blend_tile_soa`]): same dynamic-greedy tile scheduler, but each
/// worker blends into a reusable [`TileState`] from the
/// [`FrameScratch::tiles`] pool (SoA planes, no steady-state
/// allocation) and stores the planes straight into the frame image.
fn blend_tiles_soa(
    scratch: &mut FrameScratch,
    mode: BlendMode,
    t_min: f32,
    threads: usize,
    img: &mut Image,
) {
    let FrameScratch { splats, bins, tiles, work, .. } = scratch;
    let bins = &*bins;
    let splats = &splats[..];
    let work = &work[..];
    if threads <= 1 || work.len() <= 1 {
        if tiles.is_empty() {
            tiles.push(TileState::fresh());
        }
        let state = &mut tiles[0];
        for &idx in work {
            let origin = bins.tile_origin(idx as usize);
            state.reset();
            blend_tile_soa(bins.tile(idx as usize), splats, origin, mode, state, t_min);
            let shared = SharedImage::new(img);
            // SAFETY: `img` is exclusively borrowed, no concurrency.
            unsafe { shared.store_tile_planes(origin, &state.r, &state.g, &state.b) };
        }
        return;
    }

    let workers = threads.min(work.len());
    if tiles.len() < workers {
        tiles.resize_with(workers, TileState::fresh);
    }
    let cursor = AtomicUsize::new(0);
    let target = SharedImage::new(img);
    let cursor = &cursor;
    let target = &target;
    std::thread::scope(|s| {
        for state in tiles[..workers].iter_mut() {
            // Each worker owns one TileState from the pool for the
            // whole pass; the shared cursor hands out tiles.
            s.spawn(move || loop {
                let w = cursor.fetch_add(1, Ordering::Relaxed);
                if w >= work.len() {
                    break;
                }
                let idx = work[w] as usize;
                let origin = bins.tile_origin(idx);
                state.reset();
                blend_tile_soa(bins.tile(idx), splats, origin, mode, state, t_min);
                // SAFETY: `w` (hence `idx`) is claimed by exactly one
                // worker and tiles never overlap; the image outlives
                // the scope.
                unsafe {
                    target.store_tile_planes(origin, &state.r, &state.g, &state.b)
                };
            });
        }
    });
}

/// One view's slot in a multi-view batch blend: the view's prepared
/// front end (projected, binned, depth-sorted [`FrameScratch`]) plus
/// its output image. The batch blend consumes a `&mut [BatchBlendView]`
/// so each view's buffers stay distinct while the scheduler interleaves
/// their tiles ([`crate::splat::BatchWorkItem`]) over one worker pool.
pub struct BatchBlendView<'a> {
    /// Prepared front-end state. The bins/splats are only read; the
    /// scratch's own SoA tile pool is bypassed — the batch scheduler
    /// blends through one shared caller-owned pool instead, so K views
    /// need one pool of `workers` tile states rather than K.
    pub scratch: &'a mut FrameScratch,
    /// The view's output image (written tile by tile).
    pub img: &'a mut Image,
}

/// Per-view shared state the batch blend workers read.
struct BatchViewCtx<'a> {
    bins: &'a TileBins,
    splats: &'a [Splat2D],
    target: SharedImage,
}

/// Blend an interleaved multi-view tile schedule: every item names one
/// `(view, tile)` of `views`, and one dynamic-greedy atomic cursor
/// hands items from **all** views to one scoped worker pool — a view
/// with heavy tiles soaks up the workers a light view leaves idle,
/// which a per-view sequence of [`blend_tiles`] calls cannot do (each
/// call joins its workers at its own tail).
///
/// Byte-identity: each tile is blended by exactly the same per-tile
/// kernel as the single-view scheduler and written to its own view's
/// image, so the result equals per-view [`blend_tiles`] calls bit for
/// bit, at any `threads`, in any item order. The caller must list every
/// `(view, tile)` at most once (disjoint stores) and only non-empty
/// tiles it wants blended. Per-item `tau` overrides are an inert
/// foveated hook — ignored here by the byte-identity contract.
pub(crate) fn blend_tiles_batch(
    views: &mut [BatchBlendView<'_>],
    items: &[BatchWorkItem],
    pool: &mut Vec<TileState>,
    mode: BlendMode,
    kernel: BlendKernel,
    t_min: f32,
    threads: usize,
) {
    let ctxs: Vec<BatchViewCtx<'_>> = views
        .iter_mut()
        .map(|v| BatchViewCtx {
            target: SharedImage::new(v.img),
            bins: &v.scratch.bins,
            splats: &v.scratch.splats[..],
        })
        .collect();
    let ctxs = &ctxs[..];

    if threads <= 1 || items.len() <= 1 {
        match kernel {
            BlendKernel::Scalar => {
                let mut rgb = [[0.0f32; 3]; PIXELS];
                let mut t = [0.0f32; PIXELS];
                for it in items {
                    let ctx = &ctxs[it.view as usize];
                    let idx = it.tile as usize;
                    let origin = ctx.bins.tile_origin(idx);
                    blend_one_tile(
                        ctx.bins.tile(idx),
                        ctx.splats,
                        origin,
                        mode,
                        &mut rgb,
                        &mut t,
                        t_min,
                    );
                    // SAFETY: serial path — no concurrent stores; the
                    // images outlive this call.
                    unsafe { ctx.target.store_tile(origin, &rgb) };
                }
            }
            BlendKernel::Soa => {
                if pool.is_empty() {
                    pool.push(TileState::fresh());
                }
                let state = &mut pool[0];
                for it in items {
                    let ctx = &ctxs[it.view as usize];
                    let idx = it.tile as usize;
                    let origin = ctx.bins.tile_origin(idx);
                    state.reset();
                    blend_tile_soa(
                        ctx.bins.tile(idx),
                        ctx.splats,
                        origin,
                        mode,
                        state,
                        t_min,
                    );
                    // SAFETY: serial path — no concurrent stores.
                    unsafe {
                        ctx.target.store_tile_planes(
                            origin, &state.r, &state.g, &state.b,
                        )
                    };
                }
            }
        }
        return;
    }

    let workers = threads.min(items.len());
    let cursor = AtomicUsize::new(0);
    let cursor = &cursor;
    match kernel {
        BlendKernel::Scalar => {
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(move || {
                        let mut rgb = [[0.0f32; 3]; PIXELS];
                        let mut t = [0.0f32; PIXELS];
                        loop {
                            let w = cursor.fetch_add(1, Ordering::Relaxed);
                            if w >= items.len() {
                                break;
                            }
                            let it = items[w];
                            let ctx = &ctxs[it.view as usize];
                            let idx = it.tile as usize;
                            let origin = ctx.bins.tile_origin(idx);
                            blend_one_tile(
                                ctx.bins.tile(idx),
                                ctx.splats,
                                origin,
                                mode,
                                &mut rgb,
                                &mut t,
                                t_min,
                            );
                            // SAFETY: the cursor hands each item (hence
                            // each view's tile) to exactly one worker
                            // and the caller lists every (view, tile)
                            // at most once, so stores never alias; the
                            // images outlive the scope.
                            unsafe { ctx.target.store_tile(origin, &rgb) };
                        }
                    });
                }
            });
        }
        BlendKernel::Soa => {
            if pool.len() < workers {
                pool.resize_with(workers, TileState::fresh);
            }
            std::thread::scope(|s| {
                for state in pool[..workers].iter_mut() {
                    s.spawn(move || loop {
                        let w = cursor.fetch_add(1, Ordering::Relaxed);
                        if w >= items.len() {
                            break;
                        }
                        let it = items[w];
                        let ctx = &ctxs[it.view as usize];
                        let idx = it.tile as usize;
                        let origin = ctx.bins.tile_origin(idx);
                        state.reset();
                        blend_tile_soa(
                            ctx.bins.tile(idx),
                            ctx.splats,
                            origin,
                            mode,
                            state,
                            t_min,
                        );
                        // SAFETY: same disjointness argument as the
                        // scalar arm.
                        unsafe {
                            ctx.target.store_tile_planes(
                                origin, &state.r, &state.g, &state.b,
                            )
                        };
                    });
                }
            });
        }
    }
}

static DEFAULT_THREADS: OnceLock<usize> = OnceLock::new();

/// Default worker count for the tile scheduler: the `SLTARCH_THREADS`
/// env override if set, else the machine's available parallelism. The
/// env var is a deployment fallback — prefer `CpuBackend::with_threads`
/// / `RenderOptions::threads` — and is read and parsed exactly once per
/// process, never on the per-frame hot path.
pub fn default_threads() -> usize {
    *DEFAULT_THREADS.get_or_init(|| {
        std::env::var("SLTARCH_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .map(|n| n.max(1))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Pure-CPU renderer.
pub struct CpuRenderer;

impl CpuRenderer {
    /// Render the gathered rendering queue (a cut of the LoD tree) with
    /// the dynamic tile scheduler on [`default_threads`] workers.
    pub fn render(
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
    ) -> Image {
        Self::render_threaded(queue, cam, mode, rcfg, default_threads())
    }

    /// Serial reference schedule (the scheduler's ground truth).
    pub fn render_serial(
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
    ) -> Image {
        Self::render_threaded(queue, cam, mode, rcfg, 1)
    }

    /// Render with an explicit worker count. Output is bit-identical
    /// across all `threads` values: tiles are independent and disjoint.
    pub fn render_threaded(
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
        threads: usize,
    ) -> Image {
        let mut scratch = FrameScratch::new();
        Self::render_with_scratch(queue, cam, mode, rcfg, threads, &mut scratch)
    }

    /// Render reusing caller-owned front-end scratch (the batched
    /// `FramePipeline::render_path` hot loop). One `threads` knob drives
    /// the parallel front end and the blend-stage tile scheduler.
    pub fn render_with_scratch(
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
        threads: usize,
        scratch: &mut FrameScratch,
    ) -> Image {
        // The stateless reference path keeps its infallible signature:
        // a binning invariant violation here means the test/golden
        // harness itself is broken, so failing loudly is the feature.
        front_end_into(queue, cam, scratch, threads)
            .expect("front end (stateless reference path)");
        let mut img = Image::new(cam.intr.width, cam.intr.height);
        // The stateless reference renderer always runs the scalar
        // kernel — it is the ground truth the SoA kernel (selected via
        // `RenderOptions::kernel` on the session API) is tested
        // against.
        blend_tiles(
            scratch,
            mode.blend_mode(),
            BlendKernel::Scalar,
            rcfg.t_min,
            threads,
            &mut img,
        );
        img
    }
}

/// PJRT renderer: same front end, blending via the AOT artifacts in
/// K_CHUNK batches with early termination between chunks.
pub struct PjrtRenderer;

impl PjrtRenderer {
    /// Render the gathered rendering queue through the PJRT artifacts
    /// with a fresh front-end scratch.
    pub fn render(
        engine: &PjrtEngine,
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
    ) -> Result<Image> {
        let mut scratch = FrameScratch::new();
        Self::render_with_scratch(engine, queue, cam, mode, rcfg, &mut scratch)
    }

    /// Render reusing caller-owned front-end scratch (the batched
    /// `FramePipeline::render_path` loop threads one scratch through
    /// every frame on this path too).
    pub fn render_with_scratch(
        engine: &PjrtEngine,
        queue: &Gaussians,
        cam: &Camera,
        mode: AlphaMode,
        rcfg: &RenderConfig,
        scratch: &mut FrameScratch,
    ) -> Result<Image> {
        // Front end on CPU (binning/sorting is L3 work; this stateless
        // reference path keeps it serial — the session API drives the
        // parallel front end via its unified scheduler width); blending
        // on PJRT.
        front_end_into(queue, cam, scratch, 1)?;
        let mut img = Image::new(cam.intr.width, cam.intr.height);
        blend_tiles_pjrt(engine, scratch, mode == AlphaMode::Group, rcfg.t_min, &mut img)?;
        Ok(img)
    }
}

/// Blend every non-empty tile of `scratch` through the PJRT splat
/// artifacts in [`K_CHUNK`] batches, with early termination between
/// chunks (the `PjrtBackend` blend path).
pub(crate) fn blend_tiles_pjrt(
    engine: &PjrtEngine,
    scratch: &FrameScratch,
    group: bool,
    t_min: f32,
    img: &mut Image,
) -> Result<()> {
    let splats = &scratch.splats;
    let bins = &scratch.bins;
    for idx in 0..bins.tile_count() {
        let order = bins.tile(idx);
        if order.is_empty() {
            continue;
        }
        let origin = bins.tile_origin(idx);
        let mut state = SplatState::fresh();
        for chunk in order.chunks(K_CHUNK) {
            let chunk_splats: Vec<Splat2D> =
                chunk.iter().map(|&i| splats[i as usize]).collect();
            state = SplatChunk::run(engine, &chunk_splats, origin, &state, group)?;
            if state.t_max() < t_min {
                break; // tile saturated: skip remaining chunks
            }
        }
        let rgb: Vec<[f32; 3]> = state
            .rgb
            .chunks_exact(3)
            .map(|c| [c[0], c[1], c[2]])
            .collect();
        store_tile(img, origin, &rgb);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::lod::SlTree;

    fn setup() -> (crate::scene::Scene, Vec<u32>, Camera) {
        let scene = SceneConfig::small_scale().quick().build(3);
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(0);
        let cut = slt.traverse(&scene.tree, &cam, 8.0);
        (scene, cut, cam)
    }

    #[test]
    fn cpu_render_produces_content() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let img = CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &RenderConfig::default());
        let mean: f32 = img.data.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>()
            / (img.data.len() as f32 * 3.0);
        assert!(mean > 0.01, "image is black: mean {mean}");
    }

    #[test]
    fn parallel_render_is_bit_identical_to_serial() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        for mode in [AlphaMode::Pixel, AlphaMode::Group] {
            let serial = CpuRenderer::render_serial(&queue, &cam, mode, &rcfg);
            for threads in [1usize, 2, 8] {
                let par = CpuRenderer::render_threaded(&queue, &cam, mode, &rcfg, threads);
                assert_eq!(
                    serial.data, par.data,
                    "{mode:?} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_across_frames_is_bit_identical() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        let mut scratch = FrameScratch::new();
        // Two different cameras through one scratch, checked against
        // fresh-scratch renders.
        for cam_i in 0..3 {
            let cam = if cam_i == 0 { cam } else { scene.scenario_camera(cam_i) };
            let reused = CpuRenderer::render_with_scratch(
                &queue, &cam, AlphaMode::Group, &rcfg, 4, &mut scratch,
            );
            let fresh = CpuRenderer::render_threaded(&queue, &cam, AlphaMode::Group, &rcfg, 4);
            assert_eq!(reused.data, fresh.data, "camera {cam_i}");
        }
    }

    #[test]
    fn fused_front_end_matches_split_front_end() {
        // The tentpole contract: the fused project+bin sweep must
        // reproduce the split front end (project, then count) exactly —
        // the projected splats bit for bit AND the CSR arrays byte for
        // byte — at every scheduler width, on a real scene queue.
        use crate::gaussian::project_into_threaded;
        use crate::splat::{bin_splats_into_threaded, project_bin_fused};
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        for threads in [1usize, 2, 8] {
            let mut split_splats = Vec::new();
            project_into_threaded(&queue, &cam, &mut split_splats, threads);
            let mut split_bins = TileBins::default();
            bin_splats_into_threaded(
                &split_splats,
                cam.intr.width,
                cam.intr.height,
                &mut split_bins,
                threads,
            )
            .unwrap();
            let mut fused_splats = Vec::new();
            let mut fused_bins = TileBins::default();
            project_bin_fused(&queue, &cam, &mut fused_splats, &mut fused_bins, threads)
                .unwrap();
            fused_bins.validate_csr(fused_splats.len()).unwrap();
            assert_eq!(fused_splats.len(), split_splats.len(), "{threads} threads");
            for (f, s) in fused_splats.iter().zip(&split_splats) {
                assert_eq!(f.bit_pattern(), s.bit_pattern(), "{threads} threads");
            }
            assert_eq!(fused_bins.offsets, split_bins.offsets, "{threads} threads");
            assert_eq!(fused_bins.indices, split_bins.indices, "{threads} threads");
            assert_eq!(fused_bins.pairs, split_bins.pairs, "{threads} threads");
        }
    }

    #[test]
    fn soa_blend_tiles_bit_identical_to_scalar() {
        // The tile-level wiring of the SoA kernel (FrameScratch pool,
        // SoA plane stores, dynamic scheduler) must reproduce the
        // scalar kernel's frame bit for bit, in both alpha modes, at
        // serial and parallel widths, with the scratch reused across
        // frames.
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        let mut scratch = FrameScratch::new();
        for mode in [BlendMode::PerPixel, BlendMode::PixelGroup] {
            for threads in [1usize, 2, 8] {
                front_end_into(&queue, &cam, &mut scratch, threads).unwrap();
                let mut want = Image::new(cam.intr.width, cam.intr.height);
                blend_tiles(
                    &mut scratch,
                    mode,
                    BlendKernel::Scalar,
                    rcfg.t_min,
                    threads,
                    &mut want,
                );
                let mut got = Image::new(cam.intr.width, cam.intr.height);
                blend_tiles(
                    &mut scratch,
                    mode,
                    BlendKernel::Soa,
                    rcfg.t_min,
                    threads,
                    &mut got,
                );
                assert_eq!(
                    want.data, got.data,
                    "{mode:?} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn batch_blend_matches_per_view_blends() {
        // The multi-view scheduler contract: one interleaved (view,
        // tile) schedule over one worker pool must reproduce per-view
        // blend_tiles calls bit for bit — both kernels, both alpha
        // modes folded in via Group, serial and parallel widths, and
        // regardless of item interleaving order.
        let (scene, cut, _) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        let cams = [scene.scenario_camera(0), scene.scenario_camera(2)];
        let mut scratches = [FrameScratch::new(), FrameScratch::new()];
        for (cam, scratch) in cams.iter().zip(scratches.iter_mut()) {
            front_end_into(&queue, cam, scratch, 4).unwrap();
        }
        // Round-robin interleave of the two views' work lists, with an
        // inert per-tile tau on one view to pin the foveated hook as a
        // no-op.
        let mut items = Vec::new();
        let mut rank = 0usize;
        loop {
            let mut any = false;
            for (v, scratch) in scratches.iter().enumerate() {
                if rank < scratch.work.len() {
                    let tile = scratch.work[rank];
                    items.push(if v == 0 {
                        BatchWorkItem::new(v as u32, tile)
                    } else {
                        BatchWorkItem::with_tau(v as u32, tile, 16.0)
                    });
                    any = true;
                }
            }
            if !any {
                break;
            }
            rank += 1;
        }
        for kernel in [BlendKernel::Scalar, BlendKernel::Soa] {
            for threads in [1usize, 2, 8] {
                let mut want = Vec::new();
                for (cam, scratch) in cams.iter().zip(scratches.iter_mut()) {
                    let mut img = Image::new(cam.intr.width, cam.intr.height);
                    blend_tiles(
                        scratch,
                        BlendMode::PixelGroup,
                        kernel,
                        rcfg.t_min,
                        threads,
                        &mut img,
                    );
                    want.push(img);
                }
                let mut got: Vec<Image> = cams
                    .iter()
                    .map(|c| Image::new(c.intr.width, c.intr.height))
                    .collect();
                let mut pool = Vec::new();
                {
                    let mut views: Vec<BatchBlendView> = scratches
                        .iter_mut()
                        .zip(got.iter_mut())
                        .map(|(scratch, img)| BatchBlendView { scratch, img })
                        .collect();
                    blend_tiles_batch(
                        &mut views,
                        &items,
                        &mut pool,
                        BlendMode::PixelGroup,
                        kernel,
                        rcfg.t_min,
                        threads,
                    );
                }
                for (v, (w, g)) in want.iter().zip(got.iter()).enumerate() {
                    assert_eq!(
                        w.data, g.data,
                        "view {v} diverged: {kernel:?} at {threads} threads"
                    );
                }
            }
        }
    }

    #[test]
    fn group_mode_is_close_to_pixel_mode() {
        let (scene, cut, cam) = setup();
        let queue = scene.gaussians.gather(&cut);
        let rcfg = RenderConfig::default();
        let px = CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &rcfg);
        let gp = CpuRenderer::render(&queue, &cam, AlphaMode::Group, &rcfg);
        let mad = px.mad(&gp);
        assert!(mad < 0.02, "group approximation too lossy: {mad}");
        // And the approximation is not a no-op (some pixels differ) —
        // unless the scene is degenerate, which quick() scenes are not.
        assert!(mad > 0.0, "suspicious: identical images");
    }

    #[test]
    fn coarser_lod_renders_similar_image() {
        // The LoD system's whole premise: a coarser cut approximates the
        // finer render.
        let (scene, _, _) = setup();
        // Mid-distance camera so both cuts sit strictly inside the tree.
        let cam = scene.scenario_camera(3);
        let slt = SlTree::partition(&scene.tree, 32);
        let fine = slt.traverse(&scene.tree, &cam, 2.0);
        let coarse = slt.traverse(&scene.tree, &cam, 24.0);
        assert!(coarse.len() < fine.len());
        let rcfg = RenderConfig::default();
        let qa = scene.gaussians.gather(&fine);
        let qb = scene.gaussians.gather(&coarse);
        let ia = CpuRenderer::render(&qa, &cam, AlphaMode::Pixel, &rcfg);
        let ib = CpuRenderer::render(&qb, &cam, AlphaMode::Pixel, &rcfg);
        let p = crate::metrics::psnr(&ia, &ib);
        assert!(p > 14.0, "coarse LoD diverged: psnr {p}");
    }
}
