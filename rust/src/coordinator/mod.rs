//! The Layer-3 frame coordinator: LoD search -> rendering queue -> tile
//! binning -> depth sort -> chunked splatting -> image, plus the
//! workload extraction the simulators replay.
//!
//! * [`pipeline`] — the immutable [`FramePipeline`] (scene + SLTree +
//!   config + backend) and its builder.
//! * [`session`] — [`RenderSession`]: per-client mutable state (options,
//!   front-end scratch, temporal cut cache, unified stats); N sessions
//!   over one `&FramePipeline` form the multi-client serving surface.
//! * [`batch`] — [`ViewBatch`]: K cameras over one scene in one call,
//!   with identity-group front-end coalescing, cross-view LoD-search
//!   seeding through a shared cut cache, and one interleaved
//!   `(view, tile)` blend schedule — byte-identical to K independent
//!   session renders ([`BatchConfig`] picks the sharing levels).
//! * [`backend`] — the [`RenderBackend`] trait with the pure-CPU
//!   ([`CpuBackend`]) and AOT-artifact ([`PjrtBackend`]) blenders;
//!   [`RenderOptions::kernel`] picks the CPU blend-kernel
//!   implementation ([`BlendKernel`]: scalar reference or the
//!   divergence-free SoA kernel, byte-identical outputs).
//! * [`stats`] — [`RenderStats`] / [`StageTimings`]: one report type
//!   for frames, paths and serving sessions, including the cut cache's
//!   `cache_hit` / `revalidated` / `reseeded` counters and the
//!   log-bucketed [`LatencyHistogram`]s (per-stage and per-frame
//!   p50/p95/p99) the serving layer degrades on.
//! * [`renderer`] — the shared front end, the blend loops, and the
//!   stateless reference renderers the equivalence tests pin against.
//! * [`workload`] — runs the real pipeline once per (scene, camera,
//!   tau) and distils the traces every hardware model consumes.

#![warn(missing_docs)]

pub mod backend;
pub mod batch;
pub mod pipeline;
pub mod renderer;
pub mod session;
pub mod stats;
pub mod workload;

pub use crate::lod::cut_cache::{CutCache, CutCacheConfig};
pub use crate::splat::{BatchWorkItem, BlendKernel};
pub use backend::{CpuBackend, PjrtBackend, RenderBackend, RenderOptions};
pub use batch::{BatchConfig, BatchStats, ViewBatch};
pub use pipeline::{FramePipeline, FramePipelineBuilder, SimulationReport};
pub use renderer::{AlphaMode, BatchBlendView, CpuRenderer, FrameScratch};
pub use session::RenderSession;
pub use stats::{LatencyHistogram, RenderStats, StageTimings};
