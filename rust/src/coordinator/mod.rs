//! The Layer-3 frame coordinator: LoD search -> rendering queue -> tile
//! binning -> depth sort -> chunked splatting -> image, plus the
//! workload extraction the simulators replay.
//!
//! * [`workload`] — runs the real pipeline once per (scene, camera,
//!   tau) and distils the traces every hardware model consumes.
//! * [`renderer`] — produces actual images: a pure-CPU path (mirrors
//!   the kernels) and a PJRT path (executes the AOT artifacts).
//! * [`pipeline`] — the frame loop tying it together, with per-frame
//!   reports (`sltarch render` / the examples drive this).

pub mod pipeline;
pub mod renderer;
pub mod workload;

pub use pipeline::{FramePipeline, FrameReport, PathReport};
pub use renderer::{AlphaMode, CpuRenderer, FrameScratch};
