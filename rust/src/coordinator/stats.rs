//! Unified render statistics: one report type for single frames, camera
//! paths and whole serving sessions, with per-stage wall-clock
//! accumulators. Replaces the PR-1 `FrameReport`/`PathReport` split.
//!
//! Since the serving-layer PR the report also carries **log-bucketed
//! latency histograms** ([`LatencyHistogram`]): means hide exactly the
//! tail behaviour a deadline-driven serving loop degrades on, so every
//! stage and every whole frame records into a histogram that can answer
//! p50/p95/p99 queries with bounded (<= 25 %) relative error and zero
//! steady-state allocation.

/// Sub-buckets per power-of-two octave (2 mantissa bits).
const HIST_SUB: usize = 4;
/// First octave boundary: samples below `2^10` ns (~1 µs) share the
/// underflow bucket — nothing the renderer times is meaningfully faster.
const HIST_MIN_LOG2: u32 = 10;
/// Last octave boundary: samples at or above `2^34` ns (~17 s) share the
/// overflow bucket — anything that slow is an outage, not a latency.
const HIST_MAX_LOG2: u32 = 34;
/// Bucket count: underflow + `(34-10)` octaves x 4 sub-buckets + overflow.
const HIST_BUCKETS: usize = 2 + (HIST_MAX_LOG2 - HIST_MIN_LOG2) as usize * HIST_SUB;

/// Fixed-footprint log-bucketed latency histogram.
///
/// Buckets are powers of two from ~1 µs to ~17 s, each split into
/// [`HIST_SUB`] sub-buckets (2 mantissa bits), so a quantile's reported
/// upper bound overshoots the true sample by at most one sub-bucket
/// width — a relative error bounded by 25 %. Recording is O(1) with no
/// allocation ever (the counts live inline), so histograms are safe on
/// the per-frame hot path and cheap to [`LatencyHistogram::merge`]
/// across clients.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyHistogram {
    counts: [u32; HIST_BUCKETS],
    count: u64,
    sum_ns: f64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // Manual impl: `[u32; 98]` exceeds std's derived-Default arrays.
        LatencyHistogram {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum_ns: 0.0,
            max_ns: 0,
        }
    }
}

impl LatencyHistogram {
    /// An empty histogram (same as `Default`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a nanosecond sample.
    fn bucket(ns: u64) -> usize {
        if ns < (1u64 << HIST_MIN_LOG2) {
            return 0;
        }
        let oct = 63 - ns.leading_zeros();
        if oct >= HIST_MAX_LOG2 {
            return HIST_BUCKETS - 1;
        }
        let sub = ((ns >> (oct - 2)) & (HIST_SUB as u64 - 1)) as usize;
        1 + (oct - HIST_MIN_LOG2) as usize * HIST_SUB + sub
    }

    /// Inclusive upper bound (ns) of bucket `idx` — what quantiles
    /// report. The overflow bucket reports the recorded maximum.
    fn bucket_upper_ns(&self, idx: usize) -> u64 {
        if idx == 0 {
            1u64 << HIST_MIN_LOG2
        } else if idx == HIST_BUCKETS - 1 {
            self.max_ns
        } else {
            let i = idx - 1;
            let oct = HIST_MIN_LOG2 as usize + i / HIST_SUB;
            let sub = (i % HIST_SUB) as u64;
            (1u64 << (oct - 2)) * (HIST_SUB as u64 + sub + 1)
        }
    }

    /// Record one latency sample in seconds. Negative / NaN samples
    /// (degenerate clocks) clamp to zero rather than poisoning counts.
    pub fn record(&mut self, seconds: f64) {
        let ns = (seconds.max(0.0) * 1e9) as u64;
        let b = Self::bucket(ns);
        self.counts[b] = self.counts[b].saturating_add(1);
        self.count += 1;
        self.sum_ns += ns as f64;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether any sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean sample in seconds (0 when empty).
    pub fn mean_seconds(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns / self.count as f64 * 1e-9
        }
    }

    /// Largest sample in seconds.
    pub fn max_seconds(&self) -> f64 {
        self.max_ns as f64 * 1e-9
    }

    /// Quantile `q` in `[0, 1]` as seconds: the upper bound of the
    /// bucket holding the `ceil(q * count)`-th smallest sample
    /// (conservative — never under-reports a tail). 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c as u64;
            if seen >= target {
                return self.bucket_upper_ns(i) as f64 * 1e-9;
            }
        }
        self.max_seconds()
    }

    /// [`LatencyHistogram::quantile`] in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile(q) * 1e3
    }

    /// `[p50, p95, p99]` in milliseconds — the row every serving report
    /// prints.
    pub fn percentiles_ms(&self) -> [f64; 3] {
        [self.quantile_ms(0.50), self.quantile_ms(0.95), self.quantile_ms(0.99)]
    }

    /// Fold another histogram into this one (bucket-wise sum).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a = a.saturating_add(*b);
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Per-stage wall-clock seconds, accumulated across every frame a
/// [`super::session::RenderSession`] renders. The stages mirror the
/// pipeline order: LoD search (+ queue gather), the fused projection +
/// tile-count sweep, the CSR binning finish, radix depth sort, tile
/// blending.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimings {
    /// SLTree traversal + rendering-queue gather.
    pub search: f64,
    /// The fused front-end sweep: 3D -> 2D splat projection with the
    /// per-worker tile-count histograms accumulated inline (the old
    /// binning count pass rides along here since the fusion).
    pub project: f64,
    /// CSR binning finish (prefix-sum merge -> ordered scatter) plus
    /// the scheduler work-list build.
    pub bin: f64,
    /// In-place radix depth sort of every tile slice.
    pub sort: f64,
    /// Tile blending (CPU scheduler or PJRT artifacts).
    pub blend: f64,
    /// Per-stage latency histograms in pipeline order
    /// ([`StageTimings::SEARCH`] .. [`StageTimings::BLEND`]): each
    /// frame's per-stage duration is one sample, so stage tails
    /// (p95/p99) are visible next to the mean the `f64` sums give.
    pub hists: [LatencyHistogram; 5],
}

impl StageTimings {
    /// Index of the search-stage histogram in [`StageTimings::hists`].
    pub const SEARCH: usize = 0;
    /// Index of the projection-stage histogram.
    pub const PROJECT: usize = 1;
    /// Index of the binning-stage histogram.
    pub const BIN: usize = 2;
    /// Index of the sort-stage histogram.
    pub const SORT: usize = 3;
    /// Index of the blend-stage histogram.
    pub const BLEND: usize = 4;
    /// Sum of all stage accumulators. Always <= the wall-clock time of
    /// the renders that produced them (per-frame overhead — image
    /// allocation, stats bookkeeping — lands outside the stages).
    pub fn staged_total(&self) -> f64 {
        self.search + self.project + self.bin + self.sort + self.blend
    }

    /// Add another set of accumulators into this one (sums and
    /// histograms both).
    pub fn accumulate(&mut self, other: &StageTimings) {
        self.search += other.search;
        self.project += other.project;
        self.bin += other.bin;
        self.sort += other.sort;
        self.blend += other.blend;
        for (h, o) in self.hists.iter_mut().zip(other.hists.iter()) {
            h.merge(o);
        }
    }

    /// Record one frame's duration for stage `idx` (one of the
    /// [`StageTimings::SEARCH`]..[`StageTimings::BLEND`] consts) into
    /// both the wall-clock sum and the stage histogram.
    pub fn record_stage(&mut self, idx: usize, seconds: f64) {
        match idx {
            Self::SEARCH => self.search += seconds,
            Self::PROJECT => self.project += seconds,
            Self::BIN => self.bin += seconds,
            Self::SORT => self.sort += seconds,
            _ => self.blend += seconds,
        }
        self.hists[idx.min(Self::BLEND)].record(seconds);
    }

    /// `(name, [p50, p95, p99] ms)` rows in pipeline order.
    pub fn percentile_rows_ms(&self) -> [(&'static str, [f64; 3]); 5] {
        let names = self.rows().map(|(name, _)| name);
        [
            (names[0], self.hists[0].percentiles_ms()),
            (names[1], self.hists[1].percentiles_ms()),
            (names[2], self.hists[2].percentiles_ms()),
            (names[3], self.hists[3].percentiles_ms()),
            (names[4], self.hists[4].percentiles_ms()),
        ]
    }

    /// `(name, seconds)` rows in pipeline order — for reports/benches.
    pub fn rows(&self) -> [(&'static str, f64); 5] {
        [
            ("search", self.search),
            ("project", self.project),
            ("bin", self.bin),
            ("sort", self.sort),
            ("blend", self.blend),
        ]
    }

    /// `(name, ms/frame)` rows over `frames` frames — the one shared
    /// derivation every report (CLI, examples, hotpath bench) prints.
    /// `frames == 0` returns all-zero rows: there is no per-frame
    /// figure for zero frames, and silently dividing by 1 would report
    /// the raw totals as if they were one frame's cost.
    pub fn rows_ms_per_frame(&self, frames: usize) -> [(&'static str, f64); 5] {
        if frames == 0 {
            return self.rows().map(|(name, _)| (name, 0.0));
        }
        let scale = 1e3 / frames as f64;
        self.rows().map(|(name, secs)| (name, secs * scale))
    }
}

/// Unified rendering statistics. A [`super::session::RenderSession`]
/// accumulates one of these across every frame it renders; merge several
/// (one per client) for an aggregate serving report.
#[derive(Clone, Copy, Debug, Default)]
pub struct RenderStats {
    /// Frames rendered.
    pub frames: usize,
    /// Wall-clock seconds across those frames (search + render).
    pub wall_seconds: f64,
    /// Total rendering-queue length across frames.
    pub cut_total: u64,
    /// Total (gaussian, tile) pairs across frames.
    pub pairs_total: u64,
    /// Blend tile-scheduler worker count in effect (0 = offload backend).
    pub threads: usize,
    /// Unified scheduler width driving the parallel front end
    /// (project -> CSR bin -> tile sort); always >= 1 once a frame has
    /// rendered, even on offload backends (the front end stays on CPU).
    pub front_end_threads: usize,
    /// Frames whose LoD search ran the temporal cut cache's incremental
    /// revalidation path instead of a full traversal. Invariant:
    /// `cache_hit <= frames`; the complement counts cold searches
    /// (first frame, camera jumps, periodic refreshes, tau changes).
    pub cache_hit: u64,
    /// Node verdicts re-evaluated by incremental revalidation — cached
    /// frontier nodes (cut + frustum-culled boundary) plus the interior
    /// ancestors on their paths, each tested once per frame — summed
    /// across frames. 0 unless `cache_hit > 0`.
    pub revalidated: u64,
    /// Bounded refinement traversals seeded at cached cut nodes that
    /// stopped meeting the LoD, summed across frames. 0 unless
    /// `cache_hit > 0`.
    pub reseeded: u64,
    /// Frontier-path verdicts incremental revalidation reused without
    /// re-testing because the accumulated camera delta provably could
    /// not flip them (the cut cache's conservative verdict bounds),
    /// summed across frames. 0 unless `cache_hit > 0`;
    /// `revalidated + verdicts_skipped` is what an unbounded
    /// revalidation would have re-tested.
    pub verdicts_skipped: u64,
    /// Per-stage wall-clock breakdown.
    pub stages: StageTimings,
    /// End-to-end render latency histogram: one sample per frame (the
    /// same wall-clock that sums into
    /// [`RenderStats::wall_seconds`]), so p50/p95/p99 per-frame render
    /// cost is reportable, not just the mean.
    pub frame_latency: LatencyHistogram,
    /// Out-of-core slab residency telemetry (hit/miss/prefetch counts,
    /// bytes loaded/evicted/prefetched, simulated demand-stall time).
    /// All-zero unless the session's
    /// [`RenderOptions::residency`](super::backend::RenderOptions) knob
    /// is enabled; summed across clients by [`RenderStats::merge`].
    pub residency: crate::residency::ResidencyStats,
}

impl RenderStats {
    /// Aggregate throughput in frames per second.
    pub fn fps(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.frames as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Mean wall-clock milliseconds per frame.
    pub fn ms_per_frame(&self) -> f64 {
        if self.frames > 0 {
            self.wall_seconds / self.frames as f64 * 1e3
        } else {
            0.0
        }
    }

    /// Fold another session's stats into this one. Sums every counter
    /// including `wall_seconds` — correct for *sequential* windows
    /// (one client, several batches). For stats gathered from sessions
    /// that ran *concurrently*, summed wall-clock double-counts the
    /// overlap and [`RenderStats::fps`] under-reports aggregate
    /// throughput — use [`RenderStats::merge_concurrent`] with the
    /// measured span instead.
    pub fn merge(&mut self, other: &RenderStats) {
        self.frames += other.frames;
        self.wall_seconds += other.wall_seconds;
        self.cut_total += other.cut_total;
        self.pairs_total += other.pairs_total;
        self.threads = self.threads.max(other.threads);
        self.front_end_threads =
            self.front_end_threads.max(other.front_end_threads);
        self.cache_hit += other.cache_hit;
        self.revalidated += other.revalidated;
        self.reseeded += other.reseeded;
        self.verdicts_skipped += other.verdicts_skipped;
        self.stages.accumulate(&other.stages);
        self.frame_latency.merge(&other.frame_latency);
        self.residency.accumulate(&other.residency);
    }

    /// Fold a *concurrent* session's stats into this one: every counter
    /// sums like [`RenderStats::merge`], but `wall_seconds` is pinned
    /// to `span_seconds` — the measured wall-clock span the sessions
    /// ran in — so [`RenderStats::fps`] / [`RenderStats::ms_per_frame`]
    /// report true aggregate throughput instead of the summed (and
    /// overlap-double-counting) per-client time. Pass the same span on
    /// every call when folding several clients of one serving window.
    pub fn merge_concurrent(&mut self, other: &RenderStats, span_seconds: f64) {
        self.merge(other);
        self.wall_seconds = span_seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fps_and_ms_are_consistent() {
        let s = RenderStats { frames: 10, wall_seconds: 2.0, ..Default::default() };
        assert!((s.fps() - 5.0).abs() < 1e-12);
        assert!((s.ms_per_frame() - 200.0).abs() < 1e-9);
        assert_eq!(RenderStats::default().fps(), 0.0);
        assert_eq!(RenderStats::default().ms_per_frame(), 0.0);
    }

    #[test]
    fn merge_sums_counters_and_stages() {
        let mut a = RenderStats {
            frames: 2,
            wall_seconds: 1.0,
            cut_total: 10,
            pairs_total: 100,
            threads: 4,
            cache_hit: 1,
            revalidated: 200,
            reseeded: 3,
            stages: StageTimings { search: 0.1, blend: 0.2, ..Default::default() },
            ..Default::default()
        };
        let b = RenderStats {
            frames: 3,
            wall_seconds: 2.0,
            cut_total: 5,
            pairs_total: 50,
            threads: 2,
            cache_hit: 2,
            revalidated: 300,
            reseeded: 1,
            stages: StageTimings { search: 0.3, sort: 0.1, ..Default::default() },
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.frames, 5);
        assert_eq!(a.cut_total, 15);
        assert_eq!(a.pairs_total, 150);
        assert_eq!(a.threads, 4);
        assert_eq!(a.cache_hit, 3);
        assert_eq!(a.revalidated, 500);
        assert_eq!(a.reseeded, 4);
        assert!((a.wall_seconds - 3.0).abs() < 1e-12);
        assert!((a.stages.search - 0.4).abs() < 1e-12);
        assert!((a.stages.staged_total() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn merge_concurrent_pins_span_and_reports_aggregate_fps() {
        // Two clients, 10 frames in 2.0 s each, fully overlapping in a
        // 2.0 s span: aggregate throughput is 10 fps. Plain merge sums
        // the wall clocks (4.0 s -> 5 fps, the footgun); the concurrent
        // merge pins the span.
        let client = RenderStats { frames: 10, wall_seconds: 2.0, ..Default::default() };
        let mut summed = RenderStats::default();
        summed.merge(&client);
        summed.merge(&client);
        assert_eq!(summed.frames, 20);
        assert!((summed.wall_seconds - 4.0).abs() < 1e-12);
        assert!((summed.fps() - 5.0).abs() < 1e-12);
        let mut agg = RenderStats::default();
        agg.merge_concurrent(&client, 2.0);
        agg.merge_concurrent(&client, 2.0);
        assert_eq!(agg.frames, 20);
        assert!((agg.wall_seconds - 2.0).abs() < 1e-12);
        assert!((agg.fps() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_are_conservative_and_bounded() {
        let mut h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), 0.0);
        // 100 samples: 1 ms .. 100 ms.
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean_seconds() - 50.5e-3).abs() < 1e-4);
        assert!((h.max_seconds() - 100e-3).abs() < 1e-6);
        // Quantiles never under-report and overshoot by <= 25 %.
        for (q, want) in [(0.5, 50e-3), (0.95, 95e-3), (0.99, 99e-3)] {
            let got = h.quantile(q);
            assert!(got >= want, "q{q}: {got} under-reports {want}");
            assert!(got <= want * 1.25 + 1e-9, "q{q}: {got} overshoots {want}");
        }
        let [p50, p95, p99] = h.percentiles_ms();
        assert!(p50 <= p95 && p95 <= p99, "percentiles must be monotone");
    }

    #[test]
    fn histogram_extremes_clamp_instead_of_panicking() {
        let mut h = LatencyHistogram::new();
        h.record(0.0); // underflow bucket
        h.record(-1.0); // clamps to zero
        h.record(f64::NAN); // clamps to zero
        h.record(1e9); // overflow bucket (~31 years)
        assert_eq!(h.count(), 4);
        // Overflow bucket reports the recorded max, not a bucket bound.
        assert_eq!(h.quantile(1.0), h.max_seconds());
        // Underflow bucket reports ~1 µs.
        assert!(h.quantile(0.25) <= 1.1e-6);
    }

    #[test]
    fn histogram_merge_matches_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut both = LatencyHistogram::new();
        for i in 0..50 {
            let s = 1e-3 * (1.0 + i as f64);
            a.record(s);
            both.record(s);
        }
        for i in 0..50 {
            let s = 1e-2 * (1.0 + i as f64);
            b.record(s);
            both.record(s);
        }
        a.merge(&b);
        assert_eq!(a, both, "merge must equal recording the union");
    }

    #[test]
    fn record_stage_feeds_sum_and_histogram() {
        let mut st = StageTimings::default();
        st.record_stage(StageTimings::SEARCH, 0.002);
        st.record_stage(StageTimings::BLEND, 0.004);
        assert!((st.search - 0.002).abs() < 1e-12);
        assert!((st.blend - 0.004).abs() < 1e-12);
        assert_eq!(st.hists[StageTimings::SEARCH].count(), 1);
        assert_eq!(st.hists[StageTimings::BLEND].count(), 1);
        assert_eq!(st.hists[StageTimings::PROJECT].count(), 0);
        let rows = st.percentile_rows_ms();
        assert_eq!(rows[0].0, "search");
        assert!(rows[0].1[0] >= 2.0 && rows[0].1[0] <= 2.5);
        // accumulate folds histograms too.
        let mut total = StageTimings::default();
        total.accumulate(&st);
        total.accumulate(&st);
        assert_eq!(total.hists[StageTimings::SEARCH].count(), 2);
    }

    #[test]
    fn merge_folds_frame_latency_histograms() {
        let mut a = RenderStats::default();
        a.frame_latency.record(0.010);
        let mut b = RenderStats::default();
        b.frame_latency.record(0.020);
        a.merge(&b);
        assert_eq!(a.frame_latency.count(), 2);
    }

    #[test]
    fn merge_sums_residency_counters() {
        use crate::residency::ResidencyStats;
        let mut a = RenderStats::default();
        a.residency = ResidencyStats {
            frames: 1,
            hits: 2,
            misses: 1,
            bytes_loaded: 36,
            stall_seconds: 0.5,
            ..Default::default()
        };
        let mut b = RenderStats::default();
        b.residency = ResidencyStats {
            frames: 2,
            hits: 1,
            prefetch_hits: 1,
            prefetch_issued: 2,
            bytes_evicted: 72,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.residency.frames, 3);
        assert_eq!(a.residency.hits, 3);
        assert_eq!(a.residency.misses, 1);
        assert_eq!(a.residency.prefetch_hits, 1);
        assert_eq!(a.residency.bytes_loaded, 36);
        assert_eq!(a.residency.bytes_evicted, 72);
        assert!((a.residency.stall_seconds - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rows_ms_per_frame_zero_frames_is_all_zero() {
        let s = StageTimings { search: 1.5, blend: 0.5, ..Default::default() };
        for (name, ms) in s.rows_ms_per_frame(0) {
            assert_eq!(ms, 0.0, "stage {name} must report 0 for 0 frames");
        }
        // And the 1-frame report is the raw totals in ms.
        let rows = s.rows_ms_per_frame(1);
        assert_eq!(rows[0], ("search", 1500.0));
        assert_eq!(rows[4], ("blend", 500.0));
    }
}
