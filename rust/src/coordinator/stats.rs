//! Unified render statistics: one report type for single frames, camera
//! paths and whole serving sessions, with per-stage wall-clock
//! accumulators. Replaces the PR-1 `FrameReport`/`PathReport` split.

/// Per-stage wall-clock seconds, accumulated across every frame a
/// [`super::session::RenderSession`] renders. The stages mirror the
/// pipeline order: LoD search (+ queue gather), projection, CSR tile
/// binning, radix depth sort, tile blending.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageTimings {
    /// SLTree traversal + rendering-queue gather.
    pub search: f64,
    /// 3D -> 2D splat projection.
    pub project: f64,
    /// CSR tile binning (count -> prefix-sum -> scatter).
    pub bin: f64,
    /// In-place radix depth sort + work-list build.
    pub sort: f64,
    /// Tile blending (CPU scheduler or PJRT artifacts).
    pub blend: f64,
}

impl StageTimings {
    /// Sum of all stage accumulators. Always <= the wall-clock time of
    /// the renders that produced them (per-frame overhead — image
    /// allocation, stats bookkeeping — lands outside the stages).
    pub fn staged_total(&self) -> f64 {
        self.search + self.project + self.bin + self.sort + self.blend
    }

    /// Add another set of accumulators into this one.
    pub fn accumulate(&mut self, other: &StageTimings) {
        self.search += other.search;
        self.project += other.project;
        self.bin += other.bin;
        self.sort += other.sort;
        self.blend += other.blend;
    }

    /// `(name, seconds)` rows in pipeline order — for reports/benches.
    pub fn rows(&self) -> [(&'static str, f64); 5] {
        [
            ("search", self.search),
            ("project", self.project),
            ("bin", self.bin),
            ("sort", self.sort),
            ("blend", self.blend),
        ]
    }

    /// `(name, ms/frame)` rows over `frames` frames — the one shared
    /// derivation every report (CLI, examples, hotpath bench) prints.
    /// `frames == 0` returns all-zero rows: there is no per-frame
    /// figure for zero frames, and silently dividing by 1 would report
    /// the raw totals as if they were one frame's cost.
    pub fn rows_ms_per_frame(&self, frames: usize) -> [(&'static str, f64); 5] {
        if frames == 0 {
            return self.rows().map(|(name, _)| (name, 0.0));
        }
        let scale = 1e3 / frames as f64;
        self.rows().map(|(name, secs)| (name, secs * scale))
    }
}

/// Unified rendering statistics. A [`super::session::RenderSession`]
/// accumulates one of these across every frame it renders; merge several
/// (one per client) for an aggregate serving report.
#[derive(Clone, Copy, Debug, Default)]
pub struct RenderStats {
    /// Frames rendered.
    pub frames: usize,
    /// Wall-clock seconds across those frames (search + render).
    pub wall_seconds: f64,
    /// Total rendering-queue length across frames.
    pub cut_total: u64,
    /// Total (gaussian, tile) pairs across frames.
    pub pairs_total: u64,
    /// Blend tile-scheduler worker count in effect (0 = offload backend).
    pub threads: usize,
    /// Unified scheduler width driving the parallel front end
    /// (project -> CSR bin -> tile sort); always >= 1 once a frame has
    /// rendered, even on offload backends (the front end stays on CPU).
    pub front_end_threads: usize,
    /// Frames whose LoD search ran the temporal cut cache's incremental
    /// revalidation path instead of a full traversal. Invariant:
    /// `cache_hit <= frames`; the complement counts cold searches
    /// (first frame, camera jumps, periodic refreshes, tau changes).
    pub cache_hit: u64,
    /// Node verdicts re-evaluated by incremental revalidation — cached
    /// frontier nodes (cut + frustum-culled boundary) plus the interior
    /// ancestors on their paths, each tested once per frame — summed
    /// across frames. 0 unless `cache_hit > 0`.
    pub revalidated: u64,
    /// Bounded refinement traversals seeded at cached cut nodes that
    /// stopped meeting the LoD, summed across frames. 0 unless
    /// `cache_hit > 0`.
    pub reseeded: u64,
    /// Per-stage wall-clock breakdown.
    pub stages: StageTimings,
}

impl RenderStats {
    /// Aggregate throughput in frames per second.
    pub fn fps(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.frames as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Mean wall-clock milliseconds per frame.
    pub fn ms_per_frame(&self) -> f64 {
        if self.frames > 0 {
            self.wall_seconds / self.frames as f64 * 1e3
        } else {
            0.0
        }
    }

    /// Fold another session's stats into this one. Sums every counter
    /// including `wall_seconds` — correct for *sequential* windows
    /// (one client, several batches). For stats gathered from sessions
    /// that ran *concurrently*, summed wall-clock double-counts the
    /// overlap and [`RenderStats::fps`] under-reports aggregate
    /// throughput — use [`RenderStats::merge_concurrent`] with the
    /// measured span instead.
    pub fn merge(&mut self, other: &RenderStats) {
        self.frames += other.frames;
        self.wall_seconds += other.wall_seconds;
        self.cut_total += other.cut_total;
        self.pairs_total += other.pairs_total;
        self.threads = self.threads.max(other.threads);
        self.front_end_threads =
            self.front_end_threads.max(other.front_end_threads);
        self.cache_hit += other.cache_hit;
        self.revalidated += other.revalidated;
        self.reseeded += other.reseeded;
        self.stages.accumulate(&other.stages);
    }

    /// Fold a *concurrent* session's stats into this one: every counter
    /// sums like [`RenderStats::merge`], but `wall_seconds` is pinned
    /// to `span_seconds` — the measured wall-clock span the sessions
    /// ran in — so [`RenderStats::fps`] / [`RenderStats::ms_per_frame`]
    /// report true aggregate throughput instead of the summed (and
    /// overlap-double-counting) per-client time. Pass the same span on
    /// every call when folding several clients of one serving window.
    pub fn merge_concurrent(&mut self, other: &RenderStats, span_seconds: f64) {
        self.merge(other);
        self.wall_seconds = span_seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fps_and_ms_are_consistent() {
        let s = RenderStats { frames: 10, wall_seconds: 2.0, ..Default::default() };
        assert!((s.fps() - 5.0).abs() < 1e-12);
        assert!((s.ms_per_frame() - 200.0).abs() < 1e-9);
        assert_eq!(RenderStats::default().fps(), 0.0);
        assert_eq!(RenderStats::default().ms_per_frame(), 0.0);
    }

    #[test]
    fn merge_sums_counters_and_stages() {
        let mut a = RenderStats {
            frames: 2,
            wall_seconds: 1.0,
            cut_total: 10,
            pairs_total: 100,
            threads: 4,
            cache_hit: 1,
            revalidated: 200,
            reseeded: 3,
            stages: StageTimings { search: 0.1, blend: 0.2, ..Default::default() },
            ..Default::default()
        };
        let b = RenderStats {
            frames: 3,
            wall_seconds: 2.0,
            cut_total: 5,
            pairs_total: 50,
            threads: 2,
            cache_hit: 2,
            revalidated: 300,
            reseeded: 1,
            stages: StageTimings { search: 0.3, sort: 0.1, ..Default::default() },
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.frames, 5);
        assert_eq!(a.cut_total, 15);
        assert_eq!(a.pairs_total, 150);
        assert_eq!(a.threads, 4);
        assert_eq!(a.cache_hit, 3);
        assert_eq!(a.revalidated, 500);
        assert_eq!(a.reseeded, 4);
        assert!((a.wall_seconds - 3.0).abs() < 1e-12);
        assert!((a.stages.search - 0.4).abs() < 1e-12);
        assert!((a.stages.staged_total() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn merge_concurrent_pins_span_and_reports_aggregate_fps() {
        // Two clients, 10 frames in 2.0 s each, fully overlapping in a
        // 2.0 s span: aggregate throughput is 10 fps. Plain merge sums
        // the wall clocks (4.0 s -> 5 fps, the footgun); the concurrent
        // merge pins the span.
        let client = RenderStats { frames: 10, wall_seconds: 2.0, ..Default::default() };
        let mut summed = RenderStats::default();
        summed.merge(&client);
        summed.merge(&client);
        assert_eq!(summed.frames, 20);
        assert!((summed.wall_seconds - 4.0).abs() < 1e-12);
        assert!((summed.fps() - 5.0).abs() < 1e-12);
        let mut agg = RenderStats::default();
        agg.merge_concurrent(&client, 2.0);
        agg.merge_concurrent(&client, 2.0);
        assert_eq!(agg.frames, 20);
        assert!((agg.wall_seconds - 2.0).abs() < 1e-12);
        assert!((agg.fps() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn rows_ms_per_frame_zero_frames_is_all_zero() {
        let s = StageTimings { search: 1.5, blend: 0.5, ..Default::default() };
        for (name, ms) in s.rows_ms_per_frame(0) {
            assert_eq!(ms, 0.0, "stage {name} must report 0 for 0 frames");
        }
        // And the 1-frame report is the raw totals in ms.
        let rows = s.rows_ms_per_frame(1);
        assert_eq!(rows[0], ("search", 1500.0));
        assert_eq!(rows[4], ("blend", 500.0));
    }
}
