//! Multi-view batch rendering: K cameras over one scene in one call.
//!
//! A [`ViewBatch`] renders a slice of cameras through one shared front
//! end wherever cross-view structure allows it, while keeping the
//! non-negotiable contract that **batch output is byte-identical to K
//! independent single-view session renders** (pinned by the golden
//! stereo pass in `rust/tests/golden.rs` and the batch proptests in
//! `rust/tests/proptests.rs`). Three sharing levels, all exact:
//!
//! 1. **Identity groups** ([`BatchConfig::share_front_ends`]): views
//!    whose cameras are *bitwise equal* (the serving layer's duplicate
//!    coalescing case — N clients watching the same feed) form one
//!    group. The leader runs the whole frame once; members clone its
//!    image. Exact because the pipeline is deterministic: the same
//!    camera bits always produce the same frame bits.
//! 2. **Seed groups** ([`BatchConfig::seed_searches`]): identity-group
//!    leaders whose poses are close (within
//!    [`BatchConfig::max_translation`] / [`BatchConfig::max_rotation`])
//!    share one [`crate::lod::CutCache`] — every member's LoD search
//!    routes through the seed leader's cache, so the frontier a
//!    neighbouring view just searched seeds this view's search instead
//!    of a from-the-top traversal. Exact because the cache's
//!    incremental revalidation re-derives the *canonical* cut from any
//!    valid frontier at any camera delta (see `lod/cut_cache.rs`); the
//!    closeness thresholds only decide when sharing is *profitable*,
//!    never whether it is correct. When two consecutive searches in a
//!    group select bit-equal cuts, the later view also skips its
//!    gather and feeds its front end from the earlier view's rendering
//!    queue (same cut bytes => same queue bytes).
//! 3. **Interleaved blending** ([`BatchConfig::interleave_tiles`]):
//!    instead of K back-to-back blend passes (each joining its workers
//!    at its own ragged tail), the batch splices every view's
//!    non-empty-tile work list into one
//!    [`crate::splat::BatchWorkItem`] schedule drained by a single
//!    atomic-cursor worker pool
//!    ([`RenderBackend::blend_batch`]) — the LT-unit dynamic dequeue
//!    applied *across* views. Exact because tiles are disjoint and each
//!    is blended by the unchanged per-tile kernel. Work items carry an
//!    optional per-tile tau (a reserved foveated-rendering hook, inert
//!    today).
//!
//! Statistics contract: every view commits through the same
//! [`super::session::FrameWork`] bookkeeping as a single-view render,
//! so the *deterministic* counters (`frames`, `cut_total`,
//! `pairs_total`, `threads`, `front_end_threads`) always match K
//! independent sessions. The cut-cache counters (`cache_hit`,
//! `revalidated`, `reseeded`, `verdicts_skipped`) and residency
//! telemetry additionally match under [`BatchConfig::independent`];
//! with sharing enabled they reflect the shared searches actually
//! performed (identity members search nothing; seeded views hit the
//! leader's cache). Timings are wall-clock and never part of any
//! equality contract; the interleaved blend attributes each view an
//! equal 1/K share of the combined blend time.

use super::backend::{BatchBlendView, RenderBackend, RenderOptions};
use super::pipeline::FramePipeline;
use super::renderer::front_end_timed;
use super::session::{FrameWork, RenderSession};
use super::stats::{RenderStats, StageTimings};
use crate::math::Camera;
use crate::metrics::Image;
use crate::splat::{BatchWorkItem, TileState};
use anyhow::Result;
use std::time::Instant;

/// Cross-view sharing policy for a [`ViewBatch`].
///
/// Every knob is a *performance* policy: any combination renders
/// byte-identically to K independent sessions (see the module docs for
/// why each sharing level is exact).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Coalesce views with bitwise-identical cameras into one front
    /// end (leader renders, members clone the image).
    pub share_front_ends: bool,
    /// Route the LoD searches of pose-close views through one shared
    /// cut cache, so each view's search starts from the frontier a
    /// neighbouring view just established (and skip re-gathering when
    /// consecutive searches select bit-equal cuts).
    pub seed_searches: bool,
    /// Blend all views' tiles through one interleaved work list and a
    /// single scoped worker pool instead of K sequential blend passes.
    pub interleave_tiles: bool,
    /// Maximum eye-position distance (world units) for two views to
    /// share a cut cache. Grouping heuristic only — correctness never
    /// depends on it.
    pub max_translation: f32,
    /// Maximum forward-axis angle (radians) for two views to share a
    /// cut cache. Grouping heuristic only.
    pub max_rotation: f32,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            share_front_ends: true,
            seed_searches: true,
            interleave_tiles: true,
            max_translation: 0.5,
            max_rotation: std::f32::consts::FRAC_PI_8,
        }
    }
}

impl BatchConfig {
    /// All sharing off: the batch renders each view exactly like an
    /// independent session (the stats-equality reference mode).
    pub fn independent() -> Self {
        BatchConfig {
            share_front_ends: false,
            seed_searches: false,
            interleave_tiles: false,
            ..BatchConfig::default()
        }
    }
}

/// Batch-level sharing telemetry (what the cross-view machinery
/// actually reused; per-view rendering statistics live in each view's
/// [`RenderStats`], see [`ViewBatch::view_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Batch render calls.
    pub batches: u64,
    /// Views submitted across all batches.
    pub views: u64,
    /// Views served by cloning an identity-group leader's frame
    /// (their whole front end + blend was shared).
    pub front_ends_shared: u64,
    /// LoD searches routed through a pose-close neighbour's cut cache
    /// instead of this view's own.
    pub searches_seeded: u64,
    /// Gathers skipped because a view's cut was bit-equal to the
    /// previously gathered cut in its batch (the front end read the
    /// neighbour's rendering queue directly).
    pub gathers_skipped: u64,
}

/// A multi-view renderer over one [`FramePipeline`]: K cameras in, K
/// images out, with cross-view front-end sharing per [`BatchConfig`].
///
/// Owns one persistent [`RenderSession`] per view slot (grown lazily to
/// the widest batch seen), so per-slot temporal state — front-end
/// scratch, cut caches, per-view stats — carries across calls exactly
/// like long-lived single-view sessions. Construct via
/// [`FramePipeline::batch`] / [`FramePipeline::batch_with`] /
/// [`FramePipeline::batch_on`].
pub struct ViewBatch<'p> {
    pipeline: &'p FramePipeline,
    backend: &'p dyn RenderBackend,
    opts: RenderOptions,
    cfg: BatchConfig,
    /// One session per view slot, grown on demand and kept across
    /// calls (slot i always serves camera i of a batch).
    sessions: Vec<RenderSession<'p>>,
    /// Shared SoA tile-state pool for the interleaved blend.
    pool: Vec<TileState>,
    /// Reusable interleaved work-item buffer.
    items: Vec<BatchWorkItem>,
    /// The most recently gathered cut within the current batch call
    /// (drives the gather-skip comparison).
    prev_cut: Vec<u32>,
    stats: BatchStats,
}

/// Bit-level camera identity key: every field that can influence a
/// rendered frame, as raw bits (so `-0.0` vs `0.0` and NaN payloads
/// can never alias two cameras the pipeline could treat differently).
fn cam_key(cam: &Camera) -> [u32; 24] {
    let mut k = [0u32; 24];
    let mut w = 0;
    for row in cam.view.m {
        for v in row {
            k[w] = v.to_bits();
            w += 1;
        }
    }
    for v in cam.intr.to_array() {
        k[w] = v.to_bits();
        w += 1;
    }
    k[20] = cam.intr.width;
    k[21] = cam.intr.height;
    k[22] = cam.near.to_bits();
    k[23] = cam.far.to_bits();
    k
}

/// Whether two poses are close enough to profitably share a cut cache
/// (translation + forward-axis angle thresholds). Non-finite deltas
/// compare false, so degenerate cameras never group.
fn poses_close(a: &Camera, b: &Camera, cfg: &BatchConfig) -> bool {
    let dt = (a.eye() - b.eye()).length();
    let fa = a.view.rotation().row(2);
    let fb = b.view.rotation().row(2);
    let dr = fa.dot(fb).clamp(-1.0, 1.0).acos();
    dt <= cfg.max_translation && dr <= cfg.max_rotation
}

impl<'p> ViewBatch<'p> {
    pub(crate) fn new(
        pipeline: &'p FramePipeline,
        backend: &'p dyn RenderBackend,
        opts: RenderOptions,
        cfg: BatchConfig,
    ) -> Self {
        ViewBatch {
            pipeline,
            backend,
            opts,
            cfg,
            sessions: Vec::new(),
            pool: Vec::new(),
            items: Vec::new(),
            prev_cut: Vec::new(),
            stats: BatchStats::default(),
        }
    }

    /// The sharing policy this batch renders under (fixed at creation).
    pub fn config(&self) -> &BatchConfig {
        &self.cfg
    }

    /// The render options every view slot was opened with.
    pub fn options(&self) -> &RenderOptions {
        &self.opts
    }

    /// Batch-level sharing telemetry.
    pub fn batch_stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Return the sharing telemetry and start a fresh window.
    pub fn reset_batch_stats(&mut self) -> BatchStats {
        std::mem::take(&mut self.stats)
    }

    /// Rendering statistics of one view slot's session (None until a
    /// batch wide enough to open that slot has rendered).
    pub fn view_stats(&self, view: usize) -> Option<&RenderStats> {
        self.sessions.get(view).map(|s| s.stats())
    }

    /// Number of view slots opened so far (the widest batch rendered).
    pub fn view_slots(&self) -> usize {
        self.sessions.len()
    }

    /// Start a fresh statistics window on every view slot's session
    /// (cut caches and scratch stay warm, like
    /// [`RenderSession::reset_stats`]).
    pub fn reset_view_stats(&mut self) {
        for s in &mut self.sessions {
            s.reset_stats();
        }
    }

    /// Render one camera per view slot and return one image per
    /// camera, byte-identical to rendering each camera through its own
    /// independent session (see the module docs for the sharing levels
    /// and why each is exact). Errors abort the whole batch before any
    /// view's statistics commit, so the per-view counters can never
    /// count a half-rendered batch.
    pub fn render(&mut self, cams: &[Camera]) -> Result<Vec<Image>> {
        let k = cams.len();
        if k == 0 {
            return Ok(Vec::new());
        }
        while self.sessions.len() < k {
            self.sessions.push(RenderSession::new(
                self.pipeline,
                self.backend,
                self.opts,
            ));
        }
        self.stats.batches += 1;
        self.stats.views += k as u64;

        // --- plan: identity groups, then seed groups over their
        // leaders (greedy in view order, so owners always precede
        // members and splitting the session slice at a member's index
        // always exposes its owner mutably on the left).
        let keys: Vec<[u32; 24]> = cams.iter().map(cam_key).collect();
        let mut owner = vec![0usize; k];
        let mut cache = vec![0usize; k];
        let mut seed_leaders: Vec<usize> = Vec::new();
        for i in 0..k {
            owner[i] = if self.cfg.share_front_ends {
                (0..i)
                    .find(|&j| owner[j] == j && keys[j] == keys[i])
                    .unwrap_or(i)
            } else {
                i
            };
            if owner[i] != i {
                cache[i] = cache[owner[i]];
                self.stats.front_ends_shared += 1;
                continue;
            }
            cache[i] = if self.cfg.seed_searches {
                seed_leaders
                    .iter()
                    .copied()
                    .find(|&l| poses_close(&cams[l], &cams[i], &self.cfg))
                    .unwrap_or(i)
            } else {
                i
            };
            if cache[i] == i {
                seed_leaders.push(i);
            } else {
                self.stats.searches_seeded += 1;
            }
        }

        // --- per-view search/gather + front end (identity members do
        // nothing here; they clone their owner's image at commit).
        let pipeline = self.pipeline;
        let mut images: Vec<Image> = cams
            .iter()
            .map(|c| Image::new(c.intr.width, c.intr.height))
            .collect();
        let mut frames: Vec<Option<FrameWork>> = (0..k).map(|_| None).collect();
        let mut queue_src = vec![usize::MAX; k];
        let mut unique: Vec<usize> = Vec::new();
        self.prev_cut.clear();
        // View whose session queue holds the gather of `prev_cut`.
        let mut prev_owner = usize::MAX;

        for i in 0..k {
            if owner[i] != i {
                continue;
            }
            let cam = &cams[i];
            let mut fw;
            if cache[i] == i {
                // Own-cache search: the plain single-view stage.
                let s = &mut self.sessions[i];
                fw = s.begin_frame();
                s.search_and_gather(cam, &mut fw);
                self.prev_cut.clear();
                self.prev_cut.extend_from_slice(s.cut_cache.cut());
                prev_owner = i;
                queue_src[i] = i;
            } else {
                // Seeded search: route through the seed leader's cache
                // (leader index < i by construction).
                let l = cache[i];
                let (left, right) = self.sessions.split_at_mut(i);
                let leader = &mut left[l];
                let s = &mut right[0];
                fw = s.begin_frame();
                leader
                    .cut_cache
                    .set_collect_touched(s.opts.residency.enabled);
                let t = Instant::now();
                let (cut_len, same, trace) = {
                    let (cut, trace) = leader.cut_cache.search(
                        &pipeline.scene().tree,
                        pipeline.sltree(),
                        cam,
                        s.opts.lod_tau,
                        &s.opts.cut_cache,
                    );
                    let same =
                        prev_owner != usize::MAX && cut == &self.prev_cut[..];
                    if !same {
                        pipeline.scene().gaussians.gather_into(cut, &mut s.queue);
                        self.prev_cut.clear();
                        self.prev_cut.extend_from_slice(cut);
                    }
                    (cut.len() as u64, same, trace)
                };
                fw.cut_len = cut_len;
                fw.record_search(&trace);
                fw.stages
                    .record_stage(StageTimings::SEARCH, t.elapsed().as_secs_f64());
                s.charge_residency(&trace, leader.cut_cache.cut(), &mut fw);
                if same {
                    self.stats.gathers_skipped += 1;
                    queue_src[i] = prev_owner;
                } else {
                    prev_owner = i;
                    queue_src[i] = i;
                }
            }

            // Front end over this view's queue (or the bit-equal queue
            // a neighbouring view already gathered).
            let qs = queue_src[i];
            if qs == i {
                self.sessions[i].front_end(cam, &mut fw)?;
            } else {
                let (left, right) = self.sessions.split_at_mut(i);
                let src = &left[qs];
                let s = &mut right[0];
                let width = s.scheduler_width();
                front_end_timed(&src.queue, cam, &mut s.scratch, &mut fw.stages, width)?;
                fw.pairs = s.scratch.bins.pairs;
            }
            frames[i] = Some(fw);
            unique.push(i);
        }

        // --- blend: one interleaved (view, tile) schedule over all
        // unique views, or per-view passes when interleaving is off.
        if self.cfg.interleave_tiles && !unique.is_empty() {
            self.items.clear();
            let mut rank = 0usize;
            loop {
                let mut any = false;
                for (vi, &v) in unique.iter().enumerate() {
                    let work = &self.sessions[v].scratch.work;
                    if rank < work.len() {
                        self.items.push(BatchWorkItem::new(vi as u32, work[rank]));
                        any = true;
                    }
                }
                if !any {
                    break;
                }
                rank += 1;
            }
            let backend = self.backend;
            let opts = self.opts;
            let rcfg = pipeline.rcfg();
            let t = Instant::now();
            {
                let mut views: Vec<BatchBlendView<'_>> =
                    Vec::with_capacity(unique.len());
                let mut uniq = unique.iter().copied().peekable();
                for ((si, s), img) in
                    self.sessions.iter_mut().enumerate().zip(images.iter_mut())
                {
                    if uniq.peek() == Some(&si) {
                        uniq.next();
                        views.push(BatchBlendView { scratch: &mut s.scratch, img });
                    }
                }
                backend.blend_batch(
                    &mut views,
                    &self.items,
                    &mut self.pool,
                    &opts,
                    rcfg,
                )?;
            }
            // The combined pass has no per-view boundary; attribute an
            // equal share to each view (timings are telemetry, never
            // part of an equality contract).
            let share = t.elapsed().as_secs_f64() / unique.len() as f64;
            for &v in &unique {
                if let Some(fw) = frames[v].as_mut() {
                    fw.stages.record_stage(StageTimings::BLEND, share);
                }
            }
        } else {
            for &v in &unique {
                let s = &mut self.sessions[v];
                let t = Instant::now();
                s.backend
                    .blend(&mut s.scratch, &s.opts, pipeline.rcfg(), &mut images[v])?;
                if let Some(fw) = frames[v].as_mut() {
                    fw.stages
                        .record_stage(StageTimings::BLEND, t.elapsed().as_secs_f64());
                }
            }
        }

        // --- commit: whole batch succeeded. Unique views commit their
        // FrameWork; identity members clone the owner's image and
        // commit the owner's deterministic counters (what their own
        // search/front end would have computed, by determinism).
        let mut committed: Vec<(u64, u64)> = vec![(0, 0); k];
        for i in 0..k {
            if owner[i] == i {
                let fw = frames[i].take().expect("unique view has frame work");
                committed[i] = (fw.cut_len, fw.pairs);
                self.sessions[i].commit_frame(&fw);
            } else {
                let o = owner[i];
                let img = images[o].clone();
                images[i] = img;
                let mut fw = self.sessions[i].begin_frame();
                fw.cut_len = committed[o].0;
                fw.pairs = committed[o].1;
                self.sessions[i].commit_frame(&fw);
            }
        }
        Ok(images)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::coordinator::backend::CpuBackend;

    fn pipeline() -> FramePipeline {
        FramePipeline::builder(SceneConfig::small_scale().quick().build(11)).build()
    }

    fn orbit_cams(p: &FramePipeline, n: usize) -> Vec<Camera> {
        (0..n).map(|i| p.scene().scenario_camera(i)).collect()
    }

    #[test]
    fn batch_matches_independent_sessions_bitwise() {
        let p = pipeline();
        let cams = orbit_cams(&p, 3);
        for cfg in [BatchConfig::default(), BatchConfig::independent()] {
            let mut batch = p.batch_with(p.default_options(), cfg);
            let imgs = batch.render(&cams).unwrap();
            assert_eq!(imgs.len(), 3);
            for (i, (img, cam)) in imgs.iter().zip(cams.iter()).enumerate() {
                let want = p.session().render(cam).unwrap();
                assert_eq!(img.data, want.data, "view {i} diverged ({cfg:?})");
            }
        }
    }

    #[test]
    fn identity_views_share_one_front_end() {
        let p = pipeline();
        let cam = p.scene().scenario_camera(1);
        let cams = vec![cam, cam, cam, cam];
        let mut batch = p.batch();
        let imgs = batch.render(&cams).unwrap();
        let want = p.session().render(&cam).unwrap();
        for img in &imgs {
            assert_eq!(img.data, want.data);
        }
        let bs = batch.batch_stats();
        assert_eq!(bs.batches, 1);
        assert_eq!(bs.views, 4);
        assert_eq!(bs.front_ends_shared, 3, "3 of 4 identical views coalesce");
        // Deterministic per-view counters still match an independent
        // render of the same camera.
        let mut solo = p.session();
        solo.render(&cam).unwrap();
        for v in 0..4 {
            let vs = batch.view_stats(v).unwrap();
            assert_eq!(vs.frames, 1, "view {v}");
            assert_eq!(vs.cut_total, solo.stats().cut_total, "view {v}");
            assert_eq!(vs.pairs_total, solo.stats().pairs_total, "view {v}");
        }
    }

    #[test]
    fn stereo_pair_seeds_and_stays_identical() {
        let p = pipeline();
        // A stereo pair: two nearby eyes, same look target.
        let eye = crate::math::Vec3::new(6.0, 3.0, -6.0);
        let sep = crate::math::Vec3::new(0.05, 0.0, 0.0);
        let target = crate::math::Vec3::new(0.0, 0.0, 0.0);
        let up = crate::math::Vec3::new(0.0, 1.0, 0.0);
        let intr = crate::math::Intrinsics::from_fov(256, 256, 1.0);
        let cams = vec![
            Camera::look_at(eye, target, up, intr),
            Camera::look_at(eye + sep, target, up, intr),
        ];
        let mut batch = p.batch();
        // Two batch calls: the second exercises the warm shared cache.
        for _ in 0..2 {
            let imgs = batch.render(&cams).unwrap();
            for (i, cam) in cams.iter().enumerate() {
                let want = p.session().render(cam).unwrap();
                assert_eq!(imgs[i].data, want.data, "view {i}");
            }
        }
        let bs = batch.batch_stats();
        assert_eq!(bs.views, 4);
        assert_eq!(
            bs.searches_seeded, 2,
            "the right eye routes through the left eye's cache each call"
        );
    }

    #[test]
    fn independent_mode_matches_session_stats_exactly() {
        let p = pipeline();
        let cams = orbit_cams(&p, 2);
        let backend = CpuBackend::with_threads(2);
        let mut batch =
            p.batch_on(&backend, p.default_options(), BatchConfig::independent());
        // Two calls so the temporal cut caches warm per view slot.
        batch.render(&cams).unwrap();
        batch.render(&cams).unwrap();
        for (v, cam) in cams.iter().enumerate() {
            let mut solo = p.session_on(&backend, p.default_options());
            solo.render(cam).unwrap();
            solo.render(cam).unwrap();
            let vs = batch.view_stats(v).unwrap();
            let ss = solo.stats();
            assert_eq!(vs.frames, ss.frames, "view {v}");
            assert_eq!(vs.cut_total, ss.cut_total, "view {v}");
            assert_eq!(vs.pairs_total, ss.pairs_total, "view {v}");
            assert_cache_counters(vs, ss, v);
        }
        let bs = batch.batch_stats();
        assert_eq!(bs.front_ends_shared, 0);
        assert_eq!(bs.searches_seeded, 0);
        assert_eq!(bs.gathers_skipped, 0);
    }

    fn assert_cache_counters(a: &RenderStats, b: &RenderStats, v: usize) {
        assert_eq!(a.cache_hit, b.cache_hit, "view {v}");
        assert_eq!(a.revalidated, b.revalidated, "view {v}");
        assert_eq!(a.reseeded, b.reseeded, "view {v}");
        assert_eq!(a.verdicts_skipped, b.verdicts_skipped, "view {v}");
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let p = pipeline();
        let mut batch = p.batch();
        let imgs = batch.render(&[]).unwrap();
        assert!(imgs.is_empty());
        assert_eq!(batch.batch_stats().batches, 0);
        assert!(batch.view_stats(0).is_none());
    }
}
