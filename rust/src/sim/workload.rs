//! The workload descriptors every hardware model replays.
//!
//! Produced by `coordinator::workload::frame_workload` from an *actual*
//! pipeline execution (real SLTree traversal, real tile blending), so
//! all five Fig. 9 variants are compared on identical work.

use crate::lod::TraversalTrace;
use crate::splat::BlendStats;

/// Bytes of one LoD-tree node record in DRAM — re-exported from the
/// single source of truth next to `Subtree::bytes`, so the hardware
/// models and the SLTree itself can never disagree on the figure.
/// [`slab_bytes`] converts a node count to slab bytes.
pub use crate::lod::sltree::{slab_bytes, NODE_BYTES};

/// Bytes of one rendering-queue entry streamed to the splatting stage
/// (mean2d 8 + conic 12 + colour 12 + opacity 4 + depth 4 + id 4).
pub const SPLAT_BYTES: u64 = 44;

/// LoD-search workload for one frame.
#[derive(Clone, Debug, Default)]
pub struct LodWorkload {
    /// Total tree nodes (the exhaustive-search cost).
    pub total_nodes: u64,
    /// Canonical hierarchical search visit count (same as SLTree's).
    pub canonical_visited: u64,
    /// Cut size (rendering-queue length).
    pub cut_len: u64,
    /// Full SLTree traversal trace (activations, fetches, balance).
    pub trace: TraversalTrace,
    /// Per-thread node counts under the naive static one-thread-per-
    /// subtree GPU schedule (Fig. 3).
    pub naive_thread_loads: Vec<u64>,
}

/// Splatting workload for one frame.
#[derive(Clone, Debug, Default)]
pub struct SplatWorkload {
    /// Rendering-queue length (projection work).
    pub queue_len: u64,
    /// (gaussian, tile) duplication pairs (sorting + blending work).
    pub pairs: u64,
    /// Per-tile sorted-list lengths (sorting-network work).
    pub tile_lens: Vec<u64>,
    /// Aggregated blending counters under the per-pixel dataflow
    /// (GPU and GSCore replay these).
    pub pixel: BlendStats,
    /// Aggregated blending counters under the 2x2 group dataflow
    /// (SPCore replays these).
    pub group: BlendStats,
    /// Output image bytes (written back once per frame).
    pub image_bytes: u64,
}

impl SplatWorkload {
    /// DRAM bytes streamed in for the rendering queue.
    pub fn queue_bytes(&self) -> u64 {
        self.queue_len * SPLAT_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_constants_are_consistent() {
        // NODE_BYTES must match Subtree::bytes' per-node figure.
        let st = crate::lod::Subtree { nodes: vec![0, 1, 2], ..Default::default() };
        assert_eq!(st.bytes(), 3 * NODE_BYTES);
        assert_eq!(st.bytes(), slab_bytes(3));
    }

    #[test]
    fn queue_bytes_scale() {
        let w = SplatWorkload { queue_len: 100, ..Default::default() };
        assert_eq!(w.queue_bytes(), 4400);
    }
}
