//! Result containers shared by all hardware models.

use super::dram::Traffic;
use super::energy::Energy;

/// One pipeline stage on one piece of hardware.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageResult {
    /// Cycles at the unit's own clock.
    pub cycles: u64,
    /// Wall-clock seconds (cycles / clock).
    pub seconds: f64,
    /// Memory traffic attributed to the stage.
    pub traffic: Traffic,
    /// Energy attributed to the stage.
    pub energy: Energy,
}

impl StageResult {
    pub fn combine(&self, o: &StageResult) -> StageResult {
        let mut traffic = self.traffic;
        traffic.add(o.traffic);
        let mut energy = self.energy;
        energy.add(o.energy);
        StageResult {
            cycles: self.cycles + o.cycles,
            seconds: self.seconds + o.seconds,
            traffic,
            energy,
        }
    }
}

/// A full-frame simulation report for one hardware variant.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub variant: String,
    pub lod: StageResult,
    pub splat: StageResult,
    /// "Others" (paper Fig. 2): projection/duplication/sorting overhead
    /// is folded into `splat` by every model; `other` holds frame setup.
    pub other: StageResult,
}

impl SimReport {
    pub fn total_seconds(&self) -> f64 {
        self.lod.seconds + self.splat.seconds + self.other.seconds
    }

    pub fn total_energy_mj(&self) -> f64 {
        self.lod.energy.total_mj()
            + self.splat.energy.total_mj()
            + self.other.energy.total_mj()
    }

    pub fn total_dram_bytes(&self) -> u64 {
        self.lod.traffic.dram_total()
            + self.splat.traffic.dram_total()
            + self.other.traffic.dram_total()
    }

    /// Fraction of frame time spent in LoD search (Fig. 2's quantity).
    pub fn lod_fraction(&self) -> f64 {
        let t = self.total_seconds();
        if t <= 0.0 {
            0.0
        } else {
            self.lod.seconds / t
        }
    }

    /// One human-readable summary line.
    pub fn summary(&self) -> String {
        format!(
            "{:<10} total {:>9.3} ms (lod {:>6.1}% ) energy {:>9.3} mJ dram {:>8.2} MB",
            self.variant,
            self.total_seconds() * 1e3,
            self.lod_fraction() * 100.0,
            self.total_energy_mj(),
            self.total_dram_bytes() as f64 / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut r = SimReport { variant: "x".into(), ..Default::default() };
        r.lod.seconds = 0.25;
        r.splat.seconds = 0.75;
        r.lod.energy.compute_pj = 1e9;
        r.splat.energy.gpu_pj = 3e9;
        assert!((r.total_seconds() - 1.0).abs() < 1e-12);
        assert!((r.lod_fraction() - 0.25).abs() < 1e-12);
        assert!((r.total_energy_mj() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn stage_combine() {
        let a = StageResult { cycles: 10, seconds: 1.0, ..Default::default() };
        let b = StageResult { cycles: 5, seconds: 0.5, ..Default::default() };
        let c = a.combine(&b);
        assert_eq!(c.cycles, 15);
        assert!((c.seconds - 1.5).abs() < 1e-12);
    }
}
