//! Mobile-Ampere SIMT timing/energy model (the paper's GPU baseline).
//!
//! Trace-driven: replays the frame workloads through a lockstep-warp
//! machine with divergence masking, an exhaustive LoD search (what
//! HierarchicalGS ships to sidestep GPU tree imbalance — Sec. II-B),
//! and a sustained-issue-efficiency factor calibrated to Orin-class
//! parts. Energy is power x busy-time, as the paper measures via the
//! Nvidia power monitor API (then DeepScale-scaled).

use super::dram::Traffic;
use super::energy::Energy;
use super::report::StageResult;
use super::workload::{LodWorkload, SplatWorkload, NODE_BYTES};

/// Bytes per node the GPU's exhaustive search reads: unlike LTCore's
/// preprocessed 36 B cache entries, the GPU kernel loads the raw
/// Gaussian attributes (mean 12 + scale 12 + quat 16 + hierarchy 20)
/// and recomputes the projected dimension per node.
pub const GPU_NODE_BYTES: u64 = 60;
use crate::config::{DramConfig, GpuConfig};

/// Effective parallel lanes the GPU sustains.
fn effective_lanes(cfg: &GpuConfig) -> f64 {
    (cfg.sms * cfg.warp_lanes * cfg.warps_per_sm) as f64 * cfg.issue_efficiency
}

/// Effective warp-issue slots per cycle.
fn effective_warp_slots(cfg: &GpuConfig) -> f64 {
    (cfg.sms * cfg.warps_per_sm) as f64 * cfg.issue_efficiency
}

/// Exhaustive LoD search on the GPU: every tree node is streamed and
/// tested (perfectly balanced, massively wasteful — the baseline's
/// trade). Memory-bound on large scenes, which is exactly the paper's
/// "LoD search dominates at scale" observation.
pub fn lod_exhaustive(
    w: &LodWorkload,
    cfg: &GpuConfig,
    dram: &DramConfig,
) -> StageResult {
    let compute =
        (w.total_nodes * cfg.node_test_cycles) as f64 / effective_lanes(cfg);
    let traffic = Traffic::stream(w.total_nodes * GPU_NODE_BYTES);
    let mem = traffic.dram_cycles(dram) as f64;
    let cycles = compute.max(mem).ceil() as u64;
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);
    StageResult {
        cycles,
        seconds,
        traffic,
        energy: Energy::gpu(seconds, cfg),
    }
}

/// Hierarchical LoD search on the GPU with the naive static
/// one-thread-per-subtree schedule: the makespan is the slowest
/// thread's walk, with irregular pointer-chase misses stalling it
/// (Fig. 3's regime; used by the Fig. 11 comparison axis).
pub fn lod_hierarchical(
    w: &LodWorkload,
    cfg: &GpuConfig,
    dram: &DramConfig,
) -> StageResult {
    let max_load = w.naive_thread_loads.iter().copied().max().unwrap_or(0);
    let visited: u64 = w.naive_thread_loads.iter().sum();
    // The slowest thread serializes the kernel; each of its node visits
    // pays the test plus an expected irregular-miss stall.
    let per_node = cfg.node_test_cycles as f64
        + cfg.tree_miss_rate * cfg.irregular_miss_cycles as f64;
    let cycles = (max_load as f64 * per_node).ceil() as u64;
    let random_bytes = (visited as f64 * cfg.tree_miss_rate) as u64 * NODE_BYTES;
    let sram_bytes = visited * NODE_BYTES - random_bytes;
    let mut traffic = Traffic::random(random_bytes);
    traffic.add(Traffic::sram(sram_bytes));
    let _ = dram;
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);
    StageResult {
        cycles,
        seconds,
        traffic,
        energy: Energy::gpu(seconds, cfg),
    }
}

/// Splatting on the GPU: projection + radix sort + divergent per-pixel
/// blending. Warp time follows the lane-occupancy trace: a warp issues
/// the blend body iff any lane is active; masked lanes waste slots.
pub fn splat(w: &SplatWorkload, cfg: &GpuConfig, dram: &DramConfig) -> StageResult {
    let lanes = effective_lanes(cfg);
    let proj = w.queue_len as f64 * cfg.proj_cycles as f64 / lanes;
    let sort = w.pairs as f64 * cfg.sort_cycles_per_pair as f64 / lanes;
    // Blending: every issued warp runs the full alpha+blend body.
    let warp_body = (cfg.alpha_cycles + cfg.blend_cycles) as f64;
    let blend = w.pixel.divergence.warps_issued as f64 * warp_body
        / effective_warp_slots(cfg);
    let compute = proj + sort + blend;

    let mut traffic = Traffic::stream(w.queue_bytes() + w.image_bytes);
    // Tile lists are built with atomics and read back scattered.
    traffic.add(Traffic::random(w.pairs * 8));
    let mem = traffic.dram_cycles(dram) as f64;

    let cycles = compute.max(mem).ceil() as u64;
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);
    StageResult {
        cycles,
        seconds,
        traffic,
        energy: Energy::gpu(seconds, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splat::BlendStats;

    fn dram() -> DramConfig {
        DramConfig::default()
    }

    #[test]
    fn exhaustive_scales_with_tree_size() {
        let cfg = GpuConfig::default();
        let mk = |n: u64| LodWorkload { total_nodes: n, ..Default::default() };
        let small = lod_exhaustive(&mk(10_000), &cfg, &dram());
        let large = lod_exhaustive(&mk(1_000_000), &cfg, &dram());
        assert!(large.cycles > 50 * small.cycles);
        // Large trees are memory-bound: traffic grows linearly.
        assert_eq!(large.traffic.dram_stream_bytes, 1_000_000 * GPU_NODE_BYTES);
    }

    #[test]
    fn hierarchical_makespan_follows_slowest_thread() {
        let cfg = GpuConfig::default();
        let balanced = LodWorkload {
            naive_thread_loads: vec![1000; 8],
            ..Default::default()
        };
        let skewed = LodWorkload {
            naive_thread_loads: vec![100, 100, 100, 100, 100, 100, 100, 7300],
            ..Default::default()
        };
        let b = lod_hierarchical(&balanced, &cfg, &dram());
        let s = lod_hierarchical(&skewed, &cfg, &dram());
        // Same total work, ~7x worse makespan under skew.
        assert!(s.cycles > 5 * b.cycles, "{} vs {}", s.cycles, b.cycles);
    }

    #[test]
    fn divergence_inflates_splat_time() {
        let cfg = GpuConfig::default();
        let mut uniform = SplatWorkload::default();
        let mut divergent = SplatWorkload::default();
        // Same number of active lanes; divergent issues 2x the warps.
        uniform.pixel = BlendStats::default();
        uniform.pixel.divergence.warps_issued = 1000;
        uniform.pixel.divergence.active_lanes = 32_000;
        divergent.pixel.divergence.warps_issued = 2000;
        divergent.pixel.divergence.active_lanes = 32_000;
        let u = splat(&uniform, &cfg, &dram());
        let d = splat(&divergent, &cfg, &dram());
        assert!(d.cycles > u.cycles);
    }

    #[test]
    fn gpu_energy_tracks_time() {
        let cfg = GpuConfig::default();
        let w = LodWorkload { total_nodes: 500_000, ..Default::default() };
        let r = lod_exhaustive(&w, &cfg, &dram());
        let want = r.seconds * cfg.power_w * 1e12;
        assert!((r.energy.total_pj() - want).abs() < 1.0);
    }
}
