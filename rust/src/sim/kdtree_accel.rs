//! QuickNN and Crescent — kd-tree traversal accelerators re-targeted at
//! LoD search for the Fig. 11 comparison.
//!
//! Structural differences vs LTCore that the paper's argument rests on
//! (Sec. V-D):
//!
//! 1. **Binary expansion** — a kd-tree is binary; representing the
//!    LoD tree's f-ary nodes costs extra internal nodes, so the same
//!    cut requires visiting more nodes.
//! 2. **Traceback stacks** — kd-tree traversal needs a per-PE stack
//!    with push/pop on every descent/backtrack; LoD search never
//!    backtracks, so those are pure overhead.
//! 3. **Offline scheduling** — both accelerators statically partition
//!    the tree across PEs, so the view-dependent imbalance of the LoD
//!    cut hits their makespan directly.
//! 4. **Memory** — QuickNN's node accesses are cache-banked but
//!    irregular (random DRAM on misses); Crescent's schedule-aware
//!    reordering recovers mostly-streaming behaviour (its paper's
//!    contribution), at the price of extra visits.

use super::dram::Traffic;
use super::energy::{op_pj, Energy};
use super::report::StageResult;
use super::workload::{LodWorkload, NODE_BYTES};
use crate::config::DramConfig;

/// Parameters of one kd-tree-accelerator model.
#[derive(Clone, Copy, Debug)]
pub struct KdAccelConfig {
    pub name: &'static str,
    pub clock_ghz: f64,
    /// Processing elements (set equal to LTCore's LT units for the
    /// paper's "same number of PEs" comparison).
    pub pes: usize,
    /// Cycles per node test.
    pub node_test_cycles: u64,
    /// Stack push/pop cycles per visited node (traceback overhead).
    pub stack_cycles: u64,
    /// Visited-node multiplier from binary expansion of the f-ary tree.
    pub expansion: f64,
    /// Fraction of node fetches that go to DRAM as random accesses.
    pub random_fetch_rate: f64,
    /// Average stall cycles per random fetch.
    pub miss_stall_cycles: u64,
}

impl KdAccelConfig {
    /// QuickNN (HPCA'20): kd-tree NN accelerator; banked node cache,
    /// but pointer-chasing DRAM behaviour on deep trees and a static
    /// subtree split across PEs.
    pub fn quicknn() -> Self {
        KdAccelConfig {
            name: "QuickNN",
            clock_ghz: 1.0,
            pes: 4,
            node_test_cycles: 1,
            stack_cycles: 2,
            expansion: 1.8,
            random_fetch_rate: 0.30,
            miss_stall_cycles: 40,
        }
    }

    /// Crescent (ISCA'22): tames memory irregularity by schedule-aware
    /// reordering — mostly streaming DRAM — but keeps the stack
    /// dataflow and offline schedule, and pays extra visits for the
    /// reordering windows.
    pub fn crescent() -> Self {
        KdAccelConfig {
            name: "Crescent",
            clock_ghz: 1.0,
            pes: 4,
            node_test_cycles: 1,
            stack_cycles: 2,
            expansion: 2.0,
            random_fetch_rate: 0.04,
            miss_stall_cycles: 40,
        }
    }
}

/// Run the LoD-search stage on a kd-tree accelerator.
pub fn search(w: &LodWorkload, cfg: &KdAccelConfig, dram: &DramConfig) -> StageResult {
    let visited = (w.canonical_visited as f64 * cfg.expansion).ceil() as u64;

    // Static scheduling: the makespan inherits the naive partition's
    // imbalance. Re-bucket the per-thread loads onto this accelerator's
    // PE count (round-robin, offline — what QuickNN/Crescent do) and
    // take max/mean over the PEs.
    let imbalance = {
        let n_pes = cfg.pes.max(1);
        let mut pe_loads = vec![0u64; n_pes];
        for (i, &l) in w.naive_thread_loads.iter().enumerate() {
            pe_loads[i % n_pes] += l;
        }
        let max = pe_loads.iter().copied().max().unwrap_or(1) as f64;
        let mean = (pe_loads.iter().sum::<u64>() as f64 / pe_loads.len() as f64)
            .max(1.0);
        (max / mean).max(1.0)
    };

    let per_node = (cfg.node_test_cycles + cfg.stack_cycles) as f64
        + cfg.random_fetch_rate * cfg.miss_stall_cycles as f64;
    let balanced = visited as f64 / cfg.pes as f64 * per_node;
    let cycles = (balanced * imbalance).ceil() as u64;

    let random_bytes = (visited as f64 * cfg.random_fetch_rate) as u64 * NODE_BYTES;
    let stream_bytes = visited * NODE_BYTES - random_bytes;
    let mut traffic = Traffic::random(random_bytes);
    traffic.add(Traffic::stream(stream_bytes));
    // Stack spills live in PE-local SRAM.
    traffic.add(Traffic::sram(visited * 8));

    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);
    let compute_pj = visited as f64 * (op_pj::NODE_TEST + op_pj::STACK_OP);
    StageResult {
        cycles,
        seconds,
        traffic,
        energy: Energy::accel(compute_pj, &traffic, dram),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::workload::slab_bytes;
    use crate::config::LtCoreConfig;
    use crate::lod::TraversalTrace;

    fn workload() -> LodWorkload {
        LodWorkload {
            total_nodes: 300_000,
            canonical_visited: 40_000,
            cut_len: 20_000,
            naive_thread_loads: {
                // Skewed static loads (city-like imbalance).
                let mut v = vec![2_000u64; 16];
                v[0] = 18_000;
                v
            },
            trace: TraversalTrace {
                visited: 40_000,
                selected: 20_000,
                activations: 1_400,
                activation_sizes: vec![29; 1_400],
                activation_sids: (0..1_400).collect(),
                subtree_bytes: vec![slab_bytes(32) as u32; 1_400],
                bytes_streamed: 1_400 * slab_bytes(32),
                subtree_fetches: 1_400,
                per_thread_nodes: vec![10_000; 4],
                queue_peak: 64,
                ..Default::default()
            },
        }
    }

    #[test]
    fn ltcore_beats_both_kdtree_accels() {
        let w = workload();
        let dram = DramConfig::default();
        let lt = super::super::ltcore::search_workload(&w, &LtCoreConfig::default(), &dram);
        let qn = search(&w, &KdAccelConfig::quicknn(), &dram);
        let cr = search(&w, &KdAccelConfig::crescent(), &dram);
        assert!(
            lt.stage.cycles < cr.cycles && lt.stage.cycles < qn.cycles,
            "LT {} vs QuickNN {} / Crescent {}",
            lt.stage.cycles,
            qn.cycles,
            cr.cycles
        );
    }

    #[test]
    fn crescent_has_less_random_traffic_than_quicknn() {
        let w = workload();
        let dram = DramConfig::default();
        let qn = search(&w, &KdAccelConfig::quicknn(), &dram);
        let cr = search(&w, &KdAccelConfig::crescent(), &dram);
        assert!(cr.traffic.dram_random_bytes < qn.traffic.dram_random_bytes);
    }

    #[test]
    fn static_imbalance_hurts_makespan() {
        let mut balanced = workload();
        balanced.naive_thread_loads = vec![3_000; 16];
        let skewed = workload();
        let dram = DramConfig::default();
        let cfg = KdAccelConfig::quicknn();
        let b = search(&balanced, &cfg, &dram);
        let s = search(&skewed, &cfg, &dram);
        assert!(s.cycles as f64 > 1.5 * b.cycles as f64);
    }
}
