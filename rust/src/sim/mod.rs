//! Cycle-approximate, trace-driven models of every piece of hardware
//! the paper evaluates (DESIGN.md §2 explains the substitution fidelity).
//!
//! All models consume the *same* workload traces produced by the actual
//! rust pipeline (`coordinator::workload`), so comparisons are
//! apples-to-apples: the LoD traces come from real SLTree traversals and
//! the splat traces from real tile blending over the same frames.
//!
//! * [`gpu`] — mobile-Ampere SIMT baseline (lockstep warps, divergence
//!   masking, exhaustive LoD search, irregular-access penalties).
//! * [`ltcore`] — the paper's LoD-search accelerator: LT-unit array +
//!   two-segment subtree queue + set-associative subtree cache.
//! * [`spcore`] — the paper's splatting accelerator: GSCore front end +
//!   2x2 SP units (group alpha check, divergence-free blend).
//! * [`gscore`] — the GSCore baseline (per-pixel VR units + OBB tests).
//! * [`kdtree_accel`] — QuickNN / Crescent kd-tree traversal
//!   accelerators re-targeted at LoD search (Fig. 11).
//! * [`dram`] / [`energy`] — LPDDR4 + SRAM traffic and energy
//!   accounting with the paper's 25:1 and 3:1 ratios.
//! * [`variants`] — the five hardware variants of Fig. 9/10 assembled
//!   from the pieces above.

pub mod dram;
pub mod energy;
pub mod gpu;
pub mod gscore;
pub mod kdtree_accel;
pub mod ltcore;
pub mod report;
pub mod spcore;
pub mod variants;
pub mod workload;

pub use report::SimReport;
pub use variants::{simulate_variant, HwVariant, VariantResult};

/// Simulated time in cycles at the unit's own clock.
pub type Cycles = u64;

/// Convert cycles at `clock_ghz` to seconds.
#[inline]
pub fn cycles_to_seconds(cycles: Cycles, clock_ghz: f64) -> f64 {
    cycles as f64 / (clock_ghz * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_conversion() {
        assert!((cycles_to_seconds(1_000_000_000, 1.0) - 1.0).abs() < 1e-12);
        assert!((cycles_to_seconds(930_000_000, 0.93) - 1.0).abs() < 1e-9);
    }
}
