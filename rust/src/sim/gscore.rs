//! GSCore baseline (Lee et al., ASPLOS'24) as the paper models it:
//! the same projection/sorting front end, but (a) precise OBB
//! Gaussian-tile intersection refinement in the front end, and (b)
//! per-pixel volume-rendering lanes that evaluate the full alpha (exp
//! included) for every pixel of every intersecting Gaussian.
//!
//! Versus SPCore the differences the paper leans on are: extra OBB
//! compute per pair, 4x the alpha-exp work (no group gating), and
//! per-pixel divergence handled by masking lanes (idle lanes still
//! burn slots).

use super::dram::Traffic;
use super::energy::{op_pj, Energy};
use super::report::StageResult;
use super::workload::SplatWorkload;
use crate::config::{DramConfig, GsCoreConfig};
use crate::splat::sort::bitonic_compare_ops;

/// Detailed GSCore result.
#[derive(Clone, Copy, Debug, Default)]
pub struct GsCoreResult {
    pub stage: StageResult,
    pub proj_cycles: u64,
    pub sort_cycles: u64,
    pub vr_cycles: u64,
    pub memory_cycles: u64,
}

/// Run the splatting stage on GSCore by replaying the per-pixel
/// dataflow counters.
pub fn splat(w: &SplatWorkload, cfg: &GsCoreConfig, dram: &DramConfig) -> GsCoreResult {
    // Front end: projection plus the OBB refinement over every pair.
    let proj_cycles = (w.queue_len * cfg.proj_cycles + w.pairs * cfg.obb_cycles)
        .div_ceil(cfg.proj_units as u64);

    // OBB filtering trims false-positive pairs before sorting
    // (GSCore's headline optimization; ~30% of 3-sigma pairs are false
    // positives at tile granularity).
    const OBB_KEEP: f64 = 0.7;
    let cmp_ops: u64 = w
        .tile_lens
        .iter()
        .map(|&n| bitonic_compare_ops((n as f64 * OBB_KEEP) as u64))
        .sum();
    let sort_cycles = (cmp_ops as f64
        / (cfg.sort_units as f64 * cfg.sort_elems_per_cycle))
        .ceil() as u64;

    // VR units: every pixel of every surviving pair gets a full alpha
    // evaluation; blends follow the real per-pixel activity trace.
    let pixel_evals = (w.pixel.alpha_evals as f64 * OBB_KEEP) as u64;
    let vr_cycles = (pixel_evals * cfg.alpha_cycles + w.pixel.blends * cfg.blend_cycles)
        .div_ceil(cfg.vr_lanes as u64);

    let mut traffic = Traffic::stream(w.queue_bytes() + w.image_bytes);
    traffic.add(Traffic::sram(
        (w.pairs as f64 * OBB_KEEP) as u64 * super::workload::SPLAT_BYTES
            + w.pixel.blends * 16,
    ));
    let memory_cycles = traffic.dram_cycles(dram);

    let cycles = proj_cycles
        .max(sort_cycles)
        .max(vr_cycles)
        .max(memory_cycles)
        + 64;
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);

    let compute_pj = w.queue_len as f64 * op_pj::PROJECT
        + w.pairs as f64 * op_pj::PROJECT * 0.2 // OBB refinement
        + cmp_ops as f64 * op_pj::SORT_CMP
        + pixel_evals as f64 * op_pj::ALPHA_EXP
        + w.pixel.blends as f64 * op_pj::BLEND;

    GsCoreResult {
        stage: StageResult {
            cycles,
            seconds,
            traffic,
            energy: Energy::accel(compute_pj, &traffic, dram),
        },
        proj_cycles,
        sort_cycles,
        vr_cycles,
        memory_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpCoreConfig;
    use crate::splat::BlendStats;

    /// A workload where the group dataflow skips most work: SPCore must
    /// beat GSCore (the Fig. 9 LT+GS vs SLTARCH gap).
    fn sparse_workload() -> SplatWorkload {
        let gaussian_tiles = 50_000u64;
        let mut w = SplatWorkload {
            queue_len: gaussian_tiles / 4,
            pairs: gaussian_tiles,
            tile_lens: vec![gaussian_tiles / 64; 64],
            image_bytes: 256 * 256 * 12,
            ..Default::default()
        };
        // Per-pixel: every pair evaluates all 256 pixels; ~30% blend.
        w.pixel = BlendStats {
            gaussians: gaussian_tiles,
            alpha_evals: gaussian_tiles * 256,
            blends: gaussian_tiles * 77,
            ..Default::default()
        };
        // Group: 64 checks/pair; ~10% of groups survive -> alpha+blend
        // only there (matches the measured frame workloads, where group
        // evals are a few percent of the per-pixel evals).
        w.group = BlendStats {
            gaussians: gaussian_tiles,
            group_checks: gaussian_tiles * 64,
            alpha_evals: gaussian_tiles * 26,
            blends: gaussian_tiles * 26,
            ..Default::default()
        };
        w
    }

    #[test]
    fn spcore_beats_gscore_on_sparse_tiles() {
        let w = sparse_workload();
        let d = DramConfig::default();
        let gs = splat(&w, &GsCoreConfig::default(), &d);
        let sp = super::super::spcore::splat(&w, &SpCoreConfig::default(), &d);
        assert!(
            sp.stage.cycles < gs.stage.cycles,
            "SPCore {} !< GSCore {}",
            sp.stage.cycles,
            gs.stage.cycles
        );
        // Paper: 1.8x-ish speedup with 54% energy savings at the
        // splatting stage; allow a generous band here (the exact ratio
        // is workload-dependent).
        let speedup = gs.stage.cycles as f64 / sp.stage.cycles as f64;
        assert!(speedup > 1.2 && speedup < 6.0, "speedup {speedup}");
        assert!(sp.stage.energy.total_pj() < gs.stage.energy.total_pj());
    }

    #[test]
    fn obb_cost_appears_in_front_end() {
        let w = sparse_workload();
        let d = DramConfig::default();
        let gs = splat(&w, &GsCoreConfig::default(), &d);
        let mut no_obb = GsCoreConfig::default();
        no_obb.obb_cycles = 0;
        let gs2 = splat(&w, &no_obb, &d);
        assert!(gs.proj_cycles > gs2.proj_cycles);
    }
}
