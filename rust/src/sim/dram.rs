//! DRAM / SRAM traffic accounting.
//!
//! Distinguishes the two access patterns the paper's energy argument
//! rests on: *streaming* bursts (whole subtrees, whole attribute
//! slabs — what SLTree guarantees) and *random* row-activating accesses
//! (pointer-chasing tree walks — what canonical LoD trees cause).

use crate::config::DramConfig;

/// Accumulated memory traffic for one simulated stage.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    pub dram_stream_bytes: u64,
    pub dram_random_bytes: u64,
    pub sram_bytes: u64,
}

impl Traffic {
    pub fn stream(bytes: u64) -> Traffic {
        Traffic { dram_stream_bytes: bytes, ..Default::default() }
    }

    pub fn random(bytes: u64) -> Traffic {
        Traffic { dram_random_bytes: bytes, ..Default::default() }
    }

    pub fn sram(bytes: u64) -> Traffic {
        Traffic { sram_bytes: bytes, ..Default::default() }
    }

    pub fn add(&mut self, o: Traffic) {
        self.dram_stream_bytes += o.dram_stream_bytes;
        self.dram_random_bytes += o.dram_random_bytes;
        self.sram_bytes += o.sram_bytes;
    }

    #[inline]
    pub fn dram_total(&self) -> u64 {
        self.dram_stream_bytes + self.dram_random_bytes
    }

    /// Energy in pJ under the config's per-byte costs.
    pub fn energy_pj(&self, cfg: &DramConfig) -> f64 {
        self.dram_stream_bytes as f64 * cfg.stream_pj_per_byte
            + self.dram_random_bytes as f64 * cfg.random_pj_per_byte()
            + self.sram_bytes as f64 * cfg.sram_pj_per_byte
    }

    /// Cycles the DRAM needs to move this traffic (bandwidth bound;
    /// random accesses additionally pay the row-activation latency
    /// amortized per 64 B transaction).
    pub fn dram_cycles(&self, cfg: &DramConfig) -> u64 {
        let bw = cfg.peak_bytes_per_cycle();
        let stream = self.dram_stream_bytes as f64 / bw;
        let txns = self.dram_random_bytes.div_ceil(64);
        let random = self.dram_random_bytes as f64 / bw
            + (txns * cfg.random_latency_cycles) as f64 / cfg.channels as f64;
        (stream + random).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_ratios_respect_config() {
        let cfg = DramConfig::default();
        let s = Traffic::stream(1000).energy_pj(&cfg);
        let r = Traffic::random(1000).energy_pj(&cfg);
        let m = Traffic::sram(1000).energy_pj(&cfg);
        assert!((r / s - 3.0).abs() < 1e-9, "non-stream:stream must be 3:1");
        assert!((r / m - 25.0).abs() < 1e-9, "random DRAM:SRAM must be 25:1");
    }

    #[test]
    fn random_costs_more_cycles_than_streaming() {
        let cfg = DramConfig::default();
        let s = Traffic::stream(1 << 20).dram_cycles(&cfg);
        let r = Traffic::random(1 << 20).dram_cycles(&cfg);
        assert!(r > 2 * s, "random {r} vs stream {s}");
    }

    #[test]
    fn add_accumulates() {
        let mut t = Traffic::default();
        t.add(Traffic::stream(10));
        t.add(Traffic::random(20));
        t.add(Traffic::sram(30));
        assert_eq!(t.dram_total(), 30);
        assert_eq!(t.sram_bytes, 30);
    }
}
