//! SPCore — the paper's splatting accelerator (Sec. IV-C, Fig. 8).
//!
//! Front end (projection, duplication, sorting) is GSCore's — the paper
//! claims no contribution there and simplifies intersection to the
//! basic 3-sigma test. The contribution is the **SP unit**: one
//! alpha-check unit (exponent-power compare, no exp) gating four
//! blending lanes that process a 2x2 pixel group in lockstep with zero
//! divergence.
//!
//! Stages are pipelined tile-to-tile through the double-buffered global
//! buffer, so stage time is `max(projection, sorting, splatting,
//! memory)` plus a fill term.

use super::dram::Traffic;
use super::energy::{op_pj, Energy};
use super::report::StageResult;
use super::workload::SplatWorkload;
use crate::config::{DramConfig, SpCoreConfig};
use crate::splat::sort::bitonic_compare_ops;

/// Detailed SPCore result.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpCoreResult {
    pub stage: StageResult,
    pub proj_cycles: u64,
    pub sort_cycles: u64,
    pub splat_cycles: u64,
    pub memory_cycles: u64,
}

/// Run the splatting stage on SPCore by replaying the group-dataflow
/// blending counters.
pub fn splat(w: &SplatWorkload, cfg: &SpCoreConfig, dram: &DramConfig) -> SpCoreResult {
    // Projection units: pipelined, `proj_units` in parallel.
    let proj_cycles =
        (w.queue_len * cfg.proj_cycles).div_ceil(cfg.proj_units as u64);

    // Sorting units: bitonic networks over each tile list.
    let cmp_ops: u64 = w.tile_lens.iter().map(|&n| bitonic_compare_ops(n)).sum();
    let sort_cycles = (cmp_ops as f64
        / (cfg.sort_units as f64 * cfg.sort_elems_per_cycle))
        .ceil() as u64;

    // SP units: the wide-and-cheap check array gates groups; surviving
    // groups' pixels run the full alpha (exp) + blend on the blending
    // lanes. Non-surviving groups cost nothing downstream — that is the
    // divergence-free win over per-pixel dataflows.
    let check_cycles = (w.group.group_checks * cfg.alpha_check_cycles)
        .div_ceil((cfg.sp_units * cfg.check_width) as u64);
    let lanes = (cfg.sp_units * cfg.blend_lanes) as u64;
    let blend_cycles = (w.group.alpha_evals * cfg.alpha_exp_cycles
        + w.group.blends * cfg.blend_cycles)
        .div_ceil(lanes);
    let splat_cycles = check_cycles + blend_cycles;

    // Memory: rendering queue streamed in; image written back; tile
    // working set bounces through the global buffer (SRAM).
    let mut traffic = Traffic::stream(w.queue_bytes() + w.image_bytes);
    traffic.add(Traffic::sram(
        // Each (gaussian, tile) pair re-reads its attributes from the
        // global buffer; each blend touches the pixel accumulator.
        w.pairs * super::workload::SPLAT_BYTES + w.group.blends * 16,
    ));
    let memory_cycles = traffic.dram_cycles(dram);

    let cycles = proj_cycles
        .max(sort_cycles)
        .max(splat_cycles)
        .max(memory_cycles)
        + 64; // pipeline fill
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);

    let compute_pj = w.queue_len as f64 * op_pj::PROJECT
        + cmp_ops as f64 * op_pj::SORT_CMP
        + w.group.group_checks as f64 * op_pj::ALPHA_CHECK
        + w.group.alpha_evals as f64 * op_pj::ALPHA_EXP
        + w.group.blends as f64 * op_pj::BLEND;

    SpCoreResult {
        stage: StageResult {
            cycles,
            seconds,
            traffic,
            energy: Energy::accel(compute_pj, &traffic, dram),
        },
        proj_cycles,
        sort_cycles,
        splat_cycles,
        memory_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splat::BlendStats;

    fn workload(gaussian_tiles: u64) -> SplatWorkload {
        let mut w = SplatWorkload {
            queue_len: gaussian_tiles / 4,
            pairs: gaussian_tiles,
            tile_lens: vec![gaussian_tiles / 16; 16],
            image_bytes: 256 * 256 * 12,
            ..Default::default()
        };
        w.group = BlendStats {
            gaussians: gaussian_tiles,
            group_checks: gaussian_tiles * 64,
            alpha_evals: gaussian_tiles * 64, // ~25% of groups survive x4 px
            blends: gaussian_tiles * 64,
            ..Default::default()
        };
        w
    }

    #[test]
    fn stage_time_is_pipelined_max() {
        let r = splat(&workload(10_000), &SpCoreConfig::default(), &DramConfig::default());
        let max = r
            .proj_cycles
            .max(r.sort_cycles)
            .max(r.splat_cycles)
            .max(r.memory_cycles);
        assert_eq!(r.stage.cycles, max + 64);
    }

    #[test]
    fn work_scales_roughly_linearly() {
        let cfg = SpCoreConfig::default();
        let d = DramConfig::default();
        let a = splat(&workload(10_000), &cfg, &d).stage.cycles;
        let b = splat(&workload(100_000), &cfg, &d).stage.cycles;
        assert!(b > 5 * a, "{b} vs {a}");
    }

    #[test]
    fn group_check_price_is_cheap() {
        // Energy of checks must be well under the blend energy when
        // most groups survive — the SP unit premise.
        let w = workload(50_000);
        let check = w.group.group_checks as f64 * op_pj::ALPHA_CHECK;
        let blend = w.group.blends as f64 * op_pj::BLEND
            + w.group.alpha_evals as f64 * op_pj::ALPHA_EXP;
        assert!(check < blend);
    }
}
