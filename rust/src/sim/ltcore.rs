//! LTCore — the paper's LoD-search accelerator (Sec. IV-B, Fig. 6/7).
//!
//! Components modelled:
//! * **LT-unit array** — each activation (subtree + parent filter) runs
//!   on one pipelined LT unit at `node_test_cycles`/node plus a fill
//!   penalty per subtree switch; activations are dynamically scheduled
//!   onto the earliest-free unit (the subtree queue's dequeue protocol).
//! * **Two-segment subtree queue** — SIDs only become visible to LT
//!   units after their data is resident, so units never stall on cache
//!   misses; we model this as compute/memory overlap: the stage takes
//!   `max(compute makespan, DRAM streaming time)`.
//! * **Subtree cache** — 4-way set-associative, SID-indexed,
//!   round-robin replacement; replayed against the activation sequence
//!   to count refetches (a refetch = a subtree evicted between
//!   activations and streamed again).
//! * **Output buffer** — double-buffered; write-back overlaps compute
//!   and never stalls (its traffic is still accounted).

use super::dram::Traffic;
use super::energy::{op_pj, Energy};
use super::report::StageResult;
use super::workload::{LodWorkload, NODE_BYTES};
use crate::config::{DramConfig, LtCoreConfig};
use crate::lod::TraversalTrace;

/// Subtree-cache replay statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Misses beyond each subtree's first touch (evicted + refetched).
    pub refetches: u64,
}

/// SID-indexed set-associative cache with round-robin replacement
/// (the paper: "replacement policies have no impact on performance, we
/// use a round-robin replacement policy").
pub struct SubtreeCache {
    ways: usize,
    sets: usize,
    tags: Vec<u32>,
    rr: Vec<usize>,
    seen: Vec<bool>,
    pub stats: CacheStats,
}

impl SubtreeCache {
    pub fn new(cfg: &LtCoreConfig, subtree_count: usize) -> Self {
        SubtreeCache {
            ways: cfg.cache_ways,
            sets: cfg.cache_sets,
            tags: vec![u32::MAX; cfg.cache_ways * cfg.cache_sets],
            rr: vec![0; cfg.cache_sets],
            seen: vec![false; subtree_count],
            stats: CacheStats::default(),
        }
    }

    /// Access one SID; returns true on hit.
    pub fn access(&mut self, sid: u32) -> bool {
        let set = sid as usize % self.sets;
        let base = set * self.ways;
        for w in 0..self.ways {
            if self.tags[base + w] == sid {
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        if let Some(s) = self.seen.get(sid as usize) {
            if *s {
                self.stats.refetches += 1;
            }
        }
        if let Some(s) = self.seen.get_mut(sid as usize) {
            *s = true;
        }
        let victim = self.rr[set] % self.ways;
        self.rr[set] = (self.rr[set] + 1) % self.ways;
        self.tags[base + victim] = sid;
        false
    }
}

/// Greedy earliest-free scheduling of activation costs onto `units`;
/// returns the makespan and per-unit busy time.
fn schedule(costs: impl Iterator<Item = u64>, units: usize) -> (u64, Vec<u64>) {
    let mut free_at = vec![0u64; units.max(1)];
    for c in costs {
        // Earliest-free unit gets the next activation (FIFO dequeue).
        let (idx, _) = free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .unwrap();
        free_at[idx] += c;
    }
    (free_at.iter().copied().max().unwrap_or(0), free_at)
}

/// Detailed LTCore result.
#[derive(Clone, Debug, Default)]
pub struct LtCoreResult {
    pub stage: StageResult,
    pub cache: CacheStats,
    /// Compute makespan (cycles) before the memory overlap max().
    pub compute_cycles: u64,
    /// DRAM streaming cycles.
    pub memory_cycles: u64,
    /// Per-LT-unit busy cycles (utilization analysis, Fig. 12).
    pub unit_busy: Vec<u64>,
}

impl LtCoreResult {
    /// LT-unit utilization: mean busy / makespan.
    pub fn utilization(&self) -> f64 {
        let makespan = self.unit_busy.iter().copied().max().unwrap_or(0);
        if makespan == 0 {
            return 1.0;
        }
        let mean =
            self.unit_busy.iter().sum::<u64>() as f64 / self.unit_busy.len() as f64;
        mean / makespan as f64
    }
}

/// Run the LoD-search stage on LTCore by replaying a traversal trace.
pub fn search(
    trace: &TraversalTrace,
    cfg: &LtCoreConfig,
    dram: &DramConfig,
) -> LtCoreResult {
    // Cache replay over the activation sequence.
    let mut cache = SubtreeCache::new(cfg, trace.subtree_bytes.len());
    let mut fetched_bytes = 0u64;
    for &sid in &trace.activation_sids {
        if !cache.access(sid) {
            fetched_bytes += *trace
                .subtree_bytes
                .get(sid as usize)
                .unwrap_or(&(cfg.entry_bytes(32) as u32)) as u64;
        }
    }

    // Compute: dynamic schedule of activations over the LT units.
    let costs = trace
        .activation_sizes
        .iter()
        .map(|&n| n as u64 * cfg.node_test_cycles + cfg.pipeline_depth);
    let (makespan, unit_busy) = schedule(costs, cfg.lt_units);

    // Memory: streaming subtree bursts, overlapped with compute thanks
    // to the two-segment queue. Each distinct fetch still pays one row
    // activation, amortized over the channels — this is why merging
    // small subtrees (fewer, larger bursts) wins in Fig. 12.
    let mut traffic = Traffic::stream(fetched_bytes);
    // Every node test reads its attributes from the subtree cache, and
    // the cut is written through the double-buffered output buffer.
    traffic.add(Traffic::sram(trace.visited * NODE_BYTES));
    traffic.add(Traffic::stream(trace.selected * 4)); // NID write-back
    let burst_overhead = cache.stats.misses * dram.random_latency_cycles
        / dram.channels.max(1) as u64;
    let memory_cycles = traffic.dram_cycles(dram) + burst_overhead;

    let cycles = makespan.max(memory_cycles);
    let seconds = cycles as f64 / (cfg.clock_ghz * 1e9);
    let compute_pj = trace.visited as f64 * op_pj::NODE_TEST;
    LtCoreResult {
        stage: StageResult {
            cycles,
            seconds,
            traffic,
            energy: Energy::accel(compute_pj, &traffic, dram),
        },
        cache: cache.stats,
        compute_cycles: makespan,
        memory_cycles,
        unit_busy,
    }
}

/// Convenience wrapper taking the whole LoD workload.
pub fn search_workload(
    w: &LodWorkload,
    cfg: &LtCoreConfig,
    dram: &DramConfig,
) -> LtCoreResult {
    search(&w.trace, cfg, dram)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::workload::slab_bytes;

    fn cfg() -> LtCoreConfig {
        LtCoreConfig::default()
    }

    #[test]
    fn cache_hits_on_repeat_access() {
        let mut c = SubtreeCache::new(&cfg(), 16);
        assert!(!c.access(3));
        assert!(c.access(3));
        assert_eq!(c.stats, CacheStats { hits: 1, misses: 1, refetches: 0 });
    }

    #[test]
    fn cache_conflict_eviction_counts_refetch() {
        let mut small = LtCoreConfig::default();
        small.cache_ways = 2;
        small.cache_sets = 1;
        let mut c = SubtreeCache::new(&small, 16);
        c.access(1);
        c.access(2);
        c.access(3); // evicts 1 (round robin)
        assert!(!c.access(1)); // refetch
        assert_eq!(c.stats.refetches, 1);
        assert_eq!(c.stats.misses, 4);
    }

    #[test]
    fn schedule_balances_equal_costs() {
        let (makespan, busy) = schedule([10u64; 8].into_iter(), 4);
        assert_eq!(makespan, 20);
        assert!(busy.iter().all(|&b| b == 20));
    }

    #[test]
    fn schedule_handles_skew_greedily() {
        // One big item + small ones: greedy keeps makespan near optimal.
        let costs = vec![100u64, 10, 10, 10, 10, 10, 10, 10];
        let (makespan, _) = schedule(costs.into_iter(), 4);
        assert_eq!(makespan, 100);
    }

    #[test]
    fn search_overlaps_compute_and_memory() {
        let trace = TraversalTrace {
            per_thread_nodes: vec![0; 4],
            visited: 4000,
            selected: 100,
            subtree_fetches: 125,
            bytes_streamed: 125 * slab_bytes(32),
            activations: 125,
            queue_peak: 8,
            activation_sizes: vec![32; 125],
            activation_sids: (0..125).collect(),
            subtree_bytes: vec![slab_bytes(32) as u32; 125],
            ..Default::default()
        };
        let r = search(&trace, &cfg(), &DramConfig::default());
        assert_eq!(r.cache.misses, 125);
        assert_eq!(r.cache.refetches, 0);
        assert_eq!(r.stage.cycles, r.compute_cycles.max(r.memory_cycles));
        assert!(r.utilization() > 0.8, "util {}", r.utilization());
    }

    #[test]
    fn more_units_cut_makespan() {
        let mk = |units| {
            let mut c = cfg();
            c.lt_units = units;
            let trace = TraversalTrace {
                activation_sizes: vec![32; 64],
                activation_sids: (0..64).collect(),
                subtree_bytes: vec![slab_bytes(32) as u32; 64],
                visited: 2048,
                ..Default::default()
            };
            search(&trace, &c, &DramConfig::default()).compute_cycles
        };
        assert!(mk(8) < mk(2));
    }
}
