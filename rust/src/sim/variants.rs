//! The five hardware variants of Fig. 9/10, assembled from the unit
//! models. Within a frame the LoD-search and splatting stages run
//! back-to-back (the cut feeds splatting), so frame time is the sum of
//! stage times on whichever hardware owns each stage.

use super::gpu;
use super::gscore;
use super::kdtree_accel::{self, KdAccelConfig};
use super::ltcore;
use super::report::{SimReport, StageResult};
use super::spcore;
use super::workload::{LodWorkload, SplatWorkload};
use crate::config::ArchConfig;

/// Hardware variant (paper Sec. V-A "Baselines").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HwVariant {
    /// Mobile Ampere GPU for both stages.
    Gpu,
    /// GPU splatting + LTCore LoD search.
    GpuLt,
    /// GPU LoD search + GSCore splatting.
    GpuGs,
    /// LTCore LoD search + GSCore splatting.
    LtGs,
    /// Full SLTarch: LTCore + SPCore.
    SlTarch,
    /// Fig. 11 axis: GPU splatting + QuickNN LoD search.
    GpuQuickNn,
    /// Fig. 11 axis: GPU splatting + Crescent LoD search.
    GpuCrescent,
}

impl HwVariant {
    pub fn name(&self) -> &'static str {
        match self {
            HwVariant::Gpu => "GPU",
            HwVariant::GpuLt => "GPU+LT",
            HwVariant::GpuGs => "GPU+GS",
            HwVariant::LtGs => "LT+GS",
            HwVariant::SlTarch => "SLTARCH",
            HwVariant::GpuQuickNn => "GPU+QuickNN",
            HwVariant::GpuCrescent => "GPU+Crescent",
        }
    }

    /// The five Fig. 9/10 variants.
    pub fn fig9() -> [HwVariant; 5] {
        [
            HwVariant::Gpu,
            HwVariant::GpuLt,
            HwVariant::GpuGs,
            HwVariant::LtGs,
            HwVariant::SlTarch,
        ]
    }

    /// The Fig. 11 tree-accelerator comparison set.
    pub fn fig11() -> [HwVariant; 4] {
        [
            HwVariant::Gpu,
            HwVariant::GpuQuickNn,
            HwVariant::GpuCrescent,
            HwVariant::GpuLt,
        ]
    }
}

/// Result of simulating one variant over one frame.
#[derive(Clone, Debug)]
pub struct VariantResult {
    pub variant: HwVariant,
    pub report: SimReport,
}

/// Simulate one frame on one hardware variant.
pub fn simulate_variant(
    variant: HwVariant,
    lod_w: &LodWorkload,
    splat_w: &SplatWorkload,
    arch: &ArchConfig,
) -> VariantResult {
    let dram = &arch.dram;
    let lod: StageResult = match variant {
        HwVariant::Gpu | HwVariant::GpuGs => gpu::lod_exhaustive(lod_w, &arch.gpu, dram),
        HwVariant::GpuLt | HwVariant::LtGs | HwVariant::SlTarch => {
            ltcore::search_workload(lod_w, &arch.ltcore, dram).stage
        }
        HwVariant::GpuQuickNn => {
            kdtree_accel::search(lod_w, &KdAccelConfig::quicknn(), dram)
        }
        HwVariant::GpuCrescent => {
            kdtree_accel::search(lod_w, &KdAccelConfig::crescent(), dram)
        }
    };
    let splat: StageResult = match variant {
        HwVariant::Gpu
        | HwVariant::GpuLt
        | HwVariant::GpuQuickNn
        | HwVariant::GpuCrescent => gpu::splat(splat_w, &arch.gpu, dram),
        HwVariant::GpuGs | HwVariant::LtGs => {
            gscore::splat(splat_w, &arch.gscore, dram).stage
        }
        HwVariant::SlTarch => spcore::splat(splat_w, &arch.spcore, dram).stage,
    };
    VariantResult {
        variant,
        report: SimReport {
            variant: variant.name().to_string(),
            lod,
            splat,
            other: StageResult::default(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::workload::slab_bytes;
    use crate::lod::TraversalTrace;
    use crate::splat::BlendStats;

    fn workloads() -> (LodWorkload, SplatWorkload) {
        let lod = LodWorkload {
            total_nodes: 280_000,
            canonical_visited: 45_000,
            cut_len: 22_000,
            naive_thread_loads: {
                let mut v = vec![1_500u64; 32];
                v[3] = 14_000;
                v
            },
            trace: TraversalTrace {
                visited: 45_000,
                selected: 22_000,
                activations: 1_500,
                activation_sizes: vec![30; 1_500],
                activation_sids: (0..1_500).collect(),
                subtree_bytes: vec![slab_bytes(32) as u32; 1_500],
                bytes_streamed: 1_500 * slab_bytes(32),
                subtree_fetches: 1_500,
                per_thread_nodes: vec![11_250; 4],
                queue_peak: 40,
                ..Default::default()
            },
        };
        let gaussian_tiles = 70_000u64;
        let mut splat = SplatWorkload {
            queue_len: 22_000,
            pairs: gaussian_tiles,
            tile_lens: vec![gaussian_tiles / 256; 256],
            image_bytes: 256 * 256 * 12,
            ..Default::default()
        };
        splat.pixel = BlendStats {
            gaussians: gaussian_tiles,
            alpha_evals: gaussian_tiles * 256,
            blends: gaussian_tiles * 70,
            ..Default::default()
        };
        splat.pixel.divergence.warps_issued = gaussian_tiles * 6;
        splat.pixel.divergence.issued_lane_slots = gaussian_tiles * 6 * 32;
        splat.pixel.divergence.active_lanes = gaussian_tiles * 70;
        splat.pixel.divergence.warps_total = gaussian_tiles * 8;
        splat.group = BlendStats {
            gaussians: gaussian_tiles,
            group_checks: gaussian_tiles * 64,
            alpha_evals: gaussian_tiles * 24,
            blends: gaussian_tiles * 24,
            ..Default::default()
        };
        (lod, splat)
    }

    #[test]
    fn fig9_ordering_holds() {
        let (lod, splat) = workloads();
        let arch = ArchConfig::default();
        let t = |v| {
            simulate_variant(v, &lod, &splat, &arch)
                .report
                .total_seconds()
        };
        let gpu = t(HwVariant::Gpu);
        let gpu_lt = t(HwVariant::GpuLt);
        let gpu_gs = t(HwVariant::GpuGs);
        let sltarch = t(HwVariant::SlTarch);
        let lt_gs = t(HwVariant::LtGs);
        // The paper's large-scale ordering: every variant beats GPU and
        // SLTARCH beats all partial variants.
        assert!(gpu_lt < gpu, "GPU+LT {gpu_lt} !< GPU {gpu}");
        assert!(gpu_gs < gpu, "GPU+GS {gpu_gs} !< GPU {gpu}");
        assert!(sltarch < gpu_lt, "SLTARCH {sltarch} !< GPU+LT {gpu_lt}");
        assert!(sltarch < gpu_gs, "SLTARCH {sltarch} !< GPU+GS {gpu_gs}");
        assert!(sltarch <= lt_gs, "SLTARCH {sltarch} !<= LT+GS {lt_gs}");
    }

    #[test]
    fn sltarch_saves_most_energy() {
        let (lod, splat) = workloads();
        let arch = ArchConfig::default();
        let e = |v| {
            simulate_variant(v, &lod, &splat, &arch)
                .report
                .total_energy_mj()
        };
        let gpu = e(HwVariant::Gpu);
        let sltarch = e(HwVariant::SlTarch);
        let savings = 1.0 - sltarch / gpu;
        assert!(savings > 0.9, "savings {savings}");
    }

    #[test]
    fn fig11_lt_beats_kdtree_accelerators() {
        let (lod, splat) = workloads();
        let arch = ArchConfig::default();
        let lt = simulate_variant(HwVariant::GpuLt, &lod, &splat, &arch);
        let qn = simulate_variant(HwVariant::GpuQuickNn, &lod, &splat, &arch);
        let cr = simulate_variant(HwVariant::GpuCrescent, &lod, &splat, &arch);
        assert!(lt.report.lod.seconds < qn.report.lod.seconds);
        assert!(lt.report.lod.seconds < cr.report.lod.seconds);
    }
}
