//! Energy accounting.
//!
//! Two regimes, as in the paper's Fig. 10 analysis:
//!
//! * the **GPU** is power-modelled (board watts x busy seconds, scaled
//!   to 16 nm a la DeepScaleTool) — "GPU power is the primary energy
//!   contributor";
//! * the **accelerators** are op-energy-modelled: pJ per unit operation
//!   (16 nm-scale constants) plus the SRAM/DRAM traffic from
//!   [`super::dram::Traffic`].

use super::dram::Traffic;
use crate::config::{DramConfig, GpuConfig};

/// 16 nm-scale per-op energies (pJ). Constants are in line with
/// published per-op numbers for FinFET-class accelerators (a fused MADD
/// ~0.5-1 pJ, a transcendental several pJ, SRAM per-byte ~0.1-0.3 pJ —
/// the DRAM side carries the ratios the paper states explicitly).
pub mod op_pj {
    /// AABB-frustum + LoD compare in an LT unit.
    pub const NODE_TEST: f64 = 1.2;
    /// Projection of one Gaussian (EWA: ~60 MADDs).
    pub const PROJECT: f64 = 30.0;
    /// One comparator exchange in a sorting network.
    pub const SORT_CMP: f64 = 0.4;
    /// Full alpha evaluation with exp (GSCore VR unit / GPU lane).
    pub const ALPHA_EXP: f64 = 4.0;
    /// Exponent-power compare (SP-unit alpha check; no exp).
    pub const ALPHA_CHECK: f64 = 0.8;
    /// One blend MADD chain (colour accumulate + T update).
    pub const BLEND: f64 = 1.5;
    /// kd-tree stack push/pop (QuickNN/Crescent traceback).
    pub const STACK_OP: f64 = 0.6;
}

/// Energy tally in pJ with a breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct Energy {
    pub compute_pj: f64,
    pub memory_pj: f64,
    pub gpu_pj: f64,
}

impl Energy {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.memory_pj + self.gpu_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() * 1e-9
    }

    pub fn add(&mut self, o: Energy) {
        self.compute_pj += o.compute_pj;
        self.memory_pj += o.memory_pj;
        self.gpu_pj += o.gpu_pj;
    }

    /// Accelerator-side energy: op counts x per-op pJ + traffic.
    pub fn accel(compute_pj: f64, traffic: &Traffic, dram: &DramConfig) -> Energy {
        Energy {
            compute_pj,
            memory_pj: traffic.energy_pj(dram),
            gpu_pj: 0.0,
        }
    }

    /// GPU-side energy: busy seconds x board power (+ its DRAM traffic,
    /// which is already part of board power — kept separate at 0 to
    /// avoid double counting).
    pub fn gpu(busy_seconds: f64, cfg: &GpuConfig) -> Energy {
        Energy {
            compute_pj: 0.0,
            memory_pj: 0.0,
            gpu_pj: busy_seconds * cfg.power_w * 1e12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_energy_scales_with_time() {
        let cfg = GpuConfig::default();
        let e1 = Energy::gpu(0.01, &cfg);
        let e2 = Energy::gpu(0.02, &cfg);
        assert!((e2.total_pj() / e1.total_pj() - 2.0).abs() < 1e-12);
        // 10 ms at 15 W = 150 mJ.
        assert!((e1.total_mj() - 150.0).abs() < 1e-6);
    }

    #[test]
    fn accel_energy_combines_compute_and_memory() {
        let dram = DramConfig::default();
        let t = Traffic::stream(1_000_000);
        let e = Energy::accel(5e6, &t, &dram);
        assert!(e.compute_pj > 0.0 && e.memory_pj > 0.0);
        assert_eq!(e.gpu_pj, 0.0);
        assert!((e.total_pj() - (5e6 + 8e6)).abs() < 1.0);
    }

    #[test]
    fn alpha_check_is_much_cheaper_than_exp() {
        // The SP unit's reason to exist.
        assert!(op_pj::ALPHA_EXP / op_pj::ALPHA_CHECK >= 4.0);
    }
}
