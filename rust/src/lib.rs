//! # SLTarch — scalable point-based neural rendering, reproduced.
//!
//! This crate is the Layer-3 (rust) half of a three-layer reproduction of
//! *"SLTarch: Towards Scalable Point-Based Neural Rendering by Taming
//! Workload Imbalance and Memory Irregularity"* (CS.AR 2025):
//!
//! * [`lod`] — the paper's algorithmic contribution: the canonical LoD
//!   tree, **SLTree** partitioning (Algo 1 + subtree merging) and the
//!   streaming subtree-queue traversal, bit-accurate vs the canonical cut.
//! * [`sim`] — cycle-approximate models of every piece of hardware the
//!   paper evaluates: the mobile-Ampere GPU baseline, **LTCore** (LT
//!   units, two-segment subtree queue, 4-way subtree cache), **SPCore**
//!   (group-alpha SP units), GSCore, and the QuickNN/Crescent kd-tree
//!   accelerators, plus the LPDDR4/SRAM energy model.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at render time.
//! * [`coordinator`] — the frame pipeline: LoD search -> rendering queue
//!   -> tile binning -> depth sort -> chunked splatting -> image.
//! * [`experiments`] — one module per paper table/figure; each prints the
//!   rows the paper reports (see DESIGN.md §5 for the index).
//!
//! ## Pipeline parallelism (software mirror of the paper's scheduling)
//!
//! The frame front end is flat and allocation-lean by construction:
//!
//! * **CSR tile bins** — [`splat::TileBins`] stores every tile's splat
//!   list in one flat index array plus an offset table, built
//!   count -> prefix-sum -> scatter ([`splat::bin_splats_into`] reuses
//!   the buffers across frames).
//! * **In-place radix depth sort** — [`splat::sort_bins_with`] orders
//!   each CSR slice front-to-back via 64-bit `(sortable-depth, id)`
//!   keys, bit-identical to the comparison reference
//!   [`splat::sort_tile_by_depth`] including the id tie-break.
//! * **Dynamic tile scheduler** — the CPU renderer splats tiles with
//!   `std::thread::scope` workers pulling non-empty tiles greedily from
//!   a shared atomic queue (the software analogue of the LT-unit
//!   dynamic dequeue); output is bit-identical to the serial schedule
//!   at any thread count.
//! * **Batched path rendering** —
//!   [`coordinator::pipeline::FramePipeline::render_path`] renders a
//!   whole camera path reusing one front-end scratch, reporting
//!   aggregate frames/sec ([`coordinator::pipeline::PathReport`]).
//!
//! Measure the hot paths with
//! `cargo bench --bench hotpath` (add `-- --quick` for a smoke pass);
//! it prints a report and dumps `BENCH_hotpath.json` for CI. Use
//! `SLTARCH_THREADS=N` to pin the scheduler width.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use sltarch::prelude::*;
//! let scene = SceneConfig::small_scale().build(42);
//! let sltree = SlTree::partition(&scene.tree, 32);
//! let cam = scene.scenario_camera(0);
//! let cut = sltree.traverse(&scene.tree, &cam, 1.0);
//! println!("{} Gaussians selected", cut.len());
//! ```

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gaussian;
pub mod lod;
pub mod math;
pub mod metrics;
pub mod runtime;
pub mod scene;
pub mod sim;
pub mod splat;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{ArchConfig, RenderConfig, SceneConfig};
    pub use crate::coordinator::pipeline::{FramePipeline, FrameReport, PathReport};
    pub use crate::coordinator::renderer::{AlphaMode, CpuRenderer, FrameScratch};
    pub use crate::gaussian::Gaussians;
    pub use crate::lod::sltree::SlTree;
    pub use crate::lod::tree::LodTree;
    pub use crate::math::{Camera, Mat4, Vec3};
    pub use crate::metrics::{psnr, ssim, lpips_proxy};
    pub use crate::scene::Scene;
    pub use crate::sim::report::SimReport;
}
