//! # SLTarch — scalable point-based neural rendering, reproduced.
//!
//! This crate is the Layer-3 (rust) half of a three-layer reproduction of
//! *"SLTarch: Towards Scalable Point-Based Neural Rendering by Taming
//! Workload Imbalance and Memory Irregularity"* (CS.AR 2025):
//!
//! * [`lod`] — the paper's algorithmic contribution: the canonical LoD
//!   tree, **SLTree** partitioning (Algo 1 + subtree merging), the
//!   streaming subtree-queue traversal (bit-accurate vs the canonical
//!   cut), and the temporal [`lod::CutCache`] that reuses the search
//!   frontier across a camera path's frames.
//! * [`sim`] — cycle-approximate models of every piece of hardware the
//!   paper evaluates: the mobile-Ampere GPU baseline, **LTCore** (LT
//!   units, two-segment subtree queue, 4-way subtree cache), **SPCore**
//!   (group-alpha SP units), GSCore, and the QuickNN/Crescent kd-tree
//!   accelerators, plus the LPDDR4/SRAM energy model.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at render time.
//! * [`coordinator`] — the frame pipeline: LoD search -> rendering queue
//!   -> tile binning -> depth sort -> chunked splatting -> image.
//! * [`experiments`] — one module per paper table/figure; each prints the
//!   rows the paper reports (see DESIGN.md §5 for the index).
//! * [`serve`] — the deadline-aware serving layer over sessions:
//!   bounded admission with typed backpressure, per-request deadlines,
//!   log-bucketed latency percentiles, deadline-adaptive LoD
//!   degradation ([`serve::QosController`]) and a synthetic open-loop
//!   load generator ([`serve::run_load`]).
//! * [`assets`] — real-asset ingestion: std-only streaming parsers (and
//!   matching encoders) for the two de-facto 3DGS interchange formats —
//!   32-byte `.splat` records and binary little-endian PLY with
//!   `f_rest_*` SH bands — with typed [`assets::AssetError`]s in strict
//!   mode, counted drops in lossy mode, and [`assets::load_scene`]
//!   feeding loaded clouds straight into the `SceneBuilder` -> SLTree
//!   partition path (sessions, cut cache, residency and serving all work
//!   on loaded scenes unchanged).
//! * [`residency`] — out-of-core subtree-slab residency for scenes
//!   larger than memory: a hard byte budget with demand faulting,
//!   pinned LRU eviction, cut-delta prefetch between frames, and
//!   simulated demand-stall time fed into the serving layer's QoS miss
//!   signal ([`residency::ResidencyManager`]; the
//!   [`coordinator::RenderOptions::residency`] knob). Replay-based, so
//!   managed renders stay **byte-identical** to unmanaged ones.
//!
//! ## Sessions, backends and pipeline parallelism
//!
//! The public rendering API is built around three pieces:
//!
//! * **[`coordinator::FramePipeline`]** — immutable serving state
//!   (scene + SLTree + configs + backend), built once via
//!   [`coordinator::FramePipeline::builder`]. The SLTree is partitioned
//!   at `build()` and exposed through
//!   [`coordinator::FramePipeline::sltree`] — never re-partition by
//!   hand.
//! * **[`coordinator::RenderSession`]** — per-client mutable state:
//!   typed [`coordinator::RenderOptions`] (alpha dataflow, tau,
//!   scheduler width, cut-cache policy), the reusable front-end scratch
//!   (steady-state frames allocate only their output image), the
//!   temporal [`lod::CutCache`] (the previous frame's LoD cut +
//!   frustum-culled frontier is revalidated incrementally instead of
//!   re-searching from the tree top — bit-identical, just faster on
//!   coherent paths; `cache_hit` / `revalidated` / `reseeded` land in
//!   the stats), and unified [`coordinator::RenderStats`] with
//!   per-stage timings (search / project / bin / sort / blend). N
//!   sessions over one `&FramePipeline` are a thread-safe multi-client
//!   serving surface (see `examples/multi_client.rs`).
//! * **[`coordinator::ViewBatch`]** — multi-view batch rendering: K
//!   cameras over one scene in one call
//!   ([`coordinator::FramePipeline::batch`]), **byte-identical to K
//!   independent session renders** while sharing work across views —
//!   bitwise-identical cameras coalesce into one front end, pose-close
//!   views route their LoD searches through one shared cut cache (the
//!   incremental revalidation re-derives the canonical cut exactly from
//!   a neighbouring view's frontier) and skip re-gathering when
//!   consecutive cuts are bit-equal, and all views' tiles blend through
//!   one interleaved [`splat::BatchWorkItem`] schedule on a single
//!   atomic-cursor worker pool ([`coordinator::BatchConfig`] picks the
//!   levels; work items carry an inert per-tile tau hook for foveated
//!   follow-on work). See `examples/stereo.rs` and the
//!   `batch(...)` rows in `BENCH_hotpath.json`.
//! * **[`coordinator::RenderBackend`]** — who runs the blending maths:
//!   [`coordinator::CpuBackend`] (dynamic-greedy multi-threaded tile
//!   scheduler, bit-identical to serial at any width) or
//!   [`coordinator::PjrtBackend`] (the AOT JAX/Pallas artifacts). The
//!   front end (fused projection + tile-count sweep -> CSR binning
//!   finish -> radix depth sort) is hoisted out of the backends, so
//!   both consume identical sorted bins.
//!
//! The CPU blend stage itself has two interchangeable kernels
//! ([`coordinator::RenderOptions::kernel`]): the branchy AoS scalar
//! reference ([`splat::blend_tile`]) and the divergence-free SoA
//! kernel ([`splat::kernel`] — the software SPcore: SoA `r`/`g`/`b`/`t`
//! tile planes blended through fixed 16-lane SIMD-shaped row loops,
//! the Sec. IV-C no-exp group check via the exact power threshold
//! hoisted to projection time ([`gaussian::Splat2D::keep_thresh`]), a
//! per-row group-mask bitset driving a maskless inner loop, and
//! incremental early termination). The SoA kernel is the default; the
//! two are **byte-identical** per alpha mode — pinned by kernel
//! proptests and the golden harness — so the knob only trades blend
//! time; the `blend(kernel=...)` rows in `BENCH_hotpath.json` track
//! the payoff.
//!
//! ## The unified scheduler-width knob
//!
//! One width — `RenderSession::scheduler_width`, resolved from the
//! backend's width first (the CPU backend itself honors
//! `RenderOptions::threads`), else `RenderOptions::threads` for
//! offload backends, else `SLTARCH_THREADS` / machine parallelism —
//! drives **every** parallel stage of a frame:
//!
//! * the fused projection + tile-count sweep
//!   ([`splat::project_bin_sweep`]): scoped workers fill disjoint
//!   `Splat2D` ranges and accumulate their per-worker tile histograms
//!   inline (the split [`gaussian::project_into_threaded`] +
//!   [`splat::bin_splats_into_threaded`] pair remains as the
//!   equivalence reference);
//! * the CSR binning finish ([`splat::project_bin_finish`]): per-worker
//!   histograms merged by one prefix-sum, then an ordered scatter into
//!   disjoint slots;
//! * parallel tile depth sort ([`splat::sort_bins_threaded`]): the
//!   blend scheduler's dynamic atomic-cursor dequeue applied to the
//!   sorting stage;
//! * the blend-stage tile scheduler itself.
//!
//! Every stage is **byte-identical** to its serial reference at any
//! width — pinned by `rust/tests/proptests.rs` (per-stage equivalence
//! across widths {1, 2, 8}) and by the golden-frame harness
//! `rust/tests/golden.rs`, which FNV-fingerprints three fixed scenes
//! against checked-in digests so silent output drift fails tier-1.
//!
//! Migration from the pre-session API:
//!
//! | old call | new call |
//! |---|---|
//! | `FramePipeline::new(scene, rcfg, arch)` | `FramePipeline::builder(scene).render_config(rcfg).arch_config(arch).build()` |
//! | `pipeline.with_engine(engine)` | `FramePipeline::builder(scene).engine(engine).build()` |
//! | `pipeline.render(&cam, AlphaMode::Group)` | `pipeline.session().render(&cam)` |
//! | `pipeline.render(&cam, AlphaMode::Pixel)` | `pipeline.session_with(RenderOptions { alpha: AlphaMode::Pixel, ..pipeline.default_options() }).render(&cam)` |
//! | `pipeline.render_path(&cams, mode)` | `session.render_path(&cams)` then `session.stats()` |
//! | `pipeline.render_path_cpu(&cams, mode, threads)` | `pipeline.session_on(&CpuBackend::with_threads(threads), opts).render_path(&cams)` |
//! | `pipeline.rcfg.lod_tau = tau` | `pipeline.set_lod_tau(tau)` or per-session `RenderOptions::lod_tau` |
//! | `FrameReport` (render half) / `PathReport` | [`coordinator::RenderStats`] |
//! | `pipeline.simulate(..)` -> `FrameReport` | `pipeline.simulate(..)` -> [`coordinator::SimulationReport`] |
//!
//! The serial reference machinery from PR 1 is retained as ground
//! truth: CSR tile bins ([`splat::bin_splats_into`]), the in-place
//! radix depth sort ([`splat::sort_bins_with`]), and the
//! `std::thread::scope` tile scheduler mirroring the LT-unit dynamic
//! dequeue. The parallel front end above is asserted byte-identical to
//! it at every width.
//!
//! Measure the hot paths with
//! `cargo bench --bench hotpath` (add `-- --quick` for a smoke pass);
//! it prints a report (now including per-stage ms/frame rows from
//! [`coordinator::RenderStats`]) and dumps `BENCH_hotpath.json` for CI.
//! `SLTARCH_THREADS=N` remains a deployment fallback for the scheduler
//! width — parsed once per process; prefer `CpuBackend::with_threads` /
//! `RenderOptions::threads`.
//!
//! Repository-level documentation: `README.md` (build / test / bench
//! commands and the example tour), `docs/ARCHITECTURE.md` (paper
//! section -> module map, frame data flow, the cut-cache state machine)
//! and `docs/TESTING.md` (the golden-frame workflow and the
//! bit-identity contracts).
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use sltarch::prelude::*;
//! let pipeline = FramePipeline::builder(SceneConfig::small_scale().build(42))
//!     .tau(16.0)
//!     .build();
//! let cam = pipeline.scene().scenario_camera(0);
//! let mut session = pipeline.session();
//! let img = session.render(&cam).unwrap();
//! println!("{} Gaussians -> {:?} px", session.stats().cut_total, img.dims());
//! ```

pub mod assets;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gaussian;
pub mod lod;
pub mod math;
pub mod metrics;
pub mod residency;
pub mod runtime;
pub mod scene;
pub mod serve;
pub mod sim;
pub mod splat;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::assets::{
        assemble_scene, load_scene, AssembleOptions, AssetError, LoadMode,
        LoadReport, LoadedAsset,
    };
    pub use crate::config::{ArchConfig, RenderConfig, SceneConfig};
    pub use crate::coordinator::backend::{
        CpuBackend, PjrtBackend, RenderBackend, RenderOptions,
    };
    pub use crate::coordinator::batch::{BatchConfig, BatchStats, ViewBatch};
    pub use crate::coordinator::pipeline::{
        FramePipeline, FramePipelineBuilder, SimulationReport,
    };
    pub use crate::coordinator::renderer::{AlphaMode, CpuRenderer, FrameScratch};
    pub use crate::coordinator::session::RenderSession;
    pub use crate::coordinator::stats::{LatencyHistogram, RenderStats, StageTimings};
    pub use crate::gaussian::Gaussians;
    pub use crate::lod::cut_cache::{CutCache, CutCacheConfig};
    pub use crate::lod::sltree::SlTree;
    pub use crate::splat::kernel::BlendKernel;
    pub use crate::splat::BatchWorkItem;
    pub use crate::lod::tree::LodTree;
    pub use crate::math::{Camera, Mat4, Vec3};
    pub use crate::metrics::{lpips_proxy, psnr, ssim};
    pub use crate::residency::{ResidencyConfig, ResidencyManager, ResidencyStats};
    pub use crate::scene::Scene;
    pub use crate::serve::{
        FrameServer, LoadGenConfig, QosConfig, ServeConfig, ServeReport, ShedError,
        ShedReason,
    };
    pub use crate::sim::report::SimReport;
}
