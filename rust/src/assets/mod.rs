//! Real-asset ingestion: streaming parsers for the two de-facto 3DGS
//! interchange formats, plus the matching encoders the fixture zoo and
//! round-trip tests are built on.
//!
//! * [`dot_splat`] — the 32-byte `.splat` record stream
//!   (antimatter15-style): position `[f32; 3]`, scale `[f32; 3]`
//!   (stored **linearly**, unlike PLY), RGBA `u8 x 4` color + opacity
//!   (opacity already sigmoid-space), and a packed `u8 x 4` rotation
//!   quaternion decoded as `(byte - 128) / 128` then re-normalized.
//! * [`ply`] — binary little-endian PLY with the 3DGS training-output
//!   vertex schema: property order is **header-driven** (never assume
//!   field order), `f_dc_*` maps to color through the SH C0 constant,
//!   optional `f_rest_*` SH bands are parsed and band-truncated to
//!   degree 0 for now, `opacity` passes through a sigmoid, `scale_*`
//!   through `exp`, and `rot_*` is re-normalized.
//!
//! Both parsers stream from any [`std::io::Read`] / [`std::io::BufRead`]
//! source, return typed [`AssetError`]s in [`LoadMode::Strict`] and
//! never panic in [`LoadMode::Lossy`], which instead drops degenerate
//! splats and counts them in [`DropCounters`]. A loaded batch feeds the
//! existing `SceneBuilder` -> SLTree partition path via
//! [`assemble_scene`], so loaded scenes flow through sessions, the cut
//! cache, residency and serving unchanged.
//!
//! The checked-in fixture zoo lives in `rust/tests/fixtures/` (see
//! `docs/TESTING.md`); full-size captures are fetched out-of-band by
//! `scripts/fetch_scenes.sh` (sha256-verified, never run in CI).
#![warn(missing_docs)]

pub mod dot_splat;
pub mod ply;

pub use dot_splat::{load_splat, write_splat, SPLAT_RECORD_BYTES};
pub use ply::{load_ply, write_ply, SH_C0};

use std::path::Path;

use crate::gaussian::Gaussians;
use crate::scene::{build_lod_tree, scenario_cameras, Scene};

/// Hard bound on |position| / scale components a *lossy* load will
/// admit: beyond it the projection maths can overflow `f32` for
/// plausible cameras, so such splats would only ever be culled.
pub const MAX_COORD: f32 = 1e12;

/// How a parser reacts to degenerate input.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoadMode {
    /// Return the first typed [`AssetError`] and stop.
    #[default]
    Strict,
    /// Never fail on degenerate *records*: drop them, count them in
    /// [`DropCounters`], and keep going. Structural errors (bad magic,
    /// bad header, unsupported property types) still fail — without a
    /// valid header there is nothing to salvage.
    Lossy,
}

/// Per-cause counters for splats a lossy load dropped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DropCounters {
    /// Non-finite or out-of-range (>[`MAX_COORD`]) position.
    pub bad_position: u64,
    /// Non-finite, non-positive or out-of-range scale.
    pub bad_scale: u64,
    /// Non-finite or zero-norm rotation quaternion.
    pub bad_rotation: u64,
    /// Non-finite opacity.
    pub bad_opacity: u64,
    /// Non-finite color.
    pub bad_color: u64,
    /// Partial trailing record (1 at most — parsing stops there).
    pub truncated_tail: u64,
}

impl DropCounters {
    /// Total number of records dropped.
    pub fn total(&self) -> u64 {
        self.bad_position
            + self.bad_scale
            + self.bad_rotation
            + self.bad_opacity
            + self.bad_color
            + self.truncated_tail
    }
}

/// What a load did: record counts, drop counters, format telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Complete records decoded from the source (kept plus field-level
    /// drops; a partial trailing record is counted only in
    /// [`DropCounters::truncated_tail`]).
    pub records: usize,
    /// Splats admitted into the batch.
    pub kept: usize,
    /// Lossy-mode drop counters (all zero on a strict load — strict
    /// fails instead of dropping).
    pub dropped: DropCounters,
    /// `f_rest_*` SH coefficients per vertex found in a PLY header
    /// (parsed for stride, band-truncated to degree 0 for now; always 0
    /// for `.splat`, which carries no SH rest bands).
    pub sh_rest_coeffs: usize,
}

/// A parsed batch of splats plus its [`LoadReport`].
#[derive(Clone, Debug, Default)]
pub struct LoadedAsset {
    /// The admitted splats, in file order.
    pub gaussians: Gaussians,
    /// Counters describing the load.
    pub report: LoadReport,
}

/// Typed asset-ingestion errors.
#[derive(Debug)]
pub enum AssetError {
    /// Underlying I/O failure (not a format problem).
    Io(std::io::Error),
    /// The source ended mid-record.
    Truncated {
        /// Index of the record that was cut short.
        index: usize,
        /// Bytes of it that were present.
        got: usize,
    },
    /// The file does not start with the expected magic (`ply`).
    BadMagic,
    /// The header is structurally invalid (the message names the line).
    BadHeader(String),
    /// A required property has an unsupported type (or is a `list`).
    UnsupportedProperty {
        /// Property name as it appears in the header.
        name: String,
        /// The offending type token.
        ty: String,
    },
    /// The header declares an implausible vertex count.
    AbsurdVertexCount {
        /// The declared count.
        count: u64,
    },
    /// A record field is non-finite (strict mode only; the field name
    /// is one of `position`, `scale`, `rotation`, `opacity`, `color`).
    NonFinite {
        /// Which field was non-finite.
        field: &'static str,
        /// Record index.
        index: usize,
    },
    /// A rotation quaternion with zero norm (strict mode only).
    ZeroNormQuat {
        /// Record index.
        index: usize,
    },
    /// No splats survived the load — nothing to build a scene from.
    EmptyScene,
}

impl std::fmt::Display for AssetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AssetError::Io(e) => write!(f, "asset i/o error: {e}"),
            AssetError::Truncated { index, got } => write!(
                f,
                "truncated record {index}: only {got} bytes of it present"
            ),
            AssetError::BadMagic => write!(f, "bad magic: not a PLY file"),
            AssetError::BadHeader(m) => write!(f, "bad header: {m}"),
            AssetError::UnsupportedProperty { name, ty } => {
                write!(f, "unsupported property type `{ty}` for `{name}`")
            }
            AssetError::AbsurdVertexCount { count } => {
                write!(f, "absurd vertex count {count}")
            }
            AssetError::NonFinite { field, index } => {
                write!(f, "non-finite {field} in record {index}")
            }
            AssetError::ZeroNormQuat { index } => {
                write!(f, "zero-norm rotation quaternion in record {index}")
            }
            AssetError::EmptyScene => {
                write!(f, "no splats survived the load")
            }
        }
    }
}

impl std::error::Error for AssetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AssetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for AssetError {
    fn from(e: std::io::Error) -> Self {
        AssetError::Io(e)
    }
}

/// Fill `buf` from `r`, tolerating short reads and `Interrupted`.
/// Returns the number of bytes actually read (< `buf.len()` only at
/// EOF) — the caller turns a short count into its truncation handling.
pub(crate) fn read_full<R: std::io::Read>(
    r: &mut R,
    buf: &mut [u8],
) -> std::io::Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => n += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(n)
}

/// One decoded record before admission (quat *not* yet normalized).
#[derive(Clone, Copy, Debug)]
pub(crate) struct RawSplat {
    pub mean: [f32; 3],
    pub scale: [f32; 3],
    /// `(w, x, y, z)`, matching [`Gaussians::quats`] order.
    pub quat: [f32; 4],
    pub color: [f32; 3],
    pub opacity: f32,
}

/// Relative tolerance (on the squared f64 norm) under which a
/// quaternion is considered already unit-length and passed through
/// bitwise. Makes normalization exactly idempotent: re-normalizing a
/// quat this function produced is a no-op, which is what lets a
/// PLY round trip reproduce a loaded scene bit for bit.
const QUAT_SNAP: f64 = 1e-6;

/// Normalize `(w, x, y, z)` through f64, snapping already-unit inputs
/// to themselves (see [`QUAT_SNAP`]). Returns `None` for a zero-norm
/// quat. Callers must reject non-finite components first.
pub(crate) fn normalize_quat(q: [f32; 4]) -> Option<[f32; 4]> {
    let n2: f64 = q.iter().map(|&c| c as f64 * c as f64).sum();
    if n2 == 0.0 {
        return None;
    }
    if (n2 - 1.0).abs() <= QUAT_SNAP {
        return Some(q);
    }
    let inv = 1.0 / n2.sqrt();
    Some([
        (q[0] as f64 * inv) as f32,
        (q[1] as f64 * inv) as f32,
        (q[2] as f64 * inv) as f32,
        (q[3] as f64 * inv) as f32,
    ])
}

/// Check a *stored* splat for the well-formedness the lossy loader
/// guarantees: every field finite, |position| and scale within
/// [`MAX_COORD`], scale positive, quat unit-norm, opacity in `[0, 1]`.
/// Returns the first offending field name, or `None` when well-formed.
/// (This is the invariant the degenerate-input fuzz suite pins: a
/// lossy load never emits a splat the projection guards would have to
/// cull for being non-finite.)
pub fn splat_defect(g: &Gaussians, i: usize) -> Option<&'static str> {
    let finite3 = |v: &[f32; 3]| v.iter().all(|c| c.is_finite());
    if !finite3(&g.means[i]) || g.means[i].iter().any(|c| c.abs() > MAX_COORD) {
        return Some("position");
    }
    if !finite3(&g.scales[i])
        || g.scales[i].iter().any(|&c| !(c > 0.0) || c > MAX_COORD)
    {
        return Some("scale");
    }
    let q = &g.quats[i];
    let n2: f64 = q.iter().map(|&c| c as f64 * c as f64).sum();
    if !n2.is_finite() || (n2 - 1.0).abs() > 1e-3 {
        return Some("rotation");
    }
    if !g.opacity[i].is_finite() || !(0.0..=1.0).contains(&g.opacity[i]) {
        return Some("opacity");
    }
    if !finite3(&g.colors[i]) {
        return Some("color");
    }
    None
}

/// Validate one decoded record and either push it into `g`, drop it
/// (lossy: bump the matching counter), or fail (strict: typed error).
pub(crate) fn admit(
    raw: &RawSplat,
    index: usize,
    mode: LoadMode,
    g: &mut Gaussians,
    rep: &mut LoadReport,
) -> Result<(), AssetError> {
    let lossy = mode == LoadMode::Lossy;
    let finite3 = |v: &[f32; 3]| v.iter().all(|c| c.is_finite());

    if !finite3(&raw.mean) {
        if lossy {
            rep.dropped.bad_position += 1;
            return Ok(());
        }
        return Err(AssetError::NonFinite { field: "position", index });
    }
    if !finite3(&raw.scale) {
        if lossy {
            rep.dropped.bad_scale += 1;
            return Ok(());
        }
        return Err(AssetError::NonFinite { field: "scale", index });
    }
    if !raw.quat.iter().all(|c| c.is_finite()) {
        if lossy {
            rep.dropped.bad_rotation += 1;
            return Ok(());
        }
        return Err(AssetError::NonFinite { field: "rotation", index });
    }
    if !raw.opacity.is_finite() {
        if lossy {
            rep.dropped.bad_opacity += 1;
            return Ok(());
        }
        return Err(AssetError::NonFinite { field: "opacity", index });
    }
    if !finite3(&raw.color) {
        if lossy {
            rep.dropped.bad_color += 1;
            return Ok(());
        }
        return Err(AssetError::NonFinite { field: "color", index });
    }
    let quat = match normalize_quat(raw.quat) {
        Some(q) => q,
        None => {
            if lossy {
                rep.dropped.bad_rotation += 1;
                return Ok(());
            }
            return Err(AssetError::ZeroNormQuat { index });
        }
    };
    // Finite-but-unrenderable ranges: strict keeps them (a faithful
    // load), lossy drops them (they could only ever be culled).
    if lossy {
        if raw.mean.iter().any(|c| c.abs() > MAX_COORD) {
            rep.dropped.bad_position += 1;
            return Ok(());
        }
        if raw.scale.iter().any(|&c| !(c > 0.0) || c > MAX_COORD) {
            rep.dropped.bad_scale += 1;
            return Ok(());
        }
        if !(0.0..=1.0).contains(&raw.opacity) {
            rep.dropped.bad_opacity += 1;
            return Ok(());
        }
    }
    g.means.push(raw.mean);
    g.scales.push(raw.scale);
    g.quats.push(quat);
    g.colors.push(raw.color);
    g.opacity.push(raw.opacity);
    rep.kept += 1;
    Ok(())
}

/// How to turn a loaded splat batch into a renderable [`Scene`].
#[derive(Clone, Debug)]
pub struct AssembleOptions {
    /// Scene name (defaults to the file stem in [`load_scene`]).
    pub name: String,
    /// Evaluation-camera image width in pixels.
    pub width: u32,
    /// Evaluation-camera image height in pixels.
    pub height: u32,
    /// LoD-tree build seed (grouping randomness; deterministic).
    pub seed: u64,
    /// Mean sibling-group size for the LoD-tree build.
    pub mean_fanout: f32,
    /// Sibling-group size cap for the LoD-tree build.
    pub max_fanout: usize,
}

impl Default for AssembleOptions {
    fn default() -> Self {
        AssembleOptions {
            name: "loaded".into(),
            width: 256,
            height: 256,
            seed: 42,
            mean_fanout: 2.0,
            max_fanout: 512,
        }
    }
}

/// Build a [`Scene`] over loaded leaves: LoD tree via the same
/// bottom-up builder procedural scenes use, scenario cameras sized to
/// the cloud's bounding box. Fails with [`AssetError::EmptyScene`] on
/// an empty batch (the tree builder needs at least one leaf).
pub fn assemble_scene(
    leaves: Gaussians,
    opts: &AssembleOptions,
) -> Result<Scene, AssetError> {
    if leaves.is_empty() {
        return Err(AssetError::EmptyScene);
    }
    // Half-extent for the orbit cameras: the farthest coordinate from
    // the origin (captures are kept un-recentred — the data stays pure).
    let mut extent = 0.0f32;
    for m in &leaves.means {
        for c in m {
            extent = extent.max(c.abs());
        }
    }
    let extent = extent.max(1e-3);
    let (gaussians, tree, _stats) =
        build_lod_tree(leaves, opts.seed, opts.mean_fanout, opts.max_fanout);
    let cameras = scenario_cameras(extent, opts.width, opts.height);
    Ok(Scene { name: opts.name.clone(), gaussians, tree, cameras })
}

/// Load a `.splat` or `.ply` file into a renderable [`Scene`].
///
/// The format is picked by extension (`.splat` / `.ply`), falling back
/// to sniffing the `ply` magic. The scene name defaults to the file
/// stem when `opts.name` is the [`AssembleOptions::default`] value.
pub fn load_scene(
    path: &Path,
    mode: LoadMode,
    opts: &AssembleOptions,
) -> Result<(Scene, LoadReport), AssetError> {
    let file = std::fs::File::open(path)?;
    let mut reader = std::io::BufReader::new(file);
    let is_ply = match path.extension().and_then(|e| e.to_str()) {
        Some(e) if e.eq_ignore_ascii_case("ply") => true,
        Some(e) if e.eq_ignore_ascii_case("splat") => false,
        _ => {
            use std::io::BufRead;
            reader.fill_buf()?.starts_with(b"ply")
        }
    };
    let asset = if is_ply {
        load_ply(reader, mode)?
    } else {
        load_splat(reader, mode)?
    };
    let mut opts = opts.clone();
    if opts.name == AssembleOptions::default().name {
        if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
            opts.name = stem.to_string();
        }
    }
    let scene = assemble_scene(asset.gaussians, &opts)?;
    Ok((scene, asset.report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Quat, Vec3};

    fn good_raw() -> RawSplat {
        RawSplat {
            mean: [1.0, 2.0, 3.0],
            scale: [0.1, 0.2, 0.3],
            quat: [1.0, 0.0, 0.0, 0.0],
            color: [0.5, 0.6, 0.7],
            opacity: 0.8,
        }
    }

    #[test]
    fn admit_keeps_good_records_in_both_modes() {
        for mode in [LoadMode::Strict, LoadMode::Lossy] {
            let mut g = Gaussians::default();
            let mut rep = LoadReport::default();
            admit(&good_raw(), 0, mode, &mut g, &mut rep).unwrap();
            assert_eq!(g.len(), 1, "{mode:?}");
            assert_eq!(rep.kept, 1);
            assert_eq!(rep.dropped.total(), 0);
            assert_eq!(splat_defect(&g, 0), None);
        }
    }

    #[test]
    fn admit_rejects_each_degenerate_field() {
        let cases: Vec<(RawSplat, &str)> = vec![
            (RawSplat { mean: [f32::NAN, 0.0, 0.0], ..good_raw() }, "position"),
            (
                RawSplat { scale: [0.1, f32::INFINITY, 0.1], ..good_raw() },
                "scale",
            ),
            (
                RawSplat { quat: [f32::NAN, 0.0, 0.0, 0.0], ..good_raw() },
                "rotation",
            ),
            (RawSplat { opacity: f32::NAN, ..good_raw() }, "opacity"),
            (
                RawSplat { color: [0.1, f32::NEG_INFINITY, 0.1], ..good_raw() },
                "color",
            ),
        ];
        for (raw, field) in cases {
            // Strict: typed error naming the field.
            let mut g = Gaussians::default();
            let mut rep = LoadReport::default();
            match admit(&raw, 7, LoadMode::Strict, &mut g, &mut rep) {
                Err(AssetError::NonFinite { field: f, index: 7 }) => {
                    assert_eq!(f, field)
                }
                other => panic!("{field}: wrong result {other:?}"),
            }
            // Lossy: dropped + counted, never pushed.
            let mut g = Gaussians::default();
            let mut rep = LoadReport::default();
            admit(&raw, 7, LoadMode::Lossy, &mut g, &mut rep).unwrap();
            assert_eq!(g.len(), 0, "{field}");
            assert_eq!(rep.dropped.total(), 1, "{field}");
        }
    }

    #[test]
    fn zero_norm_quat_is_typed_strict_and_dropped_lossy() {
        let raw = RawSplat { quat: [0.0; 4], ..good_raw() };
        let mut g = Gaussians::default();
        let mut rep = LoadReport::default();
        match admit(&raw, 3, LoadMode::Strict, &mut g, &mut rep) {
            Err(AssetError::ZeroNormQuat { index: 3 }) => {}
            other => panic!("wrong result {other:?}"),
        }
        admit(&raw, 3, LoadMode::Lossy, &mut g, &mut rep).unwrap();
        assert_eq!(g.len(), 0);
        assert_eq!(rep.dropped.bad_rotation, 1);
    }

    #[test]
    fn lossy_drops_out_of_range_but_strict_keeps() {
        let raw = RawSplat { mean: [2e12, 0.0, 0.0], ..good_raw() };
        let mut g = Gaussians::default();
        let mut rep = LoadReport::default();
        admit(&raw, 0, LoadMode::Strict, &mut g, &mut rep).unwrap();
        assert_eq!(g.len(), 1, "strict keeps finite-but-huge");
        admit(&raw, 1, LoadMode::Lossy, &mut g, &mut rep).unwrap();
        assert_eq!(g.len(), 1, "lossy drops finite-but-huge");
        assert_eq!(rep.dropped.bad_position, 1);
    }

    #[test]
    fn normalize_quat_is_idempotent_bitwise() {
        // Unnormalized in, unit out; a second pass must be a no-op
        // (the PLY round-trip identity depends on this snap).
        for q in [
            [1.0f32, 2.0, -3.0, 0.5],
            [0.001, 0.0, 0.0, 0.0],
            [1e20, -1e20, 1e19, 0.0],
            [-0.3, 0.4, 0.5, -0.6],
        ] {
            let n1 = normalize_quat(q).unwrap();
            let n2 = normalize_quat(n1).unwrap();
            for k in 0..4 {
                assert_eq!(n1[k].to_bits(), n2[k].to_bits(), "{q:?}[{k}]");
            }
            let norm: f64 = n1.iter().map(|&c| c as f64 * c as f64).sum();
            assert!((norm - 1.0).abs() < 1e-5, "{q:?} -> {norm}");
        }
        assert!(normalize_quat([0.0; 4]).is_none());
    }

    #[test]
    fn assemble_builds_a_renderable_scene() {
        let mut g = Gaussians::default();
        // A loose shell of splats around the origin.
        for i in 0..600u32 {
            let a = i as f32 * 0.61;
            g.push(
                Vec3::new(4.0 * a.cos(), (i % 7) as f32 * 0.5 - 1.5, 4.0 * a.sin()),
                Vec3::splat(0.2),
                Quat::IDENTITY,
                [0.5, 0.4, 0.3],
                0.8,
            );
        }
        let scene = assemble_scene(g, &AssembleOptions::default()).unwrap();
        assert_eq!(scene.cameras.len(), 6);
        assert!(scene.tree.len() > 600, "interior nodes missing");
        scene.tree.check_invariants().unwrap();
        assert!(matches!(
            assemble_scene(Gaussians::default(), &AssembleOptions::default()),
            Err(AssetError::EmptyScene)
        ));
    }
}
