//! The 32-byte `.splat` record stream (antimatter15-style).
//!
//! Each record is exactly [`SPLAT_RECORD_BYTES`] bytes, little-endian:
//!
//! | bytes  | field    | encoding                                      |
//! |--------|----------|-----------------------------------------------|
//! | 0..12  | position | `[f32; 3]`                                    |
//! | 12..24 | scale    | `[f32; 3]`, stored **linearly** (no `exp`)    |
//! | 24..28 | color    | RGBA `u8 x 4`; `A` is opacity, **already**    |
//! |        |          | sigmoid-space (no activation on load)         |
//! | 28..32 | rotation | `u8 x 4` quaternion in `(w, x, y, z)` order,  |
//! |        |          | decoded as `(byte - 128) / 128` then          |
//! |        |          | re-normalized                                 |
//!
//! There is no header and no declared count: the stream ends at EOF, and
//! a partial trailing record is the truncation signal. The quantized
//! color/opacity/rotation make `.splat` a *lossy* interchange format —
//! round trips are digest-stable, not bitwise (unlike [`super::ply`]).

use std::io::Read;

use crate::gaussian::Gaussians;

use super::{admit, read_full, AssetError, LoadMode, LoadedAsset, RawSplat};

/// Size of one `.splat` record in bytes.
pub const SPLAT_RECORD_BYTES: usize = 32;

#[inline]
fn f32_at(buf: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]])
}

/// Decode the packed `u8` quaternion component: `(byte - 128) / 128`,
/// covering `[-1.0, 0.9921875]` in steps of `1/128`.
#[inline]
fn unpack_rot(b: u8) -> f32 {
    (b as i32 - 128) as f32 / 128.0
}

/// Stream a `.splat` record sequence from `r`.
///
/// Strict mode fails with a typed [`AssetError`] on the first degenerate
/// record (non-finite field, zero-norm quaternion) or partial trailing
/// record; lossy mode drops such records, counts them, and never fails
/// on record content.
pub fn load_splat<R: Read>(
    mut r: R,
    mode: LoadMode,
) -> Result<LoadedAsset, AssetError> {
    let mut out = LoadedAsset::default();
    let mut buf = [0u8; SPLAT_RECORD_BYTES];
    loop {
        let index = out.report.records;
        let got = read_full(&mut r, &mut buf)?;
        if got == 0 {
            break; // clean EOF on a record boundary
        }
        if got < SPLAT_RECORD_BYTES {
            match mode {
                LoadMode::Strict => {
                    return Err(AssetError::Truncated { index, got })
                }
                LoadMode::Lossy => {
                    out.report.dropped.truncated_tail += 1;
                    break;
                }
            }
        }
        out.report.records += 1;
        let raw = RawSplat {
            mean: [f32_at(&buf, 0), f32_at(&buf, 4), f32_at(&buf, 8)],
            scale: [f32_at(&buf, 12), f32_at(&buf, 16), f32_at(&buf, 20)],
            color: [
                buf[24] as f32 / 255.0,
                buf[25] as f32 / 255.0,
                buf[26] as f32 / 255.0,
            ],
            opacity: buf[27] as f32 / 255.0,
            quat: [
                unpack_rot(buf[28]),
                unpack_rot(buf[29]),
                unpack_rot(buf[30]),
                unpack_rot(buf[31]),
            ],
        };
        admit(&raw, index, mode, &mut out.gaussians, &mut out.report)?;
    }
    Ok(out)
}

/// Quantize a `[0, 1]` value to a `u8` channel.
#[inline]
fn pack_unit(v: f32) -> u8 {
    (v * 255.0).round().clamp(0.0, 255.0) as u8
}

/// Quantize a `[-1, 1]` quaternion component to the packed byte.
#[inline]
fn pack_rot(v: f32) -> u8 {
    (v * 128.0 + 128.0).round().clamp(0.0, 255.0) as u8
}

/// Encode a splat batch as a `.splat` record stream.
///
/// Color, opacity and rotation are quantized to `u8` (the format's
/// native precision), so `load(write(g))` matches `g` only within
/// quantization — the fixture-zoo round-trip tests pin the exact
/// tolerances. Rotations are normalized before packing; a zero-norm
/// quaternion encodes as identity.
pub fn write_splat<W: std::io::Write>(
    mut w: W,
    g: &Gaussians,
) -> std::io::Result<()> {
    let mut buf = [0u8; SPLAT_RECORD_BYTES];
    for i in 0..g.len() {
        buf[0..4].copy_from_slice(&g.means[i][0].to_le_bytes());
        buf[4..8].copy_from_slice(&g.means[i][1].to_le_bytes());
        buf[8..12].copy_from_slice(&g.means[i][2].to_le_bytes());
        buf[12..16].copy_from_slice(&g.scales[i][0].to_le_bytes());
        buf[16..20].copy_from_slice(&g.scales[i][1].to_le_bytes());
        buf[20..24].copy_from_slice(&g.scales[i][2].to_le_bytes());
        buf[24] = pack_unit(g.colors[i][0]);
        buf[25] = pack_unit(g.colors[i][1]);
        buf[26] = pack_unit(g.colors[i][2]);
        buf[27] = pack_unit(g.opacity[i]);
        let q = super::normalize_quat(g.quats[i])
            .unwrap_or([1.0, 0.0, 0.0, 0.0]);
        buf[28] = pack_rot(q[0]);
        buf[29] = pack_rot(q[1]);
        buf[30] = pack_rot(q[2]);
        buf[31] = pack_rot(q[3]);
        w.write_all(&buf)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assets::LoadMode;
    use crate::math::{Quat, Vec3};

    fn sample() -> Gaussians {
        let mut g = Gaussians::default();
        g.push(
            Vec3::new(1.5, -2.25, 3.0),
            Vec3::new(0.5, 0.25, 0.125),
            Quat::IDENTITY,
            [1.0, 0.5, 0.0],
            0.8,
        );
        g.push(
            Vec3::new(-4.0, 0.0, 7.5),
            Vec3::splat(0.0625),
            Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), 0.9),
            [0.2, 0.4, 0.6],
            1.0,
        );
        g
    }

    #[test]
    fn round_trip_within_quantization() {
        let g = sample();
        let mut bytes = Vec::new();
        write_splat(&mut bytes, &g).unwrap();
        assert_eq!(bytes.len(), g.len() * SPLAT_RECORD_BYTES);
        let got = load_splat(&bytes[..], LoadMode::Strict).unwrap();
        assert_eq!(got.gaussians.len(), g.len());
        assert_eq!(got.report.kept, g.len());
        for i in 0..g.len() {
            // Positions and scales are raw f32: bit-exact.
            assert_eq!(got.gaussians.means[i], g.means[i]);
            assert_eq!(got.gaussians.scales[i], g.scales[i]);
            // Color/opacity quantized to 1/255.
            for k in 0..3 {
                assert!(
                    (got.gaussians.colors[i][k] - g.colors[i][k]).abs()
                        <= 0.5 / 255.0 + 1e-6
                );
            }
            assert!(
                (got.gaussians.opacity[i] - g.opacity[i]).abs()
                    <= 0.5 / 255.0 + 1e-6
            );
            // Quats quantized to 1/128 then renormalized.
            for k in 0..4 {
                assert!(
                    (got.gaussians.quats[i][k] - g.quats[i][k]).abs()
                        <= 1.0 / 128.0 + 1e-5
                );
            }
        }
    }

    #[test]
    fn truncation_at_every_offset() {
        let g = sample();
        let mut bytes = Vec::new();
        write_splat(&mut bytes, &g).unwrap();
        for cut in 0..bytes.len() {
            let slice = &bytes[..cut];
            let partial = cut % SPLAT_RECORD_BYTES != 0;
            match load_splat(slice, LoadMode::Strict) {
                Ok(a) => {
                    assert!(!partial, "cut {cut} should be truncated");
                    assert_eq!(a.report.records, cut / SPLAT_RECORD_BYTES);
                }
                Err(AssetError::Truncated { index, got }) => {
                    assert!(partial, "cut {cut} wrongly truncated");
                    assert_eq!(index, cut / SPLAT_RECORD_BYTES);
                    assert_eq!(got, cut % SPLAT_RECORD_BYTES);
                }
                Err(e) => panic!("cut {cut}: wrong error {e}"),
            }
            // Lossy never fails and keeps the whole records.
            let a = load_splat(slice, LoadMode::Lossy).unwrap();
            assert_eq!(a.report.kept, cut / SPLAT_RECORD_BYTES);
            assert_eq!(
                a.report.dropped.truncated_tail,
                u64::from(partial)
            );
        }
    }

    #[test]
    fn nan_position_is_typed_strict_dropped_lossy() {
        let g = sample();
        let mut bytes = Vec::new();
        write_splat(&mut bytes, &g).unwrap();
        // Poison record 1's y-position with a NaN bit pattern.
        let off = SPLAT_RECORD_BYTES + 4;
        bytes[off..off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        match load_splat(&bytes[..], LoadMode::Strict) {
            Err(AssetError::NonFinite { field: "position", index: 1 }) => {}
            other => panic!("wrong result: {other:?}"),
        }
        let a = load_splat(&bytes[..], LoadMode::Lossy).unwrap();
        assert_eq!(a.report.kept, 1);
        assert_eq!(a.report.dropped.bad_position, 1);
    }

    #[test]
    fn zero_quat_bytes_decode_to_identityless_drop() {
        // All-128 rotation bytes decode to the zero quaternion.
        let mut bytes = vec![0u8; SPLAT_RECORD_BYTES];
        bytes[12..16].copy_from_slice(&1.0f32.to_le_bytes()); // scale > 0
        bytes[16..20].copy_from_slice(&1.0f32.to_le_bytes());
        bytes[20..24].copy_from_slice(&1.0f32.to_le_bytes());
        for b in &mut bytes[28..32] {
            *b = 128;
        }
        match load_splat(&bytes[..], LoadMode::Strict) {
            Err(AssetError::ZeroNormQuat { index: 0 }) => {}
            other => panic!("wrong result: {other:?}"),
        }
        let a = load_splat(&bytes[..], LoadMode::Lossy).unwrap();
        assert_eq!(a.report.kept, 0);
        assert_eq!(a.report.dropped.bad_rotation, 1);
    }

    #[test]
    fn empty_stream_is_an_empty_asset() {
        let a = load_splat(&[][..], LoadMode::Strict).unwrap();
        assert_eq!(a.report.records, 0);
        assert!(a.gaussians.is_empty());
    }
}
