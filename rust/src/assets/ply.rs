//! Binary little-endian PLY with the 3DGS training-output schema.
//!
//! The header names every vertex property in file order, so the parser
//! is entirely **header-driven**: required fields are located by name,
//! unknown properties (normals, extra channels) are skipped by their
//! declared size, and the record stride is whatever the header says —
//! property order is never assumed. Required float32 fields:
//! `x y z`, `f_dc_0..2`, `opacity`, `scale_0..2`, `rot_0..3`.
//!
//! Field activations (inverse of how 3DGS training stores them):
//!
//! * color = `0.5 + SH_C0 * f_dc_k` ([`SH_C0`] is the degree-0 real
//!   spherical-harmonic basis constant),
//! * `opacity` through a sigmoid (stored as a logit),
//! * `scale_*` through `exp` (stored as a log-scale),
//! * `rot_*` re-normalized, `(w, x, y, z)` component order.
//!
//! Optional `f_rest_*` higher-order SH bands are parsed (counted and
//! strided over) and band-truncated to degree 0 for now — the count is
//! reported in [`super::LoadReport::sh_rest_coeffs`].
//!
//! [`write_ply`] is the matching encoder. It searches each stored
//! field's *preimage* under the loader's activation (monotone bisection
//! in sortable-bit space), so re-encoding a **PLY-loaded** scene
//! reproduces it bit for bit: `load_ply(write_ply(s))` is the identity
//! on any `s` that a PLY load produced. That is what makes PLY
//! round-trip renders byte-identical where `.splat`'s `u8` quantization
//! is only digest-stable — a scene that came from a `.splat` load (or
//! any other source) carries values outside the activations' images,
//! and those encode as the nearest representable stored value instead
//! (see [`write_ply`]).

use std::io::BufRead;

use crate::gaussian::Gaussians;
use crate::splat::float_to_sortable_uint;

use super::{
    admit, read_full, AssetError, LoadMode, LoadReport, LoadedAsset, RawSplat,
};

/// Degree-0 real spherical-harmonic basis constant: color channels are
/// stored as `(color - 0.5) / SH_C0` by 3DGS training code.
pub const SH_C0: f32 = 0.282_094_8;

/// Vertex counts above this are treated as corrupt headers rather than
/// data ([`AssetError::AbsurdVertexCount`]): 100M splats is ~5x the
/// largest published 3DGS captures.
const MAX_VERTEX_COUNT: u64 = 100_000_000;

/// Header caps: maximum line length and line count before the header is
/// declared structurally bad (a binary blob mistaken for a header would
/// otherwise be scanned for a `\n` indefinitely).
const MAX_HEADER_LINE: usize = 1024;
const MAX_HEADER_LINES: usize = 4096;

/// Plausibility cap on the total bytes of non-vertex elements declared
/// *before* the vertex data (cameras, metadata — tiny in practice).
/// Mirrors [`MAX_VERTEX_COUNT`]: without it a hostile header could
/// declare a pre-vertex element with `count * stride` near `u64::MAX`
/// and make the loader try to skip that many bytes, which on a non-file
/// source (pipe, socket) stalls rather than hitting EOF.
const MAX_PRE_SKIP_BYTES: u64 = 1 << 30;

/// The 14 required vertex properties, all `float32`.
const REQUIRED: [&str; 14] = [
    "x", "y", "z", "f_dc_0", "f_dc_1", "f_dc_2", "opacity", "scale_0",
    "scale_1", "scale_2", "rot_0", "rot_1", "rot_2", "rot_3",
];

/// Size in bytes of a PLY scalar type token, `None` if unknown.
fn scalar_size(ty: &str) -> Option<usize> {
    Some(match ty {
        "char" | "int8" | "uchar" | "uint8" => 1,
        "short" | "int16" | "ushort" | "uint16" => 2,
        "int" | "int32" | "uint" | "uint32" | "float" | "float32" => 4,
        "double" | "float64" => 8,
        _ => return None,
    })
}

/// An element mid-description: name, declared count, running stride.
struct ElemHdr {
    name: String,
    count: u64,
    stride: usize,
}

/// Where everything lives in one vertex record.
struct VertexLayout {
    /// Declared vertex count.
    count: u64,
    /// Bytes per vertex record.
    stride: usize,
    /// Byte offset of each [`REQUIRED`] field within a record.
    offsets: [usize; 14],
    /// Number of `f_rest_*` SH coefficients per vertex.
    sh_rest: usize,
    /// Bytes of non-vertex elements stored *before* the vertex data.
    pre_skip: u64,
}

/// Fold a finished element into the layout (vertex) or the pre-vertex
/// byte skip (anything declared before the vertex element). Elements
/// *after* the vertex element need neither: parsing stops once the
/// vertex records are consumed.
fn finish_element(
    cur: &mut Option<ElemHdr>,
    layout: &mut Option<VertexLayout>,
    pre_skip: &mut u64,
) -> Result<(), AssetError> {
    if let Some(e) = cur.take() {
        if e.name == "vertex" {
            *layout = Some(VertexLayout {
                count: e.count,
                stride: e.stride,
                offsets: [usize::MAX; 14],
                sh_rest: 0,
                pre_skip: 0,
            });
        } else if layout.is_none() {
            *pre_skip = pre_skip
                .saturating_add(e.count.saturating_mul(e.stride as u64));
            if *pre_skip > MAX_PRE_SKIP_BYTES {
                return Err(AssetError::BadHeader(format!(
                    "pre-vertex element `{}` implausibly large",
                    e.name
                )));
            }
        }
    }
    Ok(())
}

/// Read one `\n`-terminated header line (CR trimmed), with length caps.
/// EOF before the `\n` is a structural error — a header never just
/// ends, not even right after `end_header`: a file cut there has lost
/// its vertex data too, and must read as truncated, not as valid.
fn header_line<R: BufRead>(r: &mut R) -> Result<String, AssetError> {
    let mut raw = Vec::new();
    // +2: room for a full-length line plus its `\n`, so hitting the
    // cap is distinguishable from a line that exactly fits it.
    let mut limited = r.take((MAX_HEADER_LINE + 2) as u64);
    let n = limited.read_until(b'\n', &mut raw)?;
    if n == 0 {
        return Err(AssetError::BadHeader("unexpected end of header".into()));
    }
    if raw.last() != Some(&b'\n') {
        return Err(AssetError::BadHeader(
            if raw.len() > MAX_HEADER_LINE + 1 {
                "header line too long".into()
            } else {
                "unterminated header line".into()
            },
        ));
    }
    raw.pop();
    while raw.last() == Some(&b'\r') {
        raw.pop();
    }
    if raw.len() > MAX_HEADER_LINE {
        return Err(AssetError::BadHeader("header line too long".into()));
    }
    String::from_utf8(raw)
        .map_err(|_| AssetError::BadHeader("non-UTF-8 header line".into()))
}

/// Parse the header through `end_header`, returning the vertex layout.
fn parse_header<R: BufRead>(r: &mut R) -> Result<VertexLayout, AssetError> {
    if header_line(r)? != "ply" {
        return Err(AssetError::BadMagic);
    }
    let mut format_ok = false;
    let mut cur: Option<ElemHdr> = None;
    let mut layout: Option<VertexLayout> = None;
    let mut pre_skip: u64 = 0;
    let mut offsets = [usize::MAX; 14];
    let mut sh_rest = 0usize;

    for _ in 0..MAX_HEADER_LINES {
        let line = header_line(r)?;
        let mut tok = line.split_ascii_whitespace();
        match tok.next() {
            None => continue, // blank line
            Some("comment") | Some("obj_info") => continue,
            Some("format") => {
                let kind = tok.next().unwrap_or("");
                if kind != "binary_little_endian" {
                    return Err(AssetError::BadHeader(format!(
                        "unsupported format `{kind}` (need binary_little_endian)"
                    )));
                }
                format_ok = true;
            }
            Some("element") => {
                finish_element(&mut cur, &mut layout, &mut pre_skip)?;
                let name = tok
                    .next()
                    .ok_or_else(|| {
                        AssetError::BadHeader("element without a name".into())
                    })?
                    .to_string();
                let count: u64 = tok
                    .next()
                    .and_then(|c| c.parse().ok())
                    .ok_or_else(|| {
                        AssetError::BadHeader(format!(
                            "element `{name}` without a count"
                        ))
                    })?;
                if name == "vertex" {
                    if layout.is_some() {
                        return Err(AssetError::BadHeader(
                            "duplicate vertex element".into(),
                        ));
                    }
                    if count > MAX_VERTEX_COUNT {
                        return Err(AssetError::AbsurdVertexCount { count });
                    }
                }
                cur = Some(ElemHdr { name, count, stride: 0 });
            }
            Some("property") => {
                let e = cur.as_mut().ok_or_else(|| {
                    AssetError::BadHeader("property before any element".into())
                })?;
                let in_vertex = e.name == "vertex";
                // Elements after the vertex element are never read, so
                // their exotic properties are harmless.
                let relevant = in_vertex || layout.is_none();
                let ty = tok.next().unwrap_or("").to_string();
                if ty == "list" {
                    // Variable-length records make the stride
                    // unknowable, so a list at or before the vertex
                    // data is unsupported.
                    if relevant {
                        let pname =
                            tok.next_back().unwrap_or("<unnamed>").to_string();
                        return Err(AssetError::UnsupportedProperty {
                            name: pname,
                            ty,
                        });
                    }
                    continue;
                }
                let pname = tok
                    .next()
                    .ok_or_else(|| {
                        AssetError::BadHeader("property without a name".into())
                    })?
                    .to_string();
                let size = match scalar_size(&ty) {
                    Some(s) => s,
                    None if relevant => {
                        return Err(AssetError::UnsupportedProperty {
                            name: pname,
                            ty,
                        })
                    }
                    None => continue,
                };
                if in_vertex {
                    if let Some(slot) =
                        REQUIRED.iter().position(|&f| f == pname)
                    {
                        if ty != "float" && ty != "float32" {
                            return Err(AssetError::UnsupportedProperty {
                                name: pname,
                                ty,
                            });
                        }
                        if offsets[slot] != usize::MAX {
                            return Err(AssetError::BadHeader(format!(
                                "duplicate property `{pname}`"
                            )));
                        }
                        offsets[slot] = e.stride;
                    } else if pname.starts_with("f_rest_")
                        && (ty == "float" || ty == "float32")
                    {
                        sh_rest += 1;
                    }
                    // Any other unknown property (nx/ny/nz, extra
                    // channels) is fine: it only contributes stride.
                }
                e.stride += size;
            }
            Some("end_header") => {
                finish_element(&mut cur, &mut layout, &mut pre_skip)?;
                if !format_ok {
                    return Err(AssetError::BadHeader(
                        "missing format line".into(),
                    ));
                }
                let mut layout = layout.ok_or_else(|| {
                    AssetError::BadHeader("no vertex element".into())
                })?;
                for (slot, off) in offsets.iter().enumerate() {
                    if *off == usize::MAX {
                        return Err(AssetError::BadHeader(format!(
                            "missing property `{}`",
                            REQUIRED[slot]
                        )));
                    }
                }
                layout.offsets = offsets;
                layout.sh_rest = sh_rest;
                layout.pre_skip = pre_skip;
                return Ok(layout);
            }
            Some(other) => {
                return Err(AssetError::BadHeader(format!(
                    "unknown header keyword `{other}`"
                )));
            }
        }
    }
    Err(AssetError::BadHeader("header too long".into()))
}

#[inline]
fn f32_at(buf: &[u8], off: usize) -> f32 {
    f32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]])
}

/// The loader's opacity activation. `1 / (1 + e^-x)`: NaN stays NaN
/// (caught by admission); `+/-inf` saturate to 1/0.
#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// The loader's color activation for one `f_dc` coefficient.
#[inline]
fn dc_to_color(dc: f32) -> f32 {
    0.5 + SH_C0 * dc
}

/// Stream a binary little-endian 3DGS PLY from `r`.
///
/// Header problems (bad magic, unsupported format or property types,
/// absurd vertex counts) fail in **both** modes — without a valid
/// layout there is nothing to salvage. Record-level problems follow
/// [`LoadMode`]: strict returns the typed [`AssetError`], lossy drops
/// and counts.
pub fn load_ply<R: BufRead>(
    mut r: R,
    mode: LoadMode,
) -> Result<LoadedAsset, AssetError> {
    let layout = parse_header(&mut r)?;
    // Vertices are capped at MAX_VERTEX_COUNT, but still bound the
    // upfront reservation — a hostile count must not allocate gigabytes
    // before the first record proves the data is really there.
    let reserve = (layout.count as usize).min(1 << 20);
    let mut out = LoadedAsset {
        gaussians: Gaussians::with_capacity(reserve),
        report: LoadReport {
            sh_rest_coeffs: layout.sh_rest,
            ..LoadReport::default()
        },
    };

    if layout.pre_skip > 0 {
        let skipped = std::io::copy(
            &mut (&mut r).take(layout.pre_skip),
            &mut std::io::sink(),
        )?;
        if skipped < layout.pre_skip {
            match mode {
                LoadMode::Strict => {
                    return Err(AssetError::Truncated { index: 0, got: 0 })
                }
                LoadMode::Lossy => {
                    out.report.dropped.truncated_tail += 1;
                    return Ok(out);
                }
            }
        }
    }

    let mut buf = vec![0u8; layout.stride];
    let o = &layout.offsets;
    for index in 0..layout.count as usize {
        let got = read_full(&mut r, &mut buf)?;
        if got < layout.stride {
            match mode {
                LoadMode::Strict => {
                    return Err(AssetError::Truncated { index, got })
                }
                LoadMode::Lossy => {
                    out.report.dropped.truncated_tail += 1;
                    break;
                }
            }
        }
        out.report.records += 1;
        let raw = RawSplat {
            mean: [f32_at(&buf, o[0]), f32_at(&buf, o[1]), f32_at(&buf, o[2])],
            color: [
                dc_to_color(f32_at(&buf, o[3])),
                dc_to_color(f32_at(&buf, o[4])),
                dc_to_color(f32_at(&buf, o[5])),
            ],
            opacity: sigmoid(f32_at(&buf, o[6])),
            scale: [
                f32_at(&buf, o[7]).exp(),
                f32_at(&buf, o[8]).exp(),
                f32_at(&buf, o[9]).exp(),
            ],
            quat: [
                f32_at(&buf, o[10]),
                f32_at(&buf, o[11]),
                f32_at(&buf, o[12]),
                f32_at(&buf, o[13]),
            ],
        };
        admit(&raw, index, mode, &mut out.gaussians, &mut out.report)?;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Encoder: exact-preimage search.

/// Inverse of [`float_to_sortable_uint`]: bisecting sortable keys
/// bisects representable `f32` values in numeric order.
fn from_ord(k: u32) -> f32 {
    if k & 0x8000_0000 != 0 {
        f32::from_bits(k & 0x7fff_ffff)
    } else {
        f32::from_bits(!k)
    }
}

/// Find an `x` in `[lo, hi]` with `fwd(x)` bitwise equal to `target`,
/// assuming `fwd` is (weakly) monotone increasing there. Bisects in
/// sortable-bit space for the smallest `x` with `fwd(x) >= target`,
/// then scans a few neighbours (tolerating sub-ulp non-monotonicity in
/// libm). When `target` is not in `fwd`'s image — possible for
/// arbitrary inputs, impossible for values a load produced — returns
/// the `x` whose image is nearest, so first-pass encodes are within an
/// ulp or two and second-pass encodes are exact.
fn invert(target: f32, lo: f32, hi: f32, fwd: impl Fn(f32) -> f32) -> f32 {
    let (mut lo_k, mut hi_k) =
        (float_to_sortable_uint(lo), float_to_sortable_uint(hi));
    while lo_k < hi_k {
        let mid = lo_k + (hi_k - lo_k) / 2;
        if fwd(from_ord(mid)) < target {
            lo_k = mid + 1;
        } else {
            hi_k = mid;
        }
    }
    let mut best = from_ord(lo_k);
    let mut best_err = f64::INFINITY;
    for d in -8i64..=8 {
        let Ok(k) = u32::try_from(lo_k as i64 + d) else { continue };
        let x = from_ord(k);
        let v = fwd(x);
        if v.to_bits() == target.to_bits() {
            return x;
        }
        let err = (v as f64 - target as f64).abs();
        if err < best_err {
            best = x;
            best_err = err;
        }
    }
    best
}

/// Stored-field ranges the preimage search covers. ±120 spans the full
/// image of both activations in `f32`: `sigmoid` saturates to exactly
/// 0/1 well inside it, and `exp` underflows to exactly 0 below −104 and
/// overflows past `f32::MAX` (so is rejected as non-finite on load)
/// above ~89 — every finite value a load produced has its preimage
/// here. `f_dc` has no such saturation, so colors get the whole finite
/// line: any finite loaded color is `dc_to_color` of some finite `f_dc`
/// and stays exactly invertible however wild the training output was.
const LOGIT_RANGE: (f32, f32) = (-120.0, 120.0);
const DC_RANGE: (f32, f32) = (f32::MIN, f32::MAX);

/// Encode a splat batch as a binary little-endian 3DGS PLY.
///
/// Positions and rotations are stored raw (rotations normalized first;
/// a zero-norm quaternion encodes as identity); color, opacity and
/// scale are stored through exact-preimage inversion of the loader's
/// activations (see [`invert`]), so a **PLY-loaded** scene survives
/// `write_ply` -> [`load_ply`] bit for bit. Fields that did not come
/// through those activations — a `.splat` load's `u8`-quantized color
/// and opacity, or a non-positive scale, which `exp` cannot produce
/// (except exactly `0.0`, which it underflows to) — encode as the
/// nearest value the activation *can* produce, within an ulp or two.
pub fn write_ply<W: std::io::Write>(
    mut w: W,
    g: &Gaussians,
) -> std::io::Result<()> {
    let mut header = String::new();
    header.push_str("ply\nformat binary_little_endian 1.0\n");
    header.push_str("comment sltarch asset encoder\n");
    header.push_str(&format!("element vertex {}\n", g.len()));
    for name in REQUIRED {
        header.push_str(&format!("property float {name}\n"));
    }
    header.push_str("end_header\n");
    w.write_all(header.as_bytes())?;

    let mut rec = [0u8; 14 * 4];
    for i in 0..g.len() {
        let q = super::normalize_quat(g.quats[i])
            .unwrap_or([1.0, 0.0, 0.0, 0.0]);
        let fields: [f32; 14] = [
            g.means[i][0],
            g.means[i][1],
            g.means[i][2],
            invert(g.colors[i][0], DC_RANGE.0, DC_RANGE.1, dc_to_color),
            invert(g.colors[i][1], DC_RANGE.0, DC_RANGE.1, dc_to_color),
            invert(g.colors[i][2], DC_RANGE.0, DC_RANGE.1, dc_to_color),
            invert(g.opacity[i], LOGIT_RANGE.0, LOGIT_RANGE.1, sigmoid),
            invert(g.scales[i][0], LOGIT_RANGE.0, LOGIT_RANGE.1, f32::exp),
            invert(g.scales[i][1], LOGIT_RANGE.0, LOGIT_RANGE.1, f32::exp),
            invert(g.scales[i][2], LOGIT_RANGE.0, LOGIT_RANGE.1, f32::exp),
            q[0],
            q[1],
            q[2],
            q[3],
        ];
        for (k, f) in fields.iter().enumerate() {
            rec[k * 4..k * 4 + 4].copy_from_slice(&f.to_le_bytes());
        }
        w.write_all(&rec)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assets::LoadMode;
    use crate::math::{Quat, Vec3};

    fn sample() -> Gaussians {
        let mut g = Gaussians::default();
        g.push(
            Vec3::new(0.5, -1.25, 2.0),
            Vec3::new(0.5, 0.03, 1.75),
            Quat::from_axis_angle(Vec3::new(1.0, 0.5, -0.25), 0.6),
            [0.9, 0.45, 0.1],
            0.95,
        );
        g.push(
            Vec3::new(-3.0, 0.0, 4.5),
            Vec3::splat(0.2),
            Quat::IDENTITY,
            [0.05, 0.5, 0.88],
            0.31,
        );
        g
    }

    #[test]
    fn invert_hits_exact_preimages() {
        // Any value in the image must invert exactly.
        for raw in [-7.5f32, -0.3, 0.0, 0.9, 3.0, 12.0] {
            let s = raw.exp();
            let back = invert(s, LOGIT_RANGE.0, LOGIT_RANGE.1, f32::exp);
            assert_eq!(back.exp().to_bits(), s.to_bits(), "exp({raw})");
            let o = sigmoid(raw);
            let back = invert(o, LOGIT_RANGE.0, LOGIT_RANGE.1, sigmoid);
            assert_eq!(sigmoid(back).to_bits(), o.to_bits(), "sigmoid({raw})");
            let c = dc_to_color(raw);
            let back = invert(c, DC_RANGE.0, DC_RANGE.1, dc_to_color);
            assert_eq!(dc_to_color(back).to_bits(), c.to_bits(), "dc({raw})");
        }
        // `f_dc` has no sane gamut: wild-but-finite training outputs
        // must still invert exactly (the DC range is the whole line).
        for raw in [-3.0e38f32, -1.0e6, 1000.0, 2.5e30, f32::MAX] {
            let c = dc_to_color(raw);
            let back = invert(c, DC_RANGE.0, DC_RANGE.1, dc_to_color);
            assert_eq!(dc_to_color(back).to_bits(), c.to_bits(), "dc({raw})");
        }
        // Scales underflowed to exactly 0.0 invert exactly too.
        let back = invert(0.0, LOGIT_RANGE.0, LOGIT_RANGE.1, f32::exp);
        assert_eq!(back.exp().to_bits(), 0.0f32.to_bits(), "exp underflow");
        // Saturated opacities have exact preimages too.
        for o in [0.0f32, 1.0] {
            let back = invert(o, LOGIT_RANGE.0, LOGIT_RANGE.1, sigmoid);
            assert_eq!(sigmoid(back).to_bits(), o.to_bits(), "sigmoid sat {o}");
        }
    }

    #[test]
    fn round_trip_is_exact_from_the_first_load_on() {
        let g0 = sample();
        let mut bytes = Vec::new();
        write_ply(&mut bytes, &g0).unwrap();
        let g1 = load_ply(&bytes[..], LoadMode::Strict).unwrap().gaussians;
        assert_eq!(g1.len(), g0.len());
        // Pass 1: raw f32 fields exact, activated fields within ulps.
        assert_eq!(g1.means, g0.means);
        for i in 0..g0.len() {
            for k in 0..3 {
                assert!(
                    (g1.colors[i][k] - g0.colors[i][k]).abs() < 1e-5,
                    "color[{i}][{k}]"
                );
                assert!(
                    (g1.scales[i][k] - g0.scales[i][k]).abs()
                        < g0.scales[i][k] * 1e-5,
                    "scale[{i}][{k}]"
                );
            }
            assert!((g1.opacity[i] - g0.opacity[i]).abs() < 1e-5);
        }
        // Pass 2: a loaded scene survives re-encoding bit for bit.
        let mut bytes2 = Vec::new();
        write_ply(&mut bytes2, &g1).unwrap();
        let g2 = load_ply(&bytes2[..], LoadMode::Strict).unwrap().gaussians;
        assert_eq!(g1.means, g2.means);
        assert_eq!(g1.scales, g2.scales);
        assert_eq!(g1.quats, g2.quats);
        assert_eq!(g1.colors, g2.colors);
        assert_eq!(g1.opacity, g2.opacity);
    }

    #[test]
    fn shuffled_property_order_loads_identically() {
        // Same two vertices, canonical vs shuffled property order plus
        // unknown nx/ny/nz and a uchar channel: identical batches.
        let g = sample();
        let mut canonical = Vec::new();
        write_ply(&mut canonical, &g).unwrap();
        let want = load_ply(&canonical[..], LoadMode::Strict).unwrap();

        // Re-emit by hand with a shuffled layout.
        let order = [
            "rot_0", "rot_1", "rot_2", "rot_3", "nx", "ny", "nz", "scale_0",
            "scale_1", "scale_2", "opacity", "x", "y", "z", "f_dc_2",
            "f_dc_1", "f_dc_0",
        ];
        let mut header = String::from(
            "ply\nformat binary_little_endian 1.0\nelement vertex 2\n",
        );
        for name in order {
            header.push_str(&format!("property float {name}\n"));
        }
        header.push_str("property uchar segmentation\nend_header\n");
        let mut bytes = header.into_bytes();
        // Pull each vertex's canonical fields back out of `canonical`.
        let body = &canonical[canonical.len() - 2 * 14 * 4..];
        let field = |v: usize, slot: usize| -> [u8; 4] {
            let off = v * 14 * 4 + slot * 4;
            body[off..off + 4].try_into().unwrap()
        };
        for v in 0..2 {
            for name in order {
                match REQUIRED.iter().position(|&r| r == name) {
                    Some(slot) => bytes.extend_from_slice(&field(v, slot)),
                    None => bytes.extend_from_slice(&0.25f32.to_le_bytes()),
                }
            }
            bytes.push(7); // the uchar channel
        }
        let got = load_ply(&bytes[..], LoadMode::Strict).unwrap();
        assert_eq!(got.gaussians.means, want.gaussians.means);
        assert_eq!(got.gaussians.scales, want.gaussians.scales);
        assert_eq!(got.gaussians.quats, want.gaussians.quats);
        assert_eq!(got.gaussians.colors, want.gaussians.colors);
        assert_eq!(got.gaussians.opacity, want.gaussians.opacity);
    }

    #[test]
    fn header_errors_are_typed() {
        let cases: [(&[u8], fn(&AssetError) -> bool); 6] = [
            (b"plx\n", |e| matches!(e, AssetError::BadMagic)),
            (b"ply\nformat ascii 1.0\nend_header\n", |e| {
                matches!(e, AssetError::BadHeader(_))
            }),
            // No vertex element at all.
            (b"ply\nformat binary_little_endian 1.0\nend_header\n", |e| {
                matches!(e, AssetError::BadHeader(_))
            }),
            // Vertex element missing required fields.
            (
                b"ply\nformat binary_little_endian 1.0\nelement vertex 2\nproperty float x\nend_header\n",
                |e| matches!(e, AssetError::BadHeader(_)),
            ),
            (
                b"ply\nformat binary_little_endian 1.0\nelement vertex 999999999999\nend_header\n",
                |e| matches!(e, AssetError::AbsurdVertexCount { .. }),
            ),
            (
                b"ply\nformat binary_little_endian 1.0\nelement vertex 1\nproperty double x\nend_header\n",
                |e| {
                    matches!(e, AssetError::UnsupportedProperty { name, .. }
                        if name == "x")
                },
            ),
        ];
        for (bytes, check) in cases {
            // Header errors are structural: both modes fail.
            for mode in [LoadMode::Strict, LoadMode::Lossy] {
                match load_ply(bytes, mode) {
                    Err(e) => assert!(check(&e), "{mode:?}: wrong error {e}"),
                    Ok(_) => panic!("{mode:?}: accepted bad header"),
                }
            }
        }
    }

    #[test]
    fn unterminated_end_header_is_a_header_error() {
        // A file cut one byte before the body has `end_header` with no
        // trailing `\n`: structurally bad in both modes, never a
        // zero-record success (the vertex data is gone with the cut).
        let g = sample();
        let mut bytes = Vec::new();
        write_ply(&mut bytes, &g).unwrap();
        let body = bytes.len() - 2 * 14 * 4;
        for mode in [LoadMode::Strict, LoadMode::Lossy] {
            match load_ply(&bytes[..body - 1], mode) {
                Err(AssetError::BadHeader(_)) => {}
                other => panic!("{mode:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn header_line_cap_is_inclusive() {
        // Exactly MAX_HEADER_LINE content bytes plus `\n` is within the
        // cap; one more content byte is not.
        let build = |pad: usize| {
            let mut h = String::from("ply\nformat binary_little_endian 1.0\n");
            h.push_str("comment ");
            h.push_str(&"x".repeat(pad - "comment ".len()));
            h.push('\n');
            h.push_str("element vertex 0\nend_header\n");
            h.into_bytes()
        };
        // An in-cap comment parses through to "no required properties".
        match load_ply(&build(MAX_HEADER_LINE)[..], LoadMode::Strict) {
            Err(AssetError::BadHeader(m)) => {
                assert!(m.contains("missing property"), "{m}")
            }
            other => panic!("cap-length line: {other:?}"),
        }
        match load_ply(&build(MAX_HEADER_LINE + 1)[..], LoadMode::Strict) {
            Err(AssetError::BadHeader(m)) => {
                assert!(m.contains("too long"), "{m}")
            }
            other => panic!("over-cap line: {other:?}"),
        }
    }

    #[test]
    fn absurd_pre_vertex_element_is_rejected() {
        // A hostile non-vertex element before the vertices must not
        // make the loader try to skip ~2^64 bytes.
        let header = format!(
            "ply\nformat binary_little_endian 1.0\n\
             element junk {}\nproperty float pad\n\
             element vertex 1\nproperty float x\nend_header\n",
            u64::MAX / 4
        );
        for mode in [LoadMode::Strict, LoadMode::Lossy] {
            match load_ply(header.as_bytes(), mode) {
                Err(AssetError::BadHeader(m)) => {
                    assert!(m.contains("implausibly large"), "{m}")
                }
                other => panic!("{mode:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_vertex_data() {
        let g = sample();
        let mut bytes = Vec::new();
        write_ply(&mut bytes, &g).unwrap();
        let body = 2 * 14 * 4;
        let header_len = bytes.len() - body;
        // Cut mid-way through the second vertex.
        let cut = header_len + 14 * 4 + 10;
        match load_ply(&bytes[..cut], LoadMode::Strict) {
            Err(AssetError::Truncated { index: 1, got: 10 }) => {}
            other => panic!("wrong result: {other:?}"),
        }
        let a = load_ply(&bytes[..cut], LoadMode::Lossy).unwrap();
        assert_eq!(a.report.kept, 1);
        assert_eq!(a.report.dropped.truncated_tail, 1);
    }

    #[test]
    fn pre_vertex_elements_are_skipped_and_f_rest_counted() {
        // A camera element before the vertices, plus 3 f_rest coeffs.
        let mut header =
            String::from("ply\nformat binary_little_endian 1.0\n");
        header.push_str(
            "element camera 2\nproperty float cx\nproperty uchar id\n",
        );
        header.push_str("element vertex 1\n");
        for name in REQUIRED {
            header.push_str(&format!("property float {name}\n"));
        }
        for k in 0..3 {
            header.push_str(&format!("property float f_rest_{k}\n"));
        }
        header.push_str("end_header\n");
        let mut bytes = header.into_bytes();
        bytes.extend_from_slice(&[0u8; 2 * 5]); // camera payload
        let mut vals = [0.0f32; 17];
        vals[..3].copy_from_slice(&[1.0, 2.0, 3.0]); // x y z
        vals[10] = 1.0; // rot_0 = w
        vals[14..17].copy_from_slice(&[9.0, 9.0, 9.0]); // f_rest junk
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let a = load_ply(&bytes[..], LoadMode::Strict).unwrap();
        assert_eq!(a.report.kept, 1);
        assert_eq!(a.report.sh_rest_coeffs, 3);
        assert_eq!(a.gaussians.means[0], [1.0, 2.0, 3.0]);
        // scale = exp(0) = 1, opacity = sigmoid(0) = 0.5.
        assert_eq!(a.gaussians.scales[0], [1.0, 1.0, 1.0]);
        assert_eq!(a.gaussians.opacity[0], 0.5);
    }
}
