//! Row-major 3x3 and 4x4 matrices (the conventions of the L2 jax model).

use super::Vec3;

/// Row-major 3x3 matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat3 {
    pub m: [[f32; 3]; 3],
}

/// Row-major 4x4 matrix (used as a rigid world->camera transform).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat4 {
    pub m: [[f32; 4]; 4],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    #[inline]
    pub fn from_rows(r0: Vec3, r1: Vec3, r2: Vec3) -> Self {
        Mat3 {
            m: [r0.to_array(), r1.to_array(), r2.to_array()],
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> Vec3 {
        Vec3::from_array(self.m[i])
    }

    #[inline]
    pub fn col(&self, j: usize) -> Vec3 {
        Vec3::new(self.m[0][j], self.m[1][j], self.m[2][j])
    }

    #[inline]
    pub fn transpose(&self) -> Mat3 {
        Mat3::from_rows(self.col(0), self.col(1), self.col(2))
    }

    #[inline]
    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }

    pub fn mul_mat(&self, o: &Mat3) -> Mat3 {
        let mut out = [[0.0f32; 3]; 3];
        for (i, row) in out.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = self.row(i).dot(o.col(j));
            }
        }
        Mat3 { m: out }
    }

    /// `diag(d)` scaling matrix.
    #[inline]
    pub fn diag(d: Vec3) -> Mat3 {
        Mat3 {
            m: [[d.x, 0.0, 0.0], [0.0, d.y, 0.0], [0.0, 0.0, d.z]],
        }
    }
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    /// Rigid transform from a rotation block and translation column.
    pub fn from_rt(r: Mat3, t: Vec3) -> Self {
        let mut m = [[0.0f32; 4]; 4];
        for i in 0..3 {
            m[i][..3].copy_from_slice(&r.m[i]);
        }
        m[0][3] = t.x;
        m[1][3] = t.y;
        m[2][3] = t.z;
        m[3][3] = 1.0;
        Mat4 { m }
    }

    #[inline]
    pub fn rotation(&self) -> Mat3 {
        Mat3 {
            m: [
                [self.m[0][0], self.m[0][1], self.m[0][2]],
                [self.m[1][0], self.m[1][1], self.m[1][2]],
                [self.m[2][0], self.m[2][1], self.m[2][2]],
            ],
        }
    }

    #[inline]
    pub fn translation(&self) -> Vec3 {
        Vec3::new(self.m[0][3], self.m[1][3], self.m[2][3])
    }

    /// Transform a point (w = 1).
    #[inline]
    pub fn transform_point(&self, p: Vec3) -> Vec3 {
        self.rotation().mul_vec(p) + self.translation()
    }

    /// Flattened row-major 16 floats (the layout the HLO artifacts take).
    pub fn to_flat(&self) -> [f32; 16] {
        let mut out = [0.0f32; 16];
        for i in 0..4 {
            out[i * 4..i * 4 + 4].copy_from_slice(&self.m[i]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat3_identity_mul() {
        let v = Vec3::new(1.0, -2.0, 3.0);
        assert_eq!(Mat3::IDENTITY.mul_vec(v), v);
        let m = Mat3::from_rows(
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(-1.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
        );
        let mt = m.transpose();
        // Rotation: m * m^T == I.
        let id = m.mul_mat(&mt);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.m[i][j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mat4_transform_point() {
        let r = Mat3::IDENTITY;
        let t = Vec3::new(1.0, 2.0, 3.0);
        let m = Mat4::from_rt(r, t);
        assert_eq!(m.transform_point(Vec3::ZERO), t);
        assert_eq!(m.to_flat()[3], 1.0);
        assert_eq!(m.to_flat()[15], 1.0);
    }
}
