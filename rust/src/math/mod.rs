//! Minimal linear-algebra substrate: vectors, matrices, quaternions,
//! axis-aligned bounding boxes, view frustums and pinhole cameras.
//!
//! Everything is `f32` and mirrors the conventions of the Layer-1/Layer-2
//! python maths exactly (row-major matrices, camera looks down +z, pixel
//! centres at `+0.5`), so the rust CPU reference pipeline and the PJRT
//! artifacts agree numerically.

mod aabb;
mod camera;
mod mat;
mod quat;
mod vec;

pub use aabb::Aabb;
pub use camera::{Camera, Frustum, Intrinsics};
pub use mat::{Mat3, Mat4};
pub use quat::Quat;
pub use vec::{Vec2, Vec3};

/// Numerically safe reciprocal used by the projection path
/// (matches the `1e-6` guard in `python/compile/kernels/ref.py`).
#[inline]
pub fn safe_recip(x: f32) -> f32 {
    let guarded = if x.abs() < 1e-6 { 1e-6 } else { x };
    1.0 / guarded
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safe_recip_guards_zero() {
        assert!(safe_recip(0.0).is_finite());
        assert_eq!(safe_recip(2.0), 0.5);
        // Sign is preserved through the guard only for |x| >= 1e-6.
        assert_eq!(safe_recip(-2.0), -0.5);
    }
}
