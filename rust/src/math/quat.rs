//! Quaternions in (w, x, y, z) order — the same convention as the L1
//! kernels (`quat_to_rotmat` in `python/compile/kernels/ref.py`).

use super::{Mat3, Vec3};

/// Unit-ish quaternion; `to_rotmat` normalizes defensively like the kernel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quat {
    pub w: f32,
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Quat {
    pub const IDENTITY: Quat = Quat { w: 1.0, x: 0.0, y: 0.0, z: 0.0 };

    #[inline]
    pub const fn new(w: f32, x: f32, y: f32, z: f32) -> Self {
        Quat { w, x, y, z }
    }

    /// Axis-angle constructor (axis need not be unit length).
    pub fn from_axis_angle(axis: Vec3, angle: f32) -> Self {
        let a = axis.normalized();
        let (s, c) = (angle * 0.5).sin_cos();
        Quat::new(c, a.x * s, a.y * s, a.z * s)
    }

    #[inline]
    pub fn norm(self) -> f32 {
        (self.w * self.w + self.x * self.x + self.y * self.y + self.z * self.z)
            .sqrt()
    }

    /// Rotation matrix; mirrors the kernel maths bit-for-bit (including
    /// the `1e-12` normalization guard).
    pub fn to_rotmat(self) -> Mat3 {
        let n = self.norm() + 1e-12;
        let (w, x, y, z) = (self.w / n, self.x / n, self.y / n, self.z / n);
        Mat3 {
            m: [
                [
                    1.0 - 2.0 * (y * y + z * z),
                    2.0 * (x * y - w * z),
                    2.0 * (x * z + w * y),
                ],
                [
                    2.0 * (x * y + w * z),
                    1.0 - 2.0 * (x * x + z * z),
                    2.0 * (y * z - w * x),
                ],
                [
                    2.0 * (x * z - w * y),
                    2.0 * (y * z + w * x),
                    1.0 - 2.0 * (x * x + y * y),
                ],
            ],
        }
    }

    #[inline]
    pub fn to_array(self) -> [f32; 4] {
        [self.w, self.x, self.y, self.z]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f32::consts::FRAC_PI_2;

    #[test]
    fn identity_is_noop() {
        let m = Quat::IDENTITY.to_rotmat();
        let v = Vec3::new(1.0, 2.0, 3.0);
        let got = m.mul_vec(v);
        assert!((got - v).length() < 1e-5);
    }

    #[test]
    fn z_quarter_turn() {
        let q = Quat::from_axis_angle(Vec3::new(0.0, 0.0, 1.0), FRAC_PI_2);
        let got = q.to_rotmat().mul_vec(Vec3::new(1.0, 0.0, 0.0));
        assert!((got - Vec3::new(0.0, 1.0, 0.0)).length() < 1e-5);
    }

    #[test]
    fn rotmat_is_orthonormal_for_unnormalized_input() {
        let q = Quat::new(0.3, -1.2, 0.4, 2.0); // deliberately unnormalized
        let m = q.to_rotmat();
        let id = m.mul_mat(&m.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.m[i][j] - want).abs() < 1e-4);
            }
        }
    }
}
