//! Pinhole camera, view matrices and frustum culling.
//!
//! Conventions (shared with `python/compile/kernels/ref.py`):
//! camera looks down **+z** in camera space, `viewmat` is row-major
//! world->camera, intrinsics are `(fx, fy, cx, cy)` in pixels.

use super::{Aabb, Mat3, Mat4, Vec3};

/// Pinhole intrinsics in pixels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Intrinsics {
    pub fx: f32,
    pub fy: f32,
    pub cx: f32,
    pub cy: f32,
    pub width: u32,
    pub height: u32,
}

impl Intrinsics {
    /// Square image with a given vertical field of view (radians).
    pub fn from_fov(width: u32, height: u32, fov_y: f32) -> Self {
        let fy = height as f32 * 0.5 / (fov_y * 0.5).tan();
        Intrinsics {
            fx: fy,
            fy,
            cx: width as f32 * 0.5,
            cy: height as f32 * 0.5,
            width,
            height,
        }
    }

    #[inline]
    pub fn to_array(&self) -> [f32; 4] {
        [self.fx, self.fy, self.cx, self.cy]
    }
}

/// A posed pinhole camera.
#[derive(Clone, Copy, Debug)]
pub struct Camera {
    pub view: Mat4,
    pub intr: Intrinsics,
    /// Near plane distance (camera-space z); matches the kernels' 0.2 cull.
    pub near: f32,
    pub far: f32,
}

impl Camera {
    /// Look-at constructor (matches `lookat_viewmat` in the python tests).
    pub fn look_at(eye: Vec3, target: Vec3, up: Vec3, intr: Intrinsics) -> Self {
        let fwd = (target - eye).normalized();
        let right = fwd.cross(up).normalized();
        let true_up = right.cross(fwd);
        let r = Mat3::from_rows(right, true_up, fwd);
        let t = -r.mul_vec(eye);
        Camera { view: Mat4::from_rt(r, t), intr, near: 0.2, far: 1.0e4 }
    }

    /// Camera position in world space.
    pub fn eye(&self) -> Vec3 {
        let r = self.view.rotation();
        -r.transpose().mul_vec(self.view.translation())
    }

    /// World -> camera.
    #[inline]
    pub fn to_camera(&self, p: Vec3) -> Vec3 {
        self.view.transform_point(p)
    }

    /// Camera-space depth of a world point.
    #[inline]
    pub fn depth(&self, p: Vec3) -> f32 {
        self.to_camera(p).z
    }

    /// The view frustum for culling.
    pub fn frustum(&self) -> Frustum {
        Frustum::from_camera(self)
    }

    /// Projected screen-space size (pixels) of a world-space extent
    /// `world_size` at depth `z` — the paper's "projected dimension" used
    /// by the LoD test. Conservative: uses max(fx, fy).
    #[inline]
    pub fn projected_size(&self, world_size: f32, z: f32) -> f32 {
        let f = self.intr.fx.max(self.intr.fy);
        if z <= self.near {
            f32::INFINITY
        } else {
            f * world_size / z
        }
    }
}

/// Frustum as 5 inward-facing planes (near + 4 sides) in world space.
/// `far` is handled by the LoD cut itself (distant nodes collapse to a
/// single coarse Gaussian) — matching the paper's traversal which never
/// far-culls explicitly.
#[derive(Clone, Copy, Debug)]
pub struct Frustum {
    /// (normal, offset): a point p is inside iff `n.dot(p) + d >= 0`.
    pub planes: [(Vec3, f32); 5],
}

impl Frustum {
    pub fn from_camera(cam: &Camera) -> Self {
        let r = cam.view.rotation();
        let eye = cam.eye();
        // Camera basis in world space.
        let right = r.row(0);
        let up = r.row(1);
        let fwd = r.row(2);

        let hw = cam.intr.width as f32 * 0.5 / cam.intr.fx;
        let hh = cam.intr.height as f32 * 0.5 / cam.intr.fy;

        // Side-plane normals: rotate `fwd` toward each image edge.
        let nl = (fwd * hw + right).normalized(); // left plane keeps +right side
        let nr = (fwd * hw - right).normalized();
        let nt = (fwd * hh + up).normalized();
        let nb = (fwd * hh - up).normalized();
        let near_n = fwd;
        let mk = |n: Vec3, p: Vec3| (n, -n.dot(p));
        Frustum {
            planes: [
                mk(near_n, eye + fwd * cam.near),
                mk(nl, eye),
                mk(nr, eye),
                mk(nt, eye),
                mk(nb, eye),
            ],
        }
    }

    /// Conservative AABB-frustum test (box accepted if it is not fully
    /// outside any plane) — exactly what the LT unit evaluates per node.
    pub fn intersects_aabb(&self, b: &Aabb) -> bool {
        let c = b.center();
        let h = b.half_extent();
        for (n, d) in &self.planes {
            // Projection radius of the box onto the plane normal.
            let r = h.x * n.x.abs() + h.y * n.y.abs() + h.z * n.z.abs();
            if n.dot(c) + d + r < 0.0 {
                return false;
            }
        }
        true
    }

    /// [`Frustum::intersects_aabb`] plus the verdict's *margin*: the
    /// smallest plane slack `n.dot(c) + d + r` over all planes when the
    /// box is accepted, or the magnitude of the first failing plane's
    /// (negative) slack when it is rejected. The boolean evaluates the
    /// exact same expressions in the same short-circuit order as
    /// `intersects_aabb`, so it is bit-identical to it — the margin is
    /// side information for the cut cache's conservative verdict bounds
    /// ([`crate::lod::CutCache`]), which skip re-tests while the camera
    /// delta provably cannot move any slack across zero.
    pub fn intersects_aabb_margin(&self, b: &Aabb) -> (bool, f32) {
        let c = b.center();
        let h = b.half_extent();
        let mut margin = f32::INFINITY;
        for (n, d) in &self.planes {
            let r = h.x * n.x.abs() + h.y * n.y.abs() + h.z * n.z.abs();
            let slack = n.dot(c) + d + r;
            if slack < 0.0 {
                return (false, -slack);
            }
            margin = margin.min(slack);
        }
        (true, margin)
    }

    #[inline]
    pub fn contains_point(&self, p: Vec3) -> bool {
        self.planes.iter().all(|(n, d)| n.dot(p) + d >= 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, -10.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            Intrinsics::from_fov(256, 256, 60f32.to_radians()),
        )
    }

    #[test]
    fn eye_roundtrip() {
        let cam = test_cam();
        assert!((cam.eye() - Vec3::new(0.0, 0.0, -10.0)).length() < 1e-4);
        // Target is 10 units in front of the camera.
        assert!((cam.depth(Vec3::ZERO) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn frustum_accepts_center_rejects_behind() {
        let cam = test_cam();
        let f = cam.frustum();
        assert!(f.contains_point(Vec3::ZERO));
        assert!(!f.contains_point(Vec3::new(0.0, 0.0, -20.0))); // behind eye
        let visible = Aabb::from_center_half(Vec3::ZERO, Vec3::splat(1.0));
        let behind =
            Aabb::from_center_half(Vec3::new(0.0, 0.0, -30.0), Vec3::splat(1.0));
        assert!(f.intersects_aabb(&visible));
        assert!(!f.intersects_aabb(&behind));
    }

    #[test]
    fn frustum_rejects_far_side() {
        let cam = test_cam();
        let f = cam.frustum();
        // 60 deg fov at depth 10 -> half-width ~5.8; x=100 is far outside.
        assert!(!f.contains_point(Vec3::new(100.0, 0.0, 0.0)));
        // A huge AABB overlapping the frustum must be accepted.
        let huge = Aabb::from_center_half(
            Vec3::new(100.0, 0.0, 0.0),
            Vec3::splat(120.0),
        );
        assert!(f.intersects_aabb(&huge));
    }

    #[test]
    fn margin_variant_agrees_with_plain_intersection_test() {
        let cam = test_cam();
        let f = cam.frustum();
        let mut rejected = 0;
        for i in -4..=4 {
            for j in -4..=4 {
                for k in -4..=4 {
                    let c = Vec3::new(i as f32, j as f32, k as f32) * 7.0;
                    let b = Aabb::from_center_half(c, Vec3::splat(1.5));
                    let (hit, margin) = f.intersects_aabb_margin(&b);
                    assert_eq!(hit, f.intersects_aabb(&b), "at {c:?}");
                    assert!(margin >= 0.0, "margin is a magnitude at {c:?}");
                    rejected += u32::from(!hit);
                }
            }
        }
        assert!(rejected > 0, "grid must exercise the rejection path");
    }

    #[test]
    fn projected_size_shrinks_with_depth() {
        let cam = test_cam();
        let near = cam.projected_size(1.0, 5.0);
        let far = cam.projected_size(1.0, 50.0);
        assert!(near > far);
        assert!(cam.projected_size(1.0, 0.0).is_infinite());
    }
}
