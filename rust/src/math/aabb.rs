//! Axis-aligned bounding boxes — the per-node geometry the LT unit tests
//! against the view frustum during SLTree traversal (paper Sec. IV-B).

use super::Vec3;

/// Closed axis-aligned box `[min, max]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub min: Vec3,
    pub max: Vec3,
}

impl Aabb {
    /// An empty box (min > max); the identity for [`Aabb::union`].
    pub const EMPTY: Aabb = Aabb {
        min: Vec3 { x: f32::INFINITY, y: f32::INFINITY, z: f32::INFINITY },
        max: Vec3 {
            x: f32::NEG_INFINITY,
            y: f32::NEG_INFINITY,
            z: f32::NEG_INFINITY,
        },
    };

    #[inline]
    pub fn new(min: Vec3, max: Vec3) -> Self {
        Aabb { min, max }
    }

    /// Box centred at `c` with half-extent `h` per axis.
    #[inline]
    pub fn from_center_half(c: Vec3, h: Vec3) -> Self {
        Aabb { min: c - h, max: c + h }
    }

    #[inline]
    pub fn center(&self) -> Vec3 {
        (self.min + self.max) * 0.5
    }

    #[inline]
    pub fn half_extent(&self) -> Vec3 {
        (self.max - self.min) * 0.5
    }

    #[inline]
    pub fn union(&self, o: &Aabb) -> Aabb {
        Aabb { min: self.min.min(o.min), max: self.max.max(o.max) }
    }

    #[inline]
    pub fn grow(&mut self, p: Vec3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    #[inline]
    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.min.x
            && p.x <= self.max.x
            && p.y >= self.min.y
            && p.y <= self.max.y
            && p.z >= self.min.z
            && p.z <= self.max.z
    }

    /// Longest edge — the "projected dimension" proxy scales from this.
    #[inline]
    pub fn longest_edge(&self) -> f32 {
        (self.max - self.min).max_component()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_and_contains() {
        let a = Aabb::new(Vec3::ZERO, Vec3::ONE);
        let b = Aabb::new(Vec3::splat(2.0), Vec3::splat(3.0));
        let u = a.union(&b);
        assert!(u.contains(Vec3::splat(0.5)));
        assert!(u.contains(Vec3::splat(2.5)));
        assert!(!a.contains(Vec3::splat(2.5)));
        assert_eq!(u.longest_edge(), 3.0);
    }

    #[test]
    fn empty_union_identity() {
        let a = Aabb::new(Vec3::ZERO, Vec3::ONE);
        let u = Aabb::EMPTY.union(&a);
        assert_eq!(u, a);
        assert!(Aabb::EMPTY.is_empty());
        assert!(!u.is_empty());
    }

    #[test]
    fn grow_expands() {
        let mut b = Aabb::EMPTY;
        b.grow(Vec3::new(1.0, -1.0, 0.0));
        b.grow(Vec3::new(-1.0, 1.0, 2.0));
        assert_eq!(b.min, Vec3::new(-1.0, -1.0, 0.0));
        assert_eq!(b.max, Vec3::new(1.0, 1.0, 2.0));
        assert_eq!(b.center(), Vec3::new(0.0, 0.0, 1.0));
    }
}
