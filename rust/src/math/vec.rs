//! 2D/3D vector types.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A 3-component `f32` vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

/// A 2-component `f32` vector (screen space).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };
    pub const ONE: Vec3 = Vec3 { x: 1.0, y: 1.0, z: 1.0 };

    #[inline]
    pub const fn new(x: f32, y: f32, z: f32) -> Self {
        Vec3 { x, y, z }
    }

    #[inline]
    pub fn splat(v: f32) -> Self {
        Vec3::new(v, v, v)
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn length_sq(self) -> f32 {
        self.dot(self)
    }

    #[inline]
    pub fn length(self) -> f32 {
        self.length_sq().sqrt()
    }

    /// Unit vector; returns +x for a (near-)zero input rather than NaN.
    #[inline]
    pub fn normalized(self) -> Vec3 {
        let len = self.length();
        if len < 1e-12 {
            Vec3::new(1.0, 0.0, 0.0)
        } else {
            self / len
        }
    }

    #[inline]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    #[inline]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    #[inline]
    pub fn max_component(self) -> f32 {
        self.x.max(self.y).max(self.z)
    }

    #[inline]
    pub fn to_array(self) -> [f32; 3] {
        [self.x, self.y, self.z]
    }

    #[inline]
    pub fn from_array(a: [f32; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }
}

impl Vec2 {
    #[inline]
    pub const fn new(x: f32, y: f32) -> Self {
        Vec2 { x, y }
    }

    #[inline]
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    #[inline]
    pub fn length(self) -> f32 {
        self.dot(self).sqrt()
    }
}

macro_rules! impl_binops {
    ($t:ty, $($f:ident),+) => {
        impl Add for $t {
            type Output = $t;
            #[inline]
            fn add(self, o: $t) -> $t { Self { $($f: self.$f + o.$f),+ } }
        }
        impl Sub for $t {
            type Output = $t;
            #[inline]
            fn sub(self, o: $t) -> $t { Self { $($f: self.$f - o.$f),+ } }
        }
        impl Mul<f32> for $t {
            type Output = $t;
            #[inline]
            fn mul(self, s: f32) -> $t { Self { $($f: self.$f * s),+ } }
        }
        impl Mul<$t> for $t {
            type Output = $t;
            #[inline]
            fn mul(self, o: $t) -> $t { Self { $($f: self.$f * o.$f),+ } }
        }
        impl Div<f32> for $t {
            type Output = $t;
            #[inline]
            fn div(self, s: f32) -> $t { Self { $($f: self.$f / s),+ } }
        }
        impl Neg for $t {
            type Output = $t;
            #[inline]
            fn neg(self) -> $t { Self { $($f: -self.$f),+ } }
        }
        impl AddAssign for $t {
            #[inline]
            fn add_assign(&mut self, o: $t) { $(self.$f += o.$f;)+ }
        }
    };
}

impl_binops!(Vec3, x, y, z);
impl_binops!(Vec2, x, y);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_is_orthogonal() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-4.0, 0.5, 2.0);
        let c = a.cross(b);
        assert!(c.dot(a).abs() < 1e-5);
        assert!(c.dot(b).abs() < 1e-5);
    }

    #[test]
    fn normalize_zero_is_finite() {
        let v = Vec3::ZERO.normalized();
        assert!((v.length() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn vec_ops() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        assert_eq!(a + a, a * 2.0);
        assert_eq!(a - a, Vec3::ZERO);
        assert_eq!((a / 2.0).x, 0.5);
        assert_eq!((-a).y, -2.0);
        assert_eq!(a.max_component(), 3.0);
    }
}
