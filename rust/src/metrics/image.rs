//! Simple float RGB image with the helpers the metrics need and a PPM
//! writer for eyeballing renders.

/// RGB image, values nominally in [0,1], row-major.
#[derive(Clone, Debug)]
pub struct Image {
    pub width: u32,
    pub height: u32,
    pub data: Vec<[f32; 3]>,
}

impl Image {
    /// Black image.
    pub fn new(width: u32, height: u32) -> Self {
        Image { width, height, data: vec![[0.0; 3]; (width * height) as usize] }
    }

    #[inline]
    pub fn dims(&self) -> (u32, u32) {
        (self.width, self.height)
    }

    #[inline]
    pub fn px(&self, x: u32, y: u32) -> [f32; 3] {
        self.data[(y * self.width + x) as usize]
    }

    #[inline]
    pub fn set(&mut self, x: u32, y: u32, v: [f32; 3]) {
        self.data[(y * self.width + x) as usize] = v;
    }

    /// Rec.601 luma per pixel.
    pub fn luma(&self) -> Vec<f32> {
        self.data
            .iter()
            .map(|p| 0.299 * p[0] + 0.587 * p[1] + 0.114 * p[2])
            .collect()
    }

    /// Gradient magnitude of the luma (forward differences).
    pub fn grad_mag(&self) -> Vec<f32> {
        let l = self.luma();
        let (w, h) = (self.width as usize, self.height as usize);
        let mut g = vec![0.0f32; w * h];
        for y in 0..h {
            for x in 0..w {
                let v = l[y * w + x];
                let gx = if x + 1 < w { l[y * w + x + 1] - v } else { 0.0 };
                let gy = if y + 1 < h { l[(y + 1) * w + x] - v } else { 0.0 };
                g[y * w + x] = gx.hypot(gy);
            }
        }
        g
    }

    /// 2x box downsample (floor dims).
    pub fn downsample2x(&self) -> Image {
        let w = (self.width / 2).max(1);
        let h = (self.height / 2).max(1);
        let mut out = Image::new(w, h);
        for y in 0..h as usize {
            for x in 0..w as usize {
                let mut acc = [0.0f32; 3];
                let mut cnt = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let sx = (x * 2 + dx).min(self.width as usize - 1) as u32;
                        let sy: u32 = (y * 2 + dy).min(self.height as usize - 1) as u32;
                        let p = self.px(sx, sy);
                        for c in 0..3 {
                            acc[c] += p[c];
                        }
                        cnt += 1.0;
                    }
                }
                out.set(x as u32, y as u32, [acc[0] / cnt, acc[1] / cnt, acc[2] / cnt]);
            }
        }
        out
    }

    /// Write a binary PPM (P6) for inspection.
    pub fn write_ppm(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "P6\n{} {}\n255\n", self.width, self.height)?;
        for p in &self.data {
            let to8 = |v: f32| (v.clamp(0.0, 1.0) * 255.0).round() as u8;
            f.write_all(&[to8(p[0]), to8(p[1]), to8(p[2])])?;
        }
        Ok(())
    }

    /// Quantize to 8-bit RGBA bytes (opaque alpha), row-major — the
    /// buffer shape clients and the golden-frame tests consume.
    pub fn to_rgba8(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for p in &self.data {
            for c in 0..3 {
                out.push((p[c].clamp(0.0, 1.0) * 255.0 + 0.5) as u8);
            }
            out.push(255);
        }
        out
    }

    /// FNV-1a (64-bit) digest over the frame dimensions plus the
    /// quantized RGBA bytes — the golden-frame fingerprint that
    /// `rust/tests/golden.rs` pins against checked-in digests.
    pub fn fnv1a64(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let dims = self.width.to_le_bytes().into_iter().chain(self.height.to_le_bytes());
        for b in dims.chain(self.to_rgba8()) {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        h
    }

    /// Mean absolute difference against another image.
    pub fn mad(&self, o: &Image) -> f64 {
        assert_eq!(self.dims(), o.dims());
        let mut acc = 0.0f64;
        for (a, b) in self.data.iter().zip(o.data.iter()) {
            for c in 0..3 {
                acc += (a[c] - b[c]).abs() as f64;
            }
        }
        acc / (self.data.len() * 3) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut img = Image::new(4, 4);
        img.set(2, 3, [0.5, 0.25, 1.0]);
        assert_eq!(img.px(2, 3), [0.5, 0.25, 1.0]);
        assert_eq!(img.px(0, 0), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn downsample_halves_dims_and_averages() {
        let mut img = Image::new(4, 4);
        for y in 0..4 {
            for x in 0..4 {
                img.set(x, y, [if (x + y) % 2 == 0 { 1.0 } else { 0.0 }; 3]);
            }
        }
        let d = img.downsample2x();
        assert_eq!(d.dims(), (2, 2));
        // Checkerboard averages to 0.5 everywhere.
        for p in &d.data {
            assert!((p[0] - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_of_flat_image_is_zero() {
        let img = Image::new(8, 8);
        assert!(img.grad_mag().iter().all(|&g| g == 0.0));
    }

    #[test]
    fn fnv_digest_is_stable_and_sensitive() {
        let mut img = Image::new(4, 4);
        let base = img.fnv1a64();
        assert_eq!(base, img.fnv1a64(), "digest not deterministic");
        img.set(1, 1, [0.5, 0.0, 0.0]);
        assert_ne!(base, img.fnv1a64(), "pixel change must move the digest");
        // Same pixel payload, different shape -> different digest.
        assert_ne!(Image::new(4, 4).fnv1a64(), Image::new(2, 8).fnv1a64());
        assert_eq!(img.to_rgba8().len(), 4 * 4 * 4);
        assert!(img.to_rgba8().chunks(4).all(|px| px[3] == 255));
    }

    #[test]
    fn ppm_writes_header_and_payload() {
        let img = Image::new(3, 2);
        let dir = std::env::temp_dir().join("sltarch_test_ppm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ppm");
        img.write_ppm(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n3 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 18);
    }
}
