//! Image-quality metrics for Table I: PSNR, SSIM and a perceptual proxy
//! for LPIPS.
//!
//! LPIPS proper requires a pretrained VGG/AlexNet which is unavailable
//! offline; `lpips_proxy` substitutes a multi-scale gradient-similarity
//! distance (documented in DESIGN.md §2). Table I's *claim* — SLTarch's
//! group-alpha approximation degrades quality only marginally vs the
//! canonical renderer — is preserved under any sane perceptual distance.

mod image;

pub use image::Image;

/// Peak signal-to-noise ratio in dB over RGB in [0,1].
/// Returns +inf for identical images.
pub fn psnr(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.dims(), b.dims(), "psnr: image dims differ");
    let n = (a.width * a.height * 3) as f64;
    let mut se = 0.0f64;
    for (pa, pb) in a.data.iter().zip(b.data.iter()) {
        for c in 0..3 {
            let d = (pa[c] - pb[c]) as f64;
            se += d * d;
        }
    }
    if se == 0.0 {
        return f64::INFINITY;
    }
    let mse = se / n;
    10.0 * (1.0 / mse).log10()
}

/// Mean SSIM over 8x8 windows on the luma channel (standard constants
/// k1=0.01, k2=0.03, L=1).
pub fn ssim(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.dims(), b.dims(), "ssim: image dims differ");
    let la = a.luma();
    let lb = b.luma();
    let (w, h) = (a.width as usize, a.height as usize);
    const WIN: usize = 8;
    const C1: f64 = 0.01 * 0.01;
    const C2: f64 = 0.03 * 0.03;
    let mut total = 0.0;
    let mut windows = 0usize;
    let mut wy = 0;
    while wy + WIN <= h {
        let mut wx = 0;
        while wx + WIN <= w {
            let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0.0, 0.0, 0.0, 0.0, 0.0);
            for y in wy..wy + WIN {
                for x in wx..wx + WIN {
                    let va = la[y * w + x] as f64;
                    let vb = lb[y * w + x] as f64;
                    sa += va;
                    sb += vb;
                    saa += va * va;
                    sbb += vb * vb;
                    sab += va * vb;
                }
            }
            let n = (WIN * WIN) as f64;
            let mu_a = sa / n;
            let mu_b = sb / n;
            let var_a = (saa / n - mu_a * mu_a).max(0.0);
            let var_b = (sbb / n - mu_b * mu_b).max(0.0);
            let cov = sab / n - mu_a * mu_b;
            let s = ((2.0 * mu_a * mu_b + C1) * (2.0 * cov + C2))
                / ((mu_a * mu_a + mu_b * mu_b + C1) * (var_a + var_b + C2));
            total += s;
            windows += 1;
            wx += WIN;
        }
        wy += WIN;
    }
    if windows == 0 {
        1.0
    } else {
        total / windows as f64
    }
}

/// Perceptual-distance proxy for LPIPS: mean absolute difference of
/// luma gradients across 3 dyadic scales (0 = identical; larger = more
/// perceptually different). Correlates with LPIPS on blur/structure
/// errors, which is the failure mode the group-alpha approximation has.
pub fn lpips_proxy(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.dims(), b.dims(), "lpips_proxy: image dims differ");
    let mut total = 0.0;
    let mut scales = 0.0;
    let mut ia = a.clone();
    let mut ib = b.clone();
    for _ in 0..3 {
        let ga = ia.grad_mag();
        let gb = ib.grad_mag();
        let n = ga.len().max(1);
        let d: f64 = ga
            .iter()
            .zip(gb.iter())
            .map(|(x, y)| (x - y).abs() as f64)
            .sum::<f64>()
            / n as f64;
        total += d;
        scales += 1.0;
        if ia.width <= 16 || ia.height <= 16 {
            break;
        }
        ia = ia.downsample2x();
        ib = ib.downsample2x();
    }
    total / scales
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn noise_image(seed: u64, w: u32, h: u32) -> Image {
        let mut rng = Rng::new(seed);
        let mut img = Image::new(w, h);
        for p in img.data.iter_mut() {
            *p = [rng.f32(), rng.f32(), rng.f32()];
        }
        img
    }

    fn perturb(img: &Image, eps: f32, seed: u64) -> Image {
        let mut rng = Rng::new(seed);
        let mut out = img.clone();
        for p in out.data.iter_mut() {
            for c in p.iter_mut() {
                *c = (*c + rng.range(-eps, eps)).clamp(0.0, 1.0);
            }
        }
        out
    }

    #[test]
    fn psnr_identical_is_infinite() {
        let a = noise_image(1, 64, 64);
        assert!(psnr(&a, &a).is_infinite());
        assert_eq!(ssim(&a, &a), 1.0);
        assert_eq!(lpips_proxy(&a, &a), 0.0);
    }

    #[test]
    fn metrics_order_by_error_magnitude() {
        let a = noise_image(2, 64, 64);
        let slight = perturb(&a, 0.01, 3);
        let heavy = perturb(&a, 0.2, 4);
        assert!(psnr(&a, &slight) > psnr(&a, &heavy));
        assert!(ssim(&a, &slight) > ssim(&a, &heavy));
        assert!(lpips_proxy(&a, &slight) < lpips_proxy(&a, &heavy));
    }

    #[test]
    fn psnr_known_value() {
        // Uniform 0.1 error on one channel: mse = 0.01/3.
        let a = Image::new(8, 8);
        let mut b = Image::new(8, 8);
        for p in b.data.iter_mut() {
            p[0] = 0.1;
        }
        let want = 10.0 * (3.0 / 0.01f64).log10();
        assert!((psnr(&a, &b) - want).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "dims differ")]
    fn dim_mismatch_panics() {
        let a = Image::new(8, 8);
        let b = Image::new(4, 4);
        psnr(&a, &b);
    }
}
