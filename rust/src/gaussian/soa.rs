//! SoA Gaussian storage.

use crate::math::{Aabb, Quat, Vec3};

/// A structure-of-arrays batch of 3D Gaussians.
///
/// Field layouts match the flat `f32` buffers the PJRT `project_n256`
/// artifact takes: `means` is `N x 3` row-major, `scales` `N x 3`,
/// `quats` `N x 4` in `(w,x,y,z)` order, `colors` `N x 3`, `opacity` `N`.
#[derive(Clone, Debug, Default)]
pub struct Gaussians {
    pub means: Vec<[f32; 3]>,
    pub scales: Vec<[f32; 3]>,
    pub quats: Vec<[f32; 4]>,
    pub colors: Vec<[f32; 3]>,
    pub opacity: Vec<f32>,
}

impl Gaussians {
    pub fn with_capacity(n: usize) -> Self {
        Gaussians {
            means: Vec::with_capacity(n),
            scales: Vec::with_capacity(n),
            quats: Vec::with_capacity(n),
            colors: Vec::with_capacity(n),
            opacity: Vec::with_capacity(n),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.means.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.means.is_empty()
    }

    /// Append one Gaussian; returns its index.
    pub fn push(
        &mut self,
        mean: Vec3,
        scale: Vec3,
        quat: Quat,
        color: [f32; 3],
        opacity: f32,
    ) -> usize {
        self.means.push(mean.to_array());
        self.scales.push(scale.to_array());
        self.quats.push(quat.to_array());
        self.colors.push(color);
        self.opacity.push(opacity);
        self.means.len() - 1
    }

    #[inline]
    pub fn mean(&self, i: usize) -> Vec3 {
        Vec3::from_array(self.means[i])
    }

    #[inline]
    pub fn scale(&self, i: usize) -> Vec3 {
        Vec3::from_array(self.scales[i])
    }

    #[inline]
    pub fn quat(&self, i: usize) -> Quat {
        let q = self.quats[i];
        Quat::new(q[0], q[1], q[2], q[3])
    }

    /// Conservative world-space AABB of Gaussian `i` at `k` standard
    /// deviations (`k = 3` bounds >99.7% of its mass per axis).
    pub fn aabb(&self, i: usize, k: f32) -> Aabb {
        // Half-extent of the rotated ellipsoid along each world axis:
        // h_a = k * sqrt(sum_j (R[a][j] * s_j)^2).
        let r = self.quat(i).to_rotmat();
        let s = self.scale(i);
        let h = Vec3::new(
            (r.m[0][0] * s.x).hypot(r.m[0][1] * s.y).hypot(r.m[0][2] * s.z),
            (r.m[1][0] * s.x).hypot(r.m[1][1] * s.y).hypot(r.m[1][2] * s.z),
            (r.m[2][0] * s.x).hypot(r.m[2][1] * s.y).hypot(r.m[2][2] * s.z),
        ) * k;
        Aabb::from_center_half(self.mean(i), h)
    }

    /// Gather a subset by index into a new batch (rendering-queue build).
    pub fn gather(&self, idx: &[u32]) -> Gaussians {
        let mut out = Gaussians::with_capacity(idx.len());
        self.gather_into(idx, &mut out);
        out
    }

    /// Gather a subset by index into a reusable batch — the per-frame
    /// rendering-queue build without [`Gaussians::gather`]'s five
    /// allocations once the buffers are warm (sessions call this every
    /// frame with their own queue buffer).
    pub fn gather_into(&self, idx: &[u32], out: &mut Gaussians) {
        out.means.clear();
        out.scales.clear();
        out.quats.clear();
        out.colors.clear();
        out.opacity.clear();
        out.means.reserve(idx.len());
        out.scales.reserve(idx.len());
        out.quats.reserve(idx.len());
        out.colors.reserve(idx.len());
        out.opacity.reserve(idx.len());
        for &i in idx {
            let i = i as usize;
            out.means.push(self.means[i]);
            out.scales.push(self.scales[i]);
            out.quats.push(self.quats[i]);
            out.colors.push(self.colors[i]);
            out.opacity.push(self.opacity[i]);
        }
    }

    /// Flat row-major buffers for the PJRT artifacts (padded to `n`).
    pub fn to_flat_padded(&self, n: usize) -> FlatGaussians {
        assert!(self.len() <= n);
        let mut f = FlatGaussians {
            means: vec![0.0; n * 3],
            scales: vec![1e-6; n * 3], // degenerate-but-valid padding
            quats: vec![0.0; n * 4],
            n_real: self.len(),
        };
        for i in 0..self.len() {
            f.means[i * 3..i * 3 + 3].copy_from_slice(&self.means[i]);
            f.scales[i * 3..i * 3 + 3].copy_from_slice(&self.scales[i]);
            f.quats[i * 4..i * 4 + 4].copy_from_slice(&self.quats[i]);
        }
        // Identity quats on padding rows keep the kernel maths finite.
        for i in self.len()..n {
            f.quats[i * 4] = 1.0;
        }
        f
    }
}

/// Flat padded buffers ready for `Literal::vec1(...).reshape(...)`.
pub struct FlatGaussians {
    pub means: Vec<f32>,
    pub scales: Vec<f32>,
    pub quats: Vec<f32>,
    pub n_real: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Gaussians {
        let mut g = Gaussians::default();
        g.push(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::splat(0.5),
            Quat::IDENTITY,
            [1.0, 0.0, 0.0],
            0.9,
        );
        g.push(
            Vec3::new(-1.0, 0.0, 1.0),
            Vec3::new(0.1, 0.2, 0.3),
            Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), 0.7),
            [0.0, 1.0, 0.0],
            0.5,
        );
        g
    }

    #[test]
    fn push_and_access() {
        let g = sample();
        assert_eq!(g.len(), 2);
        assert_eq!(g.mean(0), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(g.quat(0).w, 1.0);
        assert_eq!(g.opacity[1], 0.5);
    }

    #[test]
    fn aabb_contains_mean_and_scales_with_k() {
        let g = sample();
        let b1 = g.aabb(1, 1.0);
        let b3 = g.aabb(1, 3.0);
        assert!(b1.contains(g.mean(1)));
        assert!(b3.half_extent().x > b1.half_extent().x);
        // Axis-aligned identity Gaussian: half extent == k * scale.
        let b = g.aabb(0, 3.0);
        assert!((b.half_extent().x - 1.5).abs() < 1e-5);
    }

    #[test]
    fn gather_preserves_order() {
        let g = sample();
        let sub = g.gather(&[1, 0]);
        assert_eq!(sub.mean(0), g.mean(1));
        assert_eq!(sub.mean(1), g.mean(0));
    }

    #[test]
    fn gather_into_reuse_matches_fresh_gather() {
        let g = sample();
        let mut reused = Gaussians::default();
        // Shrinking, growing and duplicate index sets through one
        // buffer must always equal a fresh gather.
        for idx in [vec![1u32, 0], vec![0], vec![1, 1, 0, 1], vec![]] {
            g.gather_into(&idx, &mut reused);
            let fresh = g.gather(&idx);
            assert_eq!(reused.len(), fresh.len());
            assert_eq!(reused.means, fresh.means);
            assert_eq!(reused.scales, fresh.scales);
            assert_eq!(reused.quats, fresh.quats);
            assert_eq!(reused.colors, fresh.colors);
            assert_eq!(reused.opacity, fresh.opacity);
        }
    }

    #[test]
    fn flat_padding_is_valid() {
        let g = sample();
        let f = g.to_flat_padded(4);
        assert_eq!(f.means.len(), 12);
        assert_eq!(f.quats.len(), 16);
        assert_eq!(f.n_real, 2);
        // Padding quats are identity (w=1).
        assert_eq!(f.quats[2 * 4], 1.0);
        assert_eq!(f.quats[3 * 4], 1.0);
    }
}
