//! Gaussian primitive storage and the CPU mirror of the L1 maths.
//!
//! [`Gaussians`] is the SoA store the whole pipeline shares (the exact
//! flat layout the HLO artifacts consume); [`project`] mirrors the Pallas
//! projection kernel so simulators, the CPU renderer and the PJRT path
//! agree numerically.

mod projection;
mod soa;

pub use projection::{
    project, project_into, project_into_threaded, project_one, Splat2D,
};
pub use soa::Gaussians;

/// Blending constants shared with `python/compile/kernels/ref.py`.
pub const ALPHA_THRESH: f32 = 1.0 / 255.0;
pub const ALPHA_CLAMP: f32 = 0.99;
pub const COV2D_DILATION: f32 = 0.3;
/// Behind-camera cull depth (matches the kernels' `tz > 0.2`).
pub const NEAR_CULL: f32 = 0.2;
