//! CPU mirror of the L1 projection kernel (EWA splatting).
//!
//! Must stay numerically in lock-step with
//! `python/compile/kernels/project.py`; the integration test
//! `rust/tests/pjrt_roundtrip.rs` asserts allclose between this code and
//! the compiled artifact.

use super::{Gaussians, COV2D_DILATION, NEAR_CULL};
use crate::math::{safe_recip, Camera, Vec2};
use crate::splat::group_keep_threshold;

/// One projected (screen-space) Gaussian.
#[derive(Clone, Copy, Debug)]
pub struct Splat2D {
    /// Pixel-space centre.
    pub mean: Vec2,
    /// Inverse 2D covariance `(a, b, c)`:
    /// `power = -0.5*(a dx^2 + c dy^2) - b dx dy`.
    pub conic: [f32; 3],
    /// Camera-space depth.
    pub depth: f32,
    /// 3-sigma screen radius in pixels; 0 means culled.
    pub radius: f32,
    /// RGB colour (copied through for the splatting stage).
    pub color: [f32; 3],
    /// Base opacity.
    pub opacity: f32,
    /// Cached no-exp group-keep threshold —
    /// [`group_keep_threshold`]`(opacity)`, hoisted here at projection
    /// time so the blend kernels amortize the bit-space bisection
    /// across every tile the splat touches instead of re-deriving it
    /// per (splat, tile). Invariant (proptest-pinned): every splat that
    /// can reach a tile bin carries exactly
    /// `group_keep_threshold(opacity)` bit for bit; culled splats may
    /// hold `f32::INFINITY` (keep nothing) without paying for the
    /// bisection. Sites that build splats by literal call
    /// [`Splat2D::with_keep_thresh`] to maintain the invariant.
    pub keep_thresh: f32,
    /// Index into the source rendering queue.
    pub id: u32,
}

impl Default for Splat2D {
    /// Zeroed (culled) splat with `keep_thresh = INFINITY` — the
    /// keep-nothing threshold zero opacity maps to (a derived all-zero
    /// default would wrongly *keep* every `power == 0` group).
    fn default() -> Self {
        Splat2D {
            mean: Vec2::default(),
            conic: [0.0; 3],
            depth: 0.0,
            radius: 0.0,
            color: [0.0; 3],
            opacity: 0.0,
            keep_thresh: f32::INFINITY,
            id: 0,
        }
    }
}

impl Splat2D {
    #[inline]
    pub fn visible(&self) -> bool {
        self.radius > 0.0
    }

    /// Recompute the cached [`keep_thresh`](Splat2D::keep_thresh) from
    /// the current opacity. Literal-construction sites (tests, loaders)
    /// chain this to maintain the cache invariant; the projection paths
    /// fill the field directly.
    #[must_use]
    pub fn with_keep_thresh(mut self) -> Self {
        self.keep_thresh = group_keep_threshold(self.opacity);
        self
    }

    /// Every field as raw bits, in declaration order — the byte-identity
    /// fingerprint the parallel-vs-serial equivalence tests compare
    /// (f32 `==` would conflate `-0.0` and `0.0`; bits do not).
    pub fn bit_pattern(&self) -> [u32; 13] {
        [
            self.mean.x.to_bits(),
            self.mean.y.to_bits(),
            self.conic[0].to_bits(),
            self.conic[1].to_bits(),
            self.conic[2].to_bits(),
            self.depth.to_bits(),
            self.radius.to_bits(),
            self.color[0].to_bits(),
            self.color[1].to_bits(),
            self.color[2].to_bits(),
            self.opacity.to_bits(),
            self.keep_thresh.to_bits(),
            self.id,
        ]
    }
}

/// Project Gaussian `i` of `g` through `cam` (single-Gaussian scalar path).
pub fn project_one(g: &Gaussians, i: usize, cam: &Camera) -> Splat2D {
    let [fx, fy, cx, cy] = cam.intr.to_array();
    let v = &cam.view.m;
    let m = g.means[i];

    // World -> camera.
    let tx = v[0][0] * m[0] + v[0][1] * m[1] + v[0][2] * m[2] + v[0][3];
    let ty = v[1][0] * m[0] + v[1][1] * m[1] + v[1][2] * m[2] + v[1][3];
    let tz = v[2][0] * m[0] + v[2][1] * m[1] + v[2][2] * m[2] + v[2][3];
    let zinv = safe_recip(tz);

    let mean = Vec2::new(fx * tx * zinv + cx, fy * ty * zinv + cy);

    // cov3d = R diag(s^2) R^T.
    let r = g.quat(i).to_rotmat().m;
    let s = g.scales[i];
    let (sx2, sy2, sz2) = (s[0] * s[0], s[1] * s[1], s[2] * s[2]);
    let cov = |a: usize, b: usize| {
        r[a][0] * r[b][0] * sx2 + r[a][1] * r[b][1] * sy2 + r[a][2] * r[b][2] * sz2
    };
    let (c00, c01, c02) = (cov(0, 0), cov(0, 1), cov(0, 2));
    let (c11, c12, c22) = (cov(1, 1), cov(1, 2), cov(2, 2));

    // T = J @ W (2x3), J the perspective Jacobian.
    let zinv2 = zinv * zinv;
    let j00 = fx * zinv;
    let j02 = -fx * tx * zinv2;
    let j11 = fy * zinv;
    let j12 = -fy * ty * zinv2;
    let t0 = [
        j00 * v[0][0] + j02 * v[2][0],
        j00 * v[0][1] + j02 * v[2][1],
        j00 * v[0][2] + j02 * v[2][2],
    ];
    let t1 = [
        j11 * v[1][0] + j12 * v[2][0],
        j11 * v[1][1] + j12 * v[2][1],
        j11 * v[1][2] + j12 * v[2][2],
    ];

    // cov2d = T cov3d T^T (+ EWA dilation).
    let u = [
        c00 * t0[0] + c01 * t0[1] + c02 * t0[2],
        c01 * t0[0] + c11 * t0[1] + c12 * t0[2],
        c02 * t0[0] + c12 * t0[1] + c22 * t0[2],
    ];
    let w = [
        c00 * t1[0] + c01 * t1[1] + c02 * t1[2],
        c01 * t1[0] + c11 * t1[1] + c12 * t1[2],
        c02 * t1[0] + c12 * t1[1] + c22 * t1[2],
    ];
    let a = t0[0] * u[0] + t0[1] * u[1] + t0[2] * u[2] + COV2D_DILATION;
    let b = t1[0] * u[0] + t1[1] * u[1] + t1[2] * u[2];
    let c = t1[0] * w[0] + t1[1] * w[1] + t1[2] * w[2] + COV2D_DILATION;

    let det = a * c - b * b;
    let det_safe = if det <= 1e-12 { 1e-12 } else { det };
    let conic = [c / det_safe, -b / det_safe, a / det_safe];

    let mid = 0.5 * (a + c);
    let lam = mid + (mid * mid - det).max(0.0).sqrt();
    let mut radius = (3.0 * lam.max(0.0).sqrt()).ceil();
    // Degenerate-projection guard: beyond the near/det culls, never
    // emit `radius > 0` with a non-finite mean, conic, depth or radius.
    // Non-finite source data (or a covariance overflowed by huge
    // scales) can push `det` to `+inf` while the conic divides to NaN —
    // without this guard such a splat survives `visible()` and poisons
    // every tile its (infinite) footprint bins into with `exp(NaN)`.
    let finite = mean.x.is_finite()
        && mean.y.is_finite()
        && conic[0].is_finite()
        && conic[1].is_finite()
        && conic[2].is_finite()
        && tz.is_finite()
        && radius.is_finite();
    if !(tz > NEAR_CULL && det > 1e-12 && finite) {
        radius = 0.0;
    }
    // Hoist the group-keep threshold once per splat (the blend kernels
    // read the field per tile touch); culled splats skip the bisection
    // — they can never reach a bin, so keep-nothing is free and exact.
    let keep_thresh = if radius > 0.0 {
        group_keep_threshold(g.opacity[i])
    } else {
        f32::INFINITY
    };

    Splat2D {
        mean,
        conic,
        depth: tz,
        radius,
        color: g.colors[i],
        opacity: g.opacity[i],
        keep_thresh,
        id: i as u32,
    }
}

/// Project a whole batch (CPU path; the PJRT path goes through
/// `runtime::exec::ProjectExe`).
pub fn project(g: &Gaussians, cam: &Camera) -> Vec<Splat2D> {
    let mut out = Vec::new();
    project_into(g, cam, &mut out);
    out
}

/// Project into a reusable buffer — the allocation-lean path the batched
/// frame pipeline uses (no per-frame projection allocation once warm).
pub fn project_into(g: &Gaussians, cam: &Camera, out: &mut Vec<Splat2D>) {
    out.clear();
    out.reserve(g.len());
    out.extend((0..g.len()).map(|i| project_one(g, i, cam)));
}

/// Below this many Gaussians the scoped-thread fan-out costs more than
/// the projection itself, so the chunked path falls back to serial.
const PAR_PROJECT_MIN: usize = 1024;

/// Minimum splats per worker chunk: on wide machines a small frame
/// otherwise fans out into near-empty workers whose spawn cost exceeds
/// their work (fewer, larger chunks — never different output).
const PAR_PROJECT_CHUNK: usize = 256;

/// Chunked multi-threaded [`project_into`]: the rendering queue is split
/// into `threads` contiguous ranges and each range is projected by its
/// own scoped worker writing a disjoint `Splat2D` slice of `out`.
/// [`project_one`] is a pure per-splat function, so the output is
/// byte-identical to the serial path at any thread count.
pub fn project_into_threaded(
    g: &Gaussians,
    cam: &Camera,
    out: &mut Vec<Splat2D>,
    threads: usize,
) {
    let n = g.len();
    if threads <= 1 || n < PAR_PROJECT_MIN {
        project_into(g, cam, out);
        return;
    }
    // Bare resize (no clear): only newly grown tail slots are
    // initialized, and every slot in 0..n is overwritten by exactly one
    // worker below.
    out.resize(n, Splat2D::default());
    let chunk = n.div_ceil(threads).max(PAR_PROJECT_CHUNK);
    std::thread::scope(|s| {
        for (w, slots) in out.chunks_mut(chunk).enumerate() {
            let base = w * chunk;
            s.spawn(move || {
                for (j, slot) in slots.iter_mut().enumerate() {
                    *slot = project_one(g, base + j, cam);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Intrinsics, Quat, Vec3};

    fn cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, -10.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            Intrinsics { fx: 300.0, fy: 300.0, cx: 128.0, cy: 128.0, width: 256, height: 256 },
        )
    }

    fn one_at(p: Vec3) -> Gaussians {
        let mut g = Gaussians::default();
        g.push(p, Vec3::splat(0.3), Quat::IDENTITY, [1.0, 1.0, 1.0], 0.8);
        g
    }

    #[test]
    fn center_projects_to_principal_point() {
        let g = one_at(Vec3::ZERO);
        let s = project_one(&g, 0, &cam());
        assert!((s.mean.x - 128.0).abs() < 1e-3);
        assert!((s.mean.y - 128.0).abs() < 1e-3);
        assert!((s.depth - 10.0).abs() < 1e-4);
        assert!(s.visible());
    }

    #[test]
    fn behind_camera_is_culled() {
        let g = one_at(Vec3::new(0.0, 0.0, -20.0));
        let s = project_one(&g, 0, &cam());
        assert!(!s.visible());
    }

    #[test]
    fn conic_is_isotropic_for_axis_aligned_gaussian() {
        let g = one_at(Vec3::ZERO);
        let s = project_one(&g, 0, &cam());
        // Symmetric setup -> a == c, b == 0.
        assert!((s.conic[0] - s.conic[2]).abs() < 1e-4, "{:?}", s.conic);
        assert!(s.conic[1].abs() < 1e-5);
        assert!(s.radius >= 1.0);
    }

    #[test]
    fn chunked_projection_is_bit_identical_to_serial() {
        // Enough Gaussians to cross PAR_PROJECT_MIN so the scoped
        // workers really run (including behind-camera culled ones).
        let mut g = Gaussians::default();
        for i in 0..2_500u32 {
            let a = i as f32 * 0.37;
            g.push(
                Vec3::new(6.0 * a.cos(), 3.0 * (a * 0.51).sin(), 8.0 * a.sin()),
                Vec3::splat(0.05 + 0.01 * (i % 17) as f32),
                Quat::IDENTITY,
                [0.3, 0.5, 0.7],
                0.6,
            );
        }
        let cam = cam();
        let mut serial = Vec::new();
        project_into(&g, &cam, &mut serial);
        let mut par = Vec::new();
        for threads in [1usize, 2, 3, 8] {
            project_into_threaded(&g, &cam, &mut par, threads);
            assert_eq!(par.len(), serial.len(), "{threads} threads");
            for (a, b) in par.iter().zip(serial.iter()) {
                assert_eq!(a.bit_pattern(), b.bit_pattern(), "{threads} threads");
            }
        }
    }

    #[test]
    fn closer_gaussian_has_larger_radius() {
        let near = project_one(&one_at(Vec3::new(0.0, 0.0, -5.0)), 0, &cam());
        let far = project_one(&one_at(Vec3::new(0.0, 0.0, 8.0)), 0, &cam());
        assert!(near.radius > far.radius);
    }

    #[test]
    fn degenerate_inputs_are_culled_not_emitted() {
        // The projection-side guard: non-finite or overflowing source
        // data must never produce `radius > 0` with a non-finite
        // mean/conic/radius (pre-guard, a covariance overflowed to
        // `det = +inf` could emit an infinite radius + NaN conic).
        let mut degenerate = vec![
            one_at(Vec3::new(f32::NAN, 0.0, 0.0)),
            one_at(Vec3::new(0.0, f32::INFINITY, 0.0)),
            one_at(Vec3::new(0.0, 0.0, f32::NEG_INFINITY)),
            one_at(Vec3::splat(1e30)),
        ];
        // Huge scales overflow cov2d even with a finite mean.
        let mut huge = Gaussians::default();
        huge.push(Vec3::ZERO, Vec3::splat(1e25), Quat::IDENTITY, [1.0; 3], 0.8);
        degenerate.push(huge);
        for (k, g) in degenerate.iter().enumerate() {
            let s = project_one(g, 0, &cam());
            assert!(!s.visible(), "degenerate gaussian {k} not culled");
            assert_eq!(s.keep_thresh, f32::INFINITY, "gaussian {k}");
        }
    }

    #[test]
    fn keep_thresh_is_hoisted_for_visible_splats() {
        let g = one_at(Vec3::ZERO);
        let s = project_one(&g, 0, &cam());
        assert!(s.visible());
        assert_eq!(
            s.keep_thresh.to_bits(),
            crate::splat::group_keep_threshold(s.opacity).to_bits()
        );
        // Literal construction maintains the invariant via the helper.
        let lit = Splat2D { opacity: 0.8, ..Splat2D::default() }.with_keep_thresh();
        assert_eq!(
            lit.keep_thresh.to_bits(),
            crate::splat::group_keep_threshold(0.8).to_bits()
        );
        // The derived-looking default is the keep-nothing threshold.
        assert_eq!(Splat2D::default().keep_thresh, f32::INFINITY);
    }
}
