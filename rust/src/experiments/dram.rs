//! §V-C "DRAM Traffic" — LoD-search DRAM traffic: exhaustive full-tree
//! streaming vs SLTree's frustum-and-cut-bounded traversal.
//!
//! Paper claim: −76.5% (small-scale) and −69.6% (large-scale) on
//! average across scenarios.

use super::{build_pipeline, eval_scenes};
use crate::sim::workload::NODE_BYTES;

pub struct DramResult {
    pub scene: String,
    pub reduction_pct: f64,
}

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> DramResult {
    let p = build_pipeline(cfg, seed);
    let exhaustive = p.scene().tree.len() as u64 * NODE_BYTES;
    let mut reductions = Vec::new();
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let (_, w) = p.lod_only(&cam);
        let ours = w.trace.bytes_streamed;
        reductions.push(1.0 - ours as f64 / exhaustive as f64);
    }
    DramResult {
        scene: cfg.name.clone(),
        reduction_pct: reductions.iter().sum::<f64>() / reductions.len() as f64 * 100.0,
    }
}

pub fn run(quick: bool) {
    println!("\n=== §V-C: LoD-search DRAM traffic reduction ===\n");
    println!("{:<14} {:>22}", "scene", "traffic reduction");
    for cfg in eval_scenes(quick) {
        let r = evaluate(&cfg, 42);
        println!("{:<14} {:>21.1}%", r.scene, r.reduction_pct);
    }
    println!("\npaper: 76.5% (small) / 69.6% (large)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sltree_reduces_dram_traffic_substantially() {
        for cfg in eval_scenes(true) {
            let r = evaluate(&cfg, 42);
            assert!(
                r.reduction_pct > 3.0,
                "{}: reduction {}% too small",
                r.scene,
                r.reduction_pct
            );
            assert!(r.reduction_pct < 100.0);
        }
    }
}
