//! Fig. 9 — speedup of the hardware variants over the GPU baseline on
//! both scenes, per scenario.
//!
//! Paper claims: small-scale SLTARCH ~2.2x; large-scale SLTARCH ~3.9x
//! (max 6.1x); GPU+GS ~1.2x and GPU+LT ~2.2x on large-scale.

use super::{build_pipeline, eval_scenes, geomean};
use crate::sim::HwVariant;

/// Per-scene speedup table: `speedups[variant][scenario]`.
pub struct Fig9Result {
    pub scene: String,
    pub variants: Vec<HwVariant>,
    pub speedups: Vec<Vec<f64>>,
}

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Fig9Result {
    let p = build_pipeline(cfg, seed);
    let variants = HwVariant::fig9().to_vec();
    let mut speedups = vec![Vec::new(); variants.len()];
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let r = p.simulate(&cam, &variants);
        let gpu = r.sim_seconds(HwVariant::Gpu).unwrap();
        for (vi, v) in variants.iter().enumerate() {
            speedups[vi].push(gpu / r.sim_seconds(*v).unwrap());
        }
    }
    Fig9Result { scene: cfg.name.clone(), variants, speedups }
}

pub fn run(quick: bool) {
    println!("\n=== Fig. 9: speedup over GPU baseline ===\n");
    for cfg in eval_scenes(quick) {
        let r = evaluate(&cfg, 42);
        println!("--- {} ---", r.scene);
        print!("{:<12}", "variant");
        for i in 0..r.speedups[0].len() {
            print!(" {:>7}", format!("s{i}"));
        }
        println!(" {:>8} {:>7}", "geomean", "max");
        for (vi, v) in r.variants.iter().enumerate() {
            print!("{:<12}", v.name());
            for s in &r.speedups[vi] {
                print!(" {s:>7.2}");
            }
            let max = r.speedups[vi].iter().cloned().fold(0.0, f64::max);
            println!(" {:>8.2} {:>7.2}", geomean(&r.speedups[vi]), max);
        }
        println!();
    }
    println!(
        "paper: small SLTARCH 2.2x | large SLTARCH 3.9x (max 6.1x), \
         GPU+GS 1.2x, GPU+LT 2.2x"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_shape_holds_on_large_scene() {
        let cfg = eval_scenes(true).remove(1);
        let r = evaluate(&cfg, 42);
        let g = |v: HwVariant| {
            let vi = r.variants.iter().position(|&x| x == v).unwrap();
            geomean(&r.speedups[vi])
        };
        let sltarch = g(HwVariant::SlTarch);
        let gpu_lt = g(HwVariant::GpuLt);
        let gpu_gs = g(HwVariant::GpuGs);
        let lt_gs = g(HwVariant::LtGs);
        // Who-wins ordering from the paper. Note: quick scenes are
        // splat-dominated (the LoD stage only dominates at full scale),
        // so GPU+LT is only required not to regress here; the full-size
        // run recorded in EXPERIMENTS.md shows the paper's 2.2x.
        assert!(sltarch > gpu_lt, "SLTARCH {sltarch} !> GPU+LT {gpu_lt}");
        assert!(sltarch > gpu_gs, "SLTARCH {sltarch} !> GPU+GS {gpu_gs}");
        assert!(sltarch >= lt_gs * 0.95, "SLTARCH {sltarch} !>= LT+GS {lt_gs}");
        assert!(gpu_lt > 0.9, "GPU+LT regressed: {gpu_lt}");
        assert!(gpu_gs > 1.0, "GPU+GS must beat GPU: {gpu_gs}");
        // Rough factor band (paper: 3.9x; accept 1.5-12x on the
        // synthetic testbed).
        assert!(sltarch > 1.5 && sltarch < 12.0, "SLTARCH {sltarch}");
    }

    #[test]
    fn large_scene_gains_exceed_small_scene_gains() {
        let scenes = eval_scenes(true);
        let small = evaluate(&scenes[0], 42);
        let large = evaluate(&scenes[1], 42);
        let idx = small
            .variants
            .iter()
            .position(|&v| v == HwVariant::SlTarch)
            .unwrap();
        let s = geomean(&small.speedups[idx]);
        let l = geomean(&large.speedups[idx]);
        // Paper: 2.2x small vs 3.9x large — scaling must favour large.
        assert!(l > s, "large {l} !> small {s}");
    }
}
