//! One module per paper table/figure (DESIGN.md §5 index). Every
//! experiment prints the same rows the paper reports, driven by the
//! real pipeline + the trace-driven hardware models.
//!
//! `quick` mode shrinks the scenes ~20x so the full suite runs in
//! seconds (used by tests); the default sizes are the repro
//! configuration recorded in EXPERIMENTS.md.

pub mod area;
pub mod dram;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig3;
pub mod fig9;
pub mod fig10;
pub mod table1;
pub mod tau_s;

use crate::config::{ArchConfig, RenderConfig, SceneConfig};
use crate::coordinator::FramePipeline;

/// All experiment names, in paper order.
pub const ALL: [&str; 10] = [
    "fig2", "fig3", "table1", "fig9", "fig10", "dram", "fig11", "fig12", "area",
    "taus",
];

/// Run one experiment by name; returns false for an unknown name.
pub fn run_by_name(name: &str, quick: bool) -> bool {
    match name {
        "fig2" => fig2::run(quick),
        "fig3" => fig3::run(quick),
        "table1" => table1::run(quick),
        "fig9" => fig9::run(quick),
        "fig10" => fig10::run(quick),
        "dram" => dram::run(quick),
        "fig11" => fig11::run(quick),
        "fig12" => fig12::run(quick),
        "area" => area::run(quick),
        "taus" => tau_s::run(quick),
        "all" => {
            for n in ALL {
                run_by_name(n, quick);
            }
        }
        _ => return false,
    }
    true
}

/// The two evaluation scenes (small-scale / large-scale), sized per
/// `quick`.
pub fn eval_scenes(quick: bool) -> Vec<SceneConfig> {
    let mut small = SceneConfig::small_scale();
    let mut large = SceneConfig::large_scale();
    if quick {
        small = small.quick();
        large = large.quick();
    }
    vec![small, large]
}

/// Standard pipeline construction for experiments.
pub fn build_pipeline(cfg: &SceneConfig, seed: u64) -> FramePipeline {
    FramePipeline::builder(cfg.build(seed))
        .render_config(RenderConfig::default())
        .arch_config(ArchConfig::default())
        .build()
}

/// Geometric mean (speedup aggregation, as the paper reports).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn unknown_experiment_is_rejected() {
        assert!(!run_by_name("not-a-figure", true));
    }

    #[test]
    fn eval_scenes_are_small_and_large() {
        let scenes = eval_scenes(true);
        assert_eq!(scenes.len(), 2);
        assert!(scenes[0].leaves < scenes[1].leaves);
    }
}
