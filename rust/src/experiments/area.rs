//! §V-A "Area Overhead" — the published 16 nm component areas plus the
//! derived comparisons the paper quotes (negligible vs a mobile SoC,
//! similar to GSCore).

use crate::config::arch::area;

pub struct AreaRow {
    pub component: &'static str,
    pub mm2: f64,
}

pub fn table() -> Vec<AreaRow> {
    vec![
        AreaRow { component: "LT unit array", mm2: area::LT_UNIT_ARRAY },
        AreaRow { component: "Subtree cache", mm2: area::SUBTREE_CACHE },
        AreaRow { component: "LTCORE total", mm2: area::LTCORE },
        AreaRow { component: "SPCORE total", mm2: area::SPCORE },
        AreaRow { component: "SLTARCH total", mm2: area::SLTARCH_TOTAL },
        AreaRow { component: "GSCore (scaled)", mm2: area::GSCORE_TOTAL },
    ]
}

pub fn run(_quick: bool) {
    println!("\n=== §V-A: area overhead (published 16 nm numbers) ===\n");
    println!("{:<18} {:>9}", "component", "mm^2");
    for row in table() {
        println!("{:<18} {:>9.2}", row.component, row.mm2);
    }
    println!(
        "\nSLTARCH vs mobile SoC (> {:.0} mm^2): {:.1}% — negligible",
        area::MOBILE_SOC,
        area::SLTARCH_TOTAL / area::MOBILE_SOC * 100.0
    );
    println!(
        "SLTARCH vs GSCore: {:.2} vs {:.2} mm^2 ({:+.1}%)",
        area::SLTARCH_TOTAL,
        area::GSCORE_TOTAL,
        (area::SLTARCH_TOTAL / area::GSCORE_TOTAL - 1.0) * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn component_areas_sum_consistently() {
        // LTCORE + SPCORE must equal the published total.
        assert!((area::LTCORE + area::SPCORE - area::SLTARCH_TOTAL).abs() < 1e-9);
        // LT unit array + subtree cache fit inside LTCORE.
        assert!(area::LT_UNIT_ARRAY + area::SUBTREE_CACHE < area::LTCORE);
        // "Similar area" claim: within 10% of GSCore.
        assert!((area::SLTARCH_TOTAL / area::GSCORE_TOTAL - 1.0).abs() < 0.10);
        // "Negligible" claim: < 2% of a mobile SoC.
        assert!(area::SLTARCH_TOTAL / area::MOBILE_SOC < 0.02);
    }
}
