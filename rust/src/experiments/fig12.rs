//! Fig. 12 — ablation of subtree merging (Sec. III-B): LoD-search-only
//! speedup over the GPU baseline and LT-unit utilization, with and
//! without the merging pass.
//!
//! Paper claim: w/o merging 2.3x (small) / 5.2x (large); with merging
//! 3.6x / 7.8x, with correspondingly higher PE utilization.

use super::{build_pipeline, eval_scenes, geomean};
use crate::lod::{traverse_sltree, SlTree};
use crate::sim::{gpu, ltcore};

pub struct Fig12Row {
    pub scene: String,
    pub speedup_unmerged: f64,
    pub speedup_merged: f64,
    pub util_unmerged: f64,
    pub util_merged: f64,
}

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Fig12Row {
    let p = build_pipeline(cfg, seed);
    let merged = p.sltree();
    let unmerged = SlTree::partition_unmerged(&p.scene().tree, p.rcfg().subtree_size);

    let mut s_m = Vec::new();
    let mut s_u = Vec::new();
    let mut u_m = Vec::new();
    let mut u_u = Vec::new();
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let (_, lod_w) = p.lod_only(&cam);
        let gpu_lod = gpu::lod_exhaustive(&lod_w, &p.arch().gpu, &p.arch().dram);
        for (slt, speeds, utils) in
            [(merged, &mut s_m, &mut u_m), (&unmerged, &mut s_u, &mut u_u)]
        {
            let (_, trace) =
                traverse_sltree(&p.scene().tree, slt, &cam, p.rcfg().lod_tau, 4);
            let r = ltcore::search(&trace, &p.arch().ltcore, &p.arch().dram);
            speeds.push(gpu_lod.seconds / r.stage.seconds);
            utils.push(r.utilization());
        }
    }
    Fig12Row {
        scene: cfg.name.clone(),
        speedup_unmerged: geomean(&s_u),
        speedup_merged: geomean(&s_m),
        util_unmerged: u_u.iter().sum::<f64>() / u_u.len() as f64,
        util_merged: u_m.iter().sum::<f64>() / u_m.len() as f64,
    }
}

pub fn run(quick: bool) {
    println!("\n=== Fig. 12: subtree-merging ablation (LoD search only) ===\n");
    println!(
        "{:<14} {:>12} {:>12} {:>10} {:>10}",
        "scene", "S w/o merge", "S w/ merge", "U w/o", "U w/"
    );
    for cfg in eval_scenes(quick) {
        let r = evaluate(&cfg, 42);
        println!(
            "{:<14} {:>11.2}x {:>11.2}x {:>9.1}% {:>9.1}%",
            r.scene,
            r.speedup_unmerged,
            r.speedup_merged,
            r.util_unmerged * 100.0,
            r.util_merged * 100.0
        );
    }
    println!("\npaper: 2.3x/5.2x w/o merge -> 3.6x/7.8x with merge");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merging_improves_lod_speedup_and_utilization() {
        let cfg = eval_scenes(true).remove(1);
        let r = evaluate(&cfg, 42);
        assert!(
            r.speedup_merged >= r.speedup_unmerged,
            "merge must help: {} !>= {}",
            r.speedup_merged,
            r.speedup_unmerged
        );
        assert!(
            r.util_merged >= r.util_unmerged - 0.02,
            "merge must not hurt utilization: {} vs {}",
            r.util_merged,
            r.util_unmerged
        );
        // Quick trees are shallow, so LTCore's streaming advantage over
        // the GPU's exhaustive pass is muted; require no regression here
        // (the full-scale run in EXPERIMENTS.md shows the paper's
        // multi-x speedups).
        assert!(r.speedup_merged > 0.8, "LTCore regressed: {}", r.speedup_merged);
    }
}
