//! Table I — rendering quality: the canonical algorithm ("Org.") vs
//! SLTarch's group-alpha approximation, on PSNR / SSIM / LPIPS(-proxy).
//!
//! Ground truth is the canonical per-pixel render of the *finest*
//! in-frustum cut (the dataset GT substitution; DESIGN.md §2). Paper
//! claim: SLTARCH matches Org. within noise (ΔPSNR ~= -0.01 dB).

use super::{build_pipeline, eval_scenes};
use crate::coordinator::backend::RenderOptions;
use crate::coordinator::renderer::AlphaMode;
use crate::metrics::{lpips_proxy, psnr, ssim};

/// One scene's averaged metrics.
#[derive(Debug, Default, Clone, Copy)]
pub struct QualityRow {
    pub psnr_org: f64,
    pub psnr_slt: f64,
    pub ssim_org: f64,
    pub ssim_slt: f64,
    pub lpips_org: f64,
    pub lpips_slt: f64,
}

/// Evaluate Table I's metrics for a procedural eval scene.
pub fn evaluate_scene(cfg: &crate::config::SceneConfig, seed: u64) -> QualityRow {
    evaluate_pipeline(&build_pipeline(cfg, seed))
}

/// Evaluate Table I's metrics over an already-built pipeline — any
/// scene source works, including assets loaded through
/// [`crate::assets::load_scene`] (the fixture-zoo quality rows in
/// `benches/table1_quality.rs` go through here).
pub fn evaluate_pipeline(p: &crate::coordinator::FramePipeline) -> QualityRow {
    let mut row = QualityRow::default();
    let n = p.scene().cameras.len() as f64;
    // Three long-lived sessions over one pipeline: ground truth renders
    // the *finest* cut (per-session tau = 1.0, canonical dataflow);
    // Org / SLTARCH render the default-tau cut per-pixel vs group.
    let mut gt_sess = p.session_with(RenderOptions {
        alpha: AlphaMode::Pixel,
        lod_tau: 1.0,
        ..p.default_options()
    });
    let mut org_sess =
        p.session_with(RenderOptions { alpha: AlphaMode::Pixel, ..p.default_options() });
    let mut slt_sess =
        p.session_with(RenderOptions { alpha: AlphaMode::Group, ..p.default_options() });
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let gt = gt_sess.render(&cam).expect("gt render");
        let org = org_sess.render(&cam).expect("org render");
        let slt = slt_sess.render(&cam).expect("sltarch render");
        row.psnr_org += psnr(&gt, &org) / n;
        row.psnr_slt += psnr(&gt, &slt) / n;
        row.ssim_org += ssim(&gt, &org) / n;
        row.ssim_slt += ssim(&gt, &slt) / n;
        row.lpips_org += lpips_proxy(&gt, &org) / n;
        row.lpips_slt += lpips_proxy(&gt, &slt) / n;
    }
    row
}

pub fn run(quick: bool) {
    println!("\n=== Table I: rendering quality (Org. vs SLTARCH) ===\n");
    println!(
        "{:<14} {:>10} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "dataset", "PSNR org", "PSNR slt", "SSIM org", "SSIM slt", "LPIPSp o", "LPIPSp s"
    );
    for cfg in eval_scenes(quick) {
        let r = evaluate_scene(&cfg, 42);
        println!(
            "{:<14} {:>10.2} {:>10.2} {:>9.3} {:>9.3} {:>9.4} {:>9.4}",
            cfg.name, r.psnr_org, r.psnr_slt, r.ssim_org, r.ssim_slt,
            r.lpips_org, r.lpips_slt
        );
    }
    println!(
        "\npaper: PSNR 21.04/23.50 with ΔPSNR ~= -0.01 dB between Org and \
         SLTARCH\n(absolute values differ — synthetic scenes + GT \
         substitution — the claim is the tiny delta)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sltarch_quality_is_marginally_below_org() {
        let cfg = eval_scenes(true).remove(0);
        let r = evaluate_scene(&cfg, 42);
        // Org should be at least as good, but the gap must be small —
        // the paper's headline accuracy claim.
        let delta = r.psnr_org - r.psnr_slt;
        assert!(delta > -0.5, "SLTARCH unexpectedly better by {delta}");
        assert!(delta < 2.0, "group-alpha too lossy: ΔPSNR {delta}");
        assert!((r.ssim_org - r.ssim_slt).abs() < 0.05);
        assert!(r.psnr_org > 10.0, "renderer broken: PSNR {}", r.psnr_org);
    }
}
