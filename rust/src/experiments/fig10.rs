//! Fig. 10 — normalized energy of the hardware variants vs the GPU
//! baseline.
//!
//! Paper claims: SLTARCH saves ~98% across both datasets; small-scale
//! GPU+GS saves 74% / GPU+LT 26%; large-scale GPU+GS 44% / GPU+LT 57%
//! (the flip tracks which stage dominates).

use super::{build_pipeline, eval_scenes, geomean};
use crate::sim::HwVariant;

/// Normalized energy (variant / GPU) per scene, geomean over scenarios.
pub struct Fig10Result {
    pub scene: String,
    pub variants: Vec<HwVariant>,
    pub normalized: Vec<f64>,
}

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Fig10Result {
    let p = build_pipeline(cfg, seed);
    let variants = HwVariant::fig9().to_vec();
    let mut ratios = vec![Vec::new(); variants.len()];
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let r = p.simulate(&cam, &variants);
        let gpu = r
            .sims
            .iter()
            .find(|s| s.variant == HwVariant::Gpu)
            .unwrap()
            .report
            .total_energy_mj();
        for (vi, v) in variants.iter().enumerate() {
            let e = r
                .sims
                .iter()
                .find(|s| s.variant == *v)
                .unwrap()
                .report
                .total_energy_mj();
            ratios[vi].push(e / gpu);
        }
    }
    Fig10Result {
        scene: cfg.name.clone(),
        variants,
        normalized: ratios.iter().map(|r| geomean(r)).collect(),
    }
}

pub fn run(quick: bool) {
    println!("\n=== Fig. 10: normalized energy vs GPU ===\n");
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "scene", "GPU", "GPU+LT", "GPU+GS", "LT+GS", "SLTARCH"
    );
    for cfg in eval_scenes(quick) {
        let r = evaluate(&cfg, 42);
        print!("{:<14}", r.scene);
        for n in &r.normalized {
            print!(" {:>9.3}", n);
        }
        println!();
        let slt = r.normalized[r
            .variants
            .iter()
            .position(|&v| v == HwVariant::SlTarch)
            .unwrap()];
        println!("    -> SLTARCH energy savings: {:.1}%", (1.0 - slt) * 100.0);
    }
    println!(
        "\npaper: SLTARCH saves ~98%; small GPU+GS 74%/GPU+LT 26%; \
         large GPU+GS 44%/GPU+LT 57%"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sltarch_saves_the_most_energy() {
        let cfg = eval_scenes(true).remove(1);
        let r = evaluate(&cfg, 42);
        let get = |v: HwVariant| {
            r.normalized[r.variants.iter().position(|&x| x == v).unwrap()]
        };
        let slt = get(HwVariant::SlTarch);
        assert!(slt < get(HwVariant::GpuLt));
        assert!(slt < get(HwVariant::GpuGs));
        assert!(slt < 0.1, "SLTARCH must save >90%: normalized {slt}");
    }

    #[test]
    fn partial_savings_flip_with_scale() {
        // Small scale: splatting dominates -> GPU+GS saves more than
        // GPU+LT. Large scale: LoD dominates -> GPU+LT saves more.
        let scenes = eval_scenes(true);
        let small = evaluate(&scenes[0], 42);
        let large = evaluate(&scenes[1], 42);
        let get = |r: &Fig10Result, v: HwVariant| {
            r.normalized[r.variants.iter().position(|&x| x == v).unwrap()]
        };
        let small_gap =
            get(&small, HwVariant::GpuLt) - get(&small, HwVariant::GpuGs);
        let large_gap =
            get(&large, HwVariant::GpuLt) - get(&large, HwVariant::GpuGs);
        // The relative advantage of GPU+LT must improve with scale.
        assert!(
            large_gap < small_gap,
            "LoD-side savings must grow with scale: {small_gap} -> {large_gap}"
        );
    }
}
