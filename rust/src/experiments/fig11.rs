//! Fig. 11 — LoD-search-stage comparison against kd-tree traversal
//! accelerators (QuickNN, Crescent) at equal PE count, with the GPU
//! running splatting in all variants.
//!
//! Paper claim: GPU+LT wins because (1) kd-trees are ill-suited to LoD
//! search (irregular access, binary expansion) and (2) their stacks and
//! offline schedules are pure overhead here.

use super::{build_pipeline, eval_scenes, geomean};
use crate::sim::HwVariant;

pub struct Fig11Result {
    pub scene: String,
    pub variants: Vec<HwVariant>,
    /// LoD-stage speedup over the GPU's LoD stage (geomean).
    pub lod_speedups: Vec<f64>,
}

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Fig11Result {
    let p = build_pipeline(cfg, seed);
    let variants = HwVariant::fig11().to_vec();
    let mut ratios = vec![Vec::new(); variants.len()];
    for i in 0..p.scene().cameras.len() {
        let cam = p.scene().scenario_camera(i);
        let r = p.simulate(&cam, &variants);
        let gpu_lod = r
            .sims
            .iter()
            .find(|s| s.variant == HwVariant::Gpu)
            .unwrap()
            .report
            .lod
            .seconds;
        for (vi, v) in variants.iter().enumerate() {
            let lod = r
                .sims
                .iter()
                .find(|s| s.variant == *v)
                .unwrap()
                .report
                .lod
                .seconds;
            ratios[vi].push(gpu_lod / lod);
        }
    }
    Fig11Result {
        scene: cfg.name.clone(),
        variants,
        lod_speedups: ratios.iter().map(|r| geomean(r)).collect(),
    }
}

pub fn run(quick: bool) {
    println!("\n=== Fig. 11: LoD-search accelerators (same #PEs) ===\n");
    println!(
        "{:<14} {:>8} {:>12} {:>13} {:>8}",
        "scene", "GPU", "GPU+QuickNN", "GPU+Crescent", "GPU+LT"
    );
    for cfg in eval_scenes(quick) {
        let r = evaluate(&cfg, 42);
        print!("{:<14}", r.scene);
        for s in &r.lod_speedups {
            print!(" {:>8.2}x", s);
        }
        println!();
    }
    println!("\npaper: GPU+LT best; kd-tree designs pay stack + static-schedule overheads");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lt_beats_kdtree_accelerators_on_lod_search() {
        let cfg = eval_scenes(true).remove(1);
        let r = evaluate(&cfg, 42);
        let get = |v: HwVariant| {
            r.lod_speedups[r.variants.iter().position(|&x| x == v).unwrap()]
        };
        let lt = get(HwVariant::GpuLt);
        let qn = get(HwVariant::GpuQuickNn);
        let cr = get(HwVariant::GpuCrescent);
        assert!(lt > qn, "LT {lt} !> QuickNN {qn}");
        assert!(lt > cr, "LT {lt} !> Crescent {cr}");
        // Crescent's streaming recovery should beat QuickNN.
        assert!(cr > qn, "Crescent {cr} !> QuickNN {qn}");
    }
}
