//! Fig. 2 — Normalized execution breakdown of PBNR across different
//! LoDs on the GPU baseline.
//!
//! The figure's x-axis is the LoD scale: as the rendered level of
//! detail coarsens (wide/far views rendered at their appropriate LoD),
//! splatting work shrinks with the cut while the exhaustive GPU LoD
//! search keeps paying for the whole tree — so the LoD-search share
//! grows, up to ~70% in the paper, and LoD+splat stay ~85% of the frame.

use super::{build_pipeline, eval_scenes};
use crate::sim::HwVariant;

/// The LoD granularity sweep (projected pixels per Gaussian): fine ->
/// coarse, i.e. near-view rendering -> far-view rendering.
pub const TAUS: [f32; 5] = [4.0, 8.0, 16.0, 32.0, 64.0];

/// (lod_share, splat_share, frame_seconds) per tau.
pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Vec<(f64, f64, f64)> {
    let mut p = build_pipeline(cfg, seed);
    // Fixed wide view: scenario 3 captures most of the scene.
    let cam = p.scene().scenario_camera(3);
    let mut rows = Vec::new();
    for &tau in &TAUS {
        p.set_lod_tau(tau);
        let r = p.simulate(&cam, &[HwVariant::Gpu]);
        let rep = &r.sims[0].report;
        let total = rep.total_seconds();
        rows.push((rep.lod.seconds / total, rep.splat.seconds / total, total));
    }
    rows
}

pub fn run(quick: bool) {
    println!("\n=== Fig. 2: GPU execution breakdown across LoD scales ===");
    println!("(tau sweep fine -> coarse at a fixed wide view)\n");
    let cfg = &eval_scenes(quick)[1]; // large scene drives the claim
    let rows = evaluate(cfg, 42);
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>12}",
        "tau (px)", "lod %", "splat %", "other %", "frame (ms)"
    );
    let mut max_share = 0.0f64;
    for (&tau, (lod, splat, total)) in TAUS.iter().zip(rows.iter()) {
        max_share = max_share.max(*lod);
        println!(
            "{:<10} {:>9.1}% {:>9.1}% {:>9.1}% {:>12.3}",
            tau,
            lod * 100.0,
            splat * 100.0,
            (1.0 - lod - splat) * 100.0,
            total * 1e3
        );
    }
    println!(
        "\npaper: LoD share grows with LoD scale, up to ~70% | ours: max {:.1}%",
        max_share * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lod_share_grows_as_lod_coarsens() {
        let cfg = &eval_scenes(true)[1];
        let rows = evaluate(cfg, 42);
        let first = rows.first().unwrap().0;
        let last = rows.last().unwrap().0;
        assert!(
            last > first,
            "LoD share must grow fine->coarse: {first} -> {last}"
        );
        // Frame time must shrink as the LoD coarsens (less splatting).
        assert!(rows.last().unwrap().2 < rows.first().unwrap().2);
    }
}
