//! Extension ablation — subtree-size (tau_s) sensitivity.
//!
//! The paper fixes tau_s = 32 ("Unless otherwise specified, we set the
//! subtree size to 32") without showing the sweep; this experiment
//! regenerates the design-space data behind that choice: small subtrees
//! mean many DRAM bursts and queue churn, large subtrees stream
//! below-cut nodes that are never tested and blow the cache entry size.
//! The cut itself is invariant (bit-accuracy holds at every tau_s).

use super::{build_pipeline, eval_scenes, geomean};
use crate::lod::{traverse_sltree, SlTree};
use crate::sim::ltcore;

/// One sweep row.
#[derive(Clone, Debug)]
pub struct TauSRow {
    pub tau_s: u32,
    pub subtrees: usize,
    /// LTCore LoD-stage seconds (geomean over scenarios).
    pub lod_seconds: f64,
    /// DRAM bytes streamed (mean over scenarios).
    pub bytes: f64,
    /// Subtree-cache refetch rate (refetches / misses).
    pub refetch_rate: f64,
}

pub const TAU_S_SWEEP: [u32; 5] = [8, 16, 32, 64, 128];

pub fn evaluate(cfg: &crate::config::SceneConfig, seed: u64) -> Vec<TauSRow> {
    let p = build_pipeline(cfg, seed);
    let mut rows = Vec::new();
    for &tau_s in &TAU_S_SWEEP {
        let slt = SlTree::partition(&p.scene().tree, tau_s);
        let mut secs = Vec::new();
        let mut bytes = 0.0;
        let mut refetches = 0u64;
        let mut misses = 0u64;
        for i in 0..p.scene().cameras.len() {
            let cam = p.scene().scenario_camera(i);
            let (_, trace) =
                traverse_sltree(&p.scene().tree, &slt, &cam, p.rcfg().lod_tau, 4);
            let r = ltcore::search(&trace, &p.arch().ltcore, &p.arch().dram);
            secs.push(r.stage.seconds);
            bytes += trace.bytes_streamed as f64 / p.scene().cameras.len() as f64;
            refetches += r.cache.refetches;
            misses += r.cache.misses;
        }
        rows.push(TauSRow {
            tau_s,
            subtrees: slt.len(),
            lod_seconds: geomean(&secs),
            bytes,
            refetch_rate: refetches as f64 / misses.max(1) as f64,
        });
    }
    rows
}

pub fn run(quick: bool) {
    println!("\n=== Extension: subtree-size (tau_s) sensitivity ===\n");
    for cfg in eval_scenes(quick) {
        println!("--- {} ---", cfg.name);
        println!(
            "{:>7} {:>10} {:>12} {:>12} {:>10}",
            "tau_s", "subtrees", "lod (ms)", "DRAM (MB)", "refetch %"
        );
        for r in evaluate(&cfg, 42) {
            println!(
                "{:>7} {:>10} {:>12.4} {:>12.2} {:>9.2}%",
                r.tau_s,
                r.subtrees,
                r.lod_seconds * 1e3,
                r.bytes / 1e6,
                r.refetch_rate * 100.0
            );
        }
    }
    println!("\npaper default tau_s = 32 sits at/near the sweep minimum");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_is_invariant_under_tau_s() {
        let cfg = eval_scenes(true).remove(0);
        let p = build_pipeline(&cfg, 42);
        let cam = p.scene().scenario_camera(2);
        let mut cuts = Vec::new();
        for &tau_s in &TAU_S_SWEEP {
            let slt = SlTree::partition(&p.scene().tree, tau_s);
            cuts.push(slt.traverse(&p.scene().tree, &cam, p.rcfg().lod_tau));
        }
        for w in cuts.windows(2) {
            assert_eq!(w[0], w[1], "tau_s must not change search semantics");
        }
    }

    #[test]
    fn extreme_tau_s_is_never_optimal() {
        // The sweep should have an interior (or at least non-trivial)
        // structure: tiny subtrees pay per-burst overheads.
        let cfg = eval_scenes(true).remove(1);
        let rows = evaluate(&cfg, 42);
        let t8 = rows.iter().find(|r| r.tau_s == 8).unwrap();
        let t32 = rows.iter().find(|r| r.tau_s == 32).unwrap();
        assert!(
            t32.lod_seconds <= t8.lod_seconds * 1.05,
            "tau_s=32 ({}) should not lose to tau_s=8 ({})",
            t32.lod_seconds,
            t8.lod_seconds
        );
        // More subtrees at smaller tau_s, always.
        assert!(t8.subtrees > t32.subtrees);
    }
}
