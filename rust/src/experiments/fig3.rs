//! Fig. 3 — Workload variation across GPU threads under the naive
//! static one-thread-per-subtree parallelization of the LoD tree.
//!
//! Paper claim: with 64 threads the workload standard deviation is the
//! same order as the mean (sigma ~= 3.1e4 vs mu ~= 4.1e4 visited
//! nodes) — i.e. the static partition is severely imbalanced.

use super::{build_pipeline, eval_scenes};
use crate::util::stats::summarize;

pub fn run(quick: bool) {
    println!("\n=== Fig. 3: static workload imbalance across GPU threads ===\n");
    let cfg = &eval_scenes(quick)[1];
    let p = build_pipeline(cfg, 42);
    let cam = p.scene().scenario_camera(1);
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>10}",
        "threads", "mean", "std", "std/mean", "max/mean"
    );
    for threads in [8usize, 16, 32, 64, 128, 256, 512] {
        let loads = crate::lod::naive_static_workloads(
            &p.scene().tree,
            &cam,
            p.rcfg().lod_tau,
            threads,
        );
        let xs: Vec<f64> = loads.iter().map(|&x| x as f64).collect();
        let s = summarize(&xs).unwrap();
        println!(
            "{:>8} {:>12.0} {:>12.0} {:>10.2} {:>10.2}",
            threads,
            s.mean,
            s.std,
            s.std / s.mean.max(1e-9),
            s.max / s.mean.max(1e-9)
        );
    }
    println!("\npaper @64 threads: std ~0.76x mean (3.1e4 / 4.1e4)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_partition_is_imbalanced_at_64_threads() {
        let cfg = &eval_scenes(true)[1];
        let p = build_pipeline(cfg, 42);
        let cam = p.scene().scenario_camera(1);
        let loads =
            crate::lod::naive_static_workloads(&p.scene().tree, &cam, p.rcfg().lod_tau, 64);
        let xs: Vec<f64> = loads.iter().map(|&x| x as f64).collect();
        let s = summarize(&xs).unwrap();
        // The paper's regime: std within the order of the mean.
        assert!(
            s.std / s.mean.max(1e-9) > 0.4,
            "static partition unexpectedly balanced: {s:?}"
        );
    }
}
