//! Deadline-aware serving layer over [`RenderSession`] streams.
//!
//! This module turns the render-session surface into something a
//! latency-sensitive deployment can actually sit behind. Scaling
//! point-based rendering is not only a per-frame throughput problem:
//! under bursty multi-client load the failure mode is unbounded queues
//! and silent tail-latency collapse. The serving layer makes overload
//! explicit and survivable:
//!
//! * a **bounded** [`FrameQueue`] that sheds (typed error, never
//!   blocks) when the server is behind;
//! * a per-client [`AdmissionController`] so one bursty client cannot
//!   starve the others;
//! * per-request **deadlines** with exact shed/serve/miss accounting;
//! * a [`QosController`] per client stream that trades LoD quality for
//!   latency *gracefully*: consecutive deadline misses coarsen the
//!   stream's `tau` stepwise (bounded by a quality floor), and
//!   sustained headroom recovers it hysteretically. Tau steps are sized
//!   to the cut cache's
//!   [`max_tau_step`](crate::lod::CutCacheConfig::max_tau_step) so each
//!   nudge revalidates the cached cut instead of cold-starting the
//!   LoD search. When slab residency is enabled
//!   ([`crate::residency`]), the frame's simulated demand-stall time is
//!   added to the latency the controller observes, so memory pressure
//!   and compute pressure degrade quality through one signal;
//! * log-bucketed latency histograms
//!   ([`LatencyHistogram`](crate::coordinator::LatencyHistogram)) for
//!   end-to-end and queue-wait time, reported as p50/p95/p99 per client
//!   and in aggregate.
//!
//! Data flow — `submit` is called by client threads, `worker` by any
//! number of render threads:
//!
//! ```text
//! submit(client, cam)                      worker() loop
//!   ├─ AdmissionController::try_admit        ├─ FrameQueue::pop_blocking
//!   │    └─ Err: shed(ClientSaturated)       ├─ expired? drop + count (optional)
//!   ├─ FrameQueue::push                      ├─ RenderSession::render
//!   │    └─ Err: release + shed(QueueFull)   ├─ QosController::observe → tau
//!   └─ Ok: request in flight                 └─ AdmissionController::release
//! ```
//!
//! The ledger invariant (tested): after [`FrameServer::drain`], every
//! submission is accounted exactly once —
//! `submitted == served + expired + failed + shed_queue + shed_admission`.
//!
//! **Batch lane** ([`FrameServer::submit_batch`]): correlated
//! same-scene requests (stereo pairs, co-located XR clients, grid
//! review walls) can be submitted as one atomic group. The group
//! occupies one queue slot per member, sheds whole (per-member
//! admission charges roll back on refusal), and renders through a
//! server-owned [`ViewBatch`] — bitwise-identical frames to the
//! per-client lanes, but with identity-group coalescing, cross-view
//! LoD-search seeding and one interleaved tile schedule across the
//! group. Batch-lane frames bypass per-stream QoS tau adaptation (the
//! batch renders every member at the lane's base options); deadlines,
//! misses and the ledger are still tracked per member.
//!
//! [`loadgen`] drives this stack with synthetic open-loop camera
//! streams (burst and slow-client fault injection, plus a correlated
//! co-orbit mode that exercises the batch lane) and is what the
//! `hotpath` bench and `examples/multi_client.rs` run.

#![warn(missing_docs)]

pub mod admission;
pub mod loadgen;
pub mod qos;
pub mod queue;

pub use admission::AdmissionController;
pub use loadgen::{calibrate_frame_seconds, run_load, LoadGenConfig};
pub use qos::{QosConfig, QosController};
pub use queue::{FrameQueue, FrameRequest, QueueEntry, ShedError, ShedReason};

use crate::coordinator::{
    BatchConfig, BatchStats, FramePipeline, LatencyHistogram, RenderOptions, RenderSession,
    RenderStats, ViewBatch,
};
use crate::math::Camera;
use crate::metrics::Image;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serving-layer configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Bound on the shared frame queue; submissions beyond it shed with
    /// [`ShedReason::QueueFull`].
    pub queue_capacity: usize,
    /// Per-client in-flight cap (queued + rendering); submissions
    /// beyond it shed with [`ShedReason::ClientSaturated`].
    pub max_inflight: usize,
    /// Number of render worker threads the load generator spawns.
    pub workers: usize,
    /// Per-request latency budget in seconds; the deadline is
    /// `enqueued + budget` and a served frame slower than this counts
    /// as a deadline miss.
    pub budget: f64,
    /// Drop requests that are already past their deadline when a worker
    /// picks them up (counted as `expired`, still a QoS miss signal)
    /// instead of rendering them late.
    pub shed_expired: bool,
    /// Keep rendered frames in the lane (tests / offline use; a real
    /// deployment would hand them to a transport instead).
    pub keep_frames: bool,
    /// Per-stream deadline-adaptive LoD degradation.
    pub qos: QosConfig,
    /// Sharing policy of the batch lane ([`FrameServer::submit_batch`]
    /// groups render through a server-owned [`ViewBatch`] under this
    /// config; any setting is byte-identical, it only tunes sharing).
    pub batch: BatchConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 64,
            max_inflight: 4,
            workers: 2,
            budget: 0.050,
            shed_expired: false,
            keep_frames: false,
            qos: QosConfig::default(),
            batch: BatchConfig::default(),
        }
    }
}

/// Everything mutable one client stream owns, behind one mutex so the
/// stream's cut cache and QoS state stay coherent even when several
/// workers pull its requests.
struct ClientLane<'p> {
    session: RenderSession<'p>,
    qos: QosController,
    e2e: LatencyHistogram,
    queue_wait: LatencyHistogram,
    served: u64,
    missed: u64,
    expired: u64,
    /// `(seq, frame)` pairs when [`ServeConfig::keep_frames`] is set;
    /// workers may complete out of submission order, so consumers sort
    /// by `seq`.
    frames: Vec<(u64, Image)>,
}

/// Multi-client serving front end over one shared [`FramePipeline`].
///
/// Thread-safe by construction: `submit` and `worker` both take
/// `&self`, so client threads and render workers share one server
/// through plain borrows (see [`loadgen::run_load`]).
pub struct FrameServer<'p> {
    cfg: ServeConfig,
    queue: FrameQueue,
    admission: AdmissionController,
    lanes: Vec<Mutex<ClientLane<'p>>>,
    /// The batch lane: one [`ViewBatch`] shared by every
    /// [`submit_batch`](Self::submit_batch) group (its per-slot cut
    /// caches stay warm across groups, which is the whole point of
    /// coalescing correlated streams).
    batch: Mutex<ViewBatch<'p>>,
    seq: AtomicU64,
    submitted: AtomicU64,
    shed_queue: AtomicU64,
    shed_admission: AtomicU64,
    served: AtomicU64,
    missed: AtomicU64,
    expired: AtomicU64,
    failed: AtomicU64,
    window_t0: Mutex<Instant>,
}

impl<'p> FrameServer<'p> {
    /// A server with `clients` independent lanes rendering through
    /// `pipeline` at its default options.
    pub fn new(pipeline: &'p FramePipeline, cfg: ServeConfig, clients: usize) -> Self {
        Self::with_options(pipeline, cfg, clients, pipeline.default_options())
    }

    /// Like [`new`](Self::new) but with explicit per-lane render
    /// options; `opts.lod_tau` becomes every lane's QoS base (full
    /// quality) tau.
    pub fn with_options(
        pipeline: &'p FramePipeline,
        cfg: ServeConfig,
        clients: usize,
        opts: RenderOptions,
    ) -> Self {
        let lanes = (0..clients.max(1))
            .map(|_| {
                Mutex::new(ClientLane {
                    session: pipeline.session_with(opts),
                    qos: QosController::new(opts.lod_tau),
                    e2e: LatencyHistogram::new(),
                    queue_wait: LatencyHistogram::new(),
                    served: 0,
                    missed: 0,
                    expired: 0,
                    frames: Vec::new(),
                })
            })
            .collect();
        FrameServer {
            cfg,
            queue: FrameQueue::new(cfg.queue_capacity),
            admission: AdmissionController::new(cfg.max_inflight),
            lanes,
            batch: Mutex::new(pipeline.batch_with(opts, cfg.batch)),
            seq: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            shed_queue: AtomicU64::new(0),
            shed_admission: AtomicU64::new(0),
            served: AtomicU64::new(0),
            missed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            window_t0: Mutex::new(Instant::now()),
        }
    }

    /// The configuration this server runs with.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Number of client lanes.
    pub fn clients(&self) -> usize {
        self.lanes.len()
    }

    fn lane(&self, client: usize) -> MutexGuard<'_, ClientLane<'p>> {
        self.lanes[client].lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Submit one frame request for `client`. Never blocks: overload
    /// sheds with a typed [`ShedError`] (admission first, then the
    /// bounded queue; an admission charge is rolled back if the queue
    /// rejects, so every shed is counted exactly once).
    pub fn submit(&self, client: usize, cam: Camera) -> Result<u64, ShedError> {
        assert!(client < self.lanes.len(), "unknown client {client}");
        self.submitted.fetch_add(1, Ordering::Relaxed);
        if let Err(reason) = self.admission.try_admit(client) {
            self.shed_admission.fetch_add(1, Ordering::Relaxed);
            return Err(ShedError { client, reason });
        }
        let now = Instant::now();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let budget = Duration::from_secs_f64(self.cfg.budget.clamp(0.0, 1e9));
        let req = FrameRequest { client, seq, cam, enqueued: now, deadline: now + budget };
        if let Err(reason) = self.queue.push(req) {
            self.admission.release(client);
            self.shed_queue.fetch_add(1, Ordering::Relaxed);
            return Err(ShedError { client, reason });
        }
        Ok(seq)
    }

    /// Submit a coalesced same-scene group — one `(client, camera)`
    /// member per correlated stream — rendered together through the
    /// server's batch lane ([`ViewBatch`]). Returns the members'
    /// sequence numbers in submission order.
    ///
    /// Groups are **atomic**: admission is charged per member, and if
    /// any member is refused (or the whole group does not fit the
    /// bounded queue) every already-charged admission rolls back and
    /// the entire group sheds — each member counts as exactly one shed,
    /// so the ledger stays per-frame. The [`ShedError::client`] names
    /// the member that triggered the refusal (first member for a full
    /// queue).
    ///
    /// Deadlines, served/missed/expired accounting and kept frames are
    /// per member, exactly like [`submit`](Self::submit). The one
    /// deliberate difference: batch-lane frames bypass per-stream QoS
    /// tau adaptation, because the group renders at the batch lane's
    /// base options rather than each lane's degraded tau (coalescing
    /// only makes sense for streams that share one quality setting).
    pub fn submit_batch(&self, reqs: &[(usize, Camera)]) -> Result<Vec<u64>, ShedError> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        self.submitted.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        for (admitted, &(client, _)) in reqs.iter().enumerate() {
            assert!(client < self.lanes.len(), "unknown client {client}");
            if let Err(reason) = self.admission.try_admit(client) {
                for &(c, _) in &reqs[..admitted] {
                    self.admission.release(c);
                }
                self.shed_admission.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                return Err(ShedError { client, reason });
            }
        }
        let now = Instant::now();
        let budget = Duration::from_secs_f64(self.cfg.budget.clamp(0.0, 1e9));
        let mut seqs = Vec::with_capacity(reqs.len());
        let group: Vec<FrameRequest> = reqs
            .iter()
            .map(|&(client, cam)| {
                let seq = self.seq.fetch_add(1, Ordering::Relaxed);
                seqs.push(seq);
                FrameRequest { client, seq, cam, enqueued: now, deadline: now + budget }
            })
            .collect();
        if let Err(reason) = self.queue.push_group(group) {
            for &(c, _) in reqs {
                self.admission.release(c);
            }
            self.shed_queue.fetch_add(reqs.len() as u64, Ordering::Relaxed);
            return Err(ShedError { client: reqs[0].0, reason });
        }
        Ok(seqs)
    }

    /// Render-worker loop: drains the queue until the server is closed,
    /// then returns. Run any number of these concurrently (typically
    /// from scoped threads — see [`loadgen::run_load`]).
    pub fn worker(&self) {
        while let Some(entry) = self.queue.pop_blocking() {
            match entry {
                QueueEntry::Single(req) => self.handle(req),
                QueueEntry::Group(group) => self.handle_group(group),
            }
        }
    }

    /// Process one dequeued request end to end.
    fn handle(&self, req: FrameRequest) {
        let client = req.client;
        {
            let mut lane = self.lane(client);
            lane.queue_wait.record(req.enqueued.elapsed().as_secs_f64());
            let late = Instant::now() >= req.deadline;
            if self.cfg.shed_expired && late {
                // Expired in queue: don't waste render time on a frame
                // nobody can use, but the controller must still see the
                // miss or overload could never trigger degradation.
                lane.expired += 1;
                self.expired.fetch_add(1, Ordering::Relaxed);
                let waited = req.enqueued.elapsed().as_secs_f64();
                if let Some(tau) = lane.qos.observe(waited, self.cfg.budget, &self.cfg.qos)
                {
                    lane.session.options_mut().lod_tau = tau;
                }
            } else {
                match lane.session.render(&req.cam) {
                    Ok(img) => {
                        let e2e = req.enqueued.elapsed().as_secs_f64();
                        lane.e2e.record(e2e);
                        lane.served += 1;
                        self.served.fetch_add(1, Ordering::Relaxed);
                        if e2e > self.cfg.budget {
                            lane.missed += 1;
                            self.missed.fetch_add(1, Ordering::Relaxed);
                        }
                        // The QoS controller sees end-to-end time plus
                        // the frame's simulated out-of-core demand
                        // stall, so a residency-thrashing stream
                        // degrades tau like a compute-bound one would.
                        // `missed` stays on real wall time: the stall
                        // is model time, not delivery time.
                        let stall = lane.session.last_residency_stall_seconds();
                        if let Some(tau) =
                            lane.qos.observe(e2e + stall, self.cfg.budget, &self.cfg.qos)
                        {
                            lane.session.options_mut().lod_tau = tau;
                        }
                        if self.cfg.keep_frames {
                            lane.frames.push((req.seq, img));
                        }
                    }
                    Err(_) => {
                        // A failed render degrades exactly one request;
                        // the session recovers on the next frame.
                        self.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        // Release only after the lane work is fully done, so
        // `total_inflight() == 0` really means quiescent.
        self.admission.release(client);
    }

    /// Process one dequeued batch group: shed expired members, render
    /// the survivors together through the batch lane, and account each
    /// member in its own client lane.
    fn handle_group(&self, group: Vec<FrameRequest>) {
        // Per-member expiry shed first, same policy as singles — a
        // group member past its deadline should not drag the rest of
        // the group into rendering a frame nobody can use.
        let mut live: Vec<FrameRequest> = Vec::with_capacity(group.len());
        for req in group {
            let mut lane = self.lane(req.client);
            lane.queue_wait.record(req.enqueued.elapsed().as_secs_f64());
            if self.cfg.shed_expired && Instant::now() >= req.deadline {
                lane.expired += 1;
                self.expired.fetch_add(1, Ordering::Relaxed);
                drop(lane);
                self.admission.release(req.client);
            } else {
                live.push(req);
            }
        }
        if live.is_empty() {
            return;
        }
        let cams: Vec<Camera> = live.iter().map(|r| r.cam).collect();
        let rendered = {
            let mut batch = self.batch.lock().unwrap_or_else(|e| e.into_inner());
            batch.render(&cams)
        };
        match rendered {
            Ok(images) => {
                for (req, img) in live.iter().zip(images) {
                    {
                        let mut lane = self.lane(req.client);
                        let e2e = req.enqueued.elapsed().as_secs_f64();
                        lane.e2e.record(e2e);
                        lane.served += 1;
                        self.served.fetch_add(1, Ordering::Relaxed);
                        if e2e > self.cfg.budget {
                            lane.missed += 1;
                            self.missed.fetch_add(1, Ordering::Relaxed);
                        }
                        if self.cfg.keep_frames {
                            lane.frames.push((req.seq, img));
                        }
                    }
                    self.admission.release(req.client);
                }
            }
            Err(_) => {
                // A failed batch degrades exactly this group; the batch
                // lane commits no stats on error, so the next group
                // starts clean.
                self.failed.fetch_add(live.len() as u64, Ordering::Relaxed);
                for req in &live {
                    self.admission.release(req.client);
                }
            }
        }
    }

    /// Block until every admitted request has left the system (the
    /// ledger invariant holds from then on). Call before [`close`]
    /// while workers are still running.
    ///
    /// [`close`]: Self::close
    pub fn drain(&self) {
        while self.admission.total_inflight() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Close the queue: new submissions shed with
    /// [`ShedReason::Closed`]; workers drain remaining requests and
    /// exit.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Start a fresh measurement window: zero the counters and
    /// per-lane histograms/stats and drop kept frames. QoS state
    /// (current tau, degrade/recover totals) deliberately persists —
    /// warmup is exactly when the controller finds its operating point.
    pub fn reset_window(&self) {
        for lane in &self.lanes {
            let mut lane = lane.lock().unwrap_or_else(|e| e.into_inner());
            lane.session.reset_stats();
            lane.e2e = LatencyHistogram::new();
            lane.queue_wait = LatencyHistogram::new();
            lane.served = 0;
            lane.missed = 0;
            lane.expired = 0;
            lane.frames.clear();
        }
        {
            let mut batch = self.batch.lock().unwrap_or_else(|e| e.into_inner());
            batch.reset_view_stats();
            batch.reset_batch_stats();
        }
        self.submitted.store(0, Ordering::Relaxed);
        self.shed_queue.store(0, Ordering::Relaxed);
        self.shed_admission.store(0, Ordering::Relaxed);
        self.served.store(0, Ordering::Relaxed);
        self.missed.store(0, Ordering::Relaxed);
        self.expired.store(0, Ordering::Relaxed);
        self.failed.store(0, Ordering::Relaxed);
        *self.window_t0.lock().unwrap_or_else(|e| e.into_inner()) = Instant::now();
    }

    /// Take (and clear) the frames kept for `client`, as `(seq, frame)`
    /// pairs in completion order.
    pub fn take_frames(&self, client: usize) -> Vec<(u64, Image)> {
        std::mem::take(&mut self.lane(client).frames)
    }

    /// Snapshot the serving metrics for the current window.
    pub fn report(&self) -> ServeReport {
        let span_seconds = self
            .window_t0
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .elapsed()
            .as_secs_f64();
        let mut e2e = LatencyHistogram::new();
        let mut queue_wait = LatencyHistogram::new();
        let mut render = RenderStats::default();
        let mut degrade_events = 0;
        let mut recover_events = 0;
        let mut clients = Vec::with_capacity(self.lanes.len());
        for (i, lane) in self.lanes.iter().enumerate() {
            let lane = lane.lock().unwrap_or_else(|e| e.into_inner());
            e2e.merge(&lane.e2e);
            queue_wait.merge(&lane.queue_wait);
            render.merge(lane.session.stats());
            degrade_events += lane.qos.degrade_events();
            recover_events += lane.qos.recover_events();
            clients.push(ClientReport {
                client: i,
                served: lane.served,
                missed: lane.missed,
                expired: lane.expired,
                tau: lane.qos.tau(),
                base_tau: lane.qos.base_tau(),
                degrade_events: lane.qos.degrade_events(),
                recover_events: lane.qos.recover_events(),
                e2e: lane.e2e,
            });
        }
        let batch = {
            let batch = self.batch.lock().unwrap_or_else(|e| e.into_inner());
            // Batch-lane renders live in the lane's own per-slot
            // sessions; fold them into the aggregate render stats so a
            // window's work is visible no matter which lane did it.
            for v in 0..batch.view_slots() {
                if let Some(vs) = batch.view_stats(v) {
                    render.merge(vs);
                }
            }
            *batch.batch_stats()
        };
        ServeReport {
            batch,
            clients,
            submitted: self.submitted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            missed: self.missed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed_queue: self.shed_queue.load(Ordering::Relaxed),
            shed_admission: self.shed_admission.load(Ordering::Relaxed),
            degrade_events,
            recover_events,
            e2e,
            queue_wait,
            render,
            span_seconds,
            queue_high_water: self.queue.high_water(),
            queue_capacity: self.queue.capacity(),
        }
    }
}

/// One client stream's slice of a [`ServeReport`].
#[derive(Clone, Copy, Debug)]
pub struct ClientReport {
    /// Client lane index.
    pub client: usize,
    /// Frames rendered and delivered.
    pub served: u64,
    /// Served frames that exceeded the budget (late but delivered).
    pub missed: u64,
    /// Requests dropped past their deadline without rendering.
    pub expired: u64,
    /// The stream's tau at snapshot time.
    pub tau: f32,
    /// The stream's full-quality base tau.
    pub base_tau: f32,
    /// Degradation steps this stream has taken (cumulative).
    pub degrade_events: u64,
    /// Recovery steps this stream has taken (cumulative).
    pub recover_events: u64,
    /// End-to-end (submit → frame done) latency histogram.
    pub e2e: LatencyHistogram,
}

/// Aggregate serving metrics for one measurement window.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-client breakdown.
    pub clients: Vec<ClientReport>,
    /// Batch-lane sharing telemetry (groups coalesced via
    /// [`FrameServer::submit_batch`]; zero when only singles were
    /// served).
    pub batch: BatchStats,
    /// Submissions attempted this window.
    pub submitted: u64,
    /// Frames rendered and delivered.
    pub served: u64,
    /// Served frames that exceeded the budget.
    pub missed: u64,
    /// Requests dropped past their deadline without rendering.
    pub expired: u64,
    /// Requests whose render failed (each degrades exactly one frame).
    pub failed: u64,
    /// Submissions shed at the full queue.
    pub shed_queue: u64,
    /// Submissions shed at the per-client admission cap.
    pub shed_admission: u64,
    /// Degradation steps across all streams (cumulative over the
    /// server's life — QoS state survives [`FrameServer::reset_window`]).
    pub degrade_events: u64,
    /// Recovery steps across all streams (cumulative).
    pub recover_events: u64,
    /// Aggregate end-to-end latency histogram.
    pub e2e: LatencyHistogram,
    /// Aggregate queue-wait histogram.
    pub queue_wait: LatencyHistogram,
    /// Merged render-session statistics (stage timings, cache
    /// counters).
    pub render: RenderStats,
    /// Wall-clock length of this window in seconds.
    pub span_seconds: f64,
    /// Highest queue occupancy observed (never exceeds
    /// `queue_capacity`).
    pub queue_high_water: usize,
    /// The queue bound in force.
    pub queue_capacity: usize,
}

impl ServeReport {
    /// Total shed submissions (queue + admission).
    pub fn shed_total(&self) -> u64 {
        self.shed_queue + self.shed_admission
    }

    /// Frames actually delivered per wall-clock second this window.
    pub fn served_fps(&self) -> f64 {
        if self.span_seconds > 0.0 {
            self.served as f64 / self.span_seconds
        } else {
            0.0
        }
    }

    /// Aggregate end-to-end `[p50, p95, p99]` in milliseconds.
    pub fn e2e_percentiles_ms(&self) -> [f64; 3] {
        self.e2e.percentiles_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::walkthrough;
    use crate::util::prop::forall;

    fn pipeline() -> FramePipeline {
        FramePipeline::builder(SceneConfig::small_scale().quick().build(21)).build()
    }

    /// Submit-all / close / drain-inline pattern: no worker threads,
    /// the test thread runs the worker loop to completion itself.
    fn run_inline(server: &FrameServer<'_>) {
        server.close();
        server.worker();
    }

    #[test]
    fn ledger_accounts_every_submission_exactly_once() {
        let p = pipeline();
        let cams = walkthrough(6.0, 6, 64, 64);
        let cfg = ServeConfig {
            queue_capacity: 4,
            max_inflight: 2,
            budget: 10.0, // generous: nothing sheds on time
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 2);
        let mut ok = 0u64;
        let mut shed = 0u64;
        for (i, cam) in cams.iter().enumerate() {
            match server.submit(i % 2, *cam) {
                Ok(_) => ok += 1,
                Err(_) => shed += 1,
            }
        }
        run_inline(&server);
        let r = server.report();
        assert_eq!(r.submitted, ok + shed);
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_queue + r.shed_admission,
            "ledger must balance: {r:?}"
        );
        assert_eq!(r.served, ok);
        assert!(r.queue_high_water <= r.queue_capacity);
        // Everything left the system.
        assert_eq!(server.admission.total_inflight(), 0);
    }

    #[test]
    fn concurrent_workers_preserve_the_ledger() {
        let p = pipeline();
        let cams = walkthrough(6.0, 16, 64, 64);
        let cfg = ServeConfig {
            queue_capacity: 8,
            max_inflight: 4,
            budget: 10.0,
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 3);
        std::thread::scope(|s| {
            let workers: Vec<_> = (0..2).map(|_| s.spawn(|| server.worker())).collect();
            for (i, cam) in cams.iter().enumerate() {
                // Ignore sheds; they are part of the ledger.
                let _ = server.submit(i % 3, *cam);
                std::thread::sleep(Duration::from_micros(200));
            }
            server.drain();
            server.close();
            for w in workers {
                w.join().unwrap();
            }
        });
        let r = server.report();
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_queue + r.shed_admission
        );
        assert!(r.queue_high_water <= r.queue_capacity);
    }

    #[test]
    fn burst_from_one_client_sheds_only_that_client() {
        let p = pipeline();
        let cam = walkthrough(6.0, 1, 64, 64)[0];
        // No workers: everything admitted stays in flight.
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 2,
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 2);
        // Client 0 bursts way past its cap.
        for _ in 0..10 {
            let _ = server.submit(0, cam);
        }
        // The well-behaved client is untouched by the burst.
        for _ in 0..2 {
            assert!(server.submit(1, cam).is_ok());
        }
        let r = server.report();
        assert_eq!(r.shed_admission, 8);
        assert_eq!(r.shed_queue, 0);
        assert_eq!(
            server.submit(0, cam).unwrap_err().reason,
            ShedReason::ClientSaturated
        );
        run_inline(&server);
    }

    #[test]
    fn prop_queue_and_admission_compose_without_losing_requests() {
        let cam = walkthrough(6.0, 1, 64, 64)[0];
        forall(32, |rng| {
            let p = pipeline();
            let cfg = ServeConfig {
                queue_capacity: 1 + rng.below(6),
                max_inflight: 1 + rng.below(3),
                ..ServeConfig::default()
            };
            let clients = 1 + rng.below(3);
            let server = FrameServer::new(&p, cfg, clients);
            let mut submitted = 0u64;
            for _ in 0..rng.below(40) + 1 {
                let _ = server.submit(rng.below(clients), cam);
                submitted += 1;
                // Occupancy bound holds at every step.
                assert!(server.queue.len() <= server.queue.capacity());
            }
            let r = server.report();
            assert_eq!(r.submitted, submitted);
            // Before draining: in-flight + sheds account for everything.
            assert_eq!(
                submitted,
                server.admission.total_inflight() as u64 + r.shed_total()
            );
            run_inline(&server);
            let r = server.report();
            assert_eq!(
                submitted,
                r.served + r.expired + r.failed + r.shed_total()
            );
        });
    }

    #[test]
    fn qos_disabled_frames_are_byte_identical_to_a_direct_session() {
        let p = pipeline();
        let cams = walkthrough(6.0, 5, 64, 64);
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 16,
            keep_frames: true,
            qos: QosConfig::disabled(),
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 1);
        for cam in &cams {
            server.submit(0, *cam).unwrap();
        }
        run_inline(&server);
        let mut got = server.take_frames(0);
        got.sort_by_key(|(seq, _)| *seq);
        let mut session = p.session();
        let want = session.render_path(&cams).unwrap();
        assert_eq!(got.len(), want.len());
        for ((_, g), w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data, "served frame must match direct render");
        }
    }

    #[test]
    fn impossible_budget_degrades_to_the_quality_floor_and_no_further() {
        let p = pipeline();
        let cams = walkthrough(6.0, 12, 64, 64);
        let base_tau = p.default_options().lod_tau;
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 16,
            budget: 0.0, // every frame misses
            qos: QosConfig {
                miss_threshold: 1,
                step: 8.0,
                max_tau: base_tau + 24.0,
                ..QosConfig::default()
            },
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 1);
        for cam in &cams {
            server.submit(0, *cam).unwrap();
        }
        run_inline(&server);
        let r = server.report();
        assert_eq!(r.served, cams.len() as u64);
        assert_eq!(r.missed, r.served, "zero budget: every frame is late");
        assert_eq!(r.degrade_events, 3, "(max_tau - base) / step degrade steps");
        let lane = &r.clients[0];
        assert_eq!(lane.tau, base_tau + 24.0, "clamped at the quality floor");
        assert_eq!(r.recover_events, 0);
        assert!(!r.e2e.is_empty());
        assert_eq!(r.e2e.count(), r.served);
    }

    #[test]
    fn expired_requests_are_dropped_not_rendered_when_shedding_is_on() {
        let p = pipeline();
        let cams = walkthrough(6.0, 4, 64, 64);
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 16,
            budget: 0.0,
            shed_expired: true,
            qos: QosConfig::disabled(),
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 1);
        for cam in &cams {
            server.submit(0, *cam).unwrap();
        }
        // By the time the inline worker runs, every deadline has passed.
        run_inline(&server);
        let r = server.report();
        assert_eq!(r.expired, cams.len() as u64);
        assert_eq!(r.served, 0);
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
    }

    #[test]
    fn batch_groups_are_byte_identical_to_direct_sessions() {
        let p = pipeline();
        let cams = walkthrough(6.0, 4, 64, 64);
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 16,
            budget: 10.0,
            keep_frames: true,
            qos: QosConfig::disabled(),
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 4);
        let group: Vec<(usize, Camera)> =
            cams.iter().enumerate().map(|(c, cam)| (c, *cam)).collect();
        let seqs = server.submit_batch(&group).unwrap();
        assert_eq!(seqs.len(), 4);
        run_inline(&server);
        for (c, cam) in cams.iter().enumerate() {
            let frames = server.take_frames(c);
            assert_eq!(frames.len(), 1, "client {c}");
            assert_eq!(frames[0].0, seqs[c]);
            let want = p.session().render(cam).unwrap();
            assert_eq!(
                frames[0].1.data, want.data,
                "batch-lane frame for client {c} must match a direct render"
            );
        }
        let r = server.report();
        assert_eq!(r.served, 4);
        assert_eq!(r.batch.batches, 1);
        assert_eq!(r.batch.views, 4);
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
        // The batch lane's render work shows up in the aggregate stats.
        assert_eq!(r.render.frames, 4);
    }

    #[test]
    fn batch_group_sheds_roll_back_admission_and_balance_the_ledger() {
        let p = pipeline();
        let cam = walkthrough(6.0, 1, 64, 64)[0];
        // Queue of 2: a single plus a 2-member group cannot both fit.
        let cfg = ServeConfig {
            queue_capacity: 2,
            max_inflight: 8,
            budget: 10.0,
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 3);
        server.submit(0, cam).unwrap();
        let err = server.submit_batch(&[(1, cam), (2, cam)]).unwrap_err();
        assert_eq!(err.reason, ShedReason::QueueFull);
        // The whole group rolled back: only the single is in flight.
        assert_eq!(server.admission.total_inflight(), 1);
        // A group that fits exactly is accepted atomically.
        server.submit_batch(&[(1, cam)]).unwrap();
        run_inline(&server);
        let r = server.report();
        assert_eq!(r.submitted, 4);
        assert_eq!(r.served, 2);
        assert_eq!(r.shed_queue, 2, "each shed group member counts once");
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
        assert_eq!(server.admission.total_inflight(), 0);

        // Admission refusals roll back too: client 1 still holds no
        // in-flight budget after a mid-group refusal.
        let tight = ServeConfig { max_inflight: 1, ..cfg };
        let server = FrameServer::new(&p, tight, 3);
        server.submit(2, cam).unwrap();
        let err = server.submit_batch(&[(1, cam), (2, cam)]).unwrap_err();
        assert_eq!(err.reason, ShedReason::ClientSaturated);
        assert_eq!(err.client, 2, "the saturated member is named");
        assert_eq!(server.admission.total_inflight(), 1);
        run_inline(&server);
        let r = server.report();
        assert_eq!(r.submitted, 3);
        assert_eq!(r.shed_admission, 2);
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
    }

    #[test]
    fn reset_window_zeroes_counters_but_keeps_qos_state() {
        let p = pipeline();
        let cams = walkthrough(6.0, 4, 64, 64);
        let base_tau = p.default_options().lod_tau;
        let cfg = ServeConfig {
            queue_capacity: 16,
            max_inflight: 16,
            budget: 0.0,
            qos: QosConfig { miss_threshold: 1, ..QosConfig::default() },
            ..ServeConfig::default()
        };
        let server = FrameServer::new(&p, cfg, 1);
        for cam in &cams {
            server.submit(0, *cam).unwrap();
        }
        run_inline(&server);
        let warm = server.report();
        assert!(warm.degrade_events > 0);
        let degraded_tau = warm.clients[0].tau;
        assert!(degraded_tau > base_tau);
        server.reset_window();
        let r = server.report();
        assert_eq!(r.submitted, 0);
        assert_eq!(r.served, 0);
        assert!(r.e2e.is_empty());
        // The operating point found during warmup persists.
        assert_eq!(r.clients[0].tau, degraded_tau);
        assert_eq!(r.degrade_events, warm.degrade_events);
    }
}
