//! Bounded frame-request queue with explicit shed semantics.
//!
//! The serving layer's first rule is that overload is **visible**: a
//! full queue rejects the request with a typed [`ShedReason`] at submit
//! time — it never blocks the submitter and never grows unboundedly.
//! Consumers (the [`FrameServer`](super::FrameServer) workers) block on
//! a condvar until a request or shutdown arrives, so an idle serving
//! process burns no CPU.

use crate::math::Camera;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// One queued render request for one client stream.
#[derive(Clone, Copy, Debug)]
pub struct FrameRequest {
    /// Client lane index (0-based, assigned by the server).
    pub client: usize,
    /// Server-wide submission sequence number (orders frames within a
    /// client even when workers complete them out of order).
    pub seq: u64,
    /// Camera to render.
    pub cam: Camera,
    /// When the request entered the queue (queue-wait + end-to-end
    /// latency both measure from here).
    pub enqueued: Instant,
    /// Hard per-request deadline (`enqueued + budget`). Workers may
    /// drop a request that is already past it
    /// ([`ServeConfig::shed_expired`](super::ServeConfig::shed_expired)).
    pub deadline: Instant,
}

/// Why a submission was shed (typed backpressure — the caller can tell
/// "slow down" from "you specifically are too far behind" from "the
/// server is gone").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded queue is at capacity: the whole server is behind.
    QueueFull,
    /// This client already holds its per-client in-flight cap
    /// (admission fairness): the client is behind, not the server.
    ClientSaturated,
    /// The queue was closed (server shutting down).
    Closed,
}

/// A shed submission: which client was refused and why. This is the
/// error type [`FrameServer::submit`](super::FrameServer::submit)
/// returns — backpressure is a value, not a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShedError {
    /// The client whose request was shed.
    pub client: usize,
    /// Why it was shed.
    pub reason: ShedReason,
}

impl std::fmt::Display for ShedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let why = match self.reason {
            ShedReason::QueueFull => "frame queue full",
            ShedReason::ClientSaturated => "client at in-flight cap",
            ShedReason::Closed => "server closed",
        };
        write!(f, "request from client {} shed: {why}", self.client)
    }
}

impl std::error::Error for ShedError {}

/// One unit of queued work: a single frame request, or a coalesced
/// same-scene **group** that the server renders together through its
/// multi-view batch lane
/// ([`FrameServer::submit_batch`](super::FrameServer::submit_batch)).
/// A group occupies one queue slot *per member* — capacity accounting
/// is per frame, so coalescing can never sneak past the queue bound.
#[derive(Clone, Debug)]
pub enum QueueEntry {
    /// One client's frame request.
    Single(FrameRequest),
    /// A multi-view group (one request per participating client),
    /// dequeued atomically so the batch renders all members together.
    Group(Vec<FrameRequest>),
}

impl QueueEntry {
    /// Frame requests this entry holds (its queue-slot footprint).
    pub fn len(&self) -> usize {
        match self {
            QueueEntry::Single(_) => 1,
            QueueEntry::Group(g) => g.len(),
        }
    }

    /// Whether the entry holds no requests (only possible for an empty
    /// group, which [`FrameQueue::push_group`] refuses to enqueue).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Interior queue state behind the mutex.
#[derive(Debug, Default)]
struct QueueState {
    queue: VecDeque<QueueEntry>,
    /// Occupancy in frame requests (group entries count each member).
    len: usize,
    closed: bool,
    /// Largest occupancy ever observed (the backpressure test's bound
    /// witness and a useful serving metric).
    high_water: usize,
    /// Total accepted pushes (in frame requests).
    pushed: u64,
}

/// Bounded MPMC frame-request queue: non-blocking reject-on-full
/// producers, blocking condvar consumers, explicit close.
#[derive(Debug)]
pub struct FrameQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    ready: Condvar,
}

impl FrameQueue {
    /// An empty queue holding at most `capacity` requests (clamped to
    /// >= 1 — a zero-capacity queue could never serve anything).
    pub fn new(capacity: usize) -> Self {
        FrameQueue {
            capacity: capacity.max(1),
            state: Mutex::new(QueueState::default()),
            ready: Condvar::new(),
        }
    }

    /// Lock the state, riding through poison: every mutation below
    /// keeps the queue consistent at each step, so a panicked peer
    /// cannot leave torn state behind.
    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue a single request. Never blocks: a full or closed queue
    /// rejects immediately with the corresponding [`ShedReason`].
    pub fn push(&self, req: FrameRequest) -> Result<(), ShedReason> {
        self.push_entry(QueueEntry::Single(req))
    }

    /// Enqueue a coalesced multi-view group **atomically**: either every
    /// member fits within `capacity` (counted per frame, exactly as if
    /// they had been pushed individually) or the whole group is shed
    /// with [`ShedReason::QueueFull`]. Empty groups are refused as full
    /// rather than enqueued (a zero-frame entry would wedge workers).
    pub fn push_group(&self, group: Vec<FrameRequest>) -> Result<(), ShedReason> {
        if group.is_empty() {
            return Err(ShedReason::QueueFull);
        }
        self.push_entry(QueueEntry::Group(group))
    }

    fn push_entry(&self, entry: QueueEntry) -> Result<(), ShedReason> {
        let frames = entry.len();
        let mut st = self.lock();
        if st.closed {
            return Err(ShedReason::Closed);
        }
        if st.len + frames > self.capacity {
            return Err(ShedReason::QueueFull);
        }
        st.queue.push_back(entry);
        st.len += frames;
        st.high_water = st.high_water.max(st.len);
        st.pushed += frames as u64;
        drop(st);
        self.ready.notify_one();
        Ok(())
    }

    /// Dequeue the oldest entry, blocking until one arrives. Returns
    /// `None` once the queue is closed **and** drained — the worker
    /// shutdown signal (close never drops queued work).
    pub fn pop_blocking(&self) -> Option<QueueEntry> {
        let mut st = self.lock();
        loop {
            if let Some(entry) = st.queue.pop_front() {
                st.len -= entry.len();
                return Some(entry);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking dequeue (tests and drain probes).
    pub fn try_pop(&self) -> Option<QueueEntry> {
        let mut st = self.lock();
        let entry = st.queue.pop_front()?;
        st.len -= entry.len();
        Some(entry)
    }

    /// Close the queue: subsequent pushes shed with
    /// [`ShedReason::Closed`]; blocked consumers wake, drain what is
    /// left and then receive `None`.
    pub fn close(&self) {
        self.lock().closed = true;
        self.ready.notify_all();
    }

    /// Current occupancy in frame requests (group entries count each
    /// member).
    pub fn len(&self) -> usize {
        self.lock().len
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.lock().queue.is_empty()
    }

    /// Largest occupancy ever observed; by construction
    /// `high_water <= capacity`.
    pub fn high_water(&self) -> usize {
        self.lock().high_water
    }

    /// Total requests ever accepted (pushed successfully).
    pub fn pushed(&self) -> u64 {
        self.lock().pushed
    }

    /// The occupancy bound this queue enforces.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Intrinsics, Vec3};

    fn cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 5.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            Intrinsics::from_fov(32, 32, 1.0),
        )
    }

    fn req(client: usize, seq: u64) -> FrameRequest {
        let now = Instant::now();
        FrameRequest { client, seq, cam: cam(), enqueued: now, deadline: now }
    }

    /// Unwrap a single-request entry (the shape every pre-batch test
    /// expects).
    fn single(entry: QueueEntry) -> FrameRequest {
        match entry {
            QueueEntry::Single(r) => r,
            QueueEntry::Group(g) => panic!("expected a single entry, got a group of {}", g.len()),
        }
    }

    #[test]
    fn occupancy_never_exceeds_capacity() {
        let q = FrameQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.push(req(0, 0)).is_ok());
        assert!(q.push(req(0, 1)).is_ok());
        assert_eq!(q.push(req(0, 2)), Err(ShedReason::QueueFull));
        assert_eq!(q.len(), 2);
        assert_eq!(q.high_water(), 2);
        assert_eq!(q.pushed(), 2);
        // Freeing a slot re-admits exactly one.
        assert_eq!(single(q.try_pop().unwrap()).seq, 0);
        assert!(q.push(req(0, 3)).is_ok());
        assert_eq!(q.push(req(0, 4)), Err(ShedReason::QueueFull));
        assert!(q.high_water() <= q.capacity());
    }

    #[test]
    fn fifo_order_is_preserved() {
        let q = FrameQueue::new(8);
        for s in 0..5u64 {
            q.push(req(0, s)).unwrap();
        }
        for s in 0..5u64 {
            assert_eq!(single(q.pop_blocking().unwrap()).seq, s);
        }
        assert!(q.is_empty());
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn close_sheds_new_pushes_but_drains_queued_work() {
        let q = FrameQueue::new(4);
        q.push(req(0, 0)).unwrap();
        q.push(req(1, 1)).unwrap();
        q.close();
        assert_eq!(q.push(req(0, 2)), Err(ShedReason::Closed));
        // Queued work is still delivered, then the shutdown signal.
        assert_eq!(single(q.pop_blocking().unwrap()).seq, 0);
        assert_eq!(single(q.pop_blocking().unwrap()).seq, 1);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_blocking().is_none(), "None must be sticky");
    }

    #[test]
    fn groups_count_per_member_and_shed_atomically() {
        let q = FrameQueue::new(4);
        q.push(req(0, 0)).unwrap();
        // A 3-member group fits exactly (1 + 3 == capacity 4)...
        q.push_group(vec![req(0, 1), req(1, 2), req(2, 3)]).unwrap();
        assert_eq!(q.len(), 4);
        assert_eq!(q.high_water(), 4);
        assert_eq!(q.pushed(), 4);
        // ...and the next single sheds: no slots left.
        assert_eq!(q.push(req(0, 4)), Err(ShedReason::QueueFull));
        // A 2-member group after a single pop still doesn't fit (3 + 2
        // > 4): the whole group sheds, the queue is untouched.
        assert_eq!(single(q.try_pop().unwrap()).seq, 0);
        assert_eq!(
            q.push_group(vec![req(0, 5), req(1, 6)]),
            Err(ShedReason::QueueFull)
        );
        assert_eq!(q.len(), 3);
        // The group dequeues as one atomic entry, FIFO-ordered inside.
        let entry = q.try_pop().unwrap();
        assert_eq!(entry.len(), 3);
        assert!(!entry.is_empty());
        match entry {
            QueueEntry::Group(g) => {
                assert_eq!(g.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
            }
            QueueEntry::Single(_) => panic!("expected a group"),
        }
        assert!(q.is_empty());
        // Empty groups are refused, not enqueued.
        assert_eq!(q.push_group(Vec::new()), Err(ShedReason::QueueFull));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let q = FrameQueue::new(0);
        assert_eq!(q.capacity(), 1);
        assert!(q.push(req(0, 0)).is_ok());
        assert_eq!(q.push(req(0, 1)), Err(ShedReason::QueueFull));
    }

    #[test]
    fn blocked_consumer_wakes_on_push_and_on_close() {
        let q = FrameQueue::new(4);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| {
                let mut got = Vec::new();
                while let Some(e) = q.pop_blocking() {
                    got.push(single(e).seq);
                }
                got
            });
            // Stagger pushes so the consumer really parks in between.
            for seq in 0..3u64 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                q.push(req(0, seq)).unwrap();
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
            q.close();
            assert_eq!(consumer.join().unwrap(), vec![0, 1, 2]);
        });
    }
}
