//! Per-client admission control: fairness under bursts.
//!
//! The bounded queue alone cannot be fair — one bursty client could
//! fill every slot and starve well-behaved streams. The admission
//! controller caps how many requests each client may hold in flight
//! (queued **or** rendering) at once, so a burst from one client sheds
//! *that client's* overflow ([`ShedReason::ClientSaturated`]) while
//! others keep their slots. Admission is charged at submit and released
//! only after the request leaves the system (served, expired or
//! failed), which is what makes the shed ledger exact.

use super::queue::ShedReason;
use std::sync::{Mutex, MutexGuard};

/// Interior ledger behind the mutex.
#[derive(Debug, Default)]
struct AdmissionState {
    /// In-flight count per client (grown on first sight of a client).
    inflight: Vec<usize>,
    /// Sum of `inflight` (kept incrementally; checked in debug builds).
    total: usize,
    admitted: u64,
    rejected: u64,
}

/// Caps each client's in-flight requests at a fixed bound and keeps an
/// exact admitted/rejected ledger.
#[derive(Debug)]
pub struct AdmissionController {
    max_inflight: usize,
    state: Mutex<AdmissionState>,
}

impl AdmissionController {
    /// A controller allowing each client at most `max_inflight`
    /// outstanding requests (clamped to >= 1 so every client can always
    /// make progress).
    pub fn new(max_inflight: usize) -> Self {
        AdmissionController {
            max_inflight: max_inflight.max(1),
            state: Mutex::new(AdmissionState::default()),
        }
    }

    fn lock(&self) -> MutexGuard<'_, AdmissionState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Try to charge one in-flight slot to `client`. Rejects with
    /// [`ShedReason::ClientSaturated`] when the client is at its cap.
    pub fn try_admit(&self, client: usize) -> Result<(), ShedReason> {
        let mut st = self.lock();
        if client >= st.inflight.len() {
            st.inflight.resize(client + 1, 0);
        }
        if st.inflight[client] >= self.max_inflight {
            st.rejected += 1;
            return Err(ShedReason::ClientSaturated);
        }
        st.inflight[client] += 1;
        st.total += 1;
        st.admitted += 1;
        Ok(())
    }

    /// Release one in-flight slot for `client` (after serve, expiry or
    /// failure). Releasing a client with nothing in flight is a bug in
    /// the caller's accounting; it is ignored in release builds and
    /// trips a debug assertion otherwise.
    pub fn release(&self, client: usize) {
        let mut guard = self.lock();
        // Reborrow through the guard once so the field borrows below
        // are disjoint (`inflight` vs `total`).
        let st = &mut *guard;
        let slot = st.inflight.get_mut(client).filter(|c| **c > 0);
        debug_assert!(
            slot.is_some(),
            "release without matching admit (client {client})"
        );
        if let Some(c) = slot {
            *c -= 1;
            st.total -= 1;
        }
    }

    /// Requests currently in flight for `client`.
    pub fn inflight(&self, client: usize) -> usize {
        self.lock().inflight.get(client).copied().unwrap_or(0)
    }

    /// Requests currently in flight across every client.
    pub fn total_inflight(&self) -> usize {
        self.lock().total
    }

    /// Total submissions ever admitted.
    pub fn admitted(&self) -> u64 {
        self.lock().admitted
    }

    /// Total submissions ever rejected at the cap.
    pub fn rejected(&self) -> u64 {
        self.lock().rejected
    }

    /// The per-client in-flight bound this controller enforces.
    pub fn max_inflight(&self) -> usize {
        self.max_inflight
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn per_client_cap_and_release() {
        let a = AdmissionController::new(2);
        assert!(a.try_admit(0).is_ok());
        assert!(a.try_admit(0).is_ok());
        assert_eq!(a.try_admit(0), Err(ShedReason::ClientSaturated));
        // Another client is unaffected by client 0 being saturated.
        assert!(a.try_admit(1).is_ok());
        assert_eq!(a.inflight(0), 2);
        assert_eq!(a.inflight(1), 1);
        assert_eq!(a.total_inflight(), 3);
        a.release(0);
        assert!(a.try_admit(0).is_ok());
        assert_eq!(a.admitted(), 4);
        assert_eq!(a.rejected(), 1);
    }

    #[test]
    fn zero_cap_clamps_to_one() {
        let a = AdmissionController::new(0);
        assert_eq!(a.max_inflight(), 1);
        assert!(a.try_admit(5).is_ok());
        assert_eq!(a.try_admit(5), Err(ShedReason::ClientSaturated));
    }

    #[test]
    fn burst_from_one_client_cannot_starve_another() {
        let a = AdmissionController::new(3);
        // Client 0 bursts far past its cap: exactly `cap` slots stick.
        let mut shed = 0u64;
        for _ in 0..50 {
            if a.try_admit(0).is_err() {
                shed += 1;
            }
        }
        assert_eq!(a.inflight(0), 3);
        assert_eq!(shed, 47);
        // The well-behaved client still gets all of its slots.
        for _ in 0..3 {
            assert!(a.try_admit(1).is_ok());
        }
    }

    #[test]
    fn prop_admission_ledger_is_exact_under_random_interleaving() {
        forall(64, |rng| {
            let cap = 1 + rng.below(4);
            let clients = 1 + rng.below(5);
            let a = AdmissionController::new(cap);
            // Shadow model: per-client in-flight counts.
            let mut model = vec![0usize; clients];
            let mut admitted = 0u64;
            let mut rejected = 0u64;
            for _ in 0..200 {
                let c = rng.below(clients);
                if rng.below(3) == 0 && model[c] > 0 {
                    a.release(c);
                    model[c] -= 1;
                } else {
                    match a.try_admit(c) {
                        Ok(()) => {
                            model[c] += 1;
                            admitted += 1;
                        }
                        Err(r) => {
                            assert_eq!(r, ShedReason::ClientSaturated);
                            rejected += 1;
                        }
                    }
                }
                // Invariants hold at every step, not just at the end.
                assert!(a.inflight(c) <= cap, "cap violated for client {c}");
                assert_eq!(a.inflight(c), model[c]);
            }
            let total: usize = model.iter().sum();
            assert_eq!(a.total_inflight(), total);
            assert_eq!(a.admitted(), admitted);
            assert_eq!(a.rejected(), rejected);
        });
    }
}
