//! Synthetic open-loop load generator for the serving layer.
//!
//! Drives a [`FrameServer`] with per-client camera streams on a fixed
//! arrival schedule. **Open-loop** is the load-testing property that
//! matters: arrivals never wait for completions (and
//! [`FrameServer::submit`] never blocks), so when the server falls
//! behind, pressure builds exactly as it would from real clients —
//! this is what makes shed counts and tail latencies honest instead of
//! the coordinated-omission numbers a closed loop would report.
//!
//! Fault injection:
//!
//! * **bursts** — client 0 periodically dumps
//!   [`LoadGenConfig::burst_extra`] extra requests on top of its
//!   schedule, the admission-fairness stressor;
//! * **slow client** — the last client wakes at a quarter of the rate
//!   but submits its backlog of four requests at once (same average
//!   rate, maximally clumped), the classic laggy-stream pattern;
//! * **jitter** — uniform arrival-time noise, deterministic per seed.
//!
//! **Correlated mode** ([`LoadGenConfig::correlated`]): instead of
//! independent per-client streams, every client orbits the *same*
//! scene path with a small fixed per-client eye offset
//! ([`LoadGenConfig::correlated_spread`]) — the stereo-pair /
//! co-located-XR workload. Each tick submits the whole set as one
//! atomic group through [`FrameServer::submit_batch`], so the server's
//! batch lane (shared front ends, cross-view LoD-search seeding, one
//! interleaved tile schedule) carries the load. A group that does not
//! fit the queue sheds whole, one shed per member, keeping the ledger
//! per-frame.
//!
//! The run is two-phase: a warmup phase finds the QoS operating point,
//! then [`FrameServer::reset_window`] starts the measured window, so
//! reported percentiles and the accounting ledger cover exactly the
//! measured arrivals.

use super::{FrameServer, ServeConfig, ServeReport};
use crate::coordinator::{FramePipeline, RenderOptions};
use crate::math::{Camera, Vec3};
use crate::util::Rng;
use std::time::{Duration, Instant};

/// Load-generator configuration: one synthetic arrival schedule per
/// client.
#[derive(Clone, Copy, Debug)]
pub struct LoadGenConfig {
    /// Number of concurrent client streams.
    pub clients: usize,
    /// Measured submissions per client (after warmup).
    pub frames: usize,
    /// Warmup submissions per client (excluded from the report window;
    /// QoS state found during warmup persists).
    pub warmup: usize,
    /// Seconds between arrivals per client; `0.0` means back-to-back
    /// maximum pressure.
    pub period: f64,
    /// Every `burst_every`-th arrival of client 0 is a burst
    /// (`0` disables bursts).
    pub burst_every: usize,
    /// Extra requests client 0 submits per burst.
    pub burst_extra: usize,
    /// Uniform arrival jitter as a fraction of `period` (e.g. `0.2`
    /// shifts each arrival by up to ±20% of the period).
    pub jitter: f64,
    /// Make the last client a slow/clumped stream (4x period, 4
    /// requests per wakeup); needs at least 2 clients.
    pub slow_client: bool,
    /// Correlated co-orbit mode: all clients follow the first camera
    /// path with small per-client eye offsets, and each tick submits
    /// one atomic group via [`FrameServer::submit_batch`] (the batch
    /// lane renders it). Bursts and the slow client do not apply — the
    /// group *is* the correlated arrival pattern.
    pub correlated: bool,
    /// Eye-offset spacing (world units) between adjacent clients in
    /// correlated mode; keep it small so the batch lane's pose-close
    /// seeding applies.
    pub correlated_spread: f32,
    /// Seed for the deterministic jitter streams.
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            clients: 2,
            frames: 32,
            warmup: 8,
            period: 0.005,
            burst_every: 0,
            burst_extra: 0,
            jitter: 0.0,
            slow_client: false,
            correlated: false,
            correlated_spread: 0.05,
            seed: 0x51E7_ACE5,
        }
    }
}

/// Shift `cam`'s eye by `offset` world units keeping orientation and
/// intrinsics exactly — the per-client disparity of correlated mode.
/// For a view `V(x) = R x + t`, moving the eye by `d` gives
/// `t' = t - R d`.
fn offset_camera(cam: &Camera, offset: Vec3) -> Camera {
    let mut out = *cam;
    let r = cam.view.rotation();
    for i in 0..3 {
        out.view.m[i][3] -= r.row(i).dot(offset);
    }
    out
}

/// Client `c`'s fixed eye offset in correlated mode: clients fan out
/// laterally, centred on the base path.
fn correlated_offset(load: &LoadGenConfig, c: usize) -> Vec3 {
    let centred = c as f32 - (load.clients.saturating_sub(1)) as f32 / 2.0;
    Vec3::new(load.correlated_spread * centred, 0.0, 0.0)
}

/// `(arrival period, requests per arrival)` for one client stream.
fn stream_plan(load: &LoadGenConfig, client: usize) -> (f64, usize) {
    if load.slow_client && load.clients > 1 && client == load.clients - 1 {
        (load.period * 4.0, 4)
    } else {
        (load.period, 1)
    }
}

/// Run one phase: every client submits exactly `frames` requests on its
/// open-loop schedule; returns when all generator threads have finished
/// submitting (not when the server has finished rendering).
fn drive(
    server: &FrameServer<'_>,
    load: &LoadGenConfig,
    paths: &[Vec<Camera>],
    frames: usize,
    phase_tag: u64,
) {
    if frames == 0 {
        return;
    }
    std::thread::scope(|s| {
        for c in 0..load.clients {
            let path = &paths[c % paths.len()];
            s.spawn(move || {
                let mut rng =
                    Rng::new(load.seed ^ (c as u64).wrapping_mul(0x9E37_79B9) ^ phase_tag);
                let (period, per_arrival) = stream_plan(load, c);
                let start = Instant::now();
                let mut sent = 0usize;
                let mut arrival = 0usize;
                while sent < frames {
                    // Absolute schedule: lateness never shifts future
                    // arrivals (open loop).
                    let mut due = period * arrival as f64;
                    if load.jitter > 0.0 {
                        due += period * load.jitter * (2.0 * rng.f32() as f64 - 1.0);
                    }
                    let elapsed = start.elapsed().as_secs_f64();
                    if due > elapsed {
                        std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                    }
                    let mut n = per_arrival;
                    if c == 0
                        && load.burst_every > 0
                        && arrival % load.burst_every == load.burst_every - 1
                    {
                        n += load.burst_extra;
                    }
                    // Sheds are part of the experiment, not an error.
                    for _ in 0..n.min(frames - sent) {
                        let _ = server.submit(c, path[sent % path.len()]);
                        sent += 1;
                    }
                    arrival += 1;
                }
            });
        }
    });
}

/// Run one correlated phase: every tick submits one atomic group (one
/// offset view of the shared path per client) via
/// [`FrameServer::submit_batch`]. Open loop like [`drive`]: the
/// schedule is absolute, and a shed group never delays later ticks.
fn drive_correlated(
    server: &FrameServer<'_>,
    load: &LoadGenConfig,
    path: &[Camera],
    frames: usize,
    phase_tag: u64,
) {
    if frames == 0 {
        return;
    }
    let mut rng = Rng::new(load.seed ^ phase_tag);
    let mut group: Vec<(usize, Camera)> = Vec::with_capacity(load.clients);
    let start = Instant::now();
    for tick in 0..frames {
        let mut due = load.period * tick as f64;
        if load.jitter > 0.0 {
            due += load.period * load.jitter * (2.0 * rng.f32() as f64 - 1.0);
        }
        let elapsed = start.elapsed().as_secs_f64();
        if due > elapsed {
            std::thread::sleep(Duration::from_secs_f64(due - elapsed));
        }
        let base = path[tick % path.len()];
        group.clear();
        group.extend(
            (0..load.clients).map(|c| (c, offset_camera(&base, correlated_offset(load, c)))),
        );
        // A shed group is part of the experiment, not an error.
        let _ = server.submit_batch(&group);
    }
}

/// Drive `pipeline` through a [`FrameServer`] with `serve` settings
/// under the synthetic load `load`, one camera path per client
/// (recycled modulo when `paths` is shorter). Returns the measured
/// window's [`ServeReport`]: per the generator, exactly
/// `load.clients * load.frames` submissions, each accounted once as
/// served / expired / failed / shed.
pub fn run_load(
    pipeline: &FramePipeline,
    serve: ServeConfig,
    load: &LoadGenConfig,
    paths: &[Vec<Camera>],
) -> ServeReport {
    assert!(load.clients > 0, "load generator needs at least one client");
    assert!(
        !paths.is_empty() && paths.iter().all(|p| !p.is_empty()),
        "load generator needs at least one non-empty camera path"
    );
    let server = FrameServer::new(pipeline, serve, load.clients);
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..serve.workers.max(1))
            .map(|_| s.spawn(|| server.worker()))
            .collect();
        if load.warmup > 0 {
            if load.correlated {
                drive_correlated(&server, load, &paths[0], load.warmup, 0xAA);
            } else {
                drive(&server, load, paths, load.warmup, 0xAA);
            }
            server.drain();
        }
        // Warmup found the QoS operating point; measure from here.
        server.reset_window();
        if load.correlated {
            drive_correlated(&server, load, &paths[0], load.frames, 0xBB);
        } else {
            drive(&server, load, paths, load.frames, 0xBB);
        }
        server.drain();
        server.close();
        for w in workers {
            w.join().unwrap();
        }
    });
    server.report()
}

/// Mean seconds/frame of a fresh session over `cams` at LoD bound
/// `tau` — the calibration the bench scenarios use to pick offered
/// rates and budgets relative to what the machine can actually do.
pub fn calibrate_frame_seconds(
    pipeline: &FramePipeline,
    tau: f32,
    cams: &[Camera],
) -> f64 {
    let mut session = pipeline
        .session_with(RenderOptions { lod_tau: tau, ..pipeline.default_options() });
    for cam in cams {
        let _ = session.render(cam);
    }
    let st = session.stats();
    if st.frames == 0 {
        0.0
    } else {
        st.wall_seconds / st.frames as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::walkthrough;
    use crate::serve::QosConfig;

    fn pipeline() -> FramePipeline {
        FramePipeline::builder(SceneConfig::small_scale().quick().build(23)).build()
    }

    #[test]
    fn measured_window_accounts_exactly_the_measured_arrivals() {
        let p = pipeline();
        let paths = vec![walkthrough(6.0, 8, 64, 64)];
        let load = LoadGenConfig {
            clients: 2,
            frames: 5,
            warmup: 2,
            period: 0.0,
            ..LoadGenConfig::default()
        };
        let serve = ServeConfig {
            queue_capacity: 32,
            max_inflight: 32,
            workers: 2,
            budget: 10.0,
            ..ServeConfig::default()
        };
        let r = run_load(&p, serve, &load, &paths);
        assert_eq!(r.submitted, 10, "2 clients x 5 measured frames");
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
        assert_eq!(r.served, 10, "roomy caps + huge budget: nothing sheds");
        assert!(r.span_seconds > 0.0);
        assert!(r.served_fps() > 0.0);
        assert_eq!(r.clients.len(), 2);
        assert_eq!(r.e2e.count(), r.served);
    }

    #[test]
    fn bursts_and_slow_clients_keep_per_client_totals_exact() {
        let p = pipeline();
        let paths = vec![walkthrough(6.0, 6, 64, 64)];
        let load = LoadGenConfig {
            clients: 3,
            frames: 7,
            warmup: 0,
            period: 0.001,
            burst_every: 2,
            burst_extra: 3,
            jitter: 0.2,
            slow_client: true,
            ..LoadGenConfig::default()
        };
        let serve = ServeConfig {
            queue_capacity: 4,
            max_inflight: 2,
            workers: 1,
            budget: 10.0,
            qos: QosConfig::disabled(),
            ..ServeConfig::default()
        };
        let r = run_load(&p, serve, &load, &paths);
        // Fault injection changes arrival *shape*, never the totals.
        assert_eq!(r.submitted, 21, "3 clients x 7 frames");
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
        assert!(r.queue_high_water <= r.queue_capacity);
    }

    #[test]
    fn correlated_mode_batches_every_tick_and_balances_the_ledger() {
        let p = pipeline();
        let paths = vec![walkthrough(6.0, 5, 64, 64)];
        let load = LoadGenConfig {
            clients: 3,
            frames: 4,
            warmup: 1,
            period: 0.0,
            correlated: true,
            ..LoadGenConfig::default()
        };
        let serve = ServeConfig {
            queue_capacity: 32,
            max_inflight: 32,
            workers: 1,
            budget: 10.0,
            qos: QosConfig::disabled(),
            ..ServeConfig::default()
        };
        let r = run_load(&p, serve, &load, &paths);
        assert_eq!(r.submitted, 12, "3 clients x 4 measured ticks");
        assert_eq!(r.served, 12, "roomy caps + huge budget: nothing sheds");
        assert_eq!(
            r.submitted,
            r.served + r.expired + r.failed + r.shed_total()
        );
        // Each measured tick went through the batch lane as one group.
        assert_eq!(r.batch.batches, 4);
        assert_eq!(r.batch.views, 12);
        // Pure lateral offsets well inside the pose-close thresholds:
        // the two non-leader views seed off the leader every tick.
        assert_eq!(r.batch.searches_seeded, 8);
    }

    #[test]
    fn calibration_reports_positive_frame_time() {
        let p = pipeline();
        let cams = walkthrough(6.0, 3, 64, 64);
        let s = calibrate_frame_seconds(&p, 32.0, &cams);
        assert!(s > 0.0 && s.is_finite());
    }
}
