//! Deadline-adaptive LoD degradation (QoS).
//!
//! When a client stream keeps missing its latency budget, the right
//! lever in a point-based renderer is the LoD error bound `tau`: a
//! coarser cut selects fewer nodes, shrinking every downstream stage
//! (project, bin, sort, blend). The [`QosController`] watches observed
//! frame latencies and walks `tau` **stepwise** between the session's
//! base value (full quality) and a configured ceiling (the quality
//! floor):
//!
//! * **degrade** — after [`QosConfig::miss_threshold`] *consecutive*
//!   deadline misses, raise `tau` by [`QosConfig::step`], clamped to
//!   [`QosConfig::max_tau`];
//! * **recover** — only after [`QosConfig::recover_after`] consecutive
//!   frames land under `recover_headroom * budget` does `tau` step back
//!   down toward base. Frames in the dead band between the headroom
//!   line and the budget reset the recovery streak, which is the
//!   hysteresis that prevents degrade/recover flapping at the boundary.
//!
//! The controller is a pure state machine — it never touches a session
//! itself. The [`FrameServer`](super::FrameServer) applies the returned
//! tau to the lane's [`RenderOptions`](crate::coordinator::RenderOptions)
//! where, with steps no larger than the cut cache's
//! [`max_tau_step`](crate::lod::CutCacheConfig::max_tau_step), each
//! nudge revalidates the cached cut instead of cold-starting the
//! search.

/// Tuning knobs for the deadline-adaptive tau controller.
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// Master switch; disabled means [`QosController::observe`] never
    /// changes tau (the fixed-quality baseline).
    pub enabled: bool,
    /// Tau increment per degradation step (and decrement per recovery
    /// step). Keep at or below the cut cache's
    /// [`max_tau_step`](crate::lod::CutCacheConfig::max_tau_step) so
    /// every QoS nudge stays on the cache's warm revalidation path.
    pub step: f32,
    /// Quality floor: tau never degrades beyond this ceiling.
    pub max_tau: f32,
    /// Consecutive deadline misses required before a degrade step.
    pub miss_threshold: u32,
    /// Recovery requires latencies at or below
    /// `recover_headroom * budget` (in `(0, 1)`); the gap to the budget
    /// is the hysteresis dead band.
    pub recover_headroom: f64,
    /// Consecutive sufficiently-fast frames required before a recovery
    /// step.
    pub recover_after: u32,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: true,
            step: 4.0,
            max_tau: 128.0,
            miss_threshold: 2,
            recover_headroom: 0.5,
            recover_after: 16,
        }
    }
}

impl QosConfig {
    /// A config with adaptation switched off (fixed-tau baseline).
    pub fn disabled() -> Self {
        QosConfig { enabled: false, ..QosConfig::default() }
    }
}

/// Per-client-stream degradation state machine. Feed it one observed
/// latency per completed frame via [`observe`](Self::observe); it
/// returns the new tau whenever one of the transitions fires.
#[derive(Clone, Copy, Debug)]
pub struct QosController {
    base_tau: f32,
    tau: f32,
    miss_streak: u32,
    calm_streak: u32,
    degrade_events: u64,
    recover_events: u64,
}

impl QosController {
    /// A controller at full quality: tau starts at (and never recovers
    /// below) `base_tau`.
    pub fn new(base_tau: f32) -> Self {
        QosController {
            base_tau,
            tau: base_tau,
            miss_streak: 0,
            calm_streak: 0,
            degrade_events: 0,
            recover_events: 0,
        }
    }

    /// The tau the stream should currently render at.
    pub fn tau(&self) -> f32 {
        self.tau
    }

    /// The full-quality tau this controller recovers toward.
    pub fn base_tau(&self) -> f32 {
        self.base_tau
    }

    /// Whether the stream is currently degraded below full quality.
    pub fn is_degraded(&self) -> bool {
        self.tau > self.base_tau
    }

    /// Degradation steps taken so far.
    pub fn degrade_events(&self) -> u64 {
        self.degrade_events
    }

    /// Recovery steps taken so far.
    pub fn recover_events(&self) -> u64 {
        self.recover_events
    }

    /// Record one observed frame latency against its budget (both in
    /// seconds). Returns `Some(new_tau)` when a degrade or recover step
    /// fired, `None` when tau is unchanged.
    pub fn observe(
        &mut self,
        latency_seconds: f64,
        budget_seconds: f64,
        cfg: &QosConfig,
    ) -> Option<f32> {
        if !cfg.enabled {
            return None;
        }
        if latency_seconds > budget_seconds {
            // Deadline miss: any recovery progress is void.
            self.calm_streak = 0;
            self.miss_streak = self.miss_streak.saturating_add(1);
            if self.miss_streak >= cfg.miss_threshold.max(1) && self.tau < cfg.max_tau {
                self.miss_streak = 0;
                self.tau = (self.tau + cfg.step).min(cfg.max_tau);
                self.degrade_events += 1;
                return Some(self.tau);
            }
            None
        } else {
            self.miss_streak = 0;
            if self.is_degraded()
                && latency_seconds <= budget_seconds * cfg.recover_headroom
            {
                self.calm_streak = self.calm_streak.saturating_add(1);
                if self.calm_streak >= cfg.recover_after.max(1) {
                    self.calm_streak = 0;
                    self.tau = (self.tau - cfg.step).max(self.base_tau);
                    self.recover_events += 1;
                    return Some(self.tau);
                }
            } else {
                // Dead-band frame (made the deadline but without enough
                // headroom) — or nothing to recover from.
                self.calm_streak = 0;
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUDGET: f64 = 0.010;

    fn cfg() -> QosConfig {
        QosConfig {
            enabled: true,
            step: 4.0,
            max_tau: 48.0,
            miss_threshold: 2,
            recover_headroom: 0.5,
            recover_after: 3,
        }
    }

    #[test]
    fn degrades_only_after_consecutive_misses() {
        let c = cfg();
        let mut q = QosController::new(32.0);
        assert_eq!(q.observe(0.020, BUDGET, &c), None, "first miss waits");
        // An on-time frame breaks the miss streak.
        assert_eq!(q.observe(0.002, BUDGET, &c), None);
        assert_eq!(q.observe(0.020, BUDGET, &c), None);
        assert_eq!(q.observe(0.020, BUDGET, &c), Some(36.0));
        assert!(q.is_degraded());
        assert_eq!(q.degrade_events(), 1);
    }

    #[test]
    fn degradation_is_clamped_at_max_tau() {
        let c = cfg();
        let mut q = QosController::new(32.0);
        for _ in 0..40 {
            q.observe(0.050, BUDGET, &c);
        }
        assert_eq!(q.tau(), c.max_tau);
        // Fully degraded: further misses fire no more events.
        let events = q.degrade_events();
        assert_eq!(q.observe(0.050, BUDGET, &c), None);
        assert_eq!(q.observe(0.050, BUDGET, &c), None);
        assert_eq!(q.degrade_events(), events);
    }

    #[test]
    fn recovery_is_hysteretic_and_never_undershoots_base() {
        let c = cfg();
        let mut q = QosController::new(32.0);
        q.observe(0.020, BUDGET, &c);
        q.observe(0.020, BUDGET, &c);
        assert_eq!(q.tau(), 36.0);
        // Dead-band frames (under budget, over headroom) never recover.
        for _ in 0..20 {
            assert_eq!(q.observe(0.008, BUDGET, &c), None);
        }
        assert_eq!(q.tau(), 36.0);
        // Two fast frames then a dead-band frame: streak resets.
        q.observe(0.002, BUDGET, &c);
        q.observe(0.002, BUDGET, &c);
        assert_eq!(q.observe(0.008, BUDGET, &c), None);
        // Three consecutive fast frames finally step back down.
        q.observe(0.002, BUDGET, &c);
        q.observe(0.002, BUDGET, &c);
        assert_eq!(q.observe(0.002, BUDGET, &c), Some(32.0));
        assert!(!q.is_degraded());
        assert_eq!(q.recover_events(), 1);
        // At base, fast frames change nothing: tau never undershoots.
        for _ in 0..10 {
            assert_eq!(q.observe(0.001, BUDGET, &c), None);
        }
        assert_eq!(q.tau(), 32.0);
    }

    #[test]
    fn disabled_controller_never_moves_tau() {
        let c = QosConfig::disabled();
        let mut q = QosController::new(32.0);
        for _ in 0..50 {
            assert_eq!(q.observe(1.0, BUDGET, &c), None);
        }
        assert_eq!(q.tau(), 32.0);
        assert_eq!(q.degrade_events(), 0);
    }

    #[test]
    fn recovery_step_clamps_onto_base_exactly() {
        // step 4 from base 32 to 34 would overshoot on the way down if
        // the clamp were missing; max_tau at 34 forces the odd ceiling.
        let c = QosConfig { max_tau: 34.0, recover_after: 1, ..cfg() };
        let mut q = QosController::new(32.0);
        q.observe(0.020, BUDGET, &c);
        q.observe(0.020, BUDGET, &c);
        assert_eq!(q.tau(), 34.0);
        assert_eq!(q.observe(0.001, BUDGET, &c), Some(32.0));
        assert_eq!(q.tau(), q.base_tau());
    }
}
