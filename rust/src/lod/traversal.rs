//! Streaming SLTree traversal (paper Sec. III-A / Fig. 4).
//!
//! A subtree queue seeds with the top subtree; worker threads (LT units)
//! dequeue one *activation* at a time — `(subtree, parent-node filter)` —
//! and run the DFS-with-skip scan over the activated root segments:
//!
//! * node out of frustum      -> skip its in-subtree descendants
//! * node meets LoD / leaf    -> select it, skip descendants
//! * node needs refinement    -> fall through to in-subtree children and
//!                               enqueue its boundary child subtrees
//!
//! All nodes of a subtree are contiguous in DRAM, so every fetch is a
//! streaming burst; because subtrees are size-capped, per-activation
//! work is bounded; dynamic (greedy) scheduling soaks up the remaining
//! view-dependent imbalance. Semantics are **bit-accurate** vs
//! `LodTree::canonical_search` (asserted by tests and the `proptest`
//! suite in `rust/tests/`).

use super::sltree::SlTree;
use super::tree::{LodTree, NONE};
use crate::math::Camera;

/// Execution + memory trace of one SLTree traversal; the input the
/// LTCore / GPU models replay.
#[derive(Clone, Debug, Default)]
pub struct TraversalTrace {
    /// Nodes tested per worker thread (dynamic greedy schedule).
    pub per_thread_nodes: Vec<u64>,
    /// Node tests in total.
    pub visited: u64,
    /// Selected (cut) Gaussians.
    pub selected: u64,
    /// Distinct subtree DRAM fetches (first touch of a subtree).
    pub subtree_fetches: u64,
    /// Bytes streamed from DRAM for fetched subtrees.
    pub bytes_streamed: u64,
    /// Total activations dequeued (>= subtree_fetches: a subtree can be
    /// activated by several boundary parents but is fetched once).
    pub activations: u64,
    /// Peak subtree-queue occupancy.
    pub queue_peak: usize,
    /// Per-activation node counts (workload distribution, Fig. 12 util).
    pub activation_sizes: Vec<u32>,
    /// Subtree id per activation, in dequeue order (replayed by the
    /// LTCore subtree-cache model).
    pub activation_sids: Vec<u32>,
    /// Bytes of each subtree (indexed by sid) for memory accounting.
    pub subtree_bytes: Vec<u32>,
}

impl TraversalTrace {
    /// PE utilization under the dynamic schedule: mean/max of per-thread
    /// work (1.0 = perfectly balanced).
    pub fn utilization(&self) -> f64 {
        let max = self.per_thread_nodes.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        let mean = self.per_thread_nodes.iter().sum::<u64>() as f64
            / self.per_thread_nodes.len() as f64;
        mean / max as f64
    }
}

/// One queued work item: an activation of `sid` for roots whose parent
/// node equals `parent_filter`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Activation {
    sid: u32,
    parent_filter: u32,
}

/// Traverse the SLTree and return the selected cut (ascending node ids)
/// plus the trace. `threads` models the LT-unit / GPU-thread count for
/// the workload-distribution statistics (results are independent of it).
pub fn traverse_sltree(
    tree: &LodTree,
    slt: &SlTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
) -> (Vec<u32>, TraversalTrace) {
    let threads = threads.max(1);
    let frustum = cam.frustum();
    let mut cut = Vec::new();
    let mut trace = TraversalTrace {
        per_thread_nodes: vec![0; threads],
        ..Default::default()
    };

    let mut queue = std::collections::VecDeque::new();
    queue.push_back(Activation { sid: slt.top, parent_filter: NONE });
    let mut fetched = vec![false; slt.len()];
    trace.subtree_bytes = slt.subtrees.iter().map(|s| s.bytes() as u32).collect();

    while let Some(act) = queue.pop_front() {
        trace.queue_peak = trace.queue_peak.max(queue.len() + 1);
        trace.activations += 1;
        let st = &slt.subtrees[act.sid as usize];
        if !fetched[act.sid as usize] {
            fetched[act.sid as usize] = true;
            trace.subtree_fetches += 1;
            trace.bytes_streamed += st.bytes();
        }

        let mut act_nodes = 0u32;
        // Scan each activated root segment with the skip dataflow.
        for root in &st.roots {
            if root.parent_node != act.parent_filter {
                continue;
            }
            let start = root.pos as usize;
            let end = start + 1 + st.skip[start] as usize;
            let mut p = start;
            while p < end {
                let n = st.nodes[p];
                act_nodes += 1;
                if !frustum.intersects_aabb(&tree.aabbs[n as usize]) {
                    p += 1 + st.skip[p] as usize;
                    continue;
                }
                let node = &tree.nodes[n as usize];
                if tree.meets_lod(n, cam, tau) || node.is_leaf() {
                    cut.push(n);
                    p += 1 + st.skip[p] as usize;
                    continue;
                }
                // Refine: descend. In-subtree children follow in DFS
                // order; out-of-subtree children are activated via the
                // boundary links of this position.
                let pos = p as u32;
                // boundary is sorted by (pos, sid): binary search the run.
                let lo = st.boundary.partition_point(|&(bp, _)| bp < pos);
                for &(bp, csid) in &st.boundary[lo..] {
                    if bp != pos {
                        break;
                    }
                    queue.push_back(Activation { sid: csid, parent_filter: n });
                }
                p += 1;
            }
        }
        trace.visited += act_nodes as u64;
        trace.activation_sizes.push(act_nodes);
        trace.activation_sids.push(act.sid);
        // Dynamic greedy schedule: next activation goes to the least
        // loaded thread (what the LT-unit round-robin dequeue achieves).
        let t = trace
            .per_thread_nodes
            .iter()
            .enumerate()
            .min_by_key(|(_, &w)| w)
            .map(|(i, _)| i)
            .unwrap();
        trace.per_thread_nodes[t] += act_nodes as u64;
    }

    trace.selected = cut.len() as u64;
    cut.sort_unstable();
    (cut, trace)
}

/// Static one-thread-per-subtree schedule over the *canonical* tree's
/// top-level subtrees — the naive GPU parallelization of Fig. 3. Returns
/// the per-thread visited-node workloads.
pub fn naive_static_workloads(
    tree: &LodTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
) -> Vec<u64> {
    let frustum = cam.frustum();
    let mut workloads = vec![0u64; threads.max(1)];
    // Assign each root-child subtree to threads round-robin (static,
    // offline — exactly what conventional tree accelerators do).
    let top_level: Vec<u32> = tree.children(LodTree::ROOT).collect();
    for (i, &sub_root) in top_level.iter().enumerate() {
        let t = i % workloads.len();
        // Sequential canonical descent of this subtree.
        let mut stack = vec![sub_root];
        while let Some(n) = stack.pop() {
            workloads[t] += 1;
            if !frustum.intersects_aabb(&tree.aabbs[n as usize]) {
                continue;
            }
            if tree.meets_lod(n, cam, tau) || tree.nodes[n as usize].is_leaf() {
                continue;
            }
            stack.extend(tree.children(n));
        }
    }
    workloads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::Scene;
    use crate::util::stats::cov;

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    #[test]
    fn bit_accurate_vs_canonical() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        for cam_i in 0..6 {
            let cam = scene.scenario_camera(cam_i);
            for tau in [2.0, 8.0, 32.0] {
                let (want, _) = scene.tree.canonical_search(&cam, tau);
                let (got, _) = traverse_sltree(&scene.tree, &slt, &cam, tau, 4);
                assert_eq!(got, want, "cam {cam_i} tau {tau}");
            }
        }
    }

    #[test]
    fn bit_accurate_without_merging_too() {
        let scene = scene();
        let slt = SlTree::partition_unmerged(&scene.tree, 16);
        let cam = scene.scenario_camera(2);
        let (want, _) = scene.tree.canonical_search(&cam, 8.0);
        let (got, _) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert_eq!(got, want);
    }

    #[test]
    fn visits_no_more_than_canonical_plus_cut_overhead() {
        // SLTree never tests nodes below the cut; activation overhead is
        // bounded by the subtree roots touched.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(1);
        let (_, ct) = scene.tree.canonical_search(&cam, 8.0);
        let (_, st) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert!(
            st.visited <= ct.visited,
            "SLTree visited {} > canonical {}",
            st.visited,
            ct.visited
        );
    }

    #[test]
    fn traversal_is_far_below_exhaustive() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        // Farthest scenario + coarse tau: the cut sits high in the tree.
        let cam = scene.scenario_camera(5);
        let (_, coarse) = traverse_sltree(&scene.tree, &slt, &cam, 128.0, 4);
        let (_, fine) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        // The §V-C DRAM claim: frustum+cut traversal touches a fraction
        // of the tree, and coarser LoD touches strictly less.
        assert!(
            (coarse.visited as f64) < 0.6 * scene.tree.len() as f64,
            "visited {} of {}",
            coarse.visited,
            scene.tree.len()
        );
        assert!(coarse.visited < fine.visited);
        assert!((fine.visited as f64) < scene.tree.len() as f64);
    }

    #[test]
    fn dynamic_schedule_is_balanced() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(0);
        let (_, t) = traverse_sltree(&scene.tree, &slt, &cam, 4.0, 8);
        let naive = naive_static_workloads(&scene.tree, &cam, 4.0, 8);
        let balanced: Vec<f64> = t.per_thread_nodes.iter().map(|&w| w as f64).collect();
        let imbalanced: Vec<f64> = naive.iter().map(|&w| w as f64).collect();
        assert!(
            cov(&balanced) < cov(&imbalanced),
            "SLTree {} !< naive {}",
            cov(&balanced),
            cov(&imbalanced)
        );
    }

    #[test]
    fn fetches_are_bounded_by_subtree_count() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(5);
        let (_, t) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert!(t.subtree_fetches <= slt.len() as u64);
        assert!(t.activations >= t.subtree_fetches);
        // Every fetch streams one whole subtree, and only the *first*
        // activation of a subtree fetches it: recompute the expected
        // byte count by summing `subtree_bytes` over first-touch sids.
        let mut fetched = vec![false; slt.len()];
        let mut expected_bytes = 0u64;
        let mut expected_fetches = 0u64;
        for &sid in &t.activation_sids {
            if !fetched[sid as usize] {
                fetched[sid as usize] = true;
                expected_fetches += 1;
                expected_bytes += t.subtree_bytes[sid as usize] as u64;
            }
        }
        assert_eq!(t.subtree_fetches, expected_fetches);
        assert_eq!(t.bytes_streamed, expected_bytes);
        assert!(t.bytes_streamed > 0);
    }
}
