//! Streaming SLTree traversal (paper Sec. III-A / Fig. 4).
//!
//! A subtree queue seeds with the top subtree; worker threads (LT units)
//! dequeue one *activation* at a time — `(subtree, parent-node filter)` —
//! and run the DFS-with-skip scan over the activated root segments:
//!
//! * node out of frustum      -> skip its in-subtree descendants
//! * node meets LoD / leaf    -> select it, skip descendants
//! * node needs refinement    -> fall through to in-subtree children and
//!                               enqueue its boundary child subtrees
//!
//! All nodes of a subtree are contiguous in DRAM, so every fetch is a
//! streaming burst; because subtrees are size-capped, per-activation
//! work is bounded; dynamic (greedy) scheduling soaks up the remaining
//! view-dependent imbalance. Semantics are **bit-accurate** vs
//! [`LodTree::canonical_search`] (asserted by tests and the `proptest`
//! suite in `rust/tests/`).
//!
//! Two entry points share the scan dataflow:
//!
//! * [`traverse_sltree`] — the full (cold) search from the top subtree;
//! * [`refine_sltree`] — a *bounded* search seeded at one node, used by
//!   [`super::cut_cache::CutCache`] to patch a cached cut when a node
//!   stops meeting the LoD between frames.

use super::sltree::{SlTree, Subtree};
use super::tree::{LodTree, NONE};
use crate::math::{Camera, Frustum};
use std::collections::VecDeque;

/// Execution + memory trace of one SLTree traversal; the input the
/// LTCore / GPU models replay.
///
/// Counter invariants (asserted by `fetches_are_bounded_by_subtree_count`
/// and the proptest suite):
///
/// * `activations >= subtree_fetches` — a subtree may be activated by
///   several boundary parents but is fetched (streamed from DRAM) only
///   on first touch;
/// * `bytes_streamed` = sum of `subtree_bytes[sid]` over first-touch
///   sids, in bytes (36 B per node, the Fig. 7 attribute set);
/// * `visited >= selected` and `selected ==` the returned cut length;
/// * `visited == activation_sizes.iter().sum()` for full traversals;
/// * `revalidated + reseeded > 0` implies `cache_hit == 1` — only the
///   temporal cut cache's incremental path produces them.
#[derive(Clone, Debug, Default)]
pub struct TraversalTrace {
    /// Nodes tested per worker thread (dynamic greedy schedule). Empty
    /// for cut-cache incremental traces, which model no LT schedule.
    pub per_thread_nodes: Vec<u64>,
    /// Node tests in total (each is one frustum test, plus one LoD test
    /// when the node is in-frustum).
    pub visited: u64,
    /// Selected (cut) Gaussians; equals the returned cut length.
    pub selected: u64,
    /// Distinct subtree DRAM fetches (first touch of a subtree).
    pub subtree_fetches: u64,
    /// Bytes streamed from DRAM for fetched subtrees.
    pub bytes_streamed: u64,
    /// Total activations dequeued (>= subtree_fetches: a subtree can be
    /// activated by several boundary parents but is fetched once).
    pub activations: u64,
    /// Peak subtree-queue occupancy (work items, not bytes).
    pub queue_peak: usize,
    /// Per-activation node counts (workload distribution, Fig. 12 util).
    pub activation_sizes: Vec<u32>,
    /// Subtree id per activation, in dequeue order (replayed by the
    /// LTCore subtree-cache model).
    pub activation_sids: Vec<u32>,
    /// Bytes of each subtree (indexed by sid) for memory accounting.
    /// Filled by full traversals; empty for incremental traces.
    pub subtree_bytes: Vec<u32>,
    /// Frustum-culled frontier: every node that was reached (all
    /// ancestors descended) but failed the frustum test. Together with
    /// the cut these form the traversal *frontier* — the antichain the
    /// temporal cut cache revalidates next frame. Filled only by
    /// [`traverse_sltree_frontier`] (the cut cache's cold path); plain
    /// [`traverse_sltree`] leaves it empty so simulator and bench
    /// callers don't pay for a frontier they never read.
    pub culled: Vec<u32>,
    /// 1 if this trace came from the temporal cut cache's incremental
    /// revalidation path, 0 for a full (cold) traversal.
    pub cache_hit: u64,
    /// Node verdicts (frustum + LoD) re-evaluated by incremental
    /// revalidation: cached frontier nodes plus the interior ancestors
    /// on their root paths (each memoized, so counted at most once per
    /// frame). 0 for full traversals.
    pub revalidated: u64,
    /// Bounded refinements ([`refine_sltree`]) seeded at cached nodes
    /// that stopped meeting the LoD. 0 for full traversals.
    pub reseeded: u64,
    /// Subtree slabs whose node records were read by incremental
    /// revalidation, one sid per re-evaluated node verdict, in access
    /// order (duplicates kept — the consumer deduplicates per frame).
    /// Out-of-core replay input for
    /// [`crate::residency::ResidencyManager`]: warm frames touch slabs
    /// through frontier verdicts, not activations, so `activation_sids`
    /// alone under-reports the working set. Filled only when the cut
    /// cache's collect flag is on
    /// ([`super::cut_cache::CutCache::set_collect_touched`]); empty for
    /// full traversals (whose slab stream *is* `activation_sids`).
    pub touched_sids: Vec<u32>,
    /// Frontier-path verdicts the incremental revalidation *reused
    /// without re-testing* because the accumulated camera delta since
    /// the verdict was last evaluated provably cannot flip it (the cut
    /// cache's conservative verdict bounds). Always 0 for full
    /// traversals; `revalidated + verdicts_skipped` is the total
    /// frontier-path verdict count an unbounded revalidation would
    /// have evaluated.
    pub verdicts_skipped: u64,
}

impl TraversalTrace {
    /// PE utilization under the dynamic schedule: mean/max of the
    /// per-thread visited-node workloads, dimensionless in `(0, 1]`
    /// (1.0 = perfectly balanced; also 1.0 for an empty schedule, e.g.
    /// a cut-cache incremental trace, which models no LT threads).
    pub fn utilization(&self) -> f64 {
        let max = self.per_thread_nodes.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        let mean = self.per_thread_nodes.iter().sum::<u64>() as f64
            / self.per_thread_nodes.len() as f64;
        mean / max as f64
    }
}

/// One queued work item: an activation of `sid` for roots whose parent
/// node equals `parent_filter`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Activation {
    sid: u32,
    parent_filter: u32,
}

/// Enqueue the boundary child subtrees recorded at position `pos` of
/// `st` (descending past the node `n` at `pos` activates them, filtered
/// to the roots whose parent is `n`).
#[inline]
fn push_boundary(st: &Subtree, pos: u32, n: u32, queue: &mut VecDeque<Activation>) {
    // boundary is sorted by (pos, sid): binary search the run.
    let lo = st.boundary.partition_point(|&(bp, _)| bp < pos);
    for &(bp, csid) in &st.boundary[lo..] {
        if bp != pos {
            break;
        }
        queue.push_back(Activation { sid: csid, parent_filter: n });
    }
}

/// Scan positions `[start, end)` of one subtree slab with the
/// DFS-with-skip dataflow (the LT-unit inner loop): cull -> skip,
/// select -> skip, refine -> fall through and enqueue boundary children.
/// Selected nodes append to `cut`; frustum-culled frontier nodes append
/// to `culled` only when `collect_culled` is set (the cut cache's
/// frontier maintenance). Returns the number of nodes tested.
#[allow(clippy::too_many_arguments)] // the LT-unit datapath, spelled out
fn scan_positions(
    tree: &LodTree,
    st: &Subtree,
    frustum: &Frustum,
    cam: &Camera,
    tau: f32,
    start: usize,
    end: usize,
    queue: &mut VecDeque<Activation>,
    cut: &mut Vec<u32>,
    culled: &mut Vec<u32>,
    collect_culled: bool,
) -> u32 {
    let mut tested = 0u32;
    let mut p = start;
    while p < end {
        let n = st.nodes[p];
        tested += 1;
        if !frustum.intersects_aabb(&tree.aabbs[n as usize]) {
            if collect_culled {
                culled.push(n);
            }
            p += 1 + st.skip[p] as usize;
            continue;
        }
        let node = &tree.nodes[n as usize];
        if tree.meets_lod(n, cam, tau) || node.is_leaf() {
            cut.push(n);
            p += 1 + st.skip[p] as usize;
            continue;
        }
        // Refine: descend. In-subtree children follow in DFS order;
        // out-of-subtree children are activated via the boundary links
        // of this position.
        push_boundary(st, p as u32, n, queue);
        p += 1;
    }
    tested
}

/// Traverse the SLTree and return the selected cut (ascending node ids)
/// plus the trace. `threads` models the LT-unit / GPU-thread count for
/// the workload-distribution statistics (results are independent of it).
/// The trace's `culled` list stays empty — use
/// [`traverse_sltree_frontier`] when the frustum-culled frontier is
/// needed too.
pub fn traverse_sltree(
    tree: &LodTree,
    slt: &SlTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
) -> (Vec<u32>, TraversalTrace) {
    traverse_sltree_impl(tree, slt, cam, tau, threads, false)
}

/// [`traverse_sltree`] variant that additionally records the
/// frustum-culled frontier in the trace's `culled` list — the cut
/// (selected) plus `culled` (rejected) nodes together form the
/// antichain [`super::cut_cache::CutCache`] revalidates on the next
/// frame. Identical cut and counters otherwise.
pub fn traverse_sltree_frontier(
    tree: &LodTree,
    slt: &SlTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
) -> (Vec<u32>, TraversalTrace) {
    traverse_sltree_impl(tree, slt, cam, tau, threads, true)
}

fn traverse_sltree_impl(
    tree: &LodTree,
    slt: &SlTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
    collect_culled: bool,
) -> (Vec<u32>, TraversalTrace) {
    let threads = threads.max(1);
    let frustum = cam.frustum();
    let mut cut = Vec::new();
    let mut culled = Vec::new();
    let mut trace = TraversalTrace {
        per_thread_nodes: vec![0; threads],
        ..Default::default()
    };

    let mut queue = VecDeque::new();
    queue.push_back(Activation { sid: slt.top, parent_filter: NONE });
    let mut fetched = vec![false; slt.len()];
    trace.subtree_bytes = slt.subtrees.iter().map(|s| s.bytes() as u32).collect();

    while let Some(act) = queue.pop_front() {
        trace.queue_peak = trace.queue_peak.max(queue.len() + 1);
        trace.activations += 1;
        let st = &slt.subtrees[act.sid as usize];
        if !fetched[act.sid as usize] {
            fetched[act.sid as usize] = true;
            trace.subtree_fetches += 1;
            trace.bytes_streamed += st.bytes();
        }

        let mut act_nodes = 0u32;
        // Scan each activated root segment with the skip dataflow.
        for root in &st.roots {
            if root.parent_node != act.parent_filter {
                continue;
            }
            let start = root.pos as usize;
            let end = start + 1 + st.skip[start] as usize;
            act_nodes += scan_positions(
                tree, st, &frustum, cam, tau, start, end, &mut queue, &mut cut,
                &mut culled, collect_culled,
            );
        }
        trace.visited += act_nodes as u64;
        trace.activation_sizes.push(act_nodes);
        trace.activation_sids.push(act.sid);
        // Dynamic greedy schedule: next activation goes to the least
        // loaded thread (what the LT-unit round-robin dequeue achieves).
        let t = trace
            .per_thread_nodes
            .iter()
            .enumerate()
            .min_by_key(|(_, &w)| w)
            .map(|(i, _)| i)
            .unwrap();
        trace.per_thread_nodes[t] += act_nodes as u64;
    }

    trace.selected = cut.len() as u64;
    trace.culled = culled;
    cut.sort_unstable();
    (cut, trace)
}

/// Bounded SLTree refinement: re-run the streaming search *below* one
/// `seed` node that the caller has already determined must descend
/// (in-frustum, fails the LoD test, has children).
///
/// The seed's in-subtree descendants are scanned with the same
/// DFS-with-skip dataflow as [`traverse_sltree`] — one contiguous slab
/// range, `(pos, pos + 1 + skip[pos]]` — and its boundary child
/// subtrees are activated through the same subtree queue, so the
/// selected set is exactly what the full traversal would select under
/// `seed`. This is the cut cache's reseed primitive: refinement work is
/// bounded by how much the cut actually moved, not by the tree.
///
/// Newly selected nodes append to `cut` and frustum-culled frontier
/// nodes to `culled` (both unsorted — the caller owns final ordering).
/// `fetched` is the caller's per-frame first-touch set over subtree
/// ids (`len == slt.len()`), shared across refinements so a subtree
/// streamed by one seed is not double-counted by another. The trace
/// accumulates `visited` / `activations` / `subtree_fetches` /
/// `bytes_streamed` / `activation_*` exactly as the full traversal
/// does; the seed's own slab is *not* counted as a fetch (its bytes
/// were already resident from the frame that cached the cut).
#[allow(clippy::too_many_arguments)] // mirrors the traverse_sltree datapath
pub fn refine_sltree(
    tree: &LodTree,
    slt: &SlTree,
    frustum: &Frustum,
    cam: &Camera,
    tau: f32,
    seed: u32,
    cut: &mut Vec<u32>,
    culled: &mut Vec<u32>,
    fetched: &mut [bool],
    trace: &mut TraversalTrace,
) {
    debug_assert_eq!(fetched.len(), slt.len());
    let sid = slt.node_sid[seed as usize] as usize;
    let pos = slt.node_pos[seed as usize] as usize;
    let st = &slt.subtrees[sid];
    debug_assert_eq!(st.nodes[pos], seed);

    // Descend past the seed: its out-of-subtree children activate via
    // the boundary links at `pos`, its in-subtree descendants are the
    // contiguous skip range right after it.
    let mut queue = VecDeque::new();
    push_boundary(st, pos as u32, seed, &mut queue);
    let tested = scan_positions(
        tree,
        st,
        frustum,
        cam,
        tau,
        pos + 1,
        pos + 1 + st.skip[pos] as usize,
        &mut queue,
        cut,
        culled,
        true,
    );
    trace.visited += tested as u64;

    // Drain boundary activations exactly like the full traversal.
    while let Some(act) = queue.pop_front() {
        trace.queue_peak = trace.queue_peak.max(queue.len() + 1);
        trace.activations += 1;
        let st = &slt.subtrees[act.sid as usize];
        if !fetched[act.sid as usize] {
            fetched[act.sid as usize] = true;
            trace.subtree_fetches += 1;
            trace.bytes_streamed += st.bytes();
        }
        let mut act_nodes = 0u32;
        for root in &st.roots {
            if root.parent_node != act.parent_filter {
                continue;
            }
            let start = root.pos as usize;
            let end = start + 1 + st.skip[start] as usize;
            act_nodes += scan_positions(
                tree, st, frustum, cam, tau, start, end, &mut queue, cut, culled,
                true,
            );
        }
        trace.visited += act_nodes as u64;
        trace.activation_sizes.push(act_nodes);
        trace.activation_sids.push(act.sid);
    }
}

/// Static one-thread-per-subtree schedule over the *canonical* tree's
/// top-level subtrees — the naive GPU parallelization of Fig. 3. Returns
/// the per-thread visited-node workloads.
pub fn naive_static_workloads(
    tree: &LodTree,
    cam: &Camera,
    tau: f32,
    threads: usize,
) -> Vec<u64> {
    let frustum = cam.frustum();
    let mut workloads = vec![0u64; threads.max(1)];
    // Assign each root-child subtree to threads round-robin (static,
    // offline — exactly what conventional tree accelerators do).
    let top_level: Vec<u32> = tree.children(LodTree::ROOT).collect();
    for (i, &sub_root) in top_level.iter().enumerate() {
        let t = i % workloads.len();
        // Sequential canonical descent of this subtree.
        let mut stack = vec![sub_root];
        while let Some(n) = stack.pop() {
            workloads[t] += 1;
            if !frustum.intersects_aabb(&tree.aabbs[n as usize]) {
                continue;
            }
            if tree.meets_lod(n, cam, tau) || tree.nodes[n as usize].is_leaf() {
                continue;
            }
            stack.extend(tree.children(n));
        }
    }
    workloads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::Scene;
    use crate::util::stats::cov;

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    /// Reference canonical search that also records the frustum-culled
    /// frontier (the trace only counts it).
    fn canonical_with_culled(
        tree: &LodTree,
        cam: &Camera,
        tau: f32,
    ) -> (Vec<u32>, Vec<u32>) {
        let frustum = cam.frustum();
        let (mut cut, mut culled) = (Vec::new(), Vec::new());
        let mut stack = vec![LodTree::ROOT];
        while let Some(n) = stack.pop() {
            if !frustum.intersects_aabb(&tree.aabbs[n as usize]) {
                culled.push(n);
                continue;
            }
            if tree.meets_lod(n, cam, tau) || tree.nodes[n as usize].is_leaf() {
                cut.push(n);
                continue;
            }
            stack.extend(tree.children(n));
        }
        cut.sort_unstable();
        culled.sort_unstable();
        (cut, culled)
    }

    #[test]
    fn bit_accurate_vs_canonical() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        for cam_i in 0..6 {
            let cam = scene.scenario_camera(cam_i);
            for tau in [2.0, 8.0, 32.0] {
                let (want, _) = scene.tree.canonical_search(&cam, tau);
                let (got, _) = traverse_sltree(&scene.tree, &slt, &cam, tau, 4);
                assert_eq!(got, want, "cam {cam_i} tau {tau}");
            }
        }
    }

    #[test]
    fn bit_accurate_without_merging_too() {
        let scene = scene();
        let slt = SlTree::partition_unmerged(&scene.tree, 16);
        let cam = scene.scenario_camera(2);
        let (want, _) = scene.tree.canonical_search(&cam, 8.0);
        let (got, _) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert_eq!(got, want);
    }

    #[test]
    fn culled_frontier_matches_canonical() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        for cam_i in [0usize, 2, 5] {
            let cam = scene.scenario_camera(cam_i);
            for tau in [4.0, 16.0] {
                let (want_cut, want_culled) =
                    canonical_with_culled(&scene.tree, &cam, tau);
                let (got_cut, trace) =
                    traverse_sltree_frontier(&scene.tree, &slt, &cam, tau, 4);
                let mut got_culled = trace.culled.clone();
                got_culled.sort_unstable();
                assert_eq!(got_cut, want_cut, "cam {cam_i} tau {tau}");
                assert_eq!(got_culled, want_culled, "cam {cam_i} tau {tau}");
                // Frontier nodes form an antichain with the cut: no
                // culled node may sit below a cut node or vice versa.
                assert!(got_cut.iter().all(|n| !trace.culled.contains(n)));
                // The lean variant returns the identical cut with an
                // empty frontier.
                let (lean_cut, lean_trace) =
                    traverse_sltree(&scene.tree, &slt, &cam, tau, 4);
                assert_eq!(lean_cut, got_cut);
                assert!(lean_trace.culled.is_empty());
                assert_eq!(lean_trace.visited, trace.visited);
            }
        }
    }

    #[test]
    fn refine_matches_canonical_subsearch() {
        // Refining from any descend-verdict node must select exactly
        // what the canonical search selects strictly below that node.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(1);
        let tau_fine = 2.0;
        let tau_coarse = 32.0;
        let frustum = cam.frustum();
        // Seeds: the coarse cut's nodes that fail the fine LoD test —
        // exactly the reseed population the cut cache produces when tau
        // (or the camera) moves toward finer detail.
        let (coarse_cut, _) = scene.tree.canonical_search(&cam, tau_coarse);
        let mut fetched = vec![false; slt.len()];
        let mut refined = 0;
        for &seed in &coarse_cut {
            let node = &scene.tree.nodes[seed as usize];
            if node.is_leaf()
                || scene.tree.meets_lod(seed, &cam, tau_fine)
                || !frustum.intersects_aabb(&scene.tree.aabbs[seed as usize])
            {
                continue;
            }
            let (mut cut, mut culled) = (Vec::new(), Vec::new());
            let mut trace = TraversalTrace::default();
            refine_sltree(
                &scene.tree, &slt, &frustum, &cam, tau_fine, seed, &mut cut,
                &mut culled, &mut fetched, &mut trace,
            );
            // Reference: canonical descent from the seed's children.
            let (mut want_cut, mut want_culled) = (Vec::new(), Vec::new());
            let mut stack: Vec<u32> = scene.tree.children(seed).collect();
            while let Some(n) = stack.pop() {
                if !frustum.intersects_aabb(&scene.tree.aabbs[n as usize]) {
                    want_culled.push(n);
                    continue;
                }
                if scene.tree.meets_lod(n, &cam, tau_fine)
                    || scene.tree.nodes[n as usize].is_leaf()
                {
                    want_cut.push(n);
                    continue;
                }
                stack.extend(scene.tree.children(n));
            }
            cut.sort_unstable();
            culled.sort_unstable();
            want_cut.sort_unstable();
            want_culled.sort_unstable();
            assert_eq!(cut, want_cut, "seed {seed}");
            assert_eq!(culled, want_culled, "seed {seed}");
            assert!(trace.visited >= (cut.len() + culled.len()) as u64);
            refined += 1;
        }
        assert!(refined > 0, "no refinement seeds — test scene degenerate");
    }

    #[test]
    fn visits_no_more_than_canonical_plus_cut_overhead() {
        // SLTree never tests nodes below the cut; activation overhead is
        // bounded by the subtree roots touched.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(1);
        let (_, ct) = scene.tree.canonical_search(&cam, 8.0);
        let (_, st) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert!(
            st.visited <= ct.visited,
            "SLTree visited {} > canonical {}",
            st.visited,
            ct.visited
        );
    }

    #[test]
    fn traversal_is_far_below_exhaustive() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        // Farthest scenario + coarse tau: the cut sits high in the tree.
        let cam = scene.scenario_camera(5);
        let (_, coarse) = traverse_sltree(&scene.tree, &slt, &cam, 128.0, 4);
        let (_, fine) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        // The §V-C DRAM claim: frustum+cut traversal touches a fraction
        // of the tree, and coarser LoD touches strictly less.
        assert!(
            (coarse.visited as f64) < 0.6 * scene.tree.len() as f64,
            "visited {} of {}",
            coarse.visited,
            scene.tree.len()
        );
        assert!(coarse.visited < fine.visited);
        assert!((fine.visited as f64) < scene.tree.len() as f64);
    }

    #[test]
    fn dynamic_schedule_is_balanced() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(0);
        let (_, t) = traverse_sltree(&scene.tree, &slt, &cam, 4.0, 8);
        let naive = naive_static_workloads(&scene.tree, &cam, 4.0, 8);
        let balanced: Vec<f64> = t.per_thread_nodes.iter().map(|&w| w as f64).collect();
        let imbalanced: Vec<f64> = naive.iter().map(|&w| w as f64).collect();
        assert!(
            cov(&balanced) < cov(&imbalanced),
            "SLTree {} !< naive {}",
            cov(&balanced),
            cov(&imbalanced)
        );
    }

    #[test]
    fn fetches_are_bounded_by_subtree_count() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(5);
        let (_, t) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        assert!(t.subtree_fetches <= slt.len() as u64);
        assert!(t.activations >= t.subtree_fetches);
        // Cold traversals never report cache activity.
        assert_eq!(t.cache_hit, 0);
        assert_eq!(t.revalidated, 0);
        assert_eq!(t.reseeded, 0);
        // Every fetch streams one whole subtree, and only the *first*
        // activation of a subtree fetches it: recompute the expected
        // byte count by summing `subtree_bytes` over first-touch sids.
        let mut fetched = vec![false; slt.len()];
        let mut expected_bytes = 0u64;
        let mut expected_fetches = 0u64;
        for &sid in &t.activation_sids {
            if !fetched[sid as usize] {
                fetched[sid as usize] = true;
                expected_fetches += 1;
                expected_bytes += t.subtree_bytes[sid as usize] as u64;
            }
        }
        assert_eq!(t.subtree_fetches, expected_fetches);
        assert_eq!(t.bytes_streamed, expected_bytes);
        assert!(t.bytes_streamed > 0);
    }
}
