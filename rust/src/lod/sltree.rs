//! SLTree partitioning (paper Sec. III-B, Algo 1).
//!
//! Translates the canonical LoD tree into comparable-size *subtrees*
//! while preserving every hierarchical relationship, in two steps:
//!
//! 1. **Initial partitioning** — BFS from the root; whenever the
//!    cumulative traversed-node count reaches the size limit `tau_s`,
//!    the collected nodes become one subtree and every uncollected
//!    immediate child seeds a new root in the work queue.
//! 2. **Subtree merging** — adjacent small subtrees (size <= tau_s/2)
//!    under the *same parent subtree* are greedily combined while the
//!    merged size stays <= tau_s, shrinking the size variance that
//!    drives workload imbalance (evaluated in Fig. 12).
//!
//! Within each subtree, nodes are stored in **DFS order** with a
//! per-node `skip` (in-subtree descendant count), exactly the layout the
//! subtree-cache entry uses so the LT unit can bypass a node's subtree
//! with a single index increment (Sec. IV-B). Partitioning is fully
//! offline (zero render-time cost) and never alters search semantics:
//! `traversal::traverse_sltree` is bit-accurate vs the canonical search.

use super::tree::{LodTree, NONE};

/// Bytes of one LoD-tree node record inside a subtree slab: AABB 24 B +
/// world size 4 B + skip 4 B + child-SID link 4 B — the attribute set of
/// Fig. 7. The single source of truth for slab sizing; every
/// `bytes_streamed` figure, sim fixture and the residency manager's
/// budget accounting derive from it via [`slab_bytes`].
pub const NODE_BYTES: u64 = 36;

/// Bytes of a slab holding `nodes` node records — what
/// [`Subtree::bytes`], traversal's `bytes_streamed`, and the sim
/// fixtures all share.
#[inline]
pub const fn slab_bytes(nodes: u64) -> u64 {
    nodes * NODE_BYTES
}

/// Entry point of one constituent root inside a (possibly merged)
/// subtree.
#[derive(Clone, Copy, Debug)]
pub struct SubtreeRoot {
    /// Position of the root in `Subtree::nodes`.
    pub pos: u32,
    /// Parent *node* (in the full tree) of this root; `NONE` for the
    /// tree root. Traversal uses it to activate only the roots whose
    /// parent actually requested descent.
    pub parent_node: u32,
}

/// One subtree: a DFS-ordered slab of node ids plus the boundary links
/// to child subtrees — the unit of scheduling, caching and DRAM
/// streaming.
#[derive(Clone, Debug, Default)]
pub struct Subtree {
    /// Node ids in DFS order (a forest after merging: each root's
    /// segment is contiguous).
    pub nodes: Vec<u32>,
    /// In-subtree descendant count per position (the "remaining subtree
    /// size" of the cache entry): skipping node at `p` jumps to
    /// `p + 1 + skip[p]`.
    pub skip: Vec<u32>,
    /// Constituent roots (1 before merging, >=1 after).
    pub roots: Vec<SubtreeRoot>,
    /// Parent subtree id (`NONE` for the top subtree).
    pub parent_sid: u32,
    /// Boundary descent links: `(pos, child_sid)` — descending past the
    /// node at `pos` must enqueue `child_sid` (deduplicated).
    pub boundary: Vec<(u32, u32)>,
}

impl Subtree {
    /// Node count of this (possibly merged) subtree.
    #[inline]
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the subtree holds no nodes (never true after `partition`).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Bytes this subtree occupies in DRAM / one cache entry
    /// ([`NODE_BYTES`] per node — the attribute set of Fig. 7).
    #[inline]
    pub fn bytes(&self) -> u64 {
        slab_bytes(self.nodes.len() as u64)
    }
}

/// The subtree-based LoD tree.
#[derive(Clone, Debug)]
pub struct SlTree {
    /// The subtrees, indexed by subtree id (`sid`).
    pub subtrees: Vec<Subtree>,
    /// node id -> subtree id.
    pub node_sid: Vec<u32>,
    /// node id -> position of the node inside its subtree's `nodes`
    /// slab (DFS order): `subtrees[node_sid[n]].nodes[node_pos[n]] == n`.
    /// The O(1) seed lookup used by bounded re-refinement
    /// ([`super::traversal::refine_sltree`]).
    pub node_pos: Vec<u32>,
    /// The subtree containing the tree root.
    pub top: u32,
    /// Size limit used at construction.
    pub tau_s: u32,
}

impl SlTree {
    /// Full partitioning: initial BFS split + subtree merging.
    pub fn partition(tree: &LodTree, tau_s: u32) -> SlTree {
        Self::build(tree, tau_s, true)
    }

    /// Ablation variant without the merging pass (Fig. 12 "w/o merge").
    pub fn partition_unmerged(tree: &LodTree, tau_s: u32) -> SlTree {
        Self::build(tree, tau_s, false)
    }

    fn build(tree: &LodTree, tau_s: u32, merge: bool) -> SlTree {
        assert!(tau_s >= 2, "subtree size limit must be >= 2");
        assert!(!tree.is_empty(), "cannot partition an empty tree");

        // ---------- initial partitioning (Algo 1, first loop) ----------
        // Work queue of (root node, parent node).
        let mut queue = std::collections::VecDeque::new();
        queue.push_back((LodTree::ROOT, NONE));
        // Raw subtrees: (member nodes in BFS order, root, parent node).
        let mut raw: Vec<(Vec<u32>, u32, u32)> = Vec::new();
        let mut node_raw_sid = vec![NONE; tree.len()];

        // §Perf: one reusable BFS deque for all work items (a fresh
        // VecDeque per subtree showed up in the partitioning profile).
        let mut bfs = std::collections::VecDeque::new();
        while let Some((root, parent_node)) = queue.pop_front() {
            // BFS from `root`, stopping once tau_s nodes are collected.
            let mut members = Vec::with_capacity(tau_s as usize);
            bfs.clear();
            bfs.push_back(root);
            while let Some(n) = bfs.pop_front() {
                if members.len() == tau_s as usize {
                    // Uncollected: n becomes a new subtree root.
                    queue.push_back((n, tree.nodes[n as usize].parent));
                    continue;
                }
                members.push(n);
                for c in tree.children(n) {
                    bfs.push_back(c);
                }
            }
            let sid = raw.len() as u32;
            for &m in &members {
                node_raw_sid[m as usize] = sid;
            }
            raw.push((members, root, parent_node));
        }

        // ---------- subtree merging (Algo 1, second loop) --------------
        // Greedy left-to-right: absorb small subtrees that share the
        // parent subtree while the running size stays within tau_s.
        // Groups are lists of raw sids.
        let parent_raw_sid = |r: &(Vec<u32>, u32, u32)| -> u32 {
            if r.2 == NONE {
                NONE
            } else {
                node_raw_sid[r.2 as usize]
            }
        };
        let mut groups: Vec<Vec<usize>> = Vec::new();
        if merge {
            let mut cur: Vec<usize> = Vec::new();
            let mut cur_size = 0usize;
            let mut cur_parent = NONE;
            for (i, r) in raw.iter().enumerate() {
                let p = parent_raw_sid(r);
                let small = r.0.len() <= (tau_s / 2) as usize;
                if !cur.is_empty()
                    && p == cur_parent
                    && small
                    && cur_size + r.0.len() <= tau_s as usize
                {
                    cur.push(i);
                    cur_size += r.0.len();
                } else {
                    if !cur.is_empty() {
                        groups.push(std::mem::take(&mut cur));
                    }
                    cur.push(i);
                    cur_size = r.0.len();
                    cur_parent = p;
                }
            }
            if !cur.is_empty() {
                groups.push(cur);
            }
        } else {
            groups = (0..raw.len()).map(|i| vec![i]).collect();
        }

        // ---------- final layout: DFS order + skip counts ---------------
        let mut node_sid = vec![NONE; tree.len()];
        for (gid, group) in groups.iter().enumerate() {
            for &ri in group {
                for &m in &raw[ri].0 {
                    node_sid[m as usize] = gid as u32;
                }
            }
        }

        // §Perf: epoch-stamped scratch arrays replace the per-subtree
        // HashSet/HashMap (hashing dominated partitioning time; see
        // EXPERIMENTS.md §Perf). `stamp[n] == epoch` marks membership
        // and `pos_scratch[n]` holds the node's DFS position.
        let mut stamp = vec![0u32; tree.len()];
        let mut pos_scratch = vec![0u32; tree.len()];
        let mut epoch = 0u32;

        let mut subtrees = Vec::with_capacity(groups.len());
        for group in groups.iter() {
            let mut st = Subtree::default();
            let mut parent_sid = NONE;
            for &ri in group {
                let (members, root, parent_node) = &raw[ri];
                if *parent_node != NONE {
                    parent_sid = node_sid[*parent_node as usize];
                }
                // DFS within this constituent, restricted to `members`.
                epoch += 1;
                for &m in members {
                    stamp[m as usize] = epoch;
                }
                let root_pos = st.nodes.len() as u32;
                st.roots.push(SubtreeRoot { pos: root_pos, parent_node: *parent_node });
                // Iterative DFS; push children in reverse so the first
                // child is processed first (stable order).
                let mut stack = vec![*root];
                while let Some(n) = stack.pop() {
                    st.nodes.push(n);
                    st.skip.push(0); // filled below
                    for c in tree.children(n).rev() {
                        if stamp[c as usize] == epoch {
                            stack.push(c);
                        }
                    }
                }
                debug_assert_eq!(
                    st.nodes.len() as u32 - root_pos,
                    members.len() as u32
                );
            }
            // skip counts: descendants *within the subtree*. Walk
            // backwards: skip[p] = sum over in-subtree children (1 + skip).
            // Membership + positions via one fresh epoch over the whole
            // (possibly merged) subtree.
            epoch += 1;
            for (p, &n) in st.nodes.iter().enumerate() {
                stamp[n as usize] = epoch;
                pos_scratch[n as usize] = p as u32;
            }
            for p in (0..st.nodes.len()).rev() {
                let n = st.nodes[p];
                let parent = tree.nodes[n as usize].parent;
                if parent != NONE && stamp[parent as usize] == epoch {
                    let pp = pos_scratch[parent as usize];
                    // Only count if the parent precedes (same DFS seg).
                    if (pp as usize) < p {
                        st.skip[pp as usize] += 1 + st.skip[p];
                    }
                }
            }
            st.parent_sid = parent_sid;
            subtrees.push(st);
        }

        // Boundary links: for every node, children in other subtrees.
        for st in subtrees.iter_mut() {
            let mut links: Vec<(u32, u32)> = Vec::new();
            for (p, &n) in st.nodes.iter().enumerate() {
                for c in tree.children(n) {
                    let csid = node_sid[c as usize];
                    if csid != node_sid[n as usize] {
                        links.push((p as u32, csid));
                    }
                }
            }
            links.sort_unstable();
            links.dedup();
            st.boundary = links;
        }

        // Position lookup: node id -> index inside its subtree's slab.
        let mut node_pos = vec![0u32; tree.len()];
        for st in &subtrees {
            for (p, &n) in st.nodes.iter().enumerate() {
                node_pos[n as usize] = p as u32;
            }
        }

        let top = node_sid[LodTree::ROOT as usize];
        SlTree { subtrees, node_sid, node_pos, top, tau_s }
    }

    /// Convenience wrapper over [`super::traversal::traverse_sltree`]
    /// with the default LT-unit count; returns just the cut.
    pub fn traverse(&self, tree: &LodTree, cam: &crate::math::Camera, tau: f32) -> Vec<u32> {
        super::traversal::traverse_sltree(tree, self, cam, tau, 4).0
    }

    /// Number of subtrees in the partition.
    #[inline]
    pub fn len(&self) -> usize {
        self.subtrees.len()
    }

    /// Whether the partition holds no subtrees (never true after
    /// `partition` — an empty tree cannot be partitioned).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.subtrees.is_empty()
    }

    /// Size (node count) of every subtree — the Fig. 5 balance metric.
    pub fn sizes(&self) -> Vec<usize> {
        self.subtrees.iter().map(|s| s.len()).collect()
    }

    /// Validate structural invariants; returns the first violation.
    pub fn check_invariants(&self, tree: &LodTree) -> Result<(), String> {
        let mut seen = vec![false; tree.len()];
        for (sid, st) in self.subtrees.iter().enumerate() {
            let sid = sid as u32;
            if st.len() > self.tau_s as usize {
                return Err(format!("subtree {sid} exceeds tau_s: {}", st.len()));
            }
            if st.is_empty() {
                return Err(format!("subtree {sid} is empty"));
            }
            for (p, &n) in st.nodes.iter().enumerate() {
                if seen[n as usize] {
                    return Err(format!("node {n} in two subtrees"));
                }
                seen[n as usize] = true;
                if self.node_sid[n as usize] != sid {
                    return Err(format!("node {n}: node_sid mismatch"));
                }
                if self.node_pos[n as usize] != p as u32 {
                    return Err(format!("node {n}: node_pos mismatch"));
                }
                let end = p + 1 + st.skip[p] as usize;
                if end > st.len() {
                    return Err(format!("subtree {sid} pos {p}: skip escapes"));
                }
            }
            for &(pos, csid) in &st.boundary {
                if csid as usize >= self.subtrees.len() || pos as usize >= st.len() {
                    return Err(format!("subtree {sid}: dangling boundary"));
                }
            }
            for r in &st.roots {
                if r.pos as usize >= st.len() {
                    return Err(format!("subtree {sid}: root pos out of range"));
                }
                let n = st.nodes[r.pos as usize];
                if tree.nodes[n as usize].parent != r.parent_node {
                    return Err(format!("subtree {sid}: root parent mismatch"));
                }
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(format!("node {missing} not assigned to any subtree"));
        }
        // Hierarchy preservation: parent subtree of every non-top
        // subtree must contain the parents of all its roots.
        for (sid, st) in self.subtrees.iter().enumerate() {
            for r in &st.roots {
                if r.parent_node != NONE {
                    let psid = self.node_sid[r.parent_node as usize];
                    if psid == sid as u32 {
                        return Err(format!(
                            "subtree {sid}: root {} has in-subtree parent",
                            st.nodes[r.pos as usize]
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::util::stats::cov;

    fn scene_tree() -> LodTree {
        SceneConfig::small_scale().quick().build(7).tree
    }

    #[test]
    fn partition_covers_every_node_exactly_once() {
        let tree = scene_tree();
        for tau_s in [8, 32, 128] {
            let slt = SlTree::partition(&tree, tau_s);
            slt.check_invariants(&tree).unwrap();
            let total: usize = slt.sizes().iter().sum();
            assert_eq!(total, tree.len());
        }
    }

    #[test]
    fn unmerged_partition_also_valid() {
        let tree = scene_tree();
        let slt = SlTree::partition_unmerged(&tree, 32);
        slt.check_invariants(&tree).unwrap();
        // Every unmerged subtree has exactly one root.
        for st in &slt.subtrees {
            assert_eq!(st.roots.len(), 1);
        }
    }

    #[test]
    fn merging_reduces_size_variance() {
        let tree = scene_tree();
        let a = SlTree::partition_unmerged(&tree, 32);
        let b = SlTree::partition(&tree, 32);
        let cov_a = cov(&a.sizes().iter().map(|&s| s as f64).collect::<Vec<_>>());
        let cov_b = cov(&b.sizes().iter().map(|&s| s as f64).collect::<Vec<_>>());
        assert!(b.len() <= a.len(), "merging cannot add subtrees");
        assert!(
            cov_b < cov_a,
            "merging must cut size variance: {cov_b} !< {cov_a}"
        );
    }

    #[test]
    fn top_subtree_contains_root() {
        let tree = scene_tree();
        let slt = SlTree::partition(&tree, 32);
        let top = &slt.subtrees[slt.top as usize];
        assert!(top.nodes.contains(&LodTree::ROOT));
        assert_eq!(top.parent_sid, NONE);
        assert!(top.roots.iter().any(|r| r.parent_node == NONE));
    }

    #[test]
    fn dfs_skip_matches_descendant_count() {
        let tree = scene_tree();
        let slt = SlTree::partition(&tree, 32);
        // For every position, the skipped range must consist exactly of
        // nodes whose ancestor chain (within the subtree) passes through
        // the node at that position.
        for st in &slt.subtrees {
            let inset: std::collections::HashSet<u32> = st.nodes.iter().copied().collect();
            for (p, &n) in st.nodes.iter().enumerate() {
                let end = p + 1 + st.skip[p] as usize;
                for q in p + 1..end {
                    let mut anc = tree.nodes[st.nodes[q] as usize].parent;
                    let mut found = false;
                    while anc != NONE && inset.contains(&anc) {
                        if anc == n {
                            found = true;
                            break;
                        }
                        anc = tree.nodes[anc as usize].parent;
                    }
                    assert!(found, "pos {q} not a descendant of pos {p}");
                }
            }
        }
    }

    #[test]
    fn boundary_links_point_to_child_roots() {
        let tree = scene_tree();
        let slt = SlTree::partition(&tree, 32);
        for st in &slt.subtrees {
            for &(pos, csid) in &st.boundary {
                let n = st.nodes[pos as usize];
                let child_st = &slt.subtrees[csid as usize];
                // Some root of the child subtree must have n as parent.
                assert!(
                    child_st.roots.iter().any(|r| r.parent_node == n),
                    "boundary ({pos},{csid}) has no matching root"
                );
            }
        }
    }

    #[test]
    fn node_pos_roundtrips_through_the_slabs() {
        let tree = scene_tree();
        for slt in [SlTree::partition(&tree, 32), SlTree::partition_unmerged(&tree, 16)] {
            for n in 0..tree.len() as u32 {
                let sid = slt.node_sid[n as usize] as usize;
                let pos = slt.node_pos[n as usize] as usize;
                assert_eq!(slt.subtrees[sid].nodes[pos], n);
            }
        }
    }

    #[test]
    fn small_tau_means_more_subtrees() {
        let tree = scene_tree();
        let a = SlTree::partition(&tree, 8);
        let b = SlTree::partition(&tree, 64);
        assert!(a.len() > b.len());
    }
}
