//! The paper's algorithmic core: LoD trees, SLTree partitioning, the
//! streaming subtree-queue traversal, and temporal cut caching.
//!
//! * [`tree`] — the canonical LoD tree (variable fan-out, BFS node
//!   layout) and the canonical top-down LoD search that defines the
//!   ground-truth "cut" (paper Fig. 1).
//! * [`sltree`] — SLTree partitioning: Algo 1 initial BFS partitioning
//!   plus greedy subtree merging (Sec. III-B).
//! * [`traversal`] — the subtree-granular streaming traversal
//!   (Sec. III-A), bit-accurate vs the canonical search, emitting the
//!   per-thread workload and memory traces the simulators consume;
//!   plus [`refine_sltree`], the bounded seeded variant.
//! * [`cut_cache`] — frame-to-frame reuse of the search frontier along
//!   a camera path ([`CutCache`]): incremental revalidation that is
//!   bit-identical to the canonical search at every frame, with
//!   configurable full-traversal fallbacks ([`CutCacheConfig`]).

#![warn(missing_docs)]

pub mod cut_cache;
pub mod sltree;
pub mod traversal;
pub mod tree;

pub use cut_cache::{CutCache, CutCacheConfig};
pub use sltree::{slab_bytes, SlTree, Subtree, NODE_BYTES};
pub use traversal::{
    naive_static_workloads, refine_sltree, traverse_sltree,
    traverse_sltree_frontier, TraversalTrace,
};
pub use tree::{CanonicalTrace, LodTree, Node, NONE};
