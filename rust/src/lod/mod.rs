//! The paper's algorithmic core: LoD trees, SLTree partitioning, and the
//! streaming subtree-queue traversal.
//!
//! * [`tree`] — the canonical LoD tree (variable fan-out, BFS node
//!   layout) and the canonical top-down LoD search that defines the
//!   ground-truth "cut" (paper Fig. 1).
//! * [`sltree`] — SLTree partitioning: Algo 1 initial BFS partitioning
//!   plus greedy subtree merging (Sec. III-B).
//! * [`traversal`] — the subtree-granular streaming traversal
//!   (Sec. III-A), bit-accurate vs the canonical search, emitting the
//!   per-thread workload and memory traces the simulators consume.

pub mod sltree;
pub mod traversal;
pub mod tree;

pub use sltree::{SlTree, Subtree};
pub use traversal::{naive_static_workloads, traverse_sltree, TraversalTrace};
pub use tree::{CanonicalTrace, LodTree, Node, NONE};
