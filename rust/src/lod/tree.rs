//! The canonical LoD tree and the canonical (ground-truth) LoD search.
//!
//! Every tree node is one Gaussian (node index == Gaussian index; the
//! paper uses "Gaussian", "node" and "tree node" interchangeably). Child
//! counts are *unfixed* — HierarchicalGS trees reach height ~24 with
//! single parents owning >10^3 children — which is exactly the
//! irregularity SLTree exists to tame.
//!
//! Nodes are stored in BFS order from the root: parents always precede
//! children and siblings are contiguous, which is what both Algo 1 and
//! the subtree cache layout assume.

use crate::math::{Aabb, Camera};

/// Sentinel for "no node".
pub const NONE: u32 = u32::MAX;

/// One LoD-tree node. Children are the contiguous id range
/// `[first_child, first_child + child_count)`.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// Parent node id ([`NONE`] for the root).
    pub parent: u32,
    /// First child's node id (children are contiguous; unused when
    /// `child_count == 0`).
    pub first_child: u32,
    /// Number of children (0 = leaf).
    pub child_count: u32,
    /// Depth from the root (root = 0).
    pub level: u16,
}

impl Node {
    /// Whether this node has no children (a true leaf Gaussian).
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.child_count == 0
    }
}

/// The canonical LoD tree.
#[derive(Clone, Debug, Default)]
pub struct LodTree {
    /// All nodes in BFS order from the root (node id == Gaussian id).
    pub nodes: Vec<Node>,
    /// Conservative world AABB of node `i`'s entire subtree.
    pub aabbs: Vec<Aabb>,
    /// World-space extent of the node's own Gaussian (longest 3-sigma
    /// edge) — the quantity whose projection the LoD test compares.
    pub world_size: Vec<f32>,
    /// Tree height in levels (a root-only tree has height 1).
    pub height: u32,
}

/// Execution trace of a canonical search (feeds the GPU model).
#[derive(Clone, Debug, Default)]
pub struct CanonicalTrace {
    /// Total nodes visited (frustum/LoD tests executed).
    pub visited: u64,
    /// Nodes culled by the frustum test.
    pub frustum_culled: u64,
    /// Nodes selected into the cut.
    pub selected: u64,
}

impl LodTree {
    /// The root node id (BFS layout stores the root first).
    pub const ROOT: u32 = 0;

    /// Number of nodes (== number of Gaussians).
    #[inline]
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the tree has no nodes at all.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Children ids of `n` as a range.
    #[inline]
    pub fn children(&self, n: u32) -> std::ops::Range<u32> {
        let node = &self.nodes[n as usize];
        node.first_child..node.first_child + node.child_count
    }

    /// The LoD test (paper Sec. II-A): does this node, projected at the
    /// camera, already meet the target level of detail `tau` (pixels)?
    /// `true` => the node itself is fine enough to stand in for its
    /// whole subtree.
    #[inline]
    pub fn meets_lod(&self, n: u32, cam: &Camera, tau: f32) -> bool {
        let depth = cam.depth(self.aabbs[n as usize].center());
        cam.projected_size(self.world_size[n as usize], depth) <= tau
    }

    /// Canonical top-down LoD search — the semantic ground truth the
    /// SLTree traversal must reproduce **bit-accurately**.
    ///
    /// Selection rule per node:
    ///   * outside the frustum            -> skip the subtree, select none
    ///   * `meets_lod`                    -> select the node, stop descending
    ///   * fails LoD but is a true leaf   -> select the leaf (cannot refine)
    ///   * fails LoD, has children        -> recurse
    ///
    /// Returns the selected cut (ascending node ids) and the trace.
    pub fn canonical_search(
        &self,
        cam: &Camera,
        tau: f32,
    ) -> (Vec<u32>, CanonicalTrace) {
        let frustum = cam.frustum();
        let mut cut = Vec::new();
        let mut trace = CanonicalTrace::default();
        if self.is_empty() {
            return (cut, trace);
        }
        // Explicit stack: HierarchicalGS trees are deep enough that
        // recursion depth is worth avoiding on big scenes.
        let mut stack = vec![Self::ROOT];
        while let Some(n) = stack.pop() {
            trace.visited += 1;
            if !frustum.intersects_aabb(&self.aabbs[n as usize]) {
                trace.frustum_culled += 1;
                continue;
            }
            let node = &self.nodes[n as usize];
            if self.meets_lod(n, cam, tau) || node.is_leaf() {
                cut.push(n);
                continue;
            }
            stack.extend(self.children(n));
        }
        trace.selected = cut.len() as u64;
        cut.sort_unstable();
        (cut, trace)
    }

    /// The exhaustive search prior work falls back to for GPU balance
    /// (paper Sec. II-B "the existing solutions are to simply apply
    /// exhaustive searches to all tree nodes"): every node is visited and
    /// tested; the cut is identical. Returns (cut, visited_count).
    pub fn exhaustive_search(&self, cam: &Camera, tau: f32) -> (Vec<u32>, u64) {
        let frustum = cam.frustum();
        let mut cut = Vec::new();
        for n in 0..self.nodes.len() as u32 {
            if !frustum.intersects_aabb(&self.aabbs[n as usize]) {
                continue;
            }
            let node = &self.nodes[n as usize];
            let meets = self.meets_lod(n, cam, tau) || node.is_leaf();
            if !meets {
                continue;
            }
            // On the cut iff no ancestor would already have been selected.
            let parent_ok = node.parent == NONE
                || (!self.meets_lod(node.parent, cam, tau)
                    && frustum
                        .intersects_aabb(&self.aabbs[node.parent as usize]));
            // All ancestors must fail LoD and stay in-frustum.
            let mut anc = node.parent;
            let mut on_cut = parent_ok;
            while on_cut && anc != NONE {
                let a = &self.nodes[anc as usize];
                if self.meets_lod(anc, cam, tau)
                    || !frustum.intersects_aabb(&self.aabbs[anc as usize])
                {
                    on_cut = false;
                }
                anc = a.parent;
            }
            if on_cut {
                cut.push(n);
            }
        }
        cut.sort_unstable();
        (cut, self.nodes.len() as u64)
    }

    /// Per-node subtree sizes (including self) — used by SLTree
    /// partitioning, skip offsets and the imbalance study (Fig. 3).
    pub fn subtree_sizes(&self) -> Vec<u32> {
        let mut sizes = vec![1u32; self.nodes.len()];
        // BFS order => children have larger ids; accumulate in reverse.
        for i in (0..self.nodes.len()).rev() {
            let p = self.nodes[i].parent;
            if p != NONE {
                sizes[p as usize] += sizes[i];
            }
        }
        sizes
    }

    /// Validate the structural invariants the rest of the pipeline
    /// assumes (BFS layout, contiguous children, consistent AABBs).
    /// Returns a description of the first violation.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.is_empty() {
            return Ok(());
        }
        if self.nodes[0].parent != NONE {
            return Err("root must have no parent".into());
        }
        for (i, n) in self.nodes.iter().enumerate() {
            let i = i as u32;
            if n.child_count > 0 {
                if n.first_child <= i {
                    return Err(format!("node {i}: children must follow it (BFS)"));
                }
                for c in self.children(i) {
                    if self.nodes[c as usize].parent != i {
                        return Err(format!("node {c}: bad parent link"));
                    }
                    if self.nodes[c as usize].level != n.level + 1 {
                        return Err(format!("node {c}: bad level"));
                    }
                    // Parent AABB must contain child AABBs (conservative).
                    let pa = &self.aabbs[i as usize];
                    let ca = &self.aabbs[c as usize];
                    let grown = pa.union(ca);
                    if (grown.min - pa.min).length() > 1e-4
                        || (grown.max - pa.max).length() > 1e-4
                    {
                        return Err(format!("node {c}: AABB not nested in {i}"));
                    }
                }
            }
            if n.parent != NONE && n.parent >= i {
                return Err(format!("node {i}: parent must precede it (BFS)"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Intrinsics, Vec3};

    /// Tiny hand-built tree:         0
    ///                            /  |  \
    ///                           1   2   3
    ///                          / \      |
    ///                         4   5     6
    pub fn tiny_tree() -> LodTree {
        let parents = [NONE, 0, 0, 0, 1, 1, 3];
        let firsts = [1u32, 4, 0, 6, 0, 0, 0];
        let counts = [3u32, 2, 0, 1, 0, 0, 0];
        let levels = [0u16, 1, 1, 1, 2, 2, 2];
        let centers = [
            Vec3::ZERO,
            Vec3::new(-2.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(2.0, 0.0, 0.0),
            Vec3::new(-2.5, 0.0, 0.0),
            Vec3::new(-1.5, 0.0, 0.0),
            Vec3::new(2.0, 0.5, 0.0),
        ];
        let sizes = [8.0f32, 3.0, 3.0, 3.0, 1.0, 1.0, 1.0];
        let mut tree = LodTree::default();
        for i in 0..7 {
            tree.nodes.push(Node {
                parent: parents[i],
                first_child: firsts[i],
                child_count: counts[i],
                level: levels[i],
            });
            tree.world_size.push(sizes[i]);
            tree.aabbs.push(Aabb::from_center_half(
                centers[i],
                Vec3::splat(sizes[i] * 0.5),
            ));
        }
        // Make ancestors contain descendants.
        for i in (0..7).rev() {
            let p = tree.nodes[i].parent;
            if p != NONE {
                tree.aabbs[p as usize] = tree.aabbs[p as usize].union(&tree.aabbs[i]);
            }
        }
        tree.height = 3;
        tree
    }

    pub fn tiny_cam(dist: f32) -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, -dist),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            Intrinsics::from_fov(256, 256, 60f32.to_radians()),
        )
    }

    #[test]
    fn invariants_hold() {
        tiny_tree().check_invariants().unwrap();
    }

    #[test]
    fn coarse_lod_selects_high_nodes() {
        let tree = tiny_tree();
        // Far camera + large tau -> root alone satisfies the LoD.
        let (cut, trace) = tree.canonical_search(&tiny_cam(100.0), 500.0);
        assert_eq!(cut, vec![0]);
        assert_eq!(trace.visited, 1);
    }

    #[test]
    fn fine_lod_descends_to_leaves() {
        let tree = tiny_tree();
        // Near camera + tiny tau -> every in-frustum leaf selected.
        let (cut, _) = tree.canonical_search(&tiny_cam(10.0), 0.5);
        assert_eq!(cut, vec![2, 4, 5, 6]);
    }

    #[test]
    fn cut_separates_tree() {
        // Every root-to-leaf path crosses the cut at most once, and
        // in-frustum leaves are covered exactly once.
        let tree = tiny_tree();
        for tau in [0.5, 5.0, 50.0, 500.0] {
            let (cut, _) = tree.canonical_search(&tiny_cam(20.0), tau);
            let inset: std::collections::HashSet<u32> = cut.iter().copied().collect();
            for leaf in [2u32, 4, 5, 6] {
                let mut n = leaf;
                let mut crossings = 0;
                loop {
                    if inset.contains(&n) {
                        crossings += 1;
                    }
                    let p = tree.nodes[n as usize].parent;
                    if p == NONE {
                        break;
                    }
                    n = p;
                }
                assert!(crossings <= 1, "tau={tau} leaf={leaf}: {crossings}");
            }
        }
    }

    #[test]
    fn exhaustive_matches_canonical() {
        let tree = tiny_tree();
        for dist in [5.0, 20.0, 100.0] {
            for tau in [0.5, 5.0, 50.0] {
                let cam = tiny_cam(dist);
                let (c1, _) = tree.canonical_search(&cam, tau);
                let (c2, visited) = tree.exhaustive_search(&cam, tau);
                assert_eq!(c1, c2, "dist={dist} tau={tau}");
                assert_eq!(visited, 7);
            }
        }
    }

    #[test]
    fn subtree_sizes_are_consistent() {
        let tree = tiny_tree();
        let sizes = tree.subtree_sizes();
        assert_eq!(sizes[0], 7);
        assert_eq!(sizes[1], 3);
        assert_eq!(sizes[3], 2);
        assert_eq!(sizes[2], 1);
    }
}
