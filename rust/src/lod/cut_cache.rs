//! Temporal LoD cut cache: frame-to-frame reuse of the selected cut
//! along a camera path (the ROADMAP "frame-to-frame cut caching" item).
//!
//! The paper's hottest stage re-runs the LoD search from the tree top
//! every frame, yet consecutive cameras on a walkthrough select nearly
//! identical cuts. [`CutCache`] keeps the previous frame's search
//! *frontier* — the cut plus the frustum-culled boundary, which together
//! form an antichain covering every root-to-leaf path exactly once —
//! and revalidates it incrementally:
//!
//! * **coarsen** — walking up from a cached node, the first ancestor
//!   that now meets the LoD (or leaves the frustum) becomes the new
//!   frontier node; everything below it is dropped;
//! * **refine** — a cached cut node that no longer meets the LoD seeds
//!   a *bounded* streaming search
//!   ([`refine_sltree`](super::traversal::refine_sltree)) over its
//!   subtree slab and boundary activations only;
//! * **frustum patch** — cached culled nodes re-enter the view the same
//!   way (their verdict flips to select or refine), and cut nodes that
//!   leave the view move to the culled frontier.
//!
//! Ancestor verdicts are memoized per frame with epoch-stamped marks,
//! so shared prefixes of the frontier's root paths are tested once.
//! The result is **bit-identical** to
//! [`LodTree::canonical_search`](super::tree::LodTree::canonical_search)
//! at every frame — the verdict at each node is the same pure function
//! of `(node, camera, tau)` the full search evaluates, only the
//! *schedule* of evaluations changes. Property tests
//! (`rust/tests/proptests.rs`) and the golden-frame harness pin this.
//!
//! A full traversal still runs on the first frame, whenever the camera
//! jumps beyond [`CutCacheConfig::max_translation`] /
//! [`CutCacheConfig::max_rotation`], every
//! [`CutCacheConfig::refresh_every`] frames, when `tau` jumps by more
//! than [`CutCacheConfig::max_tau_step`], and when the tree changes —
//! the cache is a scheduler, never a semantic override. Small tau
//! *nudges* (the serving layer's graceful-degradation steps) take the
//! incremental path: node verdicts are pure functions of
//! `(node, camera, tau)`, and the cached frontier is an antichain
//! covering every root-to-leaf path, so revalidation under a new tau
//! re-derives the new canonical cut exactly — tau deltas, like camera
//! deltas, only change how much coarsening/refinement work the
//! revalidation does.
//!
//! ## Conservative verdict bounds
//!
//! On top of per-frame memoization, revalidation keeps a per-node
//! **stability budget**: when a verdict is evaluated, the distance of
//! its deciding quantity from the flip threshold (the smallest frustum
//! plane slack, and for LoD-tested nodes also `|z - z_threshold|`) is
//! converted — through a Lipschitz bound on how fast any slack can move
//! per unit of camera motion — into the pose distance
//! `|Δeye| + ‖ΔR‖_F` the camera may travel before the verdict could
//! possibly flip. Subsequent frames *skip the re-test* and reuse the
//! stored verdict while the accumulated pose distance stays inside the
//! budget (each skip decrements it, so chains of skips are covered by
//! the triangle inequality), counting the reuse in
//! [`TraversalTrace::verdicts_skipped`]. Budgets are halved for safety
//! and charged a relative epsilon so `f32` evaluation noise near the
//! threshold cannot be outrun by the real-arithmetic bound; any tau,
//! intrinsics or near-plane change (which the bound does not model)
//! disables skipping until budgets are rebuilt. Bit-identity of the
//! resulting cut is pinned by the incremental-≡-canonical property
//! tests and the golden digests, both of which exercise this path.

use super::sltree::SlTree;
use super::traversal::{
    refine_sltree, traverse_sltree, traverse_sltree_frontier, TraversalTrace,
};
use super::tree::{LodTree, NONE};
use crate::math::{Camera, Intrinsics, Vec3};

/// LT-unit count modelled by the cold traversal inside the cache
/// (matches [`SlTree::traverse`]; results are independent of it).
const LT_UNITS: usize = 4;

/// Per-node verdict states memoized during one incremental frame. The
/// two stop states are distinguished so a budget-covered skip can
/// replay the verdict (cut vs culled frontier) without re-testing.
const OPEN: u8 = 1; // in frustum, fails LoD, has children -> descend
const STOP_CUT: u8 = 2; // new cut (selected) frontier node here
const DEAD: u8 = 3; // below a stopped ancestor
const STOP_CULL: u8 = 4; // new frustum-culled frontier node here

/// Safety factor on verdict-stability budgets: only half the proven
/// pose-distance headroom is ever spent.
const BUDGET_SAFETY: f64 = 0.5;

/// Relative epsilon charged against every margin before it becomes a
/// budget, so `f32` rounding in the verdict expressions (the bound is
/// real-arithmetic) can never flip a "provably stable" verdict. Sized
/// ~1e3x above worst-case accumulated `f32` noise at the slack's own
/// magnitude.
const BUDGET_EPS_REL: f64 = 1e-4;

/// Fallback policy for the temporal cut cache
/// ([`RenderOptions::cut_cache`](crate::coordinator::RenderOptions)).
///
/// The cache is always bit-identical to the full search; these knobs
/// only bound *when* the incremental path is worth taking.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CutCacheConfig {
    /// Master switch. Disabled -> every frame runs the full traversal
    /// (and reports `cache_hit == 0`).
    pub enabled: bool,
    /// Camera translation (world units) beyond which the next frame
    /// falls back to a full traversal. Infinite by default: correctness
    /// never needs the fallback, it only caps worst-case revalidation
    /// work after a teleport.
    pub max_translation: f32,
    /// Camera view-direction change (radians) beyond which the next
    /// frame falls back to a full traversal.
    pub max_rotation: f32,
    /// Cap on *consecutive incremental frames*: after N cache hits in
    /// a row the next frame runs a full traversal (so the period is
    /// N + 1 frames; 0 = never force). Keeps long-running streams from
    /// depending on an unbounded chain of incremental updates.
    pub refresh_every: u32,
    /// Tau delta (absolute, LoD-threshold units) beyond which the next
    /// frame falls back to a full traversal. Like the camera-jump
    /// guards this is a *work* bound, never a correctness one: a tau
    /// nudge within the step revalidates the cached frontier (coarsen
    /// on a raise, reseeded refinement on a lower) and stays
    /// bit-identical to the canonical search. Sized to comfortably
    /// cover the QoS controller's degradation steps; a whole-regime
    /// change (e.g. a preview/quality toggle) should reseed cold.
    pub max_tau_step: f32,
}

impl Default for CutCacheConfig {
    fn default() -> Self {
        CutCacheConfig {
            enabled: true,
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::FRAC_PI_2,
            refresh_every: 64,
            max_tau_step: 8.0,
        }
    }
}

impl CutCacheConfig {
    /// A configuration that always runs the full traversal.
    pub fn disabled() -> Self {
        CutCacheConfig { enabled: false, ..Default::default() }
    }
}

/// Frame-to-frame LoD search state for one camera stream (owned by a
/// [`RenderSession`](crate::coordinator::RenderSession); one cache per
/// stream — frontiers from different streams never mix).
///
/// See the [module docs](self) for the algorithm;
/// [`CutCache::search`] is the only entry point the render loop needs.
#[derive(Debug, Default)]
pub struct CutCache {
    /// Previous frame's cut (ascending node ids).
    cut: Vec<u32>,
    /// Previous frame's frustum-culled frontier (unordered).
    culled: Vec<u32>,
    /// Whether `cut`/`culled` describe a real previous frame.
    valid: bool,
    /// Tree / SLTree shapes the cached frontier belongs to.
    nodes: usize,
    subtrees: usize,
    /// Buffer identities of the tree/SLTree the frontier was computed
    /// against (node/subtree slab base pointers). Catches a caller
    /// swapping in a different tree of coincidentally equal size —
    /// see the contract note on [`CutCache::search`].
    tree_id: usize,
    slt_id: usize,
    /// Camera pose and tau the frontier was computed at (`right`/`up`/
    /// `fwd` are the rotation rows — the full matrix feeds the
    /// verdict-budget pose metric, `fwd` alone the jump guard).
    eye: Vec3,
    right: Vec3,
    up: Vec3,
    fwd: Vec3,
    tau: f32,
    /// Incremental frames since the last full traversal.
    frames_since_full: u32,
    /// When set, revalidation records the subtree slab of every node
    /// verdict it evaluates into the trace's `touched_sids` — the
    /// out-of-core replay stream for
    /// [`crate::residency::ResidencyManager`]. Off by default so the
    /// documented zero-steady-state-allocation property holds for
    /// sessions that don't manage residency.
    collect_touched: bool,
    // ---- per-frame scratch (epoch-stamped, reused across frames) ----
    mark: Vec<u32>,
    state: Vec<u8>,
    epoch: u32,
    fetched: Vec<bool>,
    path: Vec<u32>,
    next_cut: Vec<u32>,
    next_culled: Vec<u32>,
    // ---- conservative verdict bounds (see module docs) ----
    /// Remaining pose-distance (`|Δeye| + ‖ΔR‖_F`, f64) each node's
    /// last evaluated verdict provably survives. Spent by skips.
    budget: Vec<f64>,
    /// Epoch at which `budget`/`state` were last refreshed for the
    /// node (evaluated or skipped). A skip is only legal when this is
    /// exactly the previous epoch — an unbroken per-frame chain, so
    /// the decremented budget covers the accumulated pose delta.
    budget_mark: Vec<u32>,
    /// Whether the stored budgets chain back to `eye`/`right`/`up`/
    /// `fwd` through consecutive revalidations (false after any full
    /// traversal, which leaves budgets stale).
    budgets_valid: bool,
    /// Intrinsics and near plane the budgets were computed under; the
    /// Lipschitz bound pins both, so any change disables skipping
    /// until budgets are rebuilt.
    stored_intr: Option<Intrinsics>,
    stored_near: f32,
}

/// Squared f64 distance between two `Vec3`s, for the Frobenius metric.
fn dist_sq64(a: Vec3, b: Vec3) -> f64 {
    let dx = a.x as f64 - b.x as f64;
    let dy = a.y as f64 - b.y as f64;
    let dz = a.z as f64 - b.z as f64;
    dx * dx + dy * dy + dz * dz
}

/// Convert a verdict margin (distance of the deciding slack from its
/// flip threshold, world/slack units) into a pose-distance budget.
///
/// Lipschitz bound: a camera move of pose distance
/// `pd = |Δeye| + ‖ΔR‖_F` shifts any of the node's verdict quantities
/// by at most `pd * scale` with
/// `scale = K_rot*(dist + near + h1) + near + 1`, where
/// `K_rot = 2*(max(hw, hh) + 1)` bounds the normalized side-plane
/// normals' sensitivity to rotation (`hw`/`hh` are the half-image
/// extents over focal lengths), `dist` is the node center's distance
/// from the eye at evaluation time, and `h1` is the AABB half-extent
/// L1 norm (plane slacks move with the normal through the anchor
/// offset, the anchor itself, and the projection radius; the LoD depth
/// `z = fwd·(c - eye)` moves by at most `pd*(dist + 1)`, which the
/// same scale dominates). The bound holds from the evaluation pose to
/// *any* later pose, so spending the budget frame-by-frame is covered
/// by the triangle inequality on the pose metric.
fn pose_budget(margin: f64, dist: f64, h1: f64, krot: f64, near: f64) -> f64 {
    let scale = krot * (dist + near + h1) + near + 1.0;
    let magnitude = dist + near + h1 + 1.0;
    let b = BUDGET_SAFETY * (margin - BUDGET_EPS_REL * magnitude) / scale;
    // Fail closed: degenerate inputs (NaN/inf margins, zero scale)
    // yield a zero budget, i.e. "always re-test".
    if b.is_finite() && b > 0.0 {
        b
    } else {
        0.0
    }
}

impl CutCache {
    /// An empty (cold) cache; the first [`CutCache::search`] call runs
    /// a full traversal and sizes the scratch to the tree.
    pub fn new() -> Self {
        Self::default()
    }

    /// The most recent cut (ascending node ids; empty before the first
    /// search).
    pub fn cut(&self) -> &[u32] {
        &self.cut
    }

    /// Whether the next [`CutCache::search`] may take the incremental
    /// path (a previous frame's frontier is cached).
    pub fn is_warm(&self) -> bool {
        self.valid
    }

    /// Cached frontier size (cut + culled) — the node count the next
    /// incremental frame revalidates.
    pub fn frontier_len(&self) -> usize {
        self.cut.len() + self.culled.len()
    }

    /// Drop the cached frontier; the next search runs a full traversal.
    pub fn invalidate(&mut self) {
        self.valid = false;
        self.frames_since_full = 0;
    }

    /// Enable/disable slab-touch collection: when on, incremental
    /// revalidation fills the trace's `touched_sids` with the subtree
    /// slab of every node verdict it evaluates (in access order,
    /// duplicates kept). Residency-managed sessions turn this on so the
    /// warm path's slab working set can be replayed; it never changes
    /// the search result, only what the trace reports.
    pub fn set_collect_touched(&mut self, collect: bool) {
        self.collect_touched = collect;
    }

    /// LoD search with temporal reuse: returns the cut (ascending node
    /// ids, **bit-identical** to
    /// [`LodTree::canonical_search`](super::tree::LodTree::canonical_search))
    /// and the traversal trace. The trace's `cache_hit` /
    /// `revalidated` / `reseeded` counters report which path ran.
    ///
    /// **Contract:** a warm cache is bound to the `(tree, slt)` pair it
    /// last searched. Passing a different pair falls back to a full
    /// traversal whenever that is detectable (size or backing-buffer
    /// identity changed — which covers any two simultaneously live
    /// trees); when deliberately re-pointing a cache at new data, call
    /// [`CutCache::invalidate`] first rather than relying on detection.
    pub fn search(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
        cfg: &CutCacheConfig,
    ) -> (&[u32], TraversalTrace) {
        // Disabled: run the plain full traversal without maintaining
        // any frontier state (no culled clone, no warm frontier), so a
        // cache-averse session pays nothing beyond the search itself.
        // `valid` stays false, so re-enabling later starts cold.
        if !cfg.enabled {
            let (cut, trace) = traverse_sltree(tree, slt, cam, tau, LT_UNITS);
            self.cut = cut;
            self.culled.clear();
            self.valid = false;
            return (&self.cut, trace);
        }

        let eye = cam.eye();
        let rot = cam.view.rotation();
        let fwd = rot.row(2);
        // Tau deltas within the step revalidate like camera deltas; the
        // comparison is written so a NaN tau (degenerate config) fails
        // closed into a full traversal.
        let reuse = self.valid
            && (tau - self.tau).abs() <= cfg.max_tau_step
            && self.nodes == tree.len()
            && self.subtrees == slt.len()
            && self.tree_id == tree.nodes.as_ptr() as usize
            && self.slt_id == slt.subtrees.as_ptr() as usize
            && (cfg.refresh_every == 0
                || self.frames_since_full < cfg.refresh_every)
            && self.within_delta(eye, fwd, cfg);
        let trace = if reuse {
            self.revalidate(tree, slt, cam, tau)
        } else {
            self.full_search(tree, slt, cam, tau)
        };
        self.eye = eye;
        self.right = rot.row(0);
        self.up = rot.row(1);
        self.fwd = fwd;
        self.tau = tau;
        self.valid = true;
        (&self.cut, trace)
    }

    /// Camera-jump guard: both the translation and the view-direction
    /// delta from the cached pose must stay within the config bounds.
    /// Any NaN (degenerate pose) fails closed into a full traversal.
    fn within_delta(&self, eye: Vec3, fwd: Vec3, cfg: &CutCacheConfig) -> bool {
        let translation = (eye - self.eye).length();
        let rotation = self.fwd.dot(fwd).clamp(-1.0, 1.0).acos();
        translation <= cfg.max_translation && rotation <= cfg.max_rotation
    }

    /// Cold path: full streaming traversal; the trace's `culled` list
    /// becomes the cached frontier alongside the cut.
    fn full_search(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
    ) -> TraversalTrace {
        let (cut, mut trace) = traverse_sltree_frontier(tree, slt, cam, tau, LT_UNITS);
        self.cut = cut;
        // Move the frontier out of the trace — no caller of the cache
        // reads `trace.culled`, so don't copy tens of thousands of ids.
        self.culled = std::mem::take(&mut trace.culled);
        self.nodes = tree.len();
        self.subtrees = slt.len();
        self.tree_id = tree.nodes.as_ptr() as usize;
        self.slt_id = slt.subtrees.as_ptr() as usize;
        self.frames_since_full = 0;
        // Full traversals record no margins, so the budget chain is
        // broken until the next revalidation rebuilds it.
        self.budgets_valid = false;
        if self.mark.len() != tree.len() {
            self.mark = vec![0; tree.len()];
            self.state = vec![0; tree.len()];
            self.budget = vec![0.0; tree.len()];
            self.budget_mark = vec![u32::MAX; tree.len()];
            self.epoch = 0;
        }
        if self.fetched.len() != slt.len() {
            self.fetched = vec![false; slt.len()];
        }
        trace
    }

    /// Warm path: revalidate the cached frontier against the new camera.
    ///
    /// Every root-to-leaf path crosses the cached frontier exactly once,
    /// so re-deciding each frontier node's path — with per-frame
    /// memoization of ancestor verdicts — re-derives the canonical cut
    /// exactly, while skipping the queue/activation machinery of the
    /// full traversal. With a stable cut the steady state allocates
    /// nothing (frontier buffers are double-buffered, memo arrays are
    /// epoch-stamped); reseeds that cross subtree boundaries may grow
    /// small queue/trace buffers.
    fn revalidate(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
    ) -> TraversalTrace {
        if self.epoch == u32::MAX {
            self.mark.fill(0);
            // `u32::MAX` never equals `epoch - 1` (epoch restarts at
            // 1), so pre-wrap budget chains cannot leak across the
            // wrap as false "previous epoch" matches.
            self.budget_mark.fill(u32::MAX);
            self.epoch = 0;
        }
        self.epoch += 1;
        let epoch = self.epoch;
        self.fetched.fill(false);
        let frustum = cam.frustum();
        let mut trace = TraversalTrace { cache_hit: 1, ..Default::default() };

        // Conservative verdict bounds: skipping is legal only while the
        // quantities the Lipschitz bound pins (tau, intrinsics, near)
        // are bit-unchanged and the budget chain is unbroken. The pose
        // distance `pd` is what this frame's move spends from every
        // skipped node's budget; NaN poses fail closed (`budget >= pd`
        // is false for a NaN `pd`).
        let eye_w = cam.eye();
        let rot = cam.view.rotation();
        let pd = dist_sq64(eye_w, self.eye).sqrt()
            + (dist_sq64(rot.row(0), self.right)
                + dist_sq64(rot.row(1), self.up)
                + dist_sq64(rot.row(2), self.fwd))
            .sqrt();
        let skip_ok = self.budgets_valid
            && self.budget.len() == tree.len()
            && tau.to_bits() == self.tau.to_bits()
            && self.stored_intr == Some(cam.intr)
            && self.stored_near.to_bits() == cam.near.to_bits();
        let hw = cam.intr.width as f64 * 0.5 / cam.intr.fx as f64;
        let hh = cam.intr.height as f64 * 0.5 / cam.intr.fy as f64;
        let krot = 2.0 * (hw.max(hh) + 1.0);
        let near64 = cam.near as f64;
        let tau64 = tau as f64;
        let fmax = cam.intr.fx.max(cam.intr.fy) as f64;

        let old_cut = std::mem::take(&mut self.cut);
        let old_culled = std::mem::take(&mut self.culled);
        self.next_cut.clear();
        self.next_culled.clear();

        for &n in old_cut.iter().chain(old_culled.iter()) {
            // Walk up to the first ancestor whose verdict is already
            // memoized this frame (the root is implicitly reached).
            self.path.clear();
            self.path.push(n);
            let mut a = tree.nodes[n as usize].parent;
            while a != NONE && self.mark[a as usize] != epoch {
                self.path.push(a);
                a = tree.nodes[a as usize].parent;
            }
            let mut open = a == NONE || self.state[a as usize] == OPEN;
            // Walk back down, resolving verdicts top-to-bottom. The
            // first non-descend verdict is the new frontier node on
            // this path (a coarsen when it sits above `n`).
            for &x in self.path.iter().rev() {
                let xi = x as usize;
                let s = if !open {
                    DEAD
                } else if skip_ok
                    && self.budget_mark[xi] == epoch - 1
                    && self.budget[xi] >= pd
                {
                    // The camera has provably not moved far enough
                    // since this verdict was last evaluated to flip
                    // it: replay it without re-testing. Skipped
                    // verdicts read no node record, so they push no
                    // `touched_sids` — the residency replay sees only
                    // slabs actually accessed.
                    let prev = self.state[xi];
                    trace.verdicts_skipped += 1;
                    self.budget[xi] -= pd;
                    self.budget_mark[xi] = epoch;
                    match prev {
                        STOP_CUT => self.next_cut.push(x),
                        STOP_CULL => self.next_culled.push(x),
                        _ => {}
                    }
                    prev
                } else {
                    trace.revalidated += 1;
                    trace.visited += 1;
                    if self.collect_touched {
                        // Each evaluated verdict reads one node record
                        // from its subtree slab — the warm-frame slab
                        // access the residency manager replays.
                        trace.touched_sids.push(slt.node_sid[xi]);
                    }
                    let aabb = &tree.aabbs[xi];
                    // Bit-identical to `intersects_aabb`; the margin
                    // is the verdict's distance from flipping.
                    let (inside, fmargin) =
                        frustum.intersects_aabb_margin(aabb);
                    let center = aabb.center();
                    let h = aabb.half_extent();
                    let dist = dist_sq64(center, eye_w).sqrt();
                    let h1 = (h.x + h.y + h.z) as f64;
                    let (s, margin) = if !inside {
                        self.next_culled.push(x);
                        (STOP_CULL, fmargin as f64)
                    } else {
                        let leaf = tree.nodes[xi].is_leaf();
                        // A leaf's stop verdict is LoD-independent, so
                        // only its frustum margin bounds stability.
                        let lod_margin = if leaf {
                            f64::INFINITY
                        } else {
                            // meets_lod flips where the depth crosses
                            // max(near, f*w/tau) (projected size is
                            // infinite at z <= near).
                            let z = cam.depth(center) as f64;
                            let t = (fmax
                                * tree.world_size[xi] as f64
                                / tau64)
                                .max(near64);
                            (z - t).abs()
                        };
                        let margin = (fmargin as f64).min(lod_margin);
                        if tree.meets_lod(x, cam, tau) || leaf {
                            self.next_cut.push(x);
                            (STOP_CUT, margin)
                        } else {
                            (OPEN, margin)
                        }
                    };
                    self.budget[xi] =
                        pose_budget(margin, dist, h1, krot, near64);
                    self.budget_mark[xi] = epoch;
                    s
                };
                self.mark[xi] = epoch;
                self.state[xi] = s;
                open = s == OPEN;
            }
            // The frontier node itself no longer stops the search:
            // refine below it with a bounded streaming traversal.
            if self.state[n as usize] == OPEN {
                trace.reseeded += 1;
                refine_sltree(
                    tree,
                    slt,
                    &frustum,
                    cam,
                    tau,
                    n,
                    &mut self.next_cut,
                    &mut self.next_culled,
                    &mut self.fetched,
                    &mut trace,
                );
            }
        }

        self.next_cut.sort_unstable();
        self.cut = std::mem::take(&mut self.next_cut);
        self.culled = std::mem::take(&mut self.next_culled);
        // Recycle last frame's frontier buffers for the next frame.
        self.next_cut = old_cut;
        self.next_culled = old_culled;
        self.frames_since_full = self.frames_since_full.saturating_add(1);
        // Budgets now chain to *this* camera (the pose `search` is
        // about to store) under this tau/intrinsics/near; next frame's
        // revalidation may skip inside them.
        self.budgets_valid = true;
        self.stored_intr = Some(cam.intr);
        self.stored_near = cam.near;
        trace.selected = self.cut.len() as u64;
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::{walkthrough, Scene};

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    fn assert_frame_matches(
        cache: &mut CutCache,
        scene: &Scene,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
        cfg: &CutCacheConfig,
        ctx: &str,
    ) -> TraversalTrace {
        let (want, _) = scene.tree.canonical_search(cam, tau);
        let (got, trace) = cache.search(&scene.tree, slt, cam, tau, cfg);
        assert_eq!(got, want.as_slice(), "{ctx}");
        trace
    }

    #[test]
    fn cached_path_is_bit_identical_along_a_walkthrough() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        // small_scale().quick() has world half-extent ~5.5; walk the
        // camera through the scene at that scale so cuts are non-trivial.
        let cams = walkthrough(6.0, 16, 256, 256);
        let cfg = CutCacheConfig::default();
        for tau in [4.0, 16.0] {
            let mut cache = CutCache::new();
            let mut hits = 0u64;
            for (i, cam) in cams.iter().enumerate() {
                let t = assert_frame_matches(
                    &mut cache, &scene, &slt, cam, tau, &cfg,
                    &format!("tau {tau} frame {i}"),
                );
                hits += t.cache_hit;
                if i == 0 {
                    assert_eq!(t.cache_hit, 0, "first frame must be cold");
                } else {
                    assert_eq!(t.cache_hit, 1, "frame {i} should hit");
                    // Some verdicts may ride their stability budgets
                    // instead of re-testing; the path still touches
                    // every frontier root path.
                    assert!(t.revalidated + t.verdicts_skipped > 0);
                }
            }
            assert_eq!(hits, cams.len() as u64 - 1);
        }
    }

    #[test]
    fn verdict_budgets_skip_retests_on_small_motion() {
        // A slow dolly (1e-3 units/frame) spends far less pose
        // distance than most verdicts' stability budgets, so after the
        // budget-building first revalidation the cache must start
        // skipping re-tests — while every frame's cut stays
        // bit-identical to the canonical search.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let intr = crate::math::Intrinsics::from_fov(
            256,
            256,
            60f32.to_radians(),
        );
        let mut cache = CutCache::new();
        let mut skipped = 0u64;
        let mut evaluated = 0u64;
        for i in 0..24 {
            let t = i as f32 * 1e-3;
            let cam = Camera::look_at(
                Vec3::new(8.0 + t, 3.0, -6.0),
                Vec3::new(0.0, 0.0, 0.0),
                Vec3::new(0.0, 1.0, 0.0),
                intr,
            );
            let tr = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("dolly frame {i}"),
            );
            if i == 1 {
                // Budgets are rebuilt by the first warm frame; the
                // cold frame before it recorded none.
                assert_eq!(
                    tr.verdicts_skipped, 0,
                    "no budgets exist right after a full traversal"
                );
            }
            skipped += tr.verdicts_skipped;
            evaluated += tr.revalidated;
        }
        assert!(skipped > 0, "tiny camera deltas must skip some re-tests");
        assert!(evaluated > 0, "cold + budget-building frames evaluate");
    }

    #[test]
    fn tau_nudge_disables_skipping_until_budgets_rebuild() {
        // Budgets are computed under one tau; the Lipschitz bound does
        // not model tau motion, so the frame after a tau nudge must
        // re-test everything (skip count 0) and only then resume.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(2);
        for warm in 0..3 {
            let t = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("warm {warm}"),
            );
            if warm == 2 {
                assert!(
                    t.verdicts_skipped > 0,
                    "identical pose re-search must skip via budgets"
                );
            }
        }
        let t = assert_frame_matches(
            &mut cache, &scene, &slt, &cam, 10.0, &cfg, "tau nudge",
        );
        assert_eq!(t.cache_hit, 1, "nudge stays on the incremental path");
        assert_eq!(
            t.verdicts_skipped, 0,
            "tau changed -> budgets void -> every verdict re-tested"
        );
        let t = assert_frame_matches(
            &mut cache, &scene, &slt, &cam, 10.0, &cfg, "after nudge",
        );
        assert!(t.verdicts_skipped > 0, "budgets rebuilt at the new tau");
    }

    #[test]
    fn scenario_jumps_stay_correct_even_without_fallback() {
        // Scenario cameras teleport around the scene — the incremental
        // path must stay exact no matter how far the camera moved.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig {
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::PI,
            refresh_every: 0,
            ..Default::default()
        };
        let mut cache = CutCache::new();
        for i in 0..6 {
            let cam = scene.scenario_camera(i);
            assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("scenario {i}"),
            );
        }
    }

    #[test]
    fn translation_jump_triggers_full_fallback() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig { max_translation: 0.5, ..Default::default() };
        let mut cache = CutCache::new();
        let near = scene.scenario_camera(0);
        let far = scene.scenario_camera(5);
        let t0 = assert_frame_matches(&mut cache, &scene, &slt, &near, 8.0, &cfg, "a");
        assert_eq!(t0.cache_hit, 0);
        // Same pose again: within delta -> incremental.
        let t1 = assert_frame_matches(&mut cache, &scene, &slt, &near, 8.0, &cfg, "b");
        assert_eq!(t1.cache_hit, 1);
        // Teleport: beyond delta -> full traversal, still correct.
        let t2 = assert_frame_matches(&mut cache, &scene, &slt, &far, 8.0, &cfg, "c");
        assert_eq!(t2.cache_hit, 0);
        assert_eq!(t2.revalidated, 0);
    }

    #[test]
    fn refresh_every_forces_periodic_full_searches() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig { refresh_every: 2, ..Default::default() };
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(1);
        let hits: Vec<u64> = (0..6)
            .map(|i| {
                assert_frame_matches(
                    &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                    &format!("frame {i}"),
                )
                .cache_hit
            })
            .collect();
        // cold, hit, hit, cold, hit, hit
        assert_eq!(hits, vec![0, 1, 1, 0, 1, 1]);
    }

    #[test]
    fn tau_jump_beyond_step_runs_cold() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(2);
        assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "a");
        // Delta 32 > the default max_tau_step of 8: a regime change,
        // not a nudge -> full traversal, then warm again at the new tau.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 40.0, &cfg, "b");
        assert_eq!(t.cache_hit, 0, "tau jump -> full search");
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 40.0, &cfg, "c");
        assert_eq!(t.cache_hit, 1);
    }

    #[test]
    fn tau_nudges_revalidate_instead_of_cold_starting() {
        // The serving layer's graceful-degradation steps nudge tau a
        // few units per event; those must ride the incremental path
        // (revalidate/reseed), not cold-start the whole search.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(2);
        // Precondition: the two taus select genuinely different cuts
        // (camera fixed, so the difference is purely LoD verdicts).
        let (cut8, _) = scene.tree.canonical_search(&cam, 8.0);
        let (cut2, _) = scene.tree.canonical_search(&cam, 2.0);
        assert_ne!(cut8, cut2, "degenerate scene: taus select one cut");

        assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "warm");
        // Finer nudge (delta 6 <= 8): cache hit; some cached cut node
        // now fails the stricter LoD, so refinement must reseed.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 2.0, &cfg, "finer");
        assert_eq!(t.cache_hit, 1, "nudge within max_tau_step must hit");
        assert!(t.reseeded >= 1, "finer tau must reseed refinement");
        assert!(cache.cut().len() >= cut8.len(), "finer cut cannot shrink");
        // Coarser nudge back: hit again, frontier coarsens to the old cut.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "coarser");
        assert_eq!(t.cache_hit, 1);
        assert_eq!(cache.cut().len(), cut8.len());
        // And a ramp of +2 steps stays warm the whole way up.
        for (i, tau) in [10.0f32, 12.0, 14.0, 16.0].iter().enumerate() {
            let t = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, *tau, &cfg,
                &format!("ramp {i}"),
            );
            assert_eq!(t.cache_hit, 1, "ramp step {i} must stay warm");
        }
    }

    #[test]
    fn disabled_config_always_runs_cold() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::disabled();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(0);
        for i in 0..3 {
            let t = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("frame {i}"),
            );
            assert_eq!(t.cache_hit, 0);
        }
    }

    #[test]
    fn swapping_trees_falls_back_to_full_search() {
        // A warm cache fed a *different* (tree, slt) pair must detect
        // the swap (both trees are alive, so their node slabs cannot
        // share a buffer) and run cold instead of walking stale ids.
        let a = scene();
        let b = SceneConfig::small_scale().quick().build(12);
        let slt_a = SlTree::partition(&a.tree, 32);
        let slt_b = SlTree::partition(&b.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = a.scenario_camera(1);
        assert_frame_matches(&mut cache, &a, &slt_a, &cam, 8.0, &cfg, "a0");
        let t = assert_frame_matches(&mut cache, &b, &slt_b, &cam, 8.0, &cfg, "b0");
        assert_eq!(t.cache_hit, 0, "tree swap must not reuse the frontier");
        let t = assert_frame_matches(&mut cache, &a, &slt_a, &cam, 8.0, &cfg, "a1");
        assert_eq!(t.cache_hit, 0, "swapping back is a different tree too");
    }

    #[test]
    fn invalidate_and_accessors_behave() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        assert!(!cache.is_warm());
        assert_eq!(cache.frontier_len(), 0);
        let cam = scene.scenario_camera(3);
        let (cut_len, selected) = {
            let (cut, t) = cache.search(&scene.tree, &slt, &cam, 8.0, &cfg);
            (cut.len(), t.selected)
        };
        assert_eq!(cut_len as u64, selected);
        assert!(cache.is_warm());
        assert!(cache.frontier_len() >= cache.cut().len());
        assert_eq!(cache.cut().len(), cut_len);
        cache.invalidate();
        assert!(!cache.is_warm());
        let (_, t) = cache.search(&scene.tree, &slt, &cam, 8.0, &cfg);
        assert_eq!(t.cache_hit, 0);
    }
}
