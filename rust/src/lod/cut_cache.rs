//! Temporal LoD cut cache: frame-to-frame reuse of the selected cut
//! along a camera path (the ROADMAP "frame-to-frame cut caching" item).
//!
//! The paper's hottest stage re-runs the LoD search from the tree top
//! every frame, yet consecutive cameras on a walkthrough select nearly
//! identical cuts. [`CutCache`] keeps the previous frame's search
//! *frontier* — the cut plus the frustum-culled boundary, which together
//! form an antichain covering every root-to-leaf path exactly once —
//! and revalidates it incrementally:
//!
//! * **coarsen** — walking up from a cached node, the first ancestor
//!   that now meets the LoD (or leaves the frustum) becomes the new
//!   frontier node; everything below it is dropped;
//! * **refine** — a cached cut node that no longer meets the LoD seeds
//!   a *bounded* streaming search
//!   ([`refine_sltree`](super::traversal::refine_sltree)) over its
//!   subtree slab and boundary activations only;
//! * **frustum patch** — cached culled nodes re-enter the view the same
//!   way (their verdict flips to select or refine), and cut nodes that
//!   leave the view move to the culled frontier.
//!
//! Ancestor verdicts are memoized per frame with epoch-stamped marks,
//! so shared prefixes of the frontier's root paths are tested once.
//! The result is **bit-identical** to
//! [`LodTree::canonical_search`](super::tree::LodTree::canonical_search)
//! at every frame — the verdict at each node is the same pure function
//! of `(node, camera, tau)` the full search evaluates, only the
//! *schedule* of evaluations changes. Property tests
//! (`rust/tests/proptests.rs`) and the golden-frame harness pin this.
//!
//! A full traversal still runs on the first frame, whenever the camera
//! jumps beyond [`CutCacheConfig::max_translation`] /
//! [`CutCacheConfig::max_rotation`], every
//! [`CutCacheConfig::refresh_every`] frames, when `tau` jumps by more
//! than [`CutCacheConfig::max_tau_step`], and when the tree changes —
//! the cache is a scheduler, never a semantic override. Small tau
//! *nudges* (the serving layer's graceful-degradation steps) take the
//! incremental path: node verdicts are pure functions of
//! `(node, camera, tau)`, and the cached frontier is an antichain
//! covering every root-to-leaf path, so revalidation under a new tau
//! re-derives the new canonical cut exactly — tau deltas, like camera
//! deltas, only change how much coarsening/refinement work the
//! revalidation does.

use super::sltree::SlTree;
use super::traversal::{
    refine_sltree, traverse_sltree, traverse_sltree_frontier, TraversalTrace,
};
use super::tree::{LodTree, NONE};
use crate::math::{Camera, Vec3};

/// LT-unit count modelled by the cold traversal inside the cache
/// (matches [`SlTree::traverse`]; results are independent of it).
const LT_UNITS: usize = 4;

/// Per-node verdict states memoized during one incremental frame.
const OPEN: u8 = 1; // in frustum, fails LoD, has children -> descend
const STOPPED: u8 = 2; // new frontier node (selected or culled) here
const DEAD: u8 = 3; // below a STOPPED ancestor

/// Fallback policy for the temporal cut cache
/// ([`RenderOptions::cut_cache`](crate::coordinator::RenderOptions)).
///
/// The cache is always bit-identical to the full search; these knobs
/// only bound *when* the incremental path is worth taking.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CutCacheConfig {
    /// Master switch. Disabled -> every frame runs the full traversal
    /// (and reports `cache_hit == 0`).
    pub enabled: bool,
    /// Camera translation (world units) beyond which the next frame
    /// falls back to a full traversal. Infinite by default: correctness
    /// never needs the fallback, it only caps worst-case revalidation
    /// work after a teleport.
    pub max_translation: f32,
    /// Camera view-direction change (radians) beyond which the next
    /// frame falls back to a full traversal.
    pub max_rotation: f32,
    /// Cap on *consecutive incremental frames*: after N cache hits in
    /// a row the next frame runs a full traversal (so the period is
    /// N + 1 frames; 0 = never force). Keeps long-running streams from
    /// depending on an unbounded chain of incremental updates.
    pub refresh_every: u32,
    /// Tau delta (absolute, LoD-threshold units) beyond which the next
    /// frame falls back to a full traversal. Like the camera-jump
    /// guards this is a *work* bound, never a correctness one: a tau
    /// nudge within the step revalidates the cached frontier (coarsen
    /// on a raise, reseeded refinement on a lower) and stays
    /// bit-identical to the canonical search. Sized to comfortably
    /// cover the QoS controller's degradation steps; a whole-regime
    /// change (e.g. a preview/quality toggle) should reseed cold.
    pub max_tau_step: f32,
}

impl Default for CutCacheConfig {
    fn default() -> Self {
        CutCacheConfig {
            enabled: true,
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::FRAC_PI_2,
            refresh_every: 64,
            max_tau_step: 8.0,
        }
    }
}

impl CutCacheConfig {
    /// A configuration that always runs the full traversal.
    pub fn disabled() -> Self {
        CutCacheConfig { enabled: false, ..Default::default() }
    }
}

/// Frame-to-frame LoD search state for one camera stream (owned by a
/// [`RenderSession`](crate::coordinator::RenderSession); one cache per
/// stream — frontiers from different streams never mix).
///
/// See the [module docs](self) for the algorithm;
/// [`CutCache::search`] is the only entry point the render loop needs.
#[derive(Debug, Default)]
pub struct CutCache {
    /// Previous frame's cut (ascending node ids).
    cut: Vec<u32>,
    /// Previous frame's frustum-culled frontier (unordered).
    culled: Vec<u32>,
    /// Whether `cut`/`culled` describe a real previous frame.
    valid: bool,
    /// Tree / SLTree shapes the cached frontier belongs to.
    nodes: usize,
    subtrees: usize,
    /// Buffer identities of the tree/SLTree the frontier was computed
    /// against (node/subtree slab base pointers). Catches a caller
    /// swapping in a different tree of coincidentally equal size —
    /// see the contract note on [`CutCache::search`].
    tree_id: usize,
    slt_id: usize,
    /// Camera pose and tau the frontier was computed at.
    eye: Vec3,
    fwd: Vec3,
    tau: f32,
    /// Incremental frames since the last full traversal.
    frames_since_full: u32,
    /// When set, revalidation records the subtree slab of every node
    /// verdict it evaluates into the trace's `touched_sids` — the
    /// out-of-core replay stream for
    /// [`crate::residency::ResidencyManager`]. Off by default so the
    /// documented zero-steady-state-allocation property holds for
    /// sessions that don't manage residency.
    collect_touched: bool,
    // ---- per-frame scratch (epoch-stamped, reused across frames) ----
    mark: Vec<u32>,
    state: Vec<u8>,
    epoch: u32,
    fetched: Vec<bool>,
    path: Vec<u32>,
    next_cut: Vec<u32>,
    next_culled: Vec<u32>,
}

impl CutCache {
    /// An empty (cold) cache; the first [`CutCache::search`] call runs
    /// a full traversal and sizes the scratch to the tree.
    pub fn new() -> Self {
        Self::default()
    }

    /// The most recent cut (ascending node ids; empty before the first
    /// search).
    pub fn cut(&self) -> &[u32] {
        &self.cut
    }

    /// Whether the next [`CutCache::search`] may take the incremental
    /// path (a previous frame's frontier is cached).
    pub fn is_warm(&self) -> bool {
        self.valid
    }

    /// Cached frontier size (cut + culled) — the node count the next
    /// incremental frame revalidates.
    pub fn frontier_len(&self) -> usize {
        self.cut.len() + self.culled.len()
    }

    /// Drop the cached frontier; the next search runs a full traversal.
    pub fn invalidate(&mut self) {
        self.valid = false;
        self.frames_since_full = 0;
    }

    /// Enable/disable slab-touch collection: when on, incremental
    /// revalidation fills the trace's `touched_sids` with the subtree
    /// slab of every node verdict it evaluates (in access order,
    /// duplicates kept). Residency-managed sessions turn this on so the
    /// warm path's slab working set can be replayed; it never changes
    /// the search result, only what the trace reports.
    pub fn set_collect_touched(&mut self, collect: bool) {
        self.collect_touched = collect;
    }

    /// LoD search with temporal reuse: returns the cut (ascending node
    /// ids, **bit-identical** to
    /// [`LodTree::canonical_search`](super::tree::LodTree::canonical_search))
    /// and the traversal trace. The trace's `cache_hit` /
    /// `revalidated` / `reseeded` counters report which path ran.
    ///
    /// **Contract:** a warm cache is bound to the `(tree, slt)` pair it
    /// last searched. Passing a different pair falls back to a full
    /// traversal whenever that is detectable (size or backing-buffer
    /// identity changed — which covers any two simultaneously live
    /// trees); when deliberately re-pointing a cache at new data, call
    /// [`CutCache::invalidate`] first rather than relying on detection.
    pub fn search(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
        cfg: &CutCacheConfig,
    ) -> (&[u32], TraversalTrace) {
        // Disabled: run the plain full traversal without maintaining
        // any frontier state (no culled clone, no warm frontier), so a
        // cache-averse session pays nothing beyond the search itself.
        // `valid` stays false, so re-enabling later starts cold.
        if !cfg.enabled {
            let (cut, trace) = traverse_sltree(tree, slt, cam, tau, LT_UNITS);
            self.cut = cut;
            self.culled.clear();
            self.valid = false;
            return (&self.cut, trace);
        }

        let eye = cam.eye();
        let fwd = cam.view.rotation().row(2);
        // Tau deltas within the step revalidate like camera deltas; the
        // comparison is written so a NaN tau (degenerate config) fails
        // closed into a full traversal.
        let reuse = self.valid
            && (tau - self.tau).abs() <= cfg.max_tau_step
            && self.nodes == tree.len()
            && self.subtrees == slt.len()
            && self.tree_id == tree.nodes.as_ptr() as usize
            && self.slt_id == slt.subtrees.as_ptr() as usize
            && (cfg.refresh_every == 0
                || self.frames_since_full < cfg.refresh_every)
            && self.within_delta(eye, fwd, cfg);
        let trace = if reuse {
            self.revalidate(tree, slt, cam, tau)
        } else {
            self.full_search(tree, slt, cam, tau)
        };
        self.eye = eye;
        self.fwd = fwd;
        self.tau = tau;
        self.valid = true;
        (&self.cut, trace)
    }

    /// Camera-jump guard: both the translation and the view-direction
    /// delta from the cached pose must stay within the config bounds.
    /// Any NaN (degenerate pose) fails closed into a full traversal.
    fn within_delta(&self, eye: Vec3, fwd: Vec3, cfg: &CutCacheConfig) -> bool {
        let translation = (eye - self.eye).length();
        let rotation = self.fwd.dot(fwd).clamp(-1.0, 1.0).acos();
        translation <= cfg.max_translation && rotation <= cfg.max_rotation
    }

    /// Cold path: full streaming traversal; the trace's `culled` list
    /// becomes the cached frontier alongside the cut.
    fn full_search(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
    ) -> TraversalTrace {
        let (cut, mut trace) = traverse_sltree_frontier(tree, slt, cam, tau, LT_UNITS);
        self.cut = cut;
        // Move the frontier out of the trace — no caller of the cache
        // reads `trace.culled`, so don't copy tens of thousands of ids.
        self.culled = std::mem::take(&mut trace.culled);
        self.nodes = tree.len();
        self.subtrees = slt.len();
        self.tree_id = tree.nodes.as_ptr() as usize;
        self.slt_id = slt.subtrees.as_ptr() as usize;
        self.frames_since_full = 0;
        if self.mark.len() != tree.len() {
            self.mark = vec![0; tree.len()];
            self.state = vec![0; tree.len()];
            self.epoch = 0;
        }
        if self.fetched.len() != slt.len() {
            self.fetched = vec![false; slt.len()];
        }
        trace
    }

    /// Warm path: revalidate the cached frontier against the new camera.
    ///
    /// Every root-to-leaf path crosses the cached frontier exactly once,
    /// so re-deciding each frontier node's path — with per-frame
    /// memoization of ancestor verdicts — re-derives the canonical cut
    /// exactly, while skipping the queue/activation machinery of the
    /// full traversal. With a stable cut the steady state allocates
    /// nothing (frontier buffers are double-buffered, memo arrays are
    /// epoch-stamped); reseeds that cross subtree boundaries may grow
    /// small queue/trace buffers.
    fn revalidate(
        &mut self,
        tree: &LodTree,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
    ) -> TraversalTrace {
        if self.epoch == u32::MAX {
            self.mark.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
        let epoch = self.epoch;
        self.fetched.fill(false);
        let frustum = cam.frustum();
        let mut trace = TraversalTrace { cache_hit: 1, ..Default::default() };

        let old_cut = std::mem::take(&mut self.cut);
        let old_culled = std::mem::take(&mut self.culled);
        self.next_cut.clear();
        self.next_culled.clear();

        for &n in old_cut.iter().chain(old_culled.iter()) {
            // Walk up to the first ancestor whose verdict is already
            // memoized this frame (the root is implicitly reached).
            self.path.clear();
            self.path.push(n);
            let mut a = tree.nodes[n as usize].parent;
            while a != NONE && self.mark[a as usize] != epoch {
                self.path.push(a);
                a = tree.nodes[a as usize].parent;
            }
            let mut open = a == NONE || self.state[a as usize] == OPEN;
            // Walk back down, resolving verdicts top-to-bottom. The
            // first non-descend verdict is the new frontier node on
            // this path (a coarsen when it sits above `n`).
            for &x in self.path.iter().rev() {
                let s = if !open {
                    DEAD
                } else {
                    trace.revalidated += 1;
                    trace.visited += 1;
                    if self.collect_touched {
                        // Each evaluated verdict reads one node record
                        // from its subtree slab — the warm-frame slab
                        // access the residency manager replays.
                        trace.touched_sids.push(slt.node_sid[x as usize]);
                    }
                    if !frustum.intersects_aabb(&tree.aabbs[x as usize]) {
                        self.next_culled.push(x);
                        STOPPED
                    } else if tree.meets_lod(x, cam, tau)
                        || tree.nodes[x as usize].is_leaf()
                    {
                        self.next_cut.push(x);
                        STOPPED
                    } else {
                        OPEN
                    }
                };
                self.mark[x as usize] = epoch;
                self.state[x as usize] = s;
                open = s == OPEN;
            }
            // The frontier node itself no longer stops the search:
            // refine below it with a bounded streaming traversal.
            if self.state[n as usize] == OPEN {
                trace.reseeded += 1;
                refine_sltree(
                    tree,
                    slt,
                    &frustum,
                    cam,
                    tau,
                    n,
                    &mut self.next_cut,
                    &mut self.next_culled,
                    &mut self.fetched,
                    &mut trace,
                );
            }
        }

        self.next_cut.sort_unstable();
        self.cut = std::mem::take(&mut self.next_cut);
        self.culled = std::mem::take(&mut self.next_culled);
        // Recycle last frame's frontier buffers for the next frame.
        self.next_cut = old_cut;
        self.next_culled = old_culled;
        self.frames_since_full = self.frames_since_full.saturating_add(1);
        trace.selected = self.cut.len() as u64;
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::scene::{walkthrough, Scene};

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    fn assert_frame_matches(
        cache: &mut CutCache,
        scene: &Scene,
        slt: &SlTree,
        cam: &Camera,
        tau: f32,
        cfg: &CutCacheConfig,
        ctx: &str,
    ) -> TraversalTrace {
        let (want, _) = scene.tree.canonical_search(cam, tau);
        let (got, trace) = cache.search(&scene.tree, slt, cam, tau, cfg);
        assert_eq!(got, want.as_slice(), "{ctx}");
        trace
    }

    #[test]
    fn cached_path_is_bit_identical_along_a_walkthrough() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        // small_scale().quick() has world half-extent ~5.5; walk the
        // camera through the scene at that scale so cuts are non-trivial.
        let cams = walkthrough(6.0, 16, 256, 256);
        let cfg = CutCacheConfig::default();
        for tau in [4.0, 16.0] {
            let mut cache = CutCache::new();
            let mut hits = 0u64;
            for (i, cam) in cams.iter().enumerate() {
                let t = assert_frame_matches(
                    &mut cache, &scene, &slt, cam, tau, &cfg,
                    &format!("tau {tau} frame {i}"),
                );
                hits += t.cache_hit;
                if i == 0 {
                    assert_eq!(t.cache_hit, 0, "first frame must be cold");
                } else {
                    assert_eq!(t.cache_hit, 1, "frame {i} should hit");
                    assert!(t.revalidated > 0);
                }
            }
            assert_eq!(hits, cams.len() as u64 - 1);
        }
    }

    #[test]
    fn scenario_jumps_stay_correct_even_without_fallback() {
        // Scenario cameras teleport around the scene — the incremental
        // path must stay exact no matter how far the camera moved.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig {
            max_translation: f32::INFINITY,
            max_rotation: std::f32::consts::PI,
            refresh_every: 0,
            ..Default::default()
        };
        let mut cache = CutCache::new();
        for i in 0..6 {
            let cam = scene.scenario_camera(i);
            assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("scenario {i}"),
            );
        }
    }

    #[test]
    fn translation_jump_triggers_full_fallback() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig { max_translation: 0.5, ..Default::default() };
        let mut cache = CutCache::new();
        let near = scene.scenario_camera(0);
        let far = scene.scenario_camera(5);
        let t0 = assert_frame_matches(&mut cache, &scene, &slt, &near, 8.0, &cfg, "a");
        assert_eq!(t0.cache_hit, 0);
        // Same pose again: within delta -> incremental.
        let t1 = assert_frame_matches(&mut cache, &scene, &slt, &near, 8.0, &cfg, "b");
        assert_eq!(t1.cache_hit, 1);
        // Teleport: beyond delta -> full traversal, still correct.
        let t2 = assert_frame_matches(&mut cache, &scene, &slt, &far, 8.0, &cfg, "c");
        assert_eq!(t2.cache_hit, 0);
        assert_eq!(t2.revalidated, 0);
    }

    #[test]
    fn refresh_every_forces_periodic_full_searches() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig { refresh_every: 2, ..Default::default() };
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(1);
        let hits: Vec<u64> = (0..6)
            .map(|i| {
                assert_frame_matches(
                    &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                    &format!("frame {i}"),
                )
                .cache_hit
            })
            .collect();
        // cold, hit, hit, cold, hit, hit
        assert_eq!(hits, vec![0, 1, 1, 0, 1, 1]);
    }

    #[test]
    fn tau_jump_beyond_step_runs_cold() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(2);
        assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "a");
        // Delta 32 > the default max_tau_step of 8: a regime change,
        // not a nudge -> full traversal, then warm again at the new tau.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 40.0, &cfg, "b");
        assert_eq!(t.cache_hit, 0, "tau jump -> full search");
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 40.0, &cfg, "c");
        assert_eq!(t.cache_hit, 1);
    }

    #[test]
    fn tau_nudges_revalidate_instead_of_cold_starting() {
        // The serving layer's graceful-degradation steps nudge tau a
        // few units per event; those must ride the incremental path
        // (revalidate/reseed), not cold-start the whole search.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(2);
        // Precondition: the two taus select genuinely different cuts
        // (camera fixed, so the difference is purely LoD verdicts).
        let (cut8, _) = scene.tree.canonical_search(&cam, 8.0);
        let (cut2, _) = scene.tree.canonical_search(&cam, 2.0);
        assert_ne!(cut8, cut2, "degenerate scene: taus select one cut");

        assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "warm");
        // Finer nudge (delta 6 <= 8): cache hit; some cached cut node
        // now fails the stricter LoD, so refinement must reseed.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 2.0, &cfg, "finer");
        assert_eq!(t.cache_hit, 1, "nudge within max_tau_step must hit");
        assert!(t.reseeded >= 1, "finer tau must reseed refinement");
        assert!(cache.cut().len() >= cut8.len(), "finer cut cannot shrink");
        // Coarser nudge back: hit again, frontier coarsens to the old cut.
        let t = assert_frame_matches(&mut cache, &scene, &slt, &cam, 8.0, &cfg, "coarser");
        assert_eq!(t.cache_hit, 1);
        assert_eq!(cache.cut().len(), cut8.len());
        // And a ramp of +2 steps stays warm the whole way up.
        for (i, tau) in [10.0f32, 12.0, 14.0, 16.0].iter().enumerate() {
            let t = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, *tau, &cfg,
                &format!("ramp {i}"),
            );
            assert_eq!(t.cache_hit, 1, "ramp step {i} must stay warm");
        }
    }

    #[test]
    fn disabled_config_always_runs_cold() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::disabled();
        let mut cache = CutCache::new();
        let cam = scene.scenario_camera(0);
        for i in 0..3 {
            let t = assert_frame_matches(
                &mut cache, &scene, &slt, &cam, 8.0, &cfg,
                &format!("frame {i}"),
            );
            assert_eq!(t.cache_hit, 0);
        }
    }

    #[test]
    fn swapping_trees_falls_back_to_full_search() {
        // A warm cache fed a *different* (tree, slt) pair must detect
        // the swap (both trees are alive, so their node slabs cannot
        // share a buffer) and run cold instead of walking stale ids.
        let a = scene();
        let b = SceneConfig::small_scale().quick().build(12);
        let slt_a = SlTree::partition(&a.tree, 32);
        let slt_b = SlTree::partition(&b.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        let cam = a.scenario_camera(1);
        assert_frame_matches(&mut cache, &a, &slt_a, &cam, 8.0, &cfg, "a0");
        let t = assert_frame_matches(&mut cache, &b, &slt_b, &cam, 8.0, &cfg, "b0");
        assert_eq!(t.cache_hit, 0, "tree swap must not reuse the frontier");
        let t = assert_frame_matches(&mut cache, &a, &slt_a, &cam, 8.0, &cfg, "a1");
        assert_eq!(t.cache_hit, 0, "swapping back is a different tree too");
    }

    #[test]
    fn invalidate_and_accessors_behave() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cfg = CutCacheConfig::default();
        let mut cache = CutCache::new();
        assert!(!cache.is_warm());
        assert_eq!(cache.frontier_len(), 0);
        let cam = scene.scenario_camera(3);
        let (cut_len, selected) = {
            let (cut, t) = cache.search(&scene.tree, &slt, &cam, 8.0, &cfg);
            (cut.len(), t.selected)
        };
        assert_eq!(cut_len as u64, selected);
        assert!(cache.is_warm());
        assert!(cache.frontier_len() >= cache.cut().len());
        assert_eq!(cache.cut().len(), cut_len);
        cache.invalidate();
        assert!(!cache.is_warm());
        let (_, t) = cache.search(&scene.tree, &slt, &cam, 8.0, &cfg);
        assert_eq!(t.cache_hit, 0);
    }
}
