//! Seeded property-testing loop (proptest is not vendored).
//!
//! `forall(cases, |rng| ...)` runs the closure over `cases` independent
//! deterministic RNG streams; on failure it reports the failing case
//! seed so the case can be replayed exactly:
//!
//! ```no_run
//! use sltarch::util::prop::forall;
//! forall(256, |rng| {
//!     let x = rng.range(0.0, 10.0);
//!     assert!(x >= 0.0, "negative sample");
//! });
//! ```

use super::Rng;

/// Base seed for all property tests; change to explore a new universe.
pub const PROP_SEED: u64 = 0x5175_AC47;

/// Run `body` over `cases` deterministic RNG streams; panics with the
/// failing case index + seed on the first violation.
pub fn forall(cases: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = PROP_SEED ^ case.wrapping_mul(0xA24B_AED4_963E_E407);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, mut body: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    body(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(64, |rng| {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn forall_reports_failing_seed() {
        let caught = std::panic::catch_unwind(|| {
            forall(64, |rng| {
                // Fails eventually with overwhelming probability.
                assert!(rng.f32() < 0.5, "coin landed heads");
            });
        });
        let err = caught.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("replay seed"), "missing replay info: {msg}");
    }
}
