//! Minimal bench harness (criterion is not vendored in this image).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```no_run
//! use sltarch::util::bench::Bench;
//! let mut b = Bench::new("fig9_speedup");
//! b.iter("gpu_baseline", 10, || { /* workload */ });
//! b.report();
//! ```
//!
//! Reports mean / std / min over timed iterations after warmup, in
//! criterion-like formatting, and never optimizes the workload away
//! (uses `std::hint::black_box`).

use super::stats::summarize;
use std::time::Instant;

/// One named measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

/// Bench context: collects named measurements and prints a report.
pub struct Bench {
    pub group: String,
    measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Bench { group: group.to_string(), measurements: Vec::new() }
    }

    /// Time `f` for `iters` measured iterations (plus 1 warmup); the
    /// closure's return value is black-boxed so work is not elided.
    pub fn iter<T>(&mut self, name: &str, iters: usize, mut f: impl FnMut() -> T) {
        std::hint::black_box(f()); // warmup
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples_ns: samples,
        });
    }

    /// Record an externally computed scalar (e.g. simulated cycles) so
    /// model-level results appear in the same report as wall-clock ones.
    pub fn record(&mut self, name: &str, value: f64) {
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples_ns: vec![value],
        });
    }

    /// Human-readable report to stdout.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        for m in &self.measurements {
            let s = summarize(&m.samples_ns).unwrap();
            if s.n == 1 {
                println!("  {:<42} {:>14.1}", m.name, s.mean);
            } else {
                println!(
                    "  {:<42} mean {:>11} std {:>10} min {:>11}  (n={})",
                    m.name,
                    fmt_ns(s.mean),
                    fmt_ns(s.std),
                    fmt_ns(s.min),
                    s.n
                );
            }
        }
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Machine-readable dump so the perf trajectory can accumulate in
    /// CI: `{"group": ..., "entries": [{name, n, mean_ns, std_ns,
    /// min_ns}, ...]}`. Hand-rolled JSON (serde is not vendored).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{{")?;
        writeln!(f, "  \"group\": \"{}\",", esc(&self.group))?;
        writeln!(f, "  \"entries\": [")?;
        for (i, m) in self.measurements.iter().enumerate() {
            let s = summarize(&m.samples_ns).unwrap();
            writeln!(
                f,
                "    {{\"name\": \"{}\", \"n\": {}, \"mean_ns\": {:.1}, \
                 \"std_ns\": {:.1}, \"min_ns\": {:.1}}}{}",
                esc(&m.name),
                s.n,
                s.mean,
                s.std,
                s.min,
                if i + 1 == self.measurements.len() { "" } else { "," }
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bench::new("test");
        let mut counter = 0u64;
        b.iter("noop", 5, || {
            counter += 1;
            counter
        });
        assert_eq!(b.measurements().len(), 1);
        assert_eq!(b.measurements()[0].samples_ns.len(), 5);
        // 1 warmup + 5 measured.
        assert_eq!(counter, 6);
    }

    #[test]
    fn json_dump_has_group_and_entries() {
        let mut b = Bench::new("jsontest");
        b.iter("op(a)", 3, || 1 + 1);
        b.record("scalar", 42.0);
        let dir = std::env::temp_dir().join("sltarch_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_jsontest.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"group\": \"jsontest\""));
        assert!(text.contains("\"name\": \"op(a)\""));
        assert!(text.contains("\"mean_ns\": 42.0"));
        assert!(text.trim_end().ends_with('}'));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with('s'));
    }
}
