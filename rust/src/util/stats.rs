//! Small statistics helpers shared by the bench harness, the workload
//! imbalance study (Fig. 3) and the experiment reports.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

/// Compute summary statistics; returns `None` on an empty sample.
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        let idx = (p * (n - 1) as f64).round() as usize;
        sorted[idx.min(n - 1)]
    };
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: pct(0.5),
        p95: pct(0.95),
    })
}

/// Coefficient of variation (std/mean) — the imbalance measure used by
/// Fig. 3 and the Fig. 12 utilization ablation. 0 for an empty/zero set.
pub fn cov(xs: &[f64]) -> f64 {
    match summarize(xs) {
        Some(s) if s.mean.abs() > 1e-12 => s.std / s.mean,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_is_none() {
        assert!(summarize(&[]).is_none());
        assert_eq!(cov(&[]), 0.0);
    }

    #[test]
    fn cov_balanced_vs_imbalanced() {
        let balanced = vec![10.0; 64];
        let mut imbalanced = vec![1.0; 63];
        imbalanced.push(1000.0);
        assert!(cov(&balanced) < 1e-9);
        assert!(cov(&imbalanced) > 1.0);
    }
}
