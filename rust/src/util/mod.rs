//! In-tree substitutes for crates that are not vendored in this offline
//! image: a deterministic PRNG (`rand`), a statistics-reporting bench
//! harness (`criterion`), and a seeded property-testing loop (`proptest`).
//! All deterministic by construction — experiment outputs are exactly
//! reproducible run-to-run.

pub mod bench;
pub mod prop;
mod rng;
pub mod stats;

pub use rng::Rng;
