//! Deterministic PRNG: xoshiro256++ (public-domain algorithm by
//! Blackman & Vigna), seeded via splitmix64. No external crates; every
//! scene, workload and property test derives from an explicit `u64` seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-thread determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa bits -> exactly representable uniform grid.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)`; `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Sample from a (truncated, `max`-capped) geometric-ish heavy tail —
    /// used by the scene builder to mimic HierarchicalGS's skewed fan-out
    /// (one parent can have 10^3 children).
    pub fn heavy_tail(&mut self, mean: f32, max: usize) -> usize {
        // Pareto-ish: x = mean * u^(-0.7) spread over a wide range.
        let u = self.f32().max(1e-6);
        let x = mean * u.powf(-0.7) * 0.45;
        (x as usize).clamp(1, max)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn heavy_tail_bounds_and_skew() {
        let mut r = Rng::new(3);
        let mut max_seen = 0;
        for _ in 0..20_000 {
            let x = r.heavy_tail(8.0, 1000);
            assert!((1..=1000).contains(&x));
            max_seen = max_seen.max(x);
        }
        // The tail must actually reach far beyond the mean.
        assert!(max_seen > 200, "tail too light: {max_seen}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
