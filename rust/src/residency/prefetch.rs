//! Cut-cache-driven slab prefetch prediction.
//!
//! A camera path's consecutive cuts differ by a *frontier delta*: nodes
//! newly added to the cut mean refinement advanced (and will likely
//! advance further next frame — into the boundary child slabs below the
//! added nodes), while nodes removed from the cut mean coarsening
//! retreated (and will likely retreat further — into the parent slab).
//! [`predict_slabs`] turns one frame's delta into the slab set to
//! prefetch for the next frame; the
//! [`ResidencyManager`](super::ResidencyManager) issues those loads
//! between frames so they never stall the search.

use crate::lod::sltree::SlTree;
use crate::lod::tree::NONE;

/// Push the child-subtree sids linked at `pos` of subtree `sid` (the
/// boundary run is sorted by position — binary search it).
#[inline]
fn push_boundary_children(slt: &SlTree, sid: u32, pos: u32, out: &mut Vec<u32>) {
    let st = &slt.subtrees[sid as usize];
    let lo = st.boundary.partition_point(|&(bp, _)| bp < pos);
    for &(bp, csid) in &st.boundary[lo..] {
        if bp != pos {
            break;
        }
        out.push(csid);
    }
}

/// Predict the subtree slabs the *next* frame is likely to touch from
/// the delta between two consecutive cuts (both ascending node ids, as
/// every search entry point returns them).
///
/// * node added to the cut -> its own slab plus the boundary child
///   slabs at its position (refinement momentum: the search just
///   descended to here and tends to descend past it next);
/// * node removed from the cut -> its slab's parent slab (coarsening
///   momentum: the frontier just pulled up out of this slab).
///
/// `out` is cleared, then filled sorted + deduplicated. The caller
/// filters already-resident slabs; prediction is pure — it never
/// touches residency state. An empty `prev_cut` (first frame) treats
/// the whole cut as added, which warms the boundary ring below the
/// initial frontier.
pub fn predict_slabs(slt: &SlTree, prev_cut: &[u32], cut: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0usize, 0usize);
    while i < prev_cut.len() || j < cut.len() {
        let in_prev = i < prev_cut.len();
        let in_cur = j < cut.len();
        if in_prev && in_cur && prev_cut[i] == cut[j] {
            // Unchanged frontier node: no momentum signal.
            i += 1;
            j += 1;
        } else if !in_prev || (in_cur && cut[j] < prev_cut[i]) {
            // Added: refinement reached `n`; prefetch below it.
            let n = cut[j];
            let sid = slt.node_sid[n as usize];
            out.push(sid);
            push_boundary_children(slt, sid, slt.node_pos[n as usize], out);
            j += 1;
        } else {
            // Removed: coarsening left `n`'s slab; prefetch above it.
            let n = prev_cut[i];
            let psid = slt.subtrees[slt.node_sid[n as usize] as usize].parent_sid;
            if psid != NONE {
                out.push(psid);
            }
            i += 1;
        }
    }
    out.sort_unstable();
    out.dedup();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::lod::traversal::traverse_sltree;
    use crate::scene::Scene;

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    #[test]
    fn prediction_is_sorted_deduped_and_in_range() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(0);
        let (coarse, _) = traverse_sltree(&scene.tree, &slt, &cam, 32.0, 4);
        let (fine, _) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        let mut out = Vec::new();
        predict_slabs(&slt, &coarse, &fine, &mut out);
        assert!(out.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        assert!(out.iter().all(|&s| (s as usize) < slt.len()));
        assert!(!out.is_empty(), "a real refinement delta predicts slabs");
    }

    #[test]
    fn identical_cuts_predict_nothing() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(1);
        let (cut, _) = traverse_sltree(&scene.tree, &slt, &cam, 16.0, 4);
        let mut out = vec![99]; // must be cleared
        predict_slabs(&slt, &cut, &cut, &mut out);
        assert!(out.is_empty(), "no delta -> no prediction");
    }

    #[test]
    fn added_nodes_predict_their_boundary_children() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(2);
        let (cut, _) = traverse_sltree(&scene.tree, &slt, &cam, 8.0, 4);
        let mut out = Vec::new();
        // Empty previous cut: every cut node counts as added.
        predict_slabs(&slt, &[], &cut, &mut out);
        let mut checked = 0;
        for &n in &cut {
            let sid = slt.node_sid[n as usize];
            assert!(out.binary_search(&sid).is_ok(), "own slab of node {n}");
            let st = &slt.subtrees[sid as usize];
            let pos = slt.node_pos[n as usize];
            for &(bp, csid) in &st.boundary {
                if bp == pos {
                    assert!(
                        out.binary_search(&csid).is_ok(),
                        "boundary child slab {csid} of node {n}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 0, "degenerate scene: no boundary links on the cut");
    }

    #[test]
    fn removed_nodes_predict_the_parent_slab() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let cam = scene.scenario_camera(3);
        // Coarsening direction: fine cut was cached, coarse cut is next.
        let (fine, _) = traverse_sltree(&scene.tree, &slt, &cam, 4.0, 4);
        let (coarse, _) = traverse_sltree(&scene.tree, &slt, &cam, 32.0, 4);
        let mut out = Vec::new();
        predict_slabs(&slt, &fine, &coarse, &mut out);
        let mut checked = 0;
        for &n in &fine {
            if coarse.binary_search(&n).is_ok() {
                continue; // still in the cut -> not removed
            }
            let psid = slt.subtrees[slt.node_sid[n as usize] as usize].parent_sid;
            if psid != crate::lod::tree::NONE {
                assert!(
                    out.binary_search(&psid).is_ok(),
                    "parent slab {psid} of removed node {n}"
                );
                checked += 1;
            }
        }
        assert!(checked > 0, "degenerate scene: coarsening removed nothing");
    }
}
