//! Out-of-core subtree-slab residency: render scenes **larger than
//! memory** without giving up the SLTree's memory regularity.
//!
//! The SLTree already makes every LoD-search fetch a streaming burst of
//! one size-capped slab; this subsystem adds the missing piece for
//! city-scale scenes — a hard byte budget over which slabs are actually
//! held, with demand faulting, pinned LRU eviction, and a prefetcher
//! driven by the temporal cut cache:
//!
//! * [`ResidencyManager`] — per-slab state machine
//!   (`Evicted -> Loading -> Resident`), first-touch fault accounting
//!   (compulsory vs capacity misses), LRU eviction that never evicts
//!   the root slab or a slab pinned by the current frame's cut, and
//!   bypass loads when pins fill the budget (so
//!   `resident_bytes <= budget` holds unconditionally);
//! * [`prefetch`] — a frame's coarsen/refine cut delta predicts the
//!   slabs the next frame will touch; prefetch loads issue *between*
//!   frames, so a correct prediction turns a demand stall into a free
//!   hit;
//! * [`ResidencyConfig`] / [`ResidencyStats`] — the
//!   [`RenderOptions`](crate::coordinator::RenderOptions) knob and the
//!   [`RenderStats`](crate::coordinator::RenderStats) telemetry block.
//!
//! **Bit-identity by construction.** The manager never sits on the
//! search path: the session runs the (unchanged) LoD search first, then
//! *replays* the frame's slab-access trace here. Residency decides when
//! bytes are charged — demand stall vs overlapped prefetch — never what
//! the search computes, so residency-enabled renders are byte-identical
//! to unmanaged ones (pinned by the golden harness and a dedicated
//! proptest). Demand-miss bytes become stall seconds via the
//! [`sim::dram`](crate::sim::dram) cost model, and the serving layer
//! feeds that stall into its QoS miss signal so adaptive tau responds
//! to memory pressure as well as compute pressure.

#![warn(missing_docs)]

pub mod manager;
pub mod prefetch;

pub use manager::{ResidencyManager, SlabState};
pub use prefetch::predict_slabs;

/// Residency knob on [`RenderOptions`](crate::coordinator::RenderOptions):
/// whether slab residency is managed, under what byte budget, and
/// whether the cut-delta prefetcher runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResidencyConfig {
    /// Master switch. Disabled (the default) -> the session charges no
    /// residency at all: no manager state, no stall, identical to the
    /// pre-residency behavior.
    pub enabled: bool,
    /// Resident-buffer budget in bytes. The manager never holds more
    /// than this (bypass loads keep the invariant unconditional even
    /// when one frame's pinned cut exceeds it).
    pub budget_bytes: u64,
    /// Run the cut-delta prefetcher between frames. On by default when
    /// residency is enabled; turning it off isolates demand-fault
    /// behavior (every first touch stalls).
    pub prefetch: bool,
}

impl Default for ResidencyConfig {
    fn default() -> Self {
        ResidencyConfig { enabled: false, budget_bytes: u64::MAX, prefetch: true }
    }
}

impl ResidencyConfig {
    /// Enabled residency with prefetch under `budget_bytes`.
    pub fn with_budget(budget_bytes: u64) -> Self {
        ResidencyConfig { enabled: true, budget_bytes, prefetch: true }
    }
}

/// Residency telemetry: per-frame deltas from
/// [`ResidencyManager::charge_frame`], accumulated into
/// [`RenderStats`](crate::coordinator::RenderStats) (and summed across
/// clients by its `merge`). First touch per slab per frame counts once;
/// repeats within a frame are free.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResidencyStats {
    /// Frames charged.
    pub frames: u64,
    /// First touches that found the slab resident.
    pub hits: u64,
    /// First touches that demand-faulted (compulsory + capacity).
    pub misses: u64,
    /// Misses on slabs never loaded before (compulsory / cold misses);
    /// `misses - cold_misses` are capacity misses caused by eviction.
    pub cold_misses: u64,
    /// First touches of a prefetched slab before anything else touched
    /// it — the prefetches that actually paid off.
    pub prefetch_hits: u64,
    /// Prefetch loads issued between frames.
    pub prefetch_issued: u64,
    /// Demand-miss bytes streamed from DRAM (stalling).
    pub bytes_loaded: u64,
    /// Prefetch bytes streamed from DRAM (overlapped, non-stalling).
    pub bytes_prefetched: u64,
    /// Bytes evicted to make room (LRU victims).
    pub bytes_evicted: u64,
    /// Demand loads charged but not retained because pinned slabs left
    /// no evictable room under the budget.
    pub bypass_loads: u64,
    /// Simulated demand-stall time: demand-miss traffic through
    /// [`sim::dram::Traffic::dram_cycles`](crate::sim::dram::Traffic::dram_cycles)
    /// at the 1 GHz reference clock.
    pub stall_seconds: f64,
}

impl ResidencyStats {
    /// First-touch hit rate, `hits / (hits + misses)`; 0 when idle.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fraction of issued prefetches that were touched before eviction,
    /// `prefetch_hits / prefetch_issued`; 0 when none were issued.
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_issued == 0 {
            0.0
        } else {
            self.prefetch_hits as f64 / self.prefetch_issued as f64
        }
    }

    /// Sum `other` into `self` (all counters; `stall_seconds` adds).
    pub fn accumulate(&mut self, other: &ResidencyStats) {
        self.frames += other.frames;
        self.hits += other.hits;
        self.misses += other.misses;
        self.cold_misses += other.cold_misses;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_issued += other.prefetch_issued;
        self.bytes_loaded += other.bytes_loaded;
        self.bytes_prefetched += other.bytes_prefetched;
        self.bytes_evicted += other.bytes_evicted;
        self.bypass_loads += other.bypass_loads;
        self.stall_seconds += other.stall_seconds;
    }
}
