//! The slab residency manager: per-slab state machine, first-touch
//! fault accounting, pinned LRU eviction, and prefetch issue/promote.
//!
//! One manager per [`RenderSession`](crate::coordinator::RenderSession)
//! (like the cut cache — slab recency from different camera streams
//! never mixes). The manager is a *replay* simulator: the session runs
//! the LoD search first, then charges the frame's slab-access stream
//! here, so residency can change **when** bytes are charged but never
//! **what** the search computed — bit-identity with the unmanaged path
//! holds by construction.

use super::prefetch::predict_slabs;
use super::{ResidencyConfig, ResidencyStats};
use crate::config::DramConfig;
use crate::lod::sltree::SlTree;
use crate::sim::dram::Traffic;

/// Residency state of one subtree slab.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlabState {
    /// Not in the resident buffer; a touch is a demand miss.
    Evicted,
    /// Prefetch in flight, issued at the end of the previous frame;
    /// occupies budget, promotes to `Resident` when the next frame's
    /// charge begins.
    Loading,
    /// In the resident buffer; touches are free.
    Resident,
}

/// Per-slab bookkeeping record.
#[derive(Clone, Copy, Debug)]
struct Slab {
    /// Slab size ([`crate::lod::sltree::slab_bytes`] of its node count).
    bytes: u64,
    state: SlabState,
    /// Recency tick of the last touch (LRU key; ties break by sid).
    last_use: u64,
    /// Loaded by the prefetcher and not yet demand-touched; the first
    /// touch counts as a prefetch hit and clears the flag.
    from_prefetch: bool,
    /// Ever charged to DRAM (demand or prefetch): splits compulsory
    /// (cold) misses from capacity misses.
    ever_loaded: bool,
    /// Frame epoch of the last touch: first touch per frame pays the
    /// hit/miss accounting, repeats within the frame are free.
    touch_epoch: u64,
    /// Frame epoch in which the slab was last pinned (current frame's
    /// cut slabs + the root slab). Pinned slabs are never LRU victims.
    pin_epoch: u64,
}

/// Out-of-core residency manager for SLTree subtree slabs.
///
/// Invariants, all unconditional (property-tested in
/// `rust/tests/proptests.rs` and unit-tested below):
///
/// * `resident_bytes <= budget_bytes` after (and throughout) every
///   frame — when pinned slabs leave no evictable room, a demand load
///   is a **bypass**: charged and counted, but not retained;
/// * LRU eviction never selects the root slab or a slab pinned by the
///   current frame's cut;
/// * replay never changes search results: the manager only consumes
///   traces the search already produced.
#[derive(Debug, Default)]
pub struct ResidencyManager {
    slabs: Vec<Slab>,
    /// Sum of `bytes` over `Resident` + `Loading` slabs.
    resident_bytes: u64,
    /// Monotone recency counter.
    tick: u64,
    /// Monotone frame counter (epoch stamps for touch/pin dedup).
    epoch: u64,
    /// Previous frame's cut — the prefetcher's delta baseline.
    prev_cut: Vec<u32>,
    /// Slabs issued as prefetches at the end of the last frame
    /// (`Loading`), promoted at the next charge.
    loading: Vec<u32>,
    /// Prediction scratch, reused across frames.
    predicted: Vec<u32>,
    /// Backing-buffer identity of the bound SLTree; rebinding resets.
    slt_id: usize,
}

impl ResidencyManager {
    /// An empty manager; binds to the first SLTree it charges.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently held by `Resident` + `Loading` slabs. The
    /// budget invariant: never exceeds the configured budget.
    #[inline]
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Number of slabs the manager is bound to (0 before first charge).
    #[inline]
    pub fn len(&self) -> usize {
        self.slabs.len()
    }

    /// Whether the manager is unbound (no charge yet).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slabs.is_empty()
    }

    /// Residency state of slab `sid`; `None` if out of range/unbound.
    pub fn slab_state(&self, sid: u32) -> Option<SlabState> {
        self.slabs.get(sid as usize).map(|s| s.state)
    }

    /// Whether slab `sid` currently occupies the resident buffer.
    pub fn is_resident(&self, sid: u32) -> bool {
        matches!(self.slab_state(sid), Some(SlabState::Resident))
    }

    /// Rebind to `slt` if it changed (different buffer identity or slab
    /// count), resetting all residency state.
    fn bind(&mut self, slt: &SlTree) {
        let id = slt.subtrees.as_ptr() as usize;
        if self.slt_id == id && self.slabs.len() == slt.len() {
            return;
        }
        self.slt_id = id;
        self.slabs = slt
            .subtrees
            .iter()
            .map(|s| Slab {
                bytes: s.bytes(),
                state: SlabState::Evicted,
                last_use: 0,
                from_prefetch: false,
                ever_loaded: false,
                touch_epoch: 0,
                pin_epoch: 0,
            })
            .collect();
        self.resident_bytes = 0;
        self.tick = 0;
        self.epoch = 0;
        self.prev_cut.clear();
        self.loading.clear();
    }

    /// Evict unpinned LRU residents until `need` more bytes fit under
    /// `budget`. Returns `false` — evicting *nothing* — when even
    /// evicting every unpinned resident could not make room (the caller
    /// then bypasses: a doomed admission must not churn residents).
    fn make_room(
        &mut self,
        need: u64,
        budget: u64,
        epoch: u64,
        delta: &mut ResidencyStats,
    ) -> bool {
        if self.resident_bytes.saturating_add(need) <= budget {
            return true;
        }
        let evictable: u64 = self
            .slabs
            .iter()
            .filter(|s| s.state == SlabState::Resident && s.pin_epoch != epoch)
            .map(|s| s.bytes)
            .sum();
        if (self.resident_bytes - evictable).saturating_add(need) > budget {
            return false;
        }
        while self.resident_bytes.saturating_add(need) > budget {
            let victim = self
                .slabs
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    s.state == SlabState::Resident && s.pin_epoch != epoch
                })
                .min_by_key(|(i, s)| (s.last_use, *i))
                .map(|(i, _)| i)
                .expect("feasibility checked above");
            let s = &mut self.slabs[victim];
            s.state = SlabState::Evicted;
            s.from_prefetch = false;
            self.resident_bytes -= s.bytes;
            delta.bytes_evicted += s.bytes;
        }
        true
    }

    /// Charge one frame's slab accesses and run the between-frames
    /// prefetch step. Returns this frame's stats delta (`frames == 1`);
    /// the caller accumulates it into
    /// [`RenderStats`](crate::coordinator::RenderStats).
    ///
    /// * `cut` — this frame's selected cut (pins: these slabs plus the
    ///   root slab cannot be evicted this frame);
    /// * `accesses` — the frame's slab-access streams in order (a cold
    ///   frame's `activation_sids`; a warm frame's `touched_sids`
    ///   followed by its refine `activation_sids`). First touch per
    ///   slab per frame pays hit/miss accounting; repeats are free.
    /// * `dram` — cost model for the demand-miss stall
    ///   ([`Traffic::dram_cycles`] at the 1 GHz reference clock).
    ///
    /// Frame order: promote last frame's prefetches -> pin -> replay
    /// (demand faults, LRU admission, bypass) -> stall -> predict +
    /// issue next frame's prefetches.
    pub fn charge_frame(
        &mut self,
        slt: &SlTree,
        cut: &[u32],
        accesses: &[&[u32]],
        cfg: &ResidencyConfig,
        dram: &DramConfig,
    ) -> ResidencyStats {
        if !cfg.enabled {
            return ResidencyStats::default();
        }
        self.bind(slt);
        self.epoch += 1;
        let epoch = self.epoch;
        let mut delta = ResidencyStats { frames: 1, ..Default::default() };

        // 1. Promote: prefetches issued between frames have landed.
        for &sid in &self.loading {
            let s = &mut self.slabs[sid as usize];
            if s.state == SlabState::Loading {
                s.state = SlabState::Resident;
            }
        }
        self.loading.clear();

        // 2. Pin the current frame's cut slabs + the root slab.
        self.slabs[slt.top as usize].pin_epoch = epoch;
        for &n in cut {
            self.slabs[slt.node_sid[n as usize] as usize].pin_epoch = epoch;
        }

        // 3. Replay the access streams.
        let mut demand_bytes = 0u64;
        for stream in accesses {
            for &sid in *stream {
                let i = sid as usize;
                self.tick += 1;
                self.slabs[i].last_use = self.tick;
                if self.slabs[i].touch_epoch == epoch {
                    continue; // repeat touch within the frame: free
                }
                self.slabs[i].touch_epoch = epoch;
                match self.slabs[i].state {
                    SlabState::Resident => {
                        delta.hits += 1;
                        if self.slabs[i].from_prefetch {
                            self.slabs[i].from_prefetch = false;
                            delta.prefetch_hits += 1;
                        }
                    }
                    SlabState::Loading => {
                        // Unreachable after step 1; never punish replay.
                        debug_assert!(false, "Loading slab mid-frame");
                        delta.hits += 1;
                    }
                    SlabState::Evicted => {
                        delta.misses += 1;
                        if !self.slabs[i].ever_loaded {
                            self.slabs[i].ever_loaded = true;
                            delta.cold_misses += 1;
                        }
                        let bytes = self.slabs[i].bytes;
                        demand_bytes += bytes;
                        if self.make_room(bytes, cfg.budget_bytes, epoch, &mut delta)
                        {
                            self.slabs[i].state = SlabState::Resident;
                            self.resident_bytes += bytes;
                        } else {
                            // Bypass: charged + counted, not retained —
                            // keeps resident_bytes <= budget even when
                            // pins fill the whole budget.
                            delta.bypass_loads += 1;
                        }
                    }
                }
            }
        }
        delta.bytes_loaded = demand_bytes;

        // 4. Demand-miss stall under the DRAM cost model (prefetch
        // traffic is charged but never stalls — it ran between frames).
        delta.stall_seconds =
            Traffic::stream(demand_bytes).dram_cycles(dram) as f64 * 1e-9;

        // 5. Predict next frame's slabs from the cut delta and issue
        // prefetches for whatever the budget admits.
        if cfg.prefetch {
            let mut predicted = std::mem::take(&mut self.predicted);
            predict_slabs(slt, &self.prev_cut, cut, &mut predicted);
            for &sid in &predicted {
                let i = sid as usize;
                if self.slabs[i].state != SlabState::Evicted {
                    continue; // already resident or in flight
                }
                let bytes = self.slabs[i].bytes;
                if !self.make_room(bytes, cfg.budget_bytes, epoch, &mut delta) {
                    continue;
                }
                self.tick += 1;
                self.slabs[i].state = SlabState::Loading;
                self.slabs[i].from_prefetch = true;
                self.slabs[i].ever_loaded = true;
                self.slabs[i].last_use = self.tick;
                self.resident_bytes += bytes;
                self.loading.push(sid);
                delta.prefetch_issued += 1;
                delta.bytes_prefetched += bytes;
            }
            self.predicted = predicted;
        }

        self.prev_cut.clear();
        self.prev_cut.extend_from_slice(cut);
        debug_assert!(self.resident_bytes <= cfg.budget_bytes);
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SceneConfig;
    use crate::lod::traversal::traverse_sltree;
    use crate::scene::Scene;

    fn scene() -> Scene {
        SceneConfig::small_scale().quick().build(11)
    }

    fn frame(
        scene: &Scene,
        slt: &SlTree,
        cam_i: usize,
        tau: f32,
    ) -> (Vec<u32>, Vec<u32>) {
        let cam = scene.scenario_camera(cam_i);
        let (cut, trace) = traverse_sltree(&scene.tree, slt, &cam, tau, 4);
        (cut, trace.activation_sids)
    }

    #[test]
    fn disabled_config_charges_nothing() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let (cut, sids) = frame(&scene, &slt, 0, 8.0);
        let mut mgr = ResidencyManager::new();
        let d = mgr.charge_frame(
            &slt,
            &cut,
            &[&sids],
            &ResidencyConfig::default(),
            &DramConfig::default(),
        );
        assert_eq!(d, ResidencyStats::default());
        assert!(mgr.is_empty(), "disabled manager never binds");
    }

    #[test]
    fn unbounded_budget_cold_then_warm() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let (cut, sids) = frame(&scene, &slt, 0, 8.0);
        let cfg = ResidencyConfig::with_budget(u64::MAX);
        let dram = DramConfig::default();
        let mut mgr = ResidencyManager::new();

        let d1 = mgr.charge_frame(&slt, &cut, &[&sids], &cfg, &dram);
        let mut distinct = sids.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert_eq!(d1.misses, distinct.len() as u64, "first touches all miss");
        assert_eq!(d1.cold_misses, d1.misses, "all compulsory");
        assert_eq!(d1.hits, 0);
        let expected_bytes: u64 =
            distinct.iter().map(|&s| slt.subtrees[s as usize].bytes()).sum();
        assert_eq!(d1.bytes_loaded, expected_bytes);
        assert!(d1.stall_seconds > 0.0);
        assert!(mgr.resident_bytes() >= expected_bytes);

        // Same frame again: everything resident, nothing stalls.
        let d2 = mgr.charge_frame(&slt, &cut, &[&sids], &cfg, &dram);
        assert_eq!(d2.misses, 0);
        assert_eq!(d2.hits, distinct.len() as u64);
        assert_eq!(d2.bytes_loaded, 0);
        assert_eq!(d2.stall_seconds, 0.0);
        assert_eq!(d2.bytes_evicted, 0, "unbounded budget never evicts");
    }

    #[test]
    fn budget_invariant_holds_even_when_pins_fill_it() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let dram = DramConfig::default();
        // Budget = two slabs: far below the frame's working set, so
        // pinned cut slabs alone exceed it and bypasses must kick in.
        let budget = 2 * slt.subtrees[slt.top as usize].bytes();
        let cfg = ResidencyConfig::with_budget(budget);
        let mut mgr = ResidencyManager::new();
        let mut total = ResidencyStats::default();
        for cam_i in 0..4 {
            let (cut, sids) = frame(&scene, &slt, cam_i, 8.0);
            let d = mgr.charge_frame(&slt, &cut, &[&sids], &cfg, &dram);
            assert!(
                mgr.resident_bytes() <= budget,
                "cam {cam_i}: {} > {budget}",
                mgr.resident_bytes()
            );
            total.accumulate(&d);
        }
        assert!(total.bypass_loads > 0, "tiny budget must force bypasses");
        assert!(
            total.misses > total.cold_misses,
            "tiny budget must force capacity misses"
        );
    }

    #[test]
    fn pinned_cut_slabs_survive_the_frame() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let dram = DramConfig::default();
        let (cut_a, sids_a) = frame(&scene, &slt, 0, 8.0);
        let (cut_b, sids_b) = frame(&scene, &slt, 5, 8.0);
        // Budget ~ one frame's working set: frame B must evict A's
        // slabs, but never B's own pinned ones.
        let budget = {
            let mut d = sids_a.clone();
            d.sort_unstable();
            d.dedup();
            d.iter().map(|&s| slt.subtrees[s as usize].bytes()).sum::<u64>()
        };
        let cfg = ResidencyConfig::with_budget(budget);
        let mut mgr = ResidencyManager::new();
        mgr.charge_frame(&slt, &cut_a, &[&sids_a], &cfg, &dram);
        // Snapshot which of B's pinned slabs are resident pre-charge.
        let pre_resident: Vec<u32> = cut_b
            .iter()
            .map(|&n| slt.node_sid[n as usize])
            .filter(|&s| mgr.is_resident(s))
            .collect();
        let d = mgr.charge_frame(&slt, &cut_b, &[&sids_b], &cfg, &dram);
        assert!(d.bytes_evicted > 0, "teleport under a tight budget evicts");
        for &s in &pre_resident {
            assert!(
                mgr.is_resident(s),
                "pinned slab {s} was evicted mid-frame"
            );
        }
        assert!(mgr.resident_bytes() <= budget);
    }

    #[test]
    fn prefetch_issues_promotes_and_hits() {
        // Frame 1 at coarse tau predicts the boundary children under
        // its cut; frame 2 refines (finer tau) straight into them.
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let dram = DramConfig::default();
        let cfg = ResidencyConfig::with_budget(u64::MAX);
        let mut mgr = ResidencyManager::new();
        let (cut1, sids1) = frame(&scene, &slt, 2, 32.0);
        let d1 = mgr.charge_frame(&slt, &cut1, &[&sids1], &cfg, &dram);
        assert!(d1.prefetch_issued > 0, "cut delta must issue prefetches");
        assert!(d1.bytes_prefetched > 0);
        let (cut2, sids2) = frame(&scene, &slt, 2, 8.0);
        let d2 = mgr.charge_frame(&slt, &cut2, &[&sids2], &cfg, &dram);
        assert!(d2.prefetch_hits > 0, "refinement must hit prefetched slabs");
        assert!(
            d2.misses < sids2.len() as u64,
            "prefetch must absorb some would-be misses"
        );
    }

    #[test]
    fn prefetch_disabled_never_issues() {
        let scene = scene();
        let slt = SlTree::partition(&scene.tree, 32);
        let dram = DramConfig::default();
        let cfg = ResidencyConfig {
            prefetch: false,
            ..ResidencyConfig::with_budget(u64::MAX)
        };
        let mut mgr = ResidencyManager::new();
        for cam_i in 0..3 {
            let (cut, sids) = frame(&scene, &slt, cam_i, 8.0);
            let d = mgr.charge_frame(&slt, &cut, &[&sids], &cfg, &dram);
            assert_eq!(d.prefetch_issued, 0);
            assert_eq!(d.prefetch_hits, 0);
            assert_eq!(d.bytes_prefetched, 0);
        }
    }

    #[test]
    fn rebinding_to_a_new_sltree_resets_state() {
        let scene = scene();
        let slt_a = SlTree::partition(&scene.tree, 32);
        let slt_b = SlTree::partition(&scene.tree, 16);
        let dram = DramConfig::default();
        let cfg = ResidencyConfig::with_budget(u64::MAX);
        let mut mgr = ResidencyManager::new();
        let (cut, sids) = frame(&scene, &slt_a, 0, 8.0);
        mgr.charge_frame(&slt_a, &cut, &[&sids], &cfg, &dram);
        assert!(mgr.resident_bytes() > 0);
        let cam = scene.scenario_camera(0);
        let (cut_b, trace_b) = traverse_sltree(&scene.tree, &slt_b, &cam, 8.0, 4);
        let d = mgr.charge_frame(
            &slt_b,
            &cut_b,
            &[&trace_b.activation_sids],
            &cfg,
            &dram,
        );
        assert_eq!(mgr.len(), slt_b.len(), "rebound to the new partition");
        assert_eq!(d.hits, 0, "no stale residency after a rebind");
    }

    #[test]
    fn stats_rates_and_accumulate() {
        let mut a = ResidencyStats {
            frames: 1,
            hits: 3,
            misses: 1,
            prefetch_hits: 1,
            prefetch_issued: 2,
            ..Default::default()
        };
        assert!((a.hit_rate() - 0.75).abs() < 1e-12);
        assert!((a.prefetch_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(ResidencyStats::default().hit_rate(), 0.0);
        assert_eq!(ResidencyStats::default().prefetch_hit_rate(), 0.0);
        let b = ResidencyStats {
            frames: 2,
            hits: 1,
            misses: 1,
            cold_misses: 1,
            bytes_loaded: 10,
            bytes_evicted: 5,
            bytes_prefetched: 7,
            bypass_loads: 1,
            stall_seconds: 0.25,
            ..Default::default()
        };
        a.accumulate(&b);
        assert_eq!(a.frames, 3);
        assert_eq!(a.hits, 4);
        assert_eq!(a.misses, 2);
        assert_eq!(a.cold_misses, 1);
        assert_eq!(a.bytes_loaded, 10);
        assert_eq!(a.bytes_evicted, 5);
        assert_eq!(a.bytes_prefetched, 7);
        assert_eq!(a.bypass_loads, 1);
        assert!((a.stall_seconds - 0.25).abs() < 1e-12);
    }
}
