//! Bench: regenerate Fig. 3 (static workload imbalance) and time the
//! naive static partition walk.
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let cfg = sltarch::experiments::eval_scenes(quick).remove(1);
    let p = sltarch::experiments::build_pipeline(&cfg, 42);
    let cam = p.scene().scenario_camera(1);
    let mut b = Bench::new("fig3_imbalance");
    for threads in [64usize, 256] {
        b.iter(&format!("naive_static_workloads({threads})"), 5, || {
            sltarch::lod::naive_static_workloads(&p.scene().tree, &cam, p.rcfg().lod_tau, threads)
        });
    }
    b.report();
    sltarch::experiments::fig3::run(quick);
}
