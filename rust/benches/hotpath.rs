//! Bench: the L3 hot paths in isolation — SLTree partitioning, the
//! streaming traversal, tile binning, depth sort and the blend loop.
//! This is the harness the §Perf optimization pass iterates against.
use sltarch::config::{RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{AlphaMode, CpuRenderer};
use sltarch::gaussian::project;
use sltarch::lod::{traverse_sltree, SlTree};
use sltarch::splat::{bin_splats, sort_tile_by_depth};
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let cfg = if quick {
        SceneConfig::large_scale().quick()
    } else {
        let mut c = SceneConfig::large_scale();
        c.leaves = 300_000; // keep the full bench under a minute
        c
    };
    let scene = cfg.build(42);
    let rcfg = RenderConfig::default();
    let mut b = Bench::new("hotpath");

    b.iter("sltree_partition(tau_s=32)", 3, || {
        SlTree::partition(&scene.tree, 32)
    });
    let slt = SlTree::partition(&scene.tree, 32);
    let cam = scene.scenario_camera(3);
    b.iter("traverse_sltree", 5, || {
        traverse_sltree(&scene.tree, &slt, &cam, rcfg.lod_tau, 4)
    });
    b.iter("canonical_search", 5, || scene.tree.canonical_search(&cam, rcfg.lod_tau));

    let cut = slt.traverse(&scene.tree, &cam, rcfg.lod_tau);
    let queue = scene.gaussians.gather(&cut);
    b.iter("project(cut)", 5, || project(&queue, &cam));
    let splats = project(&queue, &cam);
    b.iter("bin_splats", 5, || bin_splats(&splats, 256, 256));
    let bins = bin_splats(&splats, 256, 256);
    b.iter("sort_all_tiles", 5, || {
        let mut total = 0usize;
        for idx in 0..bins.tile_count() {
            let mut order = bins.per_tile[idx].clone();
            sort_tile_by_depth(&mut order, &splats);
            total += order.len();
        }
        total
    });
    b.iter("cpu_render(group)", 2, || {
        CpuRenderer::render(&queue, &cam, AlphaMode::Group, &rcfg)
    });
    b.iter("cpu_render(pixel)", 2, || {
        CpuRenderer::render(&queue, &cam, AlphaMode::Pixel, &rcfg)
    });
    b.report();
}
