//! Bench: the L3 hot paths in isolation — SLTree partitioning, the
//! streaming traversal, CSR tile binning, the radix depth sort, the
//! blend loop (serial vs the dynamic multi-threaded tile scheduler) and
//! the batched `render_path` API. This is the harness the §Perf
//! optimization pass iterates against; it also dumps
//! `BENCH_hotpath.json` so CI can accumulate the perf trajectory.
use sltarch::assets::{
    load_ply, load_scene, load_splat, write_ply, write_splat,
    AssembleOptions, LoadMode,
};
use sltarch::config::{RenderConfig, SceneConfig};
use sltarch::coordinator::renderer::{default_threads, AlphaMode, CpuRenderer};
use sltarch::coordinator::{
    BatchConfig, BlendKernel, CpuBackend, FramePipeline, RenderOptions,
};
use sltarch::gaussian::{
    project, project_into, project_into_threaded, Gaussians, Splat2D,
};
use sltarch::math::{Camera, Quat, Vec3};
use sltarch::lod::{traverse_sltree, CutCache, CutCacheConfig, SlTree};
use sltarch::residency::ResidencyConfig;
use sltarch::scene::{orbit_cameras, walkthrough};
use sltarch::serve::{
    calibrate_frame_seconds, run_load, LoadGenConfig, QosConfig, ServeConfig,
};
use sltarch::splat::{
    bin_splats, bin_splats_into, bin_splats_into_threaded, project_bin_fused,
    sort_bins_threaded, sort_bins_with, DepthSortScratch, TileBins,
};
use sltarch::util::bench::Bench;
use sltarch::util::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let cfg = if quick {
        SceneConfig::large_scale().quick()
    } else {
        let mut c = SceneConfig::large_scale();
        c.leaves = 300_000; // keep the full bench under a minute
        c
    };
    let extent = cfg.extent;
    let scene = cfg.build(42);
    let rcfg = RenderConfig::default();
    let threads = default_threads();
    let mut b = Bench::new("hotpath");

    b.iter("sltree_partition(tau_s=32)", 3, || {
        SlTree::partition(&scene.tree, 32)
    });
    let slt = SlTree::partition(&scene.tree, 32);
    let cam = scene.scenario_camera(3);
    b.iter("traverse_sltree", 5, || {
        traverse_sltree(&scene.tree, &slt, &cam, rcfg.lod_tau, 4)
    });
    b.iter("canonical_search", 5, || scene.tree.canonical_search(&cam, rcfg.lod_tau));

    // The PR-4 tentpole: full per-frame searches vs the temporal cut
    // cache on a vr_walkthrough-style path. Both rows time the same
    // whole-path loop, so their ratio is the per-frame search speedup
    // the cache buys on coherent camera streams.
    let walk_frames = if quick { 8 } else { 24 };
    let walk = walkthrough(extent, walk_frames, 256, 256);
    b.iter(&format!("search(cold) [{walk_frames} cams]"), 3, || {
        let mut selected = 0u64;
        for wcam in &walk {
            selected +=
                traverse_sltree(&scene.tree, &slt, wcam, rcfg.lod_tau, 4).0.len() as u64;
        }
        selected
    });
    let cache_cfg = CutCacheConfig::default();
    let mut cache = CutCache::new();
    let mut cache_counters = (0u64, 0u64, 0u64);
    b.iter(&format!("search(cached path) [{walk_frames} cams]"), 3, || {
        cache.invalidate(); // every sample replays cold frame 0 + warm rest
        cache_counters = (0, 0, 0);
        let mut selected = 0u64;
        for wcam in &walk {
            let (cut, t) =
                cache.search(&scene.tree, &slt, wcam, rcfg.lod_tau, &cache_cfg);
            selected += cut.len() as u64;
            cache_counters.0 += t.cache_hit;
            cache_counters.1 += t.revalidated;
            cache_counters.2 += t.reseeded;
        }
        selected
    });
    b.record("cut_cache hits/path", cache_counters.0 as f64);
    b.record("cut_cache revalidated/path", cache_counters.1 as f64);
    b.record("cut_cache reseeded/path", cache_counters.2 as f64);

    let cut = slt.traverse(&scene.tree, &cam, rcfg.lod_tau);
    let queue = scene.gaussians.gather(&cut);
    b.iter("project(cut)", 5, || project(&queue, &cam));
    let mut proj_buf = Vec::new();
    b.iter("project_into(reused)", 5, || {
        project_into(&queue, &cam, &mut proj_buf);
        proj_buf.len()
    });
    let splats = project(&queue, &cam);
    b.iter("bin_splats", 5, || bin_splats(&splats, 256, 256));
    let mut bins_buf = TileBins::default();
    b.iter("bin_splats_into(reused)", 5, || {
        bin_splats_into(&splats, 256, 256, &mut bins_buf).expect("bin");
        bins_buf.pairs
    });

    // Zero-clone CSR radix sort: restore the unsorted index order with a
    // flat memcpy, then re-sort every tile slice in place.
    let pristine = bin_splats(&splats, 256, 256);
    let mut bins = pristine.clone();
    let mut sort_scratch = DepthSortScratch::new();
    b.iter("sort_all_tiles", 5, || {
        bins.indices.copy_from_slice(&pristine.indices);
        sort_bins_with(&mut bins, &splats, &mut sort_scratch);
        bins.indices.len()
    });

    // The parallel front end (PR 3): the same three stages at scheduler
    // width 1 vs the machine width. The combined rows are the headline
    // numbers — project + CSR bin + tile sort ms/frame must shrink as
    // the width grows (the Amdahl bottleneck the tentpole attacks).
    let widths: &[usize] = if threads > 1 { &[1, threads] } else { &[1] };
    for &w in widths {
        b.iter(&format!("project_into({w} threads)"), 5, || {
            project_into_threaded(&queue, &cam, &mut proj_buf, w);
            proj_buf.len()
        });
        b.iter(&format!("bin_splats_into({w} threads)"), 5, || {
            bin_splats_into_threaded(&splats, 256, 256, &mut bins_buf, w).expect("bin");
            bins_buf.pairs
        });
        let mut pool: Vec<DepthSortScratch> = Vec::new();
        b.iter(&format!("sort_all_tiles({w} threads)"), 5, || {
            bins.indices.copy_from_slice(&pristine.indices);
            sort_bins_threaded(&mut bins, &splats, &mut pool, w);
            bins.indices.len()
        });
        // The PR-8 tentpole pair: the split three-pass front end (the
        // retained equivalence reference) vs the fused projection +
        // tile-count sweep. Same CSR bytes out of both (proptests +
        // golden harness), so the row delta is the saved splat pass.
        let mut fe_splats: Vec<Splat2D> = Vec::new();
        let mut fe_bins = TileBins::default();
        let mut fe_pool: Vec<DepthSortScratch> = Vec::new();
        let (iw, ih) = (cam.intr.width, cam.intr.height);
        b.iter(&format!("front_end(split, {w} threads)"), 5, || {
            project_into_threaded(&queue, &cam, &mut fe_splats, w);
            bin_splats_into_threaded(&fe_splats, iw, ih, &mut fe_bins, w).expect("bin");
            sort_bins_threaded(&mut fe_bins, &fe_splats, &mut fe_pool, w);
            fe_bins.pairs
        });
        b.iter(&format!("front_end(fused, {w} threads)"), 5, || {
            project_bin_fused(&queue, &cam, &mut fe_splats, &mut fe_bins, w)
                .expect("fused bin");
            sort_bins_threaded(&mut fe_bins, &fe_splats, &mut fe_pool, w);
            fe_bins.pairs
        });
    }

    b.iter("cpu_render(group, serial)", 2, || {
        CpuRenderer::render_threaded(&queue, &cam, AlphaMode::Group, &rcfg, 1)
    });
    // `cpu_render(group)` / `(pixel)` keep their historical names so the
    // perf trajectory stays comparable; they now run the dynamic tile
    // scheduler at `threads` workers.
    b.iter("cpu_render(group)", 2, || {
        CpuRenderer::render_threaded(&queue, &cam, AlphaMode::Group, &rcfg, threads)
    });
    b.iter("cpu_render(pixel)", 2, || {
        CpuRenderer::render_threaded(&queue, &cam, AlphaMode::Pixel, &rcfg, threads)
    });
    b.record("tile_scheduler_threads", threads as f64);

    // Batched many-camera throughput through a render session (the
    // historical `render_path` row name is kept so the perf trajectory
    // stays comparable).
    let path_frames = if quick { 12 } else { 60 };
    let cams = orbit_cameras(extent, 0.9, path_frames, 256, 256);
    let pipeline = FramePipeline::builder(scene)
        .render_config(rcfg)
        .backend(CpuBackend::with_threads(threads))
        .build();
    let mut session = pipeline.session();
    let mut path_fps = 0.0f64;
    b.iter(&format!("render_path({path_frames} cams, group)"), 2, || {
        session.reset_stats();
        let images = session.render_path(&cams).expect("session render");
        path_fps = session.stats().fps();
        images.len()
    });
    b.record("render_path fps", path_fps);
    // Per-stage breakdown of the last batch (the session API's unified
    // stats — search/project/bin/sort now run the parallel front end at
    // the session's scheduler width) — ms/frame rows for the perf
    // trajectory.
    let stats = session.stats();
    for (name, ms) in stats.stages.rows_ms_per_frame(stats.frames) {
        b.record(&format!("stage {name} ms/frame"), ms);
    }
    b.record("front_end_threads", stats.front_end_threads as f64);

    // The PR-10 tentpole rows: multi-view batch rendering. K=2 is a
    // stereo pair (6.5 cm baseline), K=8 fans four such pairs along the
    // orbit. `shared` runs the full sharing stack (identity coalescing,
    // seeded searches, gather skip, interleaved blend); `independent`
    // renders the same batch with all sharing off — the per-view
    // reference. Outputs are byte-identical either way (golden harness
    // + proptests), so every row delta is pure cross-view sharing.
    // "front end" = search + project + bin + sort ms/frame from the
    // per-view stage stats; blending is excluded so the rows isolate
    // exactly the stages the batch can share.
    let stereo = |c: &Camera, d: f32| {
        let mut out = *c;
        let r = c.view.rotation();
        for i in 0..3 {
            out.view.m[i][3] -= r.row(i).dot(Vec3::new(d, 0.0, 0.0));
        }
        out
    };
    let front_end_ms_per_frame = |stats: &sltarch::coordinator::RenderStats| {
        let fe = stats.stages.search
            + stats.stages.project
            + stats.stages.bin
            + stats.stages.sort;
        fe * 1e3 / stats.frames.max(1) as f64
    };
    let pair = vec![cams[0], stereo(&cams[0], 0.065)];
    let eight: Vec<Camera> = (0..4)
        .flat_map(|i| [cams[i * 3], stereo(&cams[i * 3], 0.065)])
        .collect();
    for (label, bcams) in [("K=2", &pair), ("K=8", &eight)] {
        for (mode, bcfg) in [
            ("shared", BatchConfig::default()),
            ("independent", BatchConfig::independent()),
        ] {
            let mut vb = pipeline.batch_with(pipeline.default_options(), bcfg);
            b.iter(&format!("batch({label}, {mode})"), 3, || {
                vb.render(bcams).expect("batch render").len()
            });
            let mut fe = 0.0f64;
            let mut frames = 0usize;
            for v in 0..bcams.len() {
                let st = vb.view_stats(v).expect("view stats");
                fe += front_end_ms_per_frame(st) * st.frames as f64;
                frames += st.frames;
            }
            b.record(
                &format!("batch({label}, {mode}) front end ms/frame"),
                fe / frames.max(1) as f64,
            );
            if mode == "shared" {
                let bs = vb.batch_stats();
                b.record(
                    &format!("batch({label}) searches seeded"),
                    bs.searches_seeded as f64,
                );
                b.record(
                    &format!("batch({label}) gathers skipped"),
                    bs.gathers_skipped as f64,
                );
            }
        }
    }
    // The duplicate-feed case: two clients on the same camera bits (the
    // serving layer's coalescing scenario) — the second view's whole
    // front end is shared, so its front-end ms/frame halves by
    // construction.
    let dup = vec![cams[0], cams[0]];
    let mut vb = pipeline.batch();
    b.iter("batch(K=2, shared, duplicate-feed)", 3, || {
        vb.render(&dup).expect("batch render").len()
    });
    {
        let mut fe = 0.0f64;
        let mut frames = 0usize;
        for v in 0..dup.len() {
            let st = vb.view_stats(v).expect("view stats");
            fe += front_end_ms_per_frame(st) * st.frames as f64;
            frames += st.frames;
        }
        b.record(
            "batch(K=2, shared, duplicate-feed) front end ms/frame",
            fe / frames.max(1) as f64,
        );
        b.record(
            "batch front_ends_shared",
            vb.batch_stats().front_ends_shared as f64,
        );
    }
    // Single-view reference over the same stereo eyes: the 2x / 8x
    // baseline the shared rows are read against.
    let mut sref = pipeline.session();
    for _ in 0..3 {
        for c in &pair {
            sref.render(c).expect("single render");
        }
    }
    b.record(
        "batch single-view front end ms/frame",
        front_end_ms_per_frame(sref.stats()),
    );
    drop(sref);

    // The PR-5 tentpole rows: the blend stage alone, scalar reference
    // kernel vs the divergence-free SoA kernel, at scheduler widths
    // {1, machine}. Both kernels render byte-identical frames (golden
    // harness), so the ms/frame delta is pure inner-loop win.
    let kernel_frames = if quick { 6 } else { 16 };
    let kernel_cams = orbit_cameras(extent, 0.9, kernel_frames, 256, 256);
    for &w in widths {
        for (kname, kernel) in [
            ("scalar", BlendKernel::Scalar),
            ("soa, simd-shaped", BlendKernel::Soa),
        ] {
            let backend = CpuBackend::with_threads(w);
            let mut kernel_session = pipeline.session_on(
                &backend,
                RenderOptions { kernel, ..pipeline.default_options() },
            );
            kernel_session.render_path(&kernel_cams).expect("kernel bench render");
            let st = kernel_session.stats();
            let blend_ms = st.stages.blend * 1e3 / st.frames as f64;
            b.record(
                &format!("blend(kernel={kname}, {w} threads) ms/frame"),
                blend_ms,
            );
        }
    }

    // The PR-7 tentpole rows: out-of-core slab residency over the same
    // orbit path, budgeted at half the scene's slab bytes so the LRU
    // must actually evict. Cold pass = compulsory faulting; warm pass =
    // steady state, where the cut-delta prefetcher turns demand stalls
    // into overlapped loads. Frames are byte-identical to unmanaged
    // renders (golden harness), so these rows are pure memory-system
    // telemetry.
    let slab_total: u64 =
        pipeline.sltree().subtrees.iter().map(|s| s.bytes()).sum();
    let res_budget = (slab_total / 2).max(1);
    b.record("residency scene slab MB", slab_total as f64 / 1e6);
    b.record("residency budget MB", res_budget as f64 / 1e6);
    let mut res_session = pipeline.session_with(RenderOptions {
        residency: ResidencyConfig::with_budget(res_budget),
        ..pipeline.default_options()
    });
    res_session.render_path(&cams).expect("residency cold pass");
    let cold = res_session.reset_stats().residency;
    b.record(
        "residency(cold) miss/frame",
        cold.misses as f64 / cold.frames.max(1) as f64,
    );
    b.record("residency(cold) MB loaded", cold.bytes_loaded as f64 / 1e6);
    res_session.render_path(&cams).expect("residency warm pass");
    let warm = res_session.stats().residency;
    b.record("residency(warm) hit rate", warm.hit_rate());
    b.record("residency(warm) MB loaded", warm.bytes_loaded as f64 / 1e6);
    b.record("residency(warm) MB evicted", warm.bytes_evicted as f64 / 1e6);
    b.record("residency(prefetch) issued", warm.prefetch_issued as f64);
    b.record("residency(prefetch) hits", warm.prefetch_hits as f64);
    b.record("residency(prefetch) accuracy", warm.prefetch_hit_rate());
    b.record(
        "residency stall ms/frame",
        warm.stall_seconds * 1e3 / warm.frames.max(1) as f64,
    );
    drop(res_session);

    // The PR-6 tentpole rows: the deadline-aware serving layer under
    // 2x overload (now 32 open-loop clients, 2 render workers — the
    // PR-7 scale-up; per-client p99 spread rows watch for starvation).
    // Three scenarios over identical offered load:
    //   fixed    — QoS disabled: the tail collapses, p99 >> budget;
    //   adaptive — deadline-adaptive tau: degrades LoD stepwise (warm
    //              cut-cache nudges) until p99 fits the budget;
    //   burst    — sustainable base rate + client-0 bursts: degrade on
    //              each burst, hysteretic recovery in the calm stretches.
    let serve_clients = 32usize;
    let serve_frames = if quick { 4 } else { 8 };
    let serve_paths: Vec<_> = (0..serve_clients)
        .map(|c| orbit_cameras(extent, 0.55 + 0.02 * (c % 8) as f32, 12, 256, 256))
        .collect();
    let base = calibrate_frame_seconds(&pipeline, rcfg.lod_tau, &serve_paths[0][..4]);
    let coarse = calibrate_frame_seconds(&pipeline, 128.0, &serve_paths[0][..4]);
    let budget = base * 1.5;
    b.record("serve calib tau=base ms/frame", base * 1e3);
    b.record("serve calib tau=128 ms/frame", coarse * 1e3);
    b.record("serve budget ms", budget * 1e3);
    // 32 clients / 2 workers: offered load is clients/period, capacity
    // is workers/base, so period = base * 8 is 2x overload.
    let overload = LoadGenConfig {
        clients: serve_clients,
        frames: serve_frames,
        warmup: serve_frames,
        period: base * 8.0,
        ..LoadGenConfig::default()
    };
    let serve_base = ServeConfig {
        queue_capacity: serve_clients * 4,
        max_inflight: 3,
        workers: 2,
        budget,
        ..ServeConfig::default()
    };
    for (label, qos) in [
        ("fixed", QosConfig::disabled()),
        (
            "adaptive",
            QosConfig {
                enabled: true,
                step: 8.0, // == CutCacheConfig::max_tau_step: warm nudges
                max_tau: 128.0,
                miss_threshold: 1,
                recover_headroom: 0.5,
                recover_after: 8,
            },
        ),
    ] {
        let r = run_load(
            &pipeline,
            ServeConfig { qos, ..serve_base },
            &overload,
            &serve_paths,
        );
        let [p50, p95, p99] = r.e2e_percentiles_ms();
        b.record(&format!("serve({label}) p50 ms"), p50);
        b.record(&format!("serve({label}) p95 ms"), p95);
        b.record(&format!("serve({label}) p99 ms"), p99);
        b.record(&format!("serve({label}) served fps"), r.served_fps());
        b.record(&format!("serve({label}) shed"), r.shed_total() as f64);
        b.record(&format!("serve({label}) deadline misses"), r.missed as f64);
        b.record(&format!("serve({label}) degrade events"), r.degrade_events as f64);
        b.record(&format!("serve({label}) recover events"), r.recover_events as f64);
        let tau_max =
            r.clients.iter().map(|c| c.tau).fold(0.0f32, f32::max);
        b.record(&format!("serve({label}) tau final"), tau_max as f64);
        // Per-client p99 spread across the 32 lanes: a fair scheduler
        // keeps the spread small; starvation shows up as a blown max.
        let mut p99_lo = f64::INFINITY;
        let mut p99_hi = 0.0f64;
        for c in r.clients.iter().filter(|c| c.served > 0) {
            let p99 = c.e2e.percentiles_ms()[2];
            p99_lo = p99_lo.min(p99);
            p99_hi = p99_hi.max(p99);
        }
        if p99_lo.is_finite() {
            b.record(&format!("serve({label}) client p99 min ms"), p99_lo);
            b.record(&format!("serve({label}) client p99 max ms"), p99_hi);
            b.record(
                &format!("serve({label}) client p99 spread ms"),
                p99_hi - p99_lo,
            );
        }
    }
    // Burst-recover: base rate the pool can sustain, client 0 dumps
    // periodic bursts; the row pair of interest is degrade AND recover
    // events both being non-zero.
    // Sustainable base rate for 32 clients on 2 workers (offered
    // ~1.3/base vs capacity 2/base), with client-0 bursts on top.
    let burst_load = LoadGenConfig {
        clients: serve_clients,
        frames: if quick { 6 } else { 12 },
        warmup: 4,
        period: base * 24.0,
        burst_every: 3,
        burst_extra: 4,
        ..LoadGenConfig::default()
    };
    let burst_qos = QosConfig {
        enabled: true,
        step: 8.0,
        max_tau: 128.0,
        miss_threshold: 1,
        recover_headroom: 0.6,
        recover_after: 3,
    };
    let r = run_load(
        &pipeline,
        ServeConfig { qos: burst_qos, ..serve_base },
        &burst_load,
        &serve_paths,
    );
    let [_, _, p99] = r.e2e_percentiles_ms();
    b.record("serve(burst) p99 ms", p99);
    b.record("serve(burst) degrade events", r.degrade_events as f64);
    b.record("serve(burst) recover events", r.recover_events as f64);
    b.record("serve(burst) shed", r.shed_total() as f64);
    b.record("serve queue high water", r.queue_high_water as f64);

    // Asset-ingestion rows: streaming-parse throughput for both
    // interchange formats over an in-memory batch (encode once, parse
    // per rep), plus the full ingest -> assemble -> render path on the
    // checked-in zoo fixture. Parse time must stay a loading-screen
    // cost, never a per-frame one.
    let asset_n = if quick { 20_000 } else { 200_000 };
    let mut arng = Rng::new(0x45537);
    let mut asset = Gaussians::with_capacity(asset_n);
    for _ in 0..asset_n {
        asset.push(
            Vec3::new(
                arng.range(-5.0, 5.0),
                arng.range(-2.0, 2.0),
                arng.range(-5.0, 5.0),
            ),
            Vec3::new(
                arng.range(0.05, 0.5),
                arng.range(0.05, 0.5),
                arng.range(0.05, 0.5),
            ),
            Quat::new(
                0.2 + arng.f32(),
                arng.range(-1.0, 1.0),
                arng.range(-1.0, 1.0),
                arng.range(-1.0, 1.0),
            ),
            [arng.f32(), arng.f32(), arng.f32()],
            arng.range(0.05, 0.99),
        );
    }
    let mut splat_bytes = Vec::new();
    write_splat(&mut splat_bytes, &asset).expect("encode .splat");
    let mut ply_bytes = Vec::new();
    write_ply(&mut ply_bytes, &asset).expect("encode ply");
    b.record("load(splat) input MB", splat_bytes.len() as f64 / 1e6);
    b.record("load(ply) input MB", ply_bytes.len() as f64 / 1e6);
    b.iter(&format!("load(splat, {asset_n} splats)"), 3, || {
        load_splat(&splat_bytes[..], LoadMode::Strict)
            .expect("load .splat")
            .report
            .kept
    });
    b.iter(&format!("load(ply, {asset_n} splats)"), 3, || {
        load_ply(&ply_bytes[..], LoadMode::Strict)
            .expect("load ply")
            .report
            .kept
    });
    let zoo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/zoo_room.splat");
    let (fscene, freport) =
        load_scene(&zoo, LoadMode::Strict, &AssembleOptions::default())
            .expect("zoo fixture");
    b.record("load(zoo_room.splat) kept", freport.kept as f64);
    let fcam = fscene.scenario_camera(0);
    let fpipe =
        FramePipeline::builder(fscene).tau(16.0).subtree_size(32).build();
    let mut fsession = fpipe.session();
    b.iter("render(loaded zoo_room.splat)", 5, || {
        fsession.render(&fcam).expect("fixture render").data.len()
    });

    b.report();
    let json = std::path::Path::new("BENCH_hotpath.json");
    match b.write_json(json) {
        Ok(()) => println!("\nwrote {}", json.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json.display()),
    }
}
