//! Bench: regenerate Fig. 10 (normalized energy vs GPU).
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fig10_energy");
    for cfg in sltarch::experiments::eval_scenes(quick) {
        let name = cfg.name.clone();
        b.iter(&format!("fig10_evaluate({name})"), 1, || {
            sltarch::experiments::fig10::evaluate(&cfg, 42)
        });
    }
    b.report();
    sltarch::experiments::fig10::run(quick);
}
