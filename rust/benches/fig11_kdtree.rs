//! Bench: regenerate Fig. 11 (LoD-search accelerator comparison).
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fig11_kdtree");
    for cfg in sltarch::experiments::eval_scenes(quick) {
        let name = cfg.name.clone();
        b.iter(&format!("fig11_evaluate({name})"), 1, || {
            sltarch::experiments::fig11::evaluate(&cfg, 42)
        });
    }
    b.report();
    sltarch::experiments::fig11::run(quick);
}
