//! Bench: regenerate Fig. 9 (speedup over the GPU baseline).
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fig9_speedup");
    for cfg in sltarch::experiments::eval_scenes(quick) {
        let name = cfg.name.clone();
        b.iter(&format!("fig9_evaluate({name})"), 1, || {
            sltarch::experiments::fig9::evaluate(&cfg, 42)
        });
    }
    b.report();
    sltarch::experiments::fig9::run(quick);
}
