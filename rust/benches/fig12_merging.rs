//! Bench: regenerate Fig. 12 (subtree-merging ablation).
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fig12_merging");
    for cfg in sltarch::experiments::eval_scenes(quick) {
        let name = cfg.name.clone();
        b.iter(&format!("fig12_evaluate({name})"), 1, || {
            sltarch::experiments::fig12::evaluate(&cfg, 42)
        });
    }
    b.report();
    sltarch::experiments::fig12::run(quick);
    sltarch::experiments::dram::run(quick);
    sltarch::experiments::area::run(quick);
}
