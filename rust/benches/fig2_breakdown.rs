//! Bench: regenerate Fig. 2 (GPU execution breakdown across LoDs) and
//! time the workload-extraction pipeline behind it.
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("fig2_breakdown");
    let cfg = sltarch::experiments::eval_scenes(quick).remove(1);
    b.iter("fig2_evaluate(large)", 3, || {
        sltarch::experiments::fig2::evaluate(&cfg, 42)
    });
    b.report();
    sltarch::experiments::fig2::run(quick);
}
