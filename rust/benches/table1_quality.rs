//! Bench: regenerate Table I (rendering quality Org vs SLTARCH).
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("table1_quality");
    let cfg = sltarch::experiments::eval_scenes(true).remove(0);
    b.iter("table1_evaluate(small,quick)", 1, || {
        sltarch::experiments::table1::evaluate_scene(&cfg, 42)
    });
    b.report();
    sltarch::experiments::table1::run(quick);
}
