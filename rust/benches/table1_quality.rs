//! Bench: regenerate Table I (rendering quality Org vs SLTARCH), plus
//! the same quality sweep over a *loaded* fixture-zoo asset — real
//! ingested splats must clear the same Org-vs-SLTARCH bar as the
//! procedural eval scenes.
use sltarch::assets::{load_scene, AssembleOptions, LoadMode};
use sltarch::coordinator::FramePipeline;
use sltarch::experiments::table1::evaluate_pipeline;
use sltarch::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SLTARCH_BENCH_QUICK").is_ok();
    let mut b = Bench::new("table1_quality");
    let cfg = sltarch::experiments::eval_scenes(true).remove(0);
    b.iter("table1_evaluate(small,quick)", 1, || {
        sltarch::experiments::table1::evaluate_scene(&cfg, 42)
    });

    // Quality rows on a loaded asset: the .splat zoo fixture through
    // the full ingest -> assemble -> render path.
    let zoo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/zoo_room.splat");
    let (scene, report) =
        load_scene(&zoo, LoadMode::Strict, &AssembleOptions::default())
            .expect("zoo fixture");
    b.record("fixture kept splats", report.kept as f64);
    let pipeline =
        FramePipeline::builder(scene).tau(16.0).subtree_size(32).build();
    let mut row = sltarch::experiments::table1::QualityRow::default();
    b.iter("table1_evaluate(zoo_room.splat)", 1, || {
        row = evaluate_pipeline(&pipeline);
        row.psnr_slt
    });
    b.record("fixture PSNR org dB", row.psnr_org);
    b.record("fixture PSNR slt dB", row.psnr_slt);
    b.record("fixture SSIM org", row.ssim_org);
    b.record("fixture SSIM slt", row.ssim_slt);

    b.report();
    sltarch::experiments::table1::run(quick);
}
